"""Serve a small LM with batched requests through the slot engine.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen3-0.6b", "--smoke", "--requests", "6",
                "--max-new", "12", "--batch", "3"])
