"""Quickstart: the MGPU-style core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's §2 walk-through: create an environment, bind a
communicator to a device group, build segmented containers, move data
with the MPI-like verb *methods* (collectives + point-to-point), call
segmented FFT/BLAS, and launch a custom kernel on every device.  Run
with XLA_FLAGS=--xla_force_host_platform_device_count=8 to see real
multi-device segmentation on CPU.
"""

import numpy as np

import jax.numpy as jnp
from repro.core import Environment, Policy
from repro.lib import blas, fft, plan_stats

# -- environment / dev_group (paper §2.1) ----------------------------------
env = Environment()
comm = env.world                       # all devices, one "data" axis
print(f"environment: {env}; communicator: {comm}")

# -- segmented containers (paper §2.2) --------------------------------------
x = np.random.randn(8, 64, 64).astype(np.complex64)   # 8 matrices
seg = comm.container(x)                                # natural split
print("segments:", seg.segments()[0], "x", seg.nseg)

clone = comm.bcast(x[0])                               # CLONE policy
blocks = comm.container(x, policy=Policy.BLOCK, block=2)
assert np.allclose(comm.gather(blocks), x)

# -- MPI-like communication (paper §2.3, Fig. 3) ----------------------------
summed = comm.reduce(seg)               # one matrix: sum over segments
summed_everywhere = seg.allreduce()     # ... CLONEd on every device
print("reduce == sum:", np.allclose(summed, x.sum(0), atol=1e-4))
full = seg.allgather()                  # MPI_Allgather -> CLONE container
print("allgather:", np.allclose(np.asarray(full.data), x, atol=0))

# -- point-to-point (paper's P2P path; lax.ppermute) ------------------------
ring = seg.shift(1)                     # each segment to the next device
print("shift ring:", comm.gather(ring).shape, "(segments rotated by 1)")
pairs = [(0, 1), (1, 0)] if comm.size > 1 else [(0, 0)]
swapped = comm.send_recv(seg, pairs)    # pairwise exchange
print("send_recv:", swapped.global_shape)

# -- ported libraries (paper §2.4/§4: plan once, call many) ------------------
k = fft.fft2_batched(seg, centered=True)               # builds the FFT plan
img = fft.fft2_batched(k, inverse=True, centered=True)
print("fft roundtrip:", np.allclose(comm.gather(img), x, atol=1e-4))

y = comm.container(np.random.randn(8, 64, 64).astype(np.complex64))
z = blas.axpy(2.0 + 1j, seg, y)                        # a*X + Y
print("dot <x,y> =", complex(blas.dot(seg, y)))
w, d = blas.axpy_dot(0.5, seg, y, y)                   # fused epilogue
print("plan cache:", plan_stats())                     # hits/builds/hit_rate

# -- invoke_kernel (paper §2.5) ----------------------------------------------
def my_kernel(xl, yl):                  # receives local ranges
    return jnp.abs(xl) ** 2 + jnp.abs(yl) ** 2

power = comm.invoke_all(my_kernel, seg, y)
print("invoke_all ->", power.global_shape, power.data.dtype)
print("quickstart OK")
