"""Quickstart: the MGPU-style core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's §2 walk-through: create an environment (device
group), build segmented containers, move data with the MPI-like verbs,
call segmented FFT/BLAS, and launch a custom kernel on every device.
Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to see real
multi-device segmentation on CPU.
"""

import numpy as np

import jax.numpy as jnp
from repro.core import (DeviceGroup, Policy, all_reduce, blas, broadcast,
                        fft, gather, invoke_kernel_all, reduce, segment)

# -- environment / dev_group (paper §2.1) ----------------------------------
group = DeviceGroup.all_devices()
print(f"environment: {group.ndev} device(s), axes {group.axis_names}")

# -- segmented containers (paper §2.2) --------------------------------------
x = np.random.randn(8, 64, 64).astype(np.complex64)   # 8 matrices
seg = segment(x, group)                                # natural split
print("segments:", seg.segments()[0], "x", seg.nseg)

clone = broadcast(x[0], group)                         # CLONE policy
blocks = segment(x, group, policy=Policy.BLOCK, block=2)
assert np.allclose(gather(blocks), x)

# -- MPI-like communication (paper §2.3, Fig. 3) ----------------------------
summed = reduce(seg)                    # one matrix: sum over segments
summed_everywhere = all_reduce(seg)     # ... CLONEd on every device
print("reduce == sum:", np.allclose(summed, x.sum(0), atol=1e-4))

# -- segmented libraries (paper §2.4) ----------------------------------------
k = fft.fft2_batched(seg, centered=True)               # batched FFT
img = fft.fft2_batched(k, inverse=True, centered=True)
print("fft roundtrip:", np.allclose(gather(img), x, atol=1e-4))

y = segment(np.random.randn(8, 64, 64).astype(np.complex64), group)
z = blas.axpy(2.0 + 1j, seg, y)                        # a*X + Y
print("dot <x,y> =", complex(blas.dot(seg, y)))

# -- invoke_kernel (paper §2.5) ----------------------------------------------
def my_kernel(xl, yl):                  # receives local ranges
    return jnp.abs(xl) ** 2 + jnp.abs(yl) ** 2

power = invoke_kernel_all(my_kernel, seg, y, group=group)
print("invoke_kernel_all ->", power.global_shape, power.data.dtype)
print("quickstart OK")
