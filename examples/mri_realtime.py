"""End-to-end driver (the paper's §3 application): real-time MRI movie
reconstruction with NLINV — acquisition simulation, streaming frames
with temporal regularization through the double-buffered frame engine,
gridding-baseline comparison, per-frame latency/jitter report.

    PYTHONPATH=src python examples/mri_realtime.py --frames 5 --n 48
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/mri_realtime.py --devices 4
"""

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Environment
from repro.nlinv import phantom
from repro.nlinv.gridding import gridding_recon
from repro.nlinv.recon import Reconstructor
from repro.nlinv.stream import FrameStream


def nrmse(img, truth, fov):
    m = np.asarray(fov) > 0
    a = np.abs(np.asarray(img))[m]
    b = np.abs(np.asarray(truth))[m]
    a /= max(a.max(), 1e-9)
    b /= max(b.max(), 1e-9)
    return float(np.sqrt(np.mean((a - b) ** 2)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--n", type=int, default=48, help="matrix size")
    ap.add_argument("--coils", type=int, default=8)
    ap.add_argument("--spokes", type=int, default=11)
    ap.add_argument("--newton", type=int, default=7)
    ap.add_argument("--devices", type=int, default=1,
                    help=">1: channel-split distributed reconstruction")
    ap.add_argument("--channel-sum", default="crop", choices=("full", "crop"))
    ap.add_argument("--report", default="",
                    help="write the latency report JSON here")
    args = ap.parse_args()

    print(f"acquiring {args.frames} frames (n={args.n}, J={args.coils}, "
          f"{args.spokes} spokes, golden-angle)")
    data = phantom.make_dataset(n=args.n, ncoils=args.coils,
                                nspokes=args.spokes, frames=args.frames)

    ndev = max(args.devices, 1)
    comm = Environment().subgroup(ndev)
    rec = Reconstructor(comm, newton=args.newton, cg_iters=20,
                        channel_sum=args.channel_sum)
    if ndev > 1:
        print(f"distributed: {ndev} devices, coils NATURAL-segmented, "
              f"{args.channel_sum} all-reduce "
              f"(paper kern_all_red_p2p_2d when cropped)")

    engine = FrameStream(rec, damping=0.9)
    movie, report = engine.run(data["y"], data["masks"], data["fov"],
                               report_path=args.report or None)
    jax.block_until_ready(movie)
    s = report.summary()
    print(f"reconstructed {args.frames} frames: first (compile) "
          f"{s['first_frame_ms']:.0f} ms, steady {s['mean_ms']:.1f} ms/frame "
          f"(p95 {s['p95_ms']:.1f}, jitter {s['jitter_ms']:.2f} ms, "
          f"{s['fps']:.1f} fps)")
    pc = s.get("plan_cache", {})
    print(f"plan cache: frame builds {pc.get('frame_builds')}, "
          f"steady builds {pc.get('steady_builds')}, "
          f"hit rate {pc.get('hit_rate')}")
    if args.report:
        print(f"latency report -> {args.report}")
    else:
        print("latency report:", json.dumps(s))

    errs, gerrs = [], []
    for f in range(args.frames):
        errs.append(nrmse(movie[f], data["rho"][f], data["fov"]))
        gr = gridding_recon(jnp.asarray(data["y"][f]),
                            jnp.asarray(data["masks"][f]),
                            jnp.asarray(data["fov"]))
        gerrs.append(nrmse(gr, data["rho"][f], data["fov"]))
    print(f"NRMSE nlinv  : {np.mean(errs):.4f}  (per-frame {np.round(errs,3)})")
    print(f"NRMSE gridding: {np.mean(gerrs):.4f}")
    print("nlinv beats gridding:", np.mean(errs) < np.mean(gerrs))


if __name__ == "__main__":
    main()
