"""End-to-end driver (the paper's §3 application): real-time MRI movie
reconstruction with NLINV — acquisition simulation, sequential frames
with temporal regularization, gridding-baseline comparison, per-frame
latency report.

    PYTHONPATH=src python examples/mri_realtime.py --frames 5 --n 48
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/mri_realtime.py --devices 4
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DeviceGroup
from repro.nlinv import phantom
from repro.nlinv.gridding import gridding_recon
from repro.nlinv.operators import sobolev_weight, uinit
from repro.nlinv.recon import (make_dist_reconstruct, pad_channels,
                               reconstruct_movie)


def nrmse(img, truth, fov):
    m = np.asarray(fov) > 0
    a = np.abs(np.asarray(img))[m]
    b = np.abs(np.asarray(truth))[m]
    a /= max(a.max(), 1e-9)
    b /= max(b.max(), 1e-9)
    return float(np.sqrt(np.mean((a - b) ** 2)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=5)
    ap.add_argument("--n", type=int, default=48, help="matrix size")
    ap.add_argument("--coils", type=int, default=8)
    ap.add_argument("--spokes", type=int, default=11)
    ap.add_argument("--newton", type=int, default=7)
    ap.add_argument("--devices", type=int, default=0,
                    help=">1: channel-split distributed reconstruction")
    args = ap.parse_args()

    print(f"acquiring {args.frames} frames (n={args.n}, J={args.coils}, "
          f"{args.spokes} spokes, golden-angle)")
    data = phantom.make_dataset(n=args.n, ncoils=args.coils,
                                nspokes=args.spokes, frames=args.frames)

    frame_fn = None
    if args.devices > 1:
        g = DeviceGroup.subset(args.devices)
        frame_fn = make_dist_reconstruct(g, "data", newton=args.newton,
                                         cg_iters=20, channel_sum="crop")
        data = dict(data)
        data["y"] = pad_channels(data["y"].reshape(-1, *data["y"].shape[1:]),
                                 args.devices).reshape(
            args.frames, -1, data["grid"], data["grid"]) \
            if data["y"].shape[1] % args.devices else data["y"]
        print(f"distributed: {args.devices} devices, coils split, "
              f"cropped all-reduce (paper kern_all_red_p2p_2d)")

    t0 = time.perf_counter()
    movie = reconstruct_movie(data, newton=args.newton, cg_iters=20,
                              frame_fn=frame_fn)
    jax.block_until_ready(movie)
    dt = time.perf_counter() - t0
    fps = args.frames / dt
    print(f"reconstructed {args.frames} frames in {dt:.2f}s "
          f"({fps:.2f} fps incl. compile)")

    errs, gerrs = [], []
    for f in range(args.frames):
        errs.append(nrmse(movie[f], data["rho"][f], data["fov"]))
        gr = gridding_recon(jnp.asarray(data["y"][f]),
                            jnp.asarray(data["masks"][f]),
                            jnp.asarray(data["fov"]))
        gerrs.append(nrmse(gr, data["rho"][f], data["fov"]))
    print(f"NRMSE nlinv  : {np.mean(errs):.4f}  (per-frame {np.round(errs,3)})")
    print(f"NRMSE gridding: {np.mean(gerrs):.4f}")
    print("nlinv beats gridding:", np.mean(errs) < np.mean(gerrs))


if __name__ == "__main__":
    main()
