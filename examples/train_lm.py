"""Train a small LM end-to-end on the synthetic Markov pipeline with
checkpointing + restart (wraps the production launcher).

    PYTHONPATH=src python examples/train_lm.py            # ~2 min on CPU
    PYTHONPATH=src python examples/train_lm.py --arch xlstm-350m
"""

import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    train_main(["--arch", args.arch, "--smoke", "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--lr", "1e-2",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "40"])
