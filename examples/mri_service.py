"""The multi-stream reconstruction service (ISSUE 7): four synthetic
scanner clients with staggered arrivals streaming through ONE
``StreamScheduler``, every tick one batched SPMD launch over all ready
clients.  Prints the per-client latency/SLO table and the aggregate
throughput.

    PYTHONPATH=src python examples/mri_service.py --frames 6 --n 32
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/mri_service.py --devices 4
"""

import argparse

from repro.core import Environment
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor
from repro.serve import NlinvStreamWorkload, ServeConfig, StreamScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--frames", type=int, default=6,
                    help="frames per client")
    ap.add_argument("--n", type=int, default=32, help="matrix size")
    ap.add_argument("--coils", type=int, default=8)
    ap.add_argument("--newton", type=int, default=4)
    ap.add_argument("--cg", type=int, default=10)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--budget-ms", type=float, default=0.0,
                    help="per-frame SLO budget (0 = auto: 2x the first "
                         "steady tick)")
    args = ap.parse_args()

    K = args.clients
    print(f"service: {K} clients, {args.frames} frames each "
          f"(n={args.n}, J={args.coils}), {max(args.devices, 1)} device(s)")
    datas = [phantom.make_dataset(n=args.n, ncoils=args.coils, nspokes=11,
                                  frames=args.frames, seed=k)
             for k in range(K)]

    comm = Environment().subgroup(max(args.devices, 1))
    rec = Reconstructor(comm, newton=args.newton, cg_iters=args.cg,
                        channel_sum="crop")
    sched = StreamScheduler(
        NlinvStreamWorkload(rec, damping=0.9),
        ServeConfig(max_concurrency=2 * K,
                    budget_ms=args.budget_ms or None,
                    buckets=(1, 2, 4, 8)))

    # staggered arrivals: client k connects at tick k, so the batch
    # width ramps 1 -> 2 -> ... -> K and the scheduler recompiles only
    # at each new bucket width
    sessions = {}
    next_frame = {}
    tick = 0
    while True:
        if tick < K:
            k = tick
            d = datas[k]
            sessions[k] = sched.open(client=f"scanner{k}", grid=d["grid"],
                                     ncoils=args.coils, fov=d["fov"])
            next_frame[k] = 0
            print(f"tick {tick}: scanner{k} connected")
        for k, sess in sessions.items():
            f = next_frame[k]
            if f < args.frames:
                sched.submit(sess, (datas[k]["y"][f], datas[k]["masks"][f]))
                next_frame[k] = f + 1
        if sched.tick() == 0 and all(f >= args.frames
                                     for f in next_frame.values()):
            break
        tick += 1

    if not args.budget_ms and len(sched.tick_ms) > 1:
        # auto-budget for the SLO column: 2x the best steady tick
        budget = 2.0 * min(sched.tick_ms[1:])
        sched.config = ServeConfig(max_concurrency=2 * K,
                                   budget_ms=budget, buckets=(1, 2, 4, 8))
    rep = sched.report()

    print(f"\n{'client':<10} {'frames':>6} {'p50 ms':>8} {'p95 ms':>8} "
          f"{'jitter':>8} {'SLO met':>8}")
    for name, row in sorted(rep["clients"].items()):
        slo = row.get("slo", {})
        met = f"{100 * slo['met']:.0f}%" if slo else "-"
        print(f"{name:<10} {row['frames']:>6} {row['p50_ms']:>8.1f} "
              f"{row['p95_ms']:>8.1f} {row['jitter_ms']:>8.2f} {met:>8}")
    agg = rep["aggregate"]
    budget = sched.config.budget_ms
    print(f"\naggregate: {agg['frames']} frames in {agg['ticks']} ticks, "
          f"{agg['fps']:.1f} fps"
          + (f" (SLO budget {budget:.1f} ms/frame)" if budget else ""))


if __name__ == "__main__":
    main()
