"""Paper Fig. 4: FFT / aX+Y / A.B over 12 complex square matrices,
1-8 devices.

Measured: us_per_call of the segmented implementations (single shard).
Derived: modeled parallel efficiency at 2/4/8 devices — FFT and aXPY are
embarrassingly batch-parallel (efficiency ~1); A.B with the contracted
dim split pays one inter-device reduction (the paper's finding that A.B
does not strong-scale).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Environment, Policy, blas, fft
from repro.core.runtime import HW

from .common import allreduce_time, fmt_row, time_fn


def rows(quick=False):
    comm = Environment().subgroup(1)
    out = []
    sizes = [128, 256] if quick else [128, 256, 512]
    for n in sizes:
        batch = 12                               # paper: 12 matrices
        x = (np.random.randn(batch, n, n) +
             1j * np.random.randn(batch, n, n)).astype(np.complex64)
        y = x[..., ::-1].copy()
        sx, sy = comm.container(x), comm.container(y)

        f = jax.jit(lambda a: fft.fft2_batched(
            fft.fft2_batched(a), inverse=True).data)
        us = time_fn(f, sx)
        # per-device batch shrinks with G; no communication
        eff = {G: 1.0 for G in (2, 4, 8)}
        out.append(fmt_row(f"fig4_fft_fwdinv_n{n}", us,
                           "eff2=1.00;eff4=1.00;eff8=1.00"))

        a = jax.jit(lambda u, v: blas.axpy(2.0 + 1j, u, v).data)
        us = time_fn(a, sx, sy)
        out.append(fmt_row(f"fig4_axpy_n{n}", us,
                           "eff2=1.00;eff4=1.00;eff8=1.00"))

        A = np.random.randn(n, n).astype(np.float32)
        B = np.random.randn(n, n).astype(np.float32)
        sA = comm.container(A, dim=1)
        sB = comm.container(B, dim=0)
        m = jax.jit(lambda u, v: blas.gemm_ksplit(u, v).data)
        us = time_fn(m, sA, sB)
        # modeled: local matmul scales 1/G, then psum of the full (n,n)
        t1 = 2 * n ** 3 / HW["peak_flops_bf16"]
        effs = []
        for G in (2, 4, 8):
            tG = t1 / G + allreduce_time(n * n * 4, G)
            effs.append(f"eff{G}={t1 / (G * tG):.2f}")
        out.append(fmt_row(f"fig4_gemm_ksplit_n{n}", us, ";".join(effs)))
    return out
