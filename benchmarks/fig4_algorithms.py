"""Paper Fig. 4 (FFT / aX+Y / A.B) — thin CLI over the registered
scenarios in ``repro.bench.suites.fig4``.

  PYTHONPATH=src python -m benchmarks.fig4_algorithms [--size ...] [--devices ...]
"""

from repro.bench.cli import figure_main

main = figure_main("fig4")

if __name__ == "__main__":
    raise SystemExit(main())
