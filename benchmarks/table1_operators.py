"""Paper Table 1 (operator breakdown, op-count asserts, fused-epilogue
rows) — thin CLI over the registered scenarios in
``repro.bench.suites.table1``.

  PYTHONPATH=src python -m benchmarks.table1_operators [--size ...] [--devices ...]
"""

from repro.bench.cli import figure_main

main = figure_main("table1")

if __name__ == "__main__":
    raise SystemExit(main())
