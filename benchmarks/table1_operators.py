"""Paper Table 1: operator breakdown of F, DF, DF^H, CG.

Asserts the structural op counts of our implementation match the paper's
table (FFT batches / pointwise ops / channel sums / scalar products /
all-reduces per operator), then times each operator at a realistic
problem size (grid 256, J=8 — the paper's 8-channel compressed setting).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.nlinv import phantom
from repro.nlinv.operators import make_ops, sobolev_weight, uinit

from .common import fmt_row, time_fn

# paper Table 1 (ours: FFT batches per operator; DG/DGH include the coil
# transform W; the all-reduce column is the distributed channel sum)
EXPECTED = {
    "F": dict(fft=2, channel_sum=0, allreduce=0),
    "DF": dict(fft=3, channel_sum=0, allreduce=0),
    "DFH": dict(fft=3, channel_sum=1, allreduce=1),
    "CG": dict(scalar_products=2),
}


def _count_ffts(fn, *args):
    def rec(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "fft":
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    n += rec(v.jaxpr)
                elif hasattr(v, "eqns"):
                    n += rec(v)
        return n
    return rec(jax.make_jaxpr(fn)(*args).jaxpr)


def rows(quick=False):
    n = 64 if quick else 128
    d = phantom.make_dataset(n=n, ncoils=8, nspokes=11, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    J, g = d["ncoils"], d["grid"]
    u0 = uinit(J, g)
    du = jax.tree.map(lambda x: x + 0.1, u0)
    r = jnp.asarray(d["y"][0])

    assert _count_ffts(ops.G, u0) == 2 + EXPECTED["F"]["fft"] - 2
    assert _count_ffts(lambda a, b: ops.DG(a, b), u0, du) == \
        EXPECTED["DF"]["fft"]
    assert _count_ffts(lambda a, b: ops.DGH(a, b), u0, r) == \
        EXPECTED["DFH"]["fft"]

    out = []
    fG = jax.jit(lambda u: ops.G(u))
    out.append(fmt_row(f"table1_F_g{g}_J{J}", time_fn(fG, u0),
                       "fft=2;pointwise=4"))
    fDG = jax.jit(lambda u, v: ops.DG(u, v))
    out.append(fmt_row(f"table1_DF_g{g}_J{J}", time_fn(fDG, u0, du),
                       "fft=3;pointwise=5"))
    fDGH = jax.jit(lambda u, v: ops.DGH(u, v))
    out.append(fmt_row(f"table1_DFH_g{g}_J{J}", time_fn(fDGH, u0, r),
                       "fft=3;pointwise=4;channel_sum=1;allreduce=1"))
    # CG iteration: normal op + 2 scalar products + 3 axpys
    from repro.nlinv.operators import udot, uaxpy
    def cg_iter(u, v):
        Ap = ops.normal(u, v, 0.5)
        a = jnp.real(udot(v, Ap))
        return uaxpy(1.0 / (a + 1.0), Ap, v)
    out.append(fmt_row(f"table1_CGiter_g{g}_J{J}",
                       time_fn(jax.jit(cg_iter), u0, du),
                       "ab=6;scalar_products=2"))

    # libblas port: the CG residual update as the fused axpy+dot plan
    # (one pass over w) vs the two-plan form — both plan-cache-hit warm.
    from repro.core import Environment
    from repro.lib import blas as lblas, plan_stats
    comm = Environment().subgroup(1)
    sx = comm.container(jnp.asarray(d["y"][0]))
    sy = comm.container(jnp.asarray(d["y"][0]) * 0.5)
    us_fused = time_fn(lambda: lblas.axpy_norm2(-0.25, sx, sy)[1])
    us_split = time_fn(lambda: lblas.norm2(lblas.axpy(-0.25, sx, sy)))
    out.append(fmt_row(f"table1_axpynorm2_fused_g{g}_J{J}", us_fused,
                       f"split={us_split:.1f}us"))
    s = plan_stats()
    out.append(fmt_row("table1_plan_cache", 0.0,
                       f"hits={s['hits']};builds={s['builds']};"
                       f"hit_rate={s['hit_rate']}"))
    return out
