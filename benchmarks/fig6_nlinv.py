"""Paper Fig. 6 (+Fig. 7): NLINV frames/sec vs (#devices, #channels,
matrix size), and energy per frame.

Measured: single-device frames/sec on CPU at reduced grid sizes.
Derived: the calibrated speedup model at 1-4 devices.  Model terms per
CG-dominated frame (paper §3.2): FFT+pointwise scale 1/G; the Sum rho_g
all-reduce grows with G (P2P ring); beyond 4 GPUs the paper's box loses
direct P2P (cross-IOH) — on TPU the analogue is leaving the ICI domain.
Validated against the paper's claims: speedup ~1.7 @ 2 GPUs, ~2.1 @ 4.
"""

import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Environment
from repro.core.runtime import HW
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor, reconstruct_frame
from repro.nlinv.stream import FrameStream
from repro.nlinv.operators import sobolev_weight, uinit

from .common import PAPER_HW, allreduce_time, fmt_row

LATENCY_ARTIFACT = pathlib.Path(__file__).parent / "out" / \
    "nlinv_stream_latency.json"


def speedup_model(grid: int, J: int, newton=7, cg_iters=6, hw="paper",
                  crop=True):
    """Modeled speedup for G devices, calibrated on op counts.

    hw="paper": GTX-580/PCIe constants -> validates the paper's claims.
    hw="v5e":   TPU constants -> our adaptation's scaling.
    Per CG iteration: DF + DF^H = 6 FFT batches over the J local
    channels + ~9 pointwise passes + 1 all-reduce of rho (cropped FOV
    quarter when ``crop``); ~7% non-scaling CG overhead (scalar products
    + host sync, per the paper's CG row of Table 1)."""
    if hw == "paper":
        peak, bw, p2p, lat = (PAPER_HW["peak_flops"], PAPER_HW["mem_bw"],
                              PAPER_HW["p2p_bw"], PAPER_HW["latency"])
    else:
        peak, bw, p2p, lat = (HW["peak_flops_bf16"], HW["hbm_bw"],
                              HW["ici_bw"], 1e-6)
    flop_fft = 2 * 5 * grid * grid * np.log2(grid * grid)   # per channel
    bytes_img = grid * grid * 8                             # complex64
    t_fft = 3 * J * flop_fft / peak
    t_pw = 9 * J * bytes_img / bw
    t_serial = 0.07 * (t_fft + t_pw)
    ar_bytes = bytes_img // 4 if crop else bytes_img
    out = {}
    t1 = t_fft + t_pw + t_serial
    for G in (1, 2, 3, 4, 8):
        t_comp = (t_fft + t_pw) / G
        t_ar = allreduce_time(ar_bytes, G, bw=p2p, latency=lat) \
            if G > 1 else 0.0
        if hw == "paper":
            if G >= 4:
                t_ar *= G / 2.0     # shared PCIe switches: ring contention
                                    # (paper Fig.9: DF^H slows at 4 GPUs)
            if G > 4:
                t_ar *= 3.0         # cross-IOH: host-staged, no P2P
        out[G] = t1 / (t_comp + t_ar + t_serial)
    return out


def rows(quick=False):
    out = []
    sizes = [(32, 4)] if quick else [(32, 4), (48, 8), (64, 8), (64, 12)]
    for n, J in sizes:
        d = phantom.make_dataset(n=n, ncoils=J, nspokes=11, frames=1)
        g = d["grid"]
        w = jnp.asarray(sobolev_weight(g))
        u0 = uinit(J, g)
        args = (jnp.asarray(d["y"][0]), jnp.asarray(d["masks"][0]),
                jnp.asarray(d["fov"]), w, u0, u0)
        # warm + timed
        ufin, img = reconstruct_frame(*args, newton=6, cg_iters=10)
        jax.block_until_ready(img)
        t0 = time.perf_counter()
        for _ in range(3):
            _, img = reconstruct_frame(*args, newton=6, cg_iters=10)
        jax.block_until_ready(img)
        dt = (time.perf_counter() - t0) / 3
        fps = 1.0 / dt
        sp = speedup_model(g, J)                      # paper hardware
        sv = speedup_model(g, J, hw="v5e")
        der = (f"fps1={fps:.2f};paper_s2={sp[2]:.2f};paper_s3={sp[3]:.2f};"
               f"paper_s4={sp[4]:.2f};v5e_s4={sv[4]:.2f}")
        out.append(fmt_row(f"fig6_nlinv_g{g}_J{J}", dt * 1e6, der))
    # streaming real-time engine: steady-state per-frame latency + jitter
    # (frame f+1 upload overlapped with frame f compute, carry donated);
    # the report artifact is the recon-service SLO evidence.
    d = phantom.make_dataset(n=32, ncoils=4, nspokes=11,
                             frames=2 if quick else 5)
    rec = Reconstructor(Environment().subgroup(1), newton=6, cg_iters=10,
                        channel_sum="crop")
    _, rep = FrameStream(rec, damping=0.9).run(
        d["y"], d["masks"], d["fov"], report_path=LATENCY_ARTIFACT)
    s = rep.summary()
    pc = s.get("plan_cache", {})
    out.append(fmt_row(
        f"fig6_stream_g{d['grid']}_J4", s["mean_ms"] * 1e3,
        f"fps={s['fps']:.2f};p95_ms={s['p95_ms']:.2f};"
        f"jitter_ms={s['jitter_ms']:.2f};artifact={LATENCY_ARTIFACT.name}"))
    # plan-cache latency column: frame 0 pays every plan build (geometry
    # setup), the steady-state frames are pure cache hits — the library-
    # port win for the real-time loop (first_frame vs steady mean).
    out.append(fmt_row(
        f"fig6_plan_latency_g{d['grid']}_J4", s["first_frame_ms"] * 1e3,
        f"steady_ms={s['mean_ms']:.2f};builds_f0={pc.get('frame_builds', [0])[0]};"
        f"steady_builds={pc.get('steady_builds', -1)};"
        f"hit_rate={pc.get('hit_rate', 0.0)}"))
    # geometry (gridding plan) setup cost vs a cache hit: what per-frame
    # re-planning would add to the latency budget at this problem size.
    import time as _time
    from repro.lib.gridding import plan_gridding, radial_trajectory
    traj = radial_trajectory(d["grid"], 11)
    t0 = _time.perf_counter()
    plan_gridding(traj, d["grid"])              # cold: builds matrices
    t_cold = (_time.perf_counter() - t0) * 1e6
    t0 = _time.perf_counter()
    plan_gridding(traj, d["grid"])              # warm: LRU hit
    t_hit = (_time.perf_counter() - t0) * 1e6
    out.append(fmt_row("fig6_gridding_plan_us", t_cold,
                       f"cache_hit={t_hit:.1f}us;speedup={t_cold / max(t_hit, 1e-9):.0f}x"))
    # paper-claims validation at the paper's own problem size
    # (grid 768 = 2x384, J=8; claims: ~1.7x @ 2 GPUs, ~2.1x @ 4)
    sp = speedup_model(768, 8)
    out.append(fmt_row(
        "fig6_paper_claims_g768_J8", 0.0,
        f"paper_s2={sp[2]:.2f}(claim~1.7);paper_s4={sp[4]:.2f}(claim~2.1);"
        f"paper_s8={sp[8]:.2f}(cross-IOH)"))
    # fig7: energy/frame model — chips busy/speedup tradeoff
    for G in (1, 2, 4):
        j_per_frame = G * 200.0 / (sp[G])
        out.append(fmt_row(f"fig7_energy_model_G{G}", 0.0,
                           f"rel_J_per_frame={j_per_frame / 200.0:.2f}"))
    return out
