"""Paper Fig. 6/7 (NLINV frame rate, paper-claims validation) plus the
streaming latency and gridding-plan scenarios that share its problem —
thin CLI over ``repro.bench.suites.{fig6,stream,gridding}``.

  PYTHONPATH=src python -m benchmarks.fig6_nlinv [--size ...] [--devices ...]
"""

from repro.bench.cli import figure_main

main = figure_main("fig6,stream,gridding")

if __name__ == "__main__":
    raise SystemExit(main())
