"""Thin forwarder — the benchmark harness lives in ``repro.bench``.

  PYTHONPATH=src python -m benchmarks.run [--size tiny|paper]
      [--devices 1,4] [--only fig4,...] [--out BENCH_paper.json]

(kept for muscle memory; ``python -m repro.bench.run`` is identical,
and ``--quick`` still means ``--size tiny``.)
"""

from repro.bench.run import main

if __name__ == "__main__":
    raise SystemExit(main())
