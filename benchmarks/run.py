"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]

Prints ``name,us_per_call,derived`` CSV rows.  Measured numbers are CPU
wall-clock of the real implementations; ``derived`` columns carry the
calibrated TPU-v5e model terms / dry-run roofline bounds (DESIGN.md §7).
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import (fig4_algorithms, fig5_transfers, fig6_nlinv,
                   fig89_operators, lm_steps, table1_operators)
    modules = {
        "fig4": fig4_algorithms, "fig5": fig5_transfers,
        "table1": table1_operators, "fig6": fig6_nlinv,
        "fig89": fig89_operators, "lm": lm_steps,
    }
    picks = args.only.split(",") if args.only else list(modules)

    print("name,us_per_call,derived")
    failed = []
    for key in picks:
        try:
            for row in modules[key].rows(quick=args.quick):
                print(row)
                sys.stdout.flush()
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
