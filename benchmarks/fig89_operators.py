"""Paper Fig. 8/9: DF and DF^H runtime vs channel count; FFT batch
scaling vs the all-reduce cost that erodes DF^H beyond 2 devices.

Measured: DF / DF^H / batched-FFT wall time at 8..12 channels.
Derived: modeled multi-device times showing the paper's crossover (the
all-reduce share grows with G — execution time of DF^H can *increase*
at G=4, paper Fig. 8 right).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import HW
from repro.nlinv import phantom
from repro.nlinv.operators import make_ops, sobolev_weight, uinit

from .common import allreduce_time, fmt_row, time_fn


def rows(quick=False):
    out = []
    n = 64 if quick else 96
    channels = [8] if quick else [8, 10, 12]
    for J in channels:
        d = phantom.make_dataset(n=n, ncoils=J, nspokes=11, frames=1)
        g = d["grid"]
        ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(g))
        u0 = uinit(J, g)
        du = jax.tree.map(lambda x: x + 0.1, u0)
        r = jnp.asarray(d["y"][0])

        us_df = time_fn(jax.jit(lambda a, b: ops.DG(a, b)), u0, du)
        us_dfh = time_fn(jax.jit(lambda a, b: ops.DGH(a, b)), u0, r)

        flop_fft = 5 * g * g * np.log2(g * g)
        t_fft1 = 3 * J * flop_fft / HW["peak_flops_bf16"]
        img_b = g * g * 8
        der = []
        for G in (1, 2, 4):
            t_dfh = t_fft1 / G + allreduce_time(img_b // 4, G)
            der.append(f"tDFH{G}={t_dfh * 1e6:.1f}us")
        out.append(fmt_row(f"fig8_DF_J{J}_g{g}", us_df, "scales=1/G"))
        out.append(fmt_row(f"fig8_DFH_J{J}_g{g}", us_dfh, ";".join(der)))

    # fig9: FFT batch scaling + all-reduce vs matrix size
    for size in ([128] if quick else [128, 256]):
        batch = 8
        x = (np.random.randn(batch, size, size) + 1j *
             np.random.randn(batch, size, size)).astype(np.complex64)
        from repro.core import Environment
        from repro.lib import fft as lfft
        comm = Environment().subgroup(1)
        sx = comm.container(x)
        plan = lfft.plan_fft2_batched(sx)       # built once per geometry
        us = time_fn(lambda a: plan(a).data, sx)
        ar = {G: allreduce_time(size * size * 8, G) * 1e6 for G in (2, 4)}
        out.append(fmt_row(
            f"fig9_fft_batch{batch}_n{size}", us,
            f"ar2={ar[2]:.1f}us;ar4={ar[4]:.1f}us"))
    return out
