"""Paper Fig. 8/9 (DF / DF^H / FFT batch scaling) — thin CLI over the
registered scenarios in ``repro.bench.suites.fig89``.

  PYTHONPATH=src python -m benchmarks.fig89_operators [--size ...] [--devices ...]
"""

from repro.bench.cli import figure_main

main = figure_main("fig89")

if __name__ == "__main__":
    raise SystemExit(main())
