"""Per-architecture step benchmarks (reduced configs, CPU): one train
step and one decode step for every assigned arch.  The derived column
carries the single-pod roofline bound from the dry-run (if present)."""

import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.core import compat
from repro.models import frontends, transformer
from repro.train import make_train_state, make_train_step

from .common import fmt_row, time_fn

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results/dryrun"


def _derived(arch, shape):
    fn = RESULTS / f"{arch}__{shape}__pod16x16.json"
    if not fn.exists():
        return "dryrun=pending"
    d = json.loads(fn.read_text())
    if "skipped" in d:
        return "skipped"
    r = d["roofline"]
    return (f"bound={r['dominant']};step_bound_ms="
            f"{r['step_time_bound_s'] * 1e3:.1f}")


def rows(quick=False):
    out = []
    archs = ARCH_IDS[:3] if quick else ARCH_IDS
    for arch in archs:
        cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32")
        mesh = compat.make_mesh((1,), ("data",))
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        step_fn, _ = make_train_step(cfg, mesh, remat=False, donate=False)
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                 cfg.vocab)
        enc = frontends.synthetic_frontend(cfg, 2)
        with mesh:
            jstep = jax.jit(step_fn)
            us = time_fn(jstep, state, tok, tok, enc, iters=3)
        out.append(fmt_row(f"lm_train_{arch}", us,
                           _derived(arch, "train_4k")))

        params = state["params"]
        cache = transformer.init_cache(cfg, 2, 64, cfg.cdtype)
        _, cache, _ = transformer.apply(cfg, params, tok[:, :16], enc=enc,
                                        mode="prefill", pos=0, cache=cache)

        @jax.jit
        def dec(p, c, t, pos):
            lg, c2, _ = transformer.apply(cfg, p, t, mode="decode",
                                          pos=pos, cache=c)
            return lg, c2
        us = time_fn(dec, params, cache, tok[:, :1], 16, iters=3)
        out.append(fmt_row(f"lm_decode_{arch}", us,
                           _derived(arch, "decode_32k")))
    return out
