"""Per-architecture LM train/decode steps — thin CLI over the
registered scenarios in ``repro.bench.suites.lm`` (paper-size only;
opt-in, not part of the CI sweep).

  PYTHONPATH=src python -m benchmarks.lm_steps --size paper
"""

from repro.bench.cli import figure_main

main = figure_main("lm")

if __name__ == "__main__":
    raise SystemExit(main())
