"""Shared benchmark utilities: timing + the calibrated multi-device
performance model used for `derived` columns.

Wall-clock on this container measures the CPU backend; multi-device
scaling columns are DERIVED from the roofline/alpha-beta model with the
TPU v5e constants (DESIGN.md §7's three-layer validation: semantics are
tested, counts are asserted, scaling comes from the model).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.runtime import HW


# The paper's 2013 testbed (Tyan FT72-B7015, 8x GTX 580): used to
# validate the paper's OWN speedup claims (1.7x @ 2 GPUs, 2.1x @ 4);
# the TPU-v5e columns show how the adaptation behaves on modern HW.
PAPER_HW = dict(
    peak_flops=0.79e12,      # GTX 580 fp32, ~50% achievable
    mem_bw=150e9,            # GDDR5 effective
    p2p_bw=6e9,              # PCIe 2.0 peer-to-peer (same IOH)
    host_bw=5e9,             # staged through host (cross IOH)
    latency=10e-6,
)


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time (us) of a jit'd callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def allreduce_time(nbytes: int, ndev: int, bw: float | None = None,
                   latency: float = 1e-6) -> float:
    """Ring all-reduce seconds for one device's payload."""
    if ndev <= 1:
        return 0.0
    bw = bw or HW["ici_bw"]
    return 2 * nbytes * (ndev - 1) / ndev / bw + 2 * (ndev - 1) * latency


def copy_time(nbytes: int, bw: float, latency: float = 5e-6) -> float:
    return nbytes / bw + latency


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
