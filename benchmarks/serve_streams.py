"""The multi-stream reconstruction service: scheduler throughput,
per-client p95 SLO, and the batched-vs-sequential A/B — thin CLI over
``repro.bench.suites.serve``.

  PYTHONPATH=src python -m benchmarks.serve_streams [--size ...] [--devices ...]
"""

from repro.bench.cli import figure_main

main = figure_main("serve")

if __name__ == "__main__":
    raise SystemExit(main())
