"""Paper Fig. 5: transfer primitives — strong copy, weak copy,
broadcast, reduce.

Measured: wall time of the verb on this host (1 device).  Derived:
modeled v5e times (host->HBM over PCIe for scatter; ICI ring for
reduce) at 1/2/4/8 devices, showing the paper's effects: strong copy
gets FASTER with more devices (parallel PCIe paths), reduce efficiency
decays with P2P hops.
"""

import numpy as np

from repro.core import Environment
from repro.core.runtime import HW

from .common import allreduce_time, copy_time, fmt_row, time_fn

PCIE_BW = 16e9          # host->device, per path (the paper's 8-GPU box
                        # has multiple independent PCIe pathways)


def rows(quick=False):
    comm = Environment().subgroup(1)
    out = []
    n = 256 if quick else 512
    batch = 8
    x = (np.random.randn(batch, n, n) + 1j *
         np.random.randn(batch, n, n)).astype(np.complex64)
    nbytes = x.nbytes

    us = time_fn(lambda: comm.container(x).data)
    der = ";".join(
        f"t{G}={copy_time(nbytes / G, PCIE_BW) * 1e6:.0f}us"
        for G in (1, 2, 4, 8))
    out.append(fmt_row(f"fig5_strong_copy_{batch}x{n}", us, der))

    us = time_fn(lambda: comm.container(x[:1]).data)   # per-device constant
    der = ";".join(
        f"t{G}={copy_time(nbytes / batch, PCIE_BW) * 1e6:.0f}us"
        for G in (1, 2, 4, 8))
    out.append(fmt_row(f"fig5_weak_copy_1x{n}", us, der))

    us = time_fn(lambda: comm.bcast(x[0]).data)
    one = x[0].nbytes
    der = ";".join(
        f"t{G}={(copy_time(one, PCIE_BW) + (G - 1) * one / HW['ici_bw']) * 1e6:.0f}us"
        for G in (1, 2, 4, 8))
    out.append(fmt_row(f"fig5_broadcast_{n}", us, der))

    sm = comm.container(x)
    us = time_fn(lambda: comm.reduce(sm))
    der = ";".join(
        f"t{G}={(allreduce_time(one, G) / 2 + copy_time(one, PCIE_BW)) * 1e6:.0f}us"
        for G in (1, 2, 4, 8))
    out.append(fmt_row(f"fig5_reduce_{n}", us, der))
    return out
