"""Paper Fig. 5 (transfer primitives) — thin CLI over the registered
scenarios in ``repro.bench.suites.fig5``.

  PYTHONPATH=src python -m benchmarks.fig5_transfers [--size ...] [--devices ...]
"""

from repro.bench.cli import figure_main

main = figure_main("fig5")

if __name__ == "__main__":
    raise SystemExit(main())
