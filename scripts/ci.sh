#!/usr/bin/env bash
# Tier-1 verification, twice: once on the host's single default device,
# and once under 4 simulated host devices so every in-process code path
# also runs with a real multi-device mesh ambient (the subprocess-based
# multi-device tests manage their own device count either way).
#
#   scripts/ci.sh            # full tier-1, both device configurations
#   scripts/ci.sh -k nlinv   # extra pytest args are forwarded
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Fail fast (~1s) on API drift before the multi-minute sweeps; the full
# sweeps below re-collect it, which is harmless.
echo "=== public-API snapshot (repro.core / Communicator surface) ==="
python -m pytest tests/test_api_surface.py -q

echo "=== docs link-check (relative links in README.md + docs/) ==="
python - <<'EOF'
import pathlib, re, sys
bad = []
for md in [pathlib.Path("README.md"), *sorted(pathlib.Path("docs").glob("*.md"))]:
    for m in re.finditer(r"\]\(([^)\s#]+)(#[^)]*)?\)", md.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not re.fullmatch(r"[A-Za-z0-9_./-]+", target) or set(target) <= {"."}:
            continue   # code like `invoke_kernel[_all](...)`, not a link
        if not (md.parent / target).exists():
            bad.append(f"{md}: broken link -> {target}")
if bad:
    print("\n".join(bad))
    sys.exit(1)
print("docs links OK")
EOF

echo "=== doctests (Communicator verbs / SegmentedArray fluent surface) ==="
python -m pytest --doctest-modules src/repro/core -q

echo "=== tier-1: single device ==="
python -m pytest -x -q "$@"

echo "=== tier-1: 4 simulated host devices ==="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -x -q "$@"
