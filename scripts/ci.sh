#!/usr/bin/env bash
# CI entry point, in named tiers:
#
#   scripts/ci.sh              # all  = fast + full (the tier-1 gate)
#   scripts/ci.sh fast         # public-API snapshot + kernel-registry
#                              #   harness (CPU) + docs link-check
#                              #   + doctests (fails on drift)
#                              #   + chaos suite (fault injection under
#                              #   the pinned REPRO_FAULT_SEED)
#   scripts/ci.sh full         # tier-1 pytest, twice: on the host's single
#                              #   default device AND under 4 simulated host
#                              #   devices (real multi-device mesh ambient;
#                              #   subprocess-based tests manage their own
#                              #   device counts either way)
#   scripts/ci.sh bench        # benchmark sweep at 1 + 2 + 4 simulated
#                              #   devices -> BENCH_paper.json: tiny size
#                              #   for every figure plus paper-size fig5
#                              #   transfer columns; repro.bench.compare
#                              #   then gates steady-state regressions vs
#                              #   the committed baseline, flags
#                              #   non-monotone speedup_vs_1dev curves,
#                              #   and emits a markdown table into the
#                              #   GitHub Actions job summary when
#                              #   available
#   scripts/ci.sh full -k nlinv   # extra args are forwarded to pytest
#   scripts/ci.sh -k nlinv        # (old form: tier defaults to all)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier=all
case "${1:-}" in
    fast|full|bench|all) tier="$1"; shift ;;
esac

run_fast() {
    # Fail fast (~1s) on API drift before the multi-minute sweeps; the
    # full sweeps below re-collect it, which is harmless.
    echo "=== public-API snapshot (repro.core / repro.bench surface) ==="
    python -m pytest tests/test_api_surface.py -q

    echo "=== kernel-registry harness (every spec: parity/fallback/props, CPU) ==="
    # deterministic blocks: CI pins every spec to its declared default
    REPRO_KERNEL_BLOCKS=default \
        python -m pytest tests/test_kernel_registry.py -q

    echo "=== docs link-and-anchor check (README.md + docs/) ==="
    python - <<'EOF'
import pathlib, re, sys

def slugs(path):
    """GitHub heading anchors of a markdown file (slugified, deduped)."""
    out, seen = set(), {}
    text = re.sub(r"```.*?```", "", path.read_text(), flags=re.S)
    for line in text.splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if not m:
            continue
        s = re.sub(r"[^\w\- ]", "", m.group(1).strip().lower())
        s = s.replace(" ", "-")
        n = seen.get(s, 0)
        seen[s] = n + 1
        out.add(s if n == 0 else f"{s}-{n}")
    return out

bad = []
for md in [pathlib.Path("README.md"), *sorted(pathlib.Path("docs").glob("*.md"))]:
    for m in re.finditer(r"\]\(([^)\s#]*)(#[^)\s]*)?\)", md.read_text()):
        target, anchor = m.group(1), m.group(2)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target and (not re.fullmatch(r"[A-Za-z0-9_./-]+", target)
                       or set(target) <= {"."}):
            continue   # code like `invoke_kernel[_all](...)`, not a link
        dest = (md.parent / target) if target else md
        if target and not dest.exists():
            bad.append(f"{md}: broken link -> {target}")
        elif anchor and dest.suffix == ".md" and \
                anchor[1:].lower() not in slugs(dest):
            bad.append(f"{md}: broken anchor -> {target}{anchor}")
if bad:
    print("\n".join(bad))
    sys.exit(1)
print("docs links+anchors OK")
EOF

    echo "=== doctests (core verbs + lib plans + serve scheduler + task graphs + ft) ==="
    python -m pytest --doctest-modules \
        src/repro/core src/repro/lib src/repro/serve src/repro/task \
        src/repro/ft -q

    echo "=== doctests (docs/task_graph.md + docs/fault_tolerance.md guides) ==="
    python -m pytest --doctest-glob='*.md' docs/task_graph.md \
        docs/fault_tolerance.md -q

    echo "=== chaos suite (fault injection, pinned seed) ==="
    # the injection schedule is a pure function of the seed, so the
    # chaos runs are as deterministic as the rest of the suite
    REPRO_FAULT_SEED=1234 \
        python -m pytest tests/test_fault_injection.py -q
}

run_full() {
    echo "=== tier-1: single device ==="
    python -m pytest -x -q "$@"

    echo "=== tier-1: 4 simulated host devices ==="
    XLA_FLAGS="--xla_force_host_platform_device_count=4" \
        python -m pytest -x -q "$@"
}

run_bench() {
    echo "=== benchmark sweep (tiny all-figures + paper fig5, 1 + 2 + 4 devices) ==="
    # paper-size fig5 rides along so the transfer schedules are gated at
    # a payload size where the schedule choice (scatter+allgather bcast,
    # rs+ag reduce) actually matters, not only at tiny-CI sizes.
    sweep="--sweep tiny:fig4,fig5,fig6,fig89,gridding,serve,stream,table1 --sweep paper:fig5"
    base=""
    if [ -f BENCH_paper.json ]; then
        base="$(mktemp)"
        trap 'rm -f "$base"' EXIT     # cleaned up even when the gate fails
        cp BENCH_paper.json "$base"
    fi
    python -m repro.bench.run $sweep --devices 1,2,4 --out BENCH_paper.json
    if [ -n "$base" ]; then
        echo "=== regression gate vs committed baseline ==="
        # Threshold 75% + 1ms floor + calibration normalization + one
        # re-measure: a real 2x slowdown fails both attempts.  On
        # shared/cgroup hosts, invisible neighbor episodes still inflate
        # individual rows 2-5x for minutes at a time, so a persistent
        # failure is ADVISORY by default (loud report, exit 0) and hard
        # only under BENCH_STRICT=1 (dedicated perf hosts).  The
        # compare tool itself always exits non-zero on regression —
        # strictness is a property of this CI tier, not of the tool.
        gate() {
            python -m repro.bench.compare "$base" BENCH_paper.json \
                --threshold 75 --min-ms 1.0 "$@"
        }
        # Per-scenario deltas land in the Actions job summary when
        # GITHUB_STEP_SUMMARY is set — emitted exactly ONCE, from the
        # final comparison (a failed first attempt must not leave a
        # stale regression table above the one that decided the run).
        summarize() {
            if [ -n "${GITHUB_STEP_SUMMARY:-}" ]; then
                gate --summary "$GITHUB_STEP_SUMMARY" >/dev/null || true
            fi
        }
        if ! gate; then
            echo "=== gate failed; re-measuring once to rule out load ==="
            python -m repro.bench.run $sweep --devices 1,2,4 \
                --out BENCH_paper.json
            if ! gate; then
                if [ "${BENCH_STRICT:-0}" = "1" ]; then
                    summarize
                    echo "bench gate FAILED twice (BENCH_STRICT=1)" >&2
                    exit 1
                fi
                echo "WARNING: bench gate failed twice; advisory on" \
                     "shared hosts (set BENCH_STRICT=1 to hard-fail)" >&2
            fi
        fi
        summarize
        rm -f "$base"
    else
        echo "no committed BENCH_paper.json baseline; skipping compare"
    fi
}

case "$tier" in
    fast)  run_fast ;;
    full)  run_full "$@" ;;
    bench) run_bench ;;
    all)   run_fast; run_full "$@" ;;
esac
