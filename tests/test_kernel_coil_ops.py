"""coil_mult + masked_allreduce kernels vs oracles (shape/dtype sweeps),
and their consistency with the NLINV operators they implement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coil_mult import (coil_adjoint, coil_adjoint_ref,
                                     coil_forward, coil_forward_ref,
                                     coil_lincomb, coil_lincomb_ref,
                                     plane_mult, plane_mult_ref)
from repro.kernels.masked_allreduce import masked_sum, masked_sum_ref


def _cplx(key, shape):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, shape) +
            1j * jax.random.normal(k2, shape)).astype(jnp.complex64)


@pytest.mark.parametrize("J,X,Y", [(2, 32, 32), (5, 64, 128), (8, 128, 64)])
def test_coil_forward_pallas(J, X, Y):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    coils, x = _cplx(ks[0], (J, X, Y)), _cplx(ks[1], (X, Y))
    got = coil_forward(coils, x, impl="pallas")
    want = coil_forward_ref(coils, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("J,X,Y,masked", [(3, 32, 32, True), (6, 64, 64, False),
                                          (8, 128, 32, True)])
def test_coil_adjoint_pallas(J, X, Y, masked):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    coils, z = _cplx(ks[0], (J, X, Y)), _cplx(ks[1], (J, X, Y))
    mask = (jax.random.uniform(ks[2], (X, Y)) > 0.5).astype(jnp.float32) \
        if masked else None
    got = coil_adjoint(coils, z, mask, impl="pallas")
    want = coil_adjoint_ref(coils, z, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("G,X,Y", [(2, 32, 32), (4, 64, 64), (8, 32, 128)])
def test_masked_sum_pallas(G, X, Y):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    partials = _cplx(ks[0], (G, X, Y))
    mask = (jax.random.uniform(ks[1], (X, Y)) > 0.3).astype(jnp.float32)
    got = masked_sum(partials, mask, impl="pallas")
    want = masked_sum_ref(partials, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("J,X,Y,two_term", [(2, 32, 32, True),
                                            (4, 64, 64, True),
                                            (3, 32, 128, False)])
def test_coil_lincomb_pallas(J, X, Y, two_term):
    """out_j = s*(a*x_j + b*y_j) — the generalized G/DG pointwise chain."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    a, x = _cplx(ks[0], (X, Y)), _cplx(ks[1], (J, X, Y))
    b = _cplx(ks[2], (X, Y)) if two_term else None
    y = _cplx(ks[3], (J, X, Y)) if two_term else None
    s = jax.random.uniform(ks[4], (X, Y)).astype(jnp.float32)
    got = coil_lincomb(a, x, b, y, s, impl="pallas")
    want = coil_lincomb_ref(a, x, b, y, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("J,X,Y", [(2, 32, 32), (6, 64, 64)])
def test_plane_mult_pallas(J, X, Y):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    z = _cplx(ks[0], (J, X, Y))
    m = (jax.random.uniform(ks[1], (X, Y)) > 0.4).astype(jnp.float32)
    got = plane_mult(z, m, impl="pallas")
    want = plane_mult_ref(z, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_lincomb_implements_dg_pointwise_chain():
    """The fused DG image chain fov*(drho*c0 + rho0*dc) == the unfused
    expression in NlinvOps.DG."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import make_ops, sobolev_weight, uinit
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    g = d["grid"]
    u0 = uinit(4, g)
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    du = {"rho": _cplx(ks[0], (g, g)), "chat": _cplx(ks[1], (4, g, g))}
    want = ops.DG(u0, du)
    got = ops.DG_fused(ops.precompute(u0), du)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_kernels_implement_dgh_channel_sum():
    """The fused adjoint kernel computes exactly the Sum_j conj(c_j) z_j
    + M_Omega step inside NlinvOps.DGH."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import make_ops, sobolev_weight, uinit
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    u0 = uinit(4, d["grid"])
    r = _cplx(jax.random.PRNGKey(3), (4, d["grid"], d["grid"]))
    want = ops.DGH(u0, r)["rho"]
    c0 = ops.coils(u0["chat"])
    from repro.nlinv.operators import ifft2c
    z = ops.fov[None] * ifft2c(ops.mask[None] * r)
    got = coil_adjoint(c0, z, mask=None, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
