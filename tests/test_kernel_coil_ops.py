"""coil_mult + masked_allreduce kernels vs oracles (shape/dtype sweeps),
and their consistency with the NLINV operators they implement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coil_mult import (coil_adjoint, coil_adjoint_ref,
                                     coil_forward, coil_forward_ref)
from repro.kernels.masked_allreduce import masked_sum, masked_sum_ref


def _cplx(key, shape):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, shape) +
            1j * jax.random.normal(k2, shape)).astype(jnp.complex64)


@pytest.mark.parametrize("J,X,Y", [(2, 32, 32), (5, 64, 128), (8, 128, 64)])
def test_coil_forward_pallas(J, X, Y):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    coils, x = _cplx(ks[0], (J, X, Y)), _cplx(ks[1], (X, Y))
    got = coil_forward(coils, x, impl="pallas")
    want = coil_forward_ref(coils, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("J,X,Y,masked", [(3, 32, 32, True), (6, 64, 64, False),
                                          (8, 128, 32, True)])
def test_coil_adjoint_pallas(J, X, Y, masked):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    coils, z = _cplx(ks[0], (J, X, Y)), _cplx(ks[1], (J, X, Y))
    mask = (jax.random.uniform(ks[2], (X, Y)) > 0.5).astype(jnp.float32) \
        if masked else None
    got = coil_adjoint(coils, z, mask, impl="pallas")
    want = coil_adjoint_ref(coils, z, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("G,X,Y", [(2, 32, 32), (4, 64, 64), (8, 32, 128)])
def test_masked_sum_pallas(G, X, Y):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    partials = _cplx(ks[0], (G, X, Y))
    mask = (jax.random.uniform(ks[1], (X, Y)) > 0.3).astype(jnp.float32)
    got = masked_sum(partials, mask, impl="pallas")
    want = masked_sum_ref(partials, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_kernels_implement_dgh_channel_sum():
    """The fused adjoint kernel computes exactly the Sum_j conj(c_j) z_j
    + M_Omega step inside NlinvOps.DGH."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import make_ops, sobolev_weight, uinit
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    u0 = uinit(4, d["grid"])
    r = _cplx(jax.random.PRNGKey(3), (4, d["grid"], d["grid"]))
    want = ops.DGH(u0, r)["rho"]
    c0 = ops.coils(u0["chat"])
    from repro.nlinv.operators import ifft2c
    z = ops.fov[None] * ifft2c(ops.mask[None] * r)
    got = coil_adjoint(c0, z, mask=None, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
