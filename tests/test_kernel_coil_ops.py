"""coil_mult + masked_allreduce kernels' consistency with the NLINV
operators they implement.  (Kernel-vs-oracle parity sweeps moved to the
shared registry harness, ``tests/test_kernel_registry.py``.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.coil_mult import coil_adjoint


def _cplx(key, shape):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, shape) +
            1j * jax.random.normal(k2, shape)).astype(jnp.complex64)


def test_lincomb_implements_dg_pointwise_chain():
    """The fused DG image chain fov*(drho*c0 + rho0*dc) == the unfused
    expression in NlinvOps.DG."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import make_ops, sobolev_weight, uinit
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    g = d["grid"]
    u0 = uinit(4, g)
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    du = {"rho": _cplx(ks[0], (g, g)), "chat": _cplx(ks[1], (4, g, g))}
    want = ops.DG(u0, du)
    got = ops.DG_fused(ops.precompute(u0), du)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_kernels_implement_dgh_channel_sum():
    """The fused adjoint kernel computes exactly the Sum_j conj(c_j) z_j
    + M_Omega step inside NlinvOps.DGH."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import make_ops, sobolev_weight, uinit
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    u0 = uinit(4, d["grid"])
    r = _cplx(jax.random.PRNGKey(3), (4, d["grid"], d["grid"]))
    want = ops.DGH(u0, r)["rho"]
    c0 = ops.coils(u0["chat"])
    from repro.nlinv.operators import ifft2c
    z = ops.fov[None] * ifft2c(ops.mask[None] * r)
    got = coil_adjoint(c0, z, mask=None, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
