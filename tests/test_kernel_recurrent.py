"""rg_lru and mlstm decode steps vs their scan oracles.

Kernel-vs-oracle parity sweeps (pallas, associative, chunkwise) live in
the shared registry harness (``tests/test_kernel_registry.py``, ISSUE
8); this file keeps the single-step decode recurrences the harness
can't express — they are separate entry points, not impls of the scan.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mlstm import init_state, mlstm_ref, mlstm_step
from repro.kernels.rg_lru import rg_lru_ref, rg_lru_step


def _lru_inputs(B, S, W, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    # log_a in [-2, 0): contraction
    log_a = -jnp.abs(jax.random.normal(ks[0], (B, S, W))) - 1e-3
    b = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    return log_a.astype(dtype), b.astype(dtype), h0.astype(dtype)


def test_rg_lru_step_consistency():
    log_a, b, h0 = _lru_inputs(2, 8, 32, jnp.float32, seed=2)
    want_h, _ = rg_lru_ref(log_a, b, h0)
    h = h0
    for t in range(8):
        h = rg_lru_step(log_a[:, t], b[:, t], h)
        np.testing.assert_allclose(np.asarray(h), np.asarray(want_h[:, t]),
                                   atol=1e-4, rtol=1e-4)


def _mlstm_inputs(B, H, S, dk, dv, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, H, S, dk)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, S, dk)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, S, dv)).astype(dtype)
    # realistic gate ranges: log_f = log sigmoid(x), log_i = x
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) + 2.0)
    log_i = jax.random.normal(ks[4], (B, H, S)) - 1.0
    return q, k, v, log_i.astype(jnp.float32), log_f.astype(jnp.float32)


def test_mlstm_step_matches_ref():
    B, H, S, dk, dv = 2, 2, 16, 32, 32
    q, k, v, li, lf = _mlstm_inputs(B, H, S, dk, dv, jnp.float32, seed=4)
    want_h, _ = mlstm_ref(q, k, v, li, lf)
    st = init_state(B, H, dk, dv)
    for t in range(S):
        h, st = mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                           li[:, :, t], lf[:, :, t], st)
        np.testing.assert_allclose(np.asarray(h),
                                   np.asarray(want_h[:, :, t]),
                                   atol=2e-3, rtol=2e-3)
