"""rg_lru and mlstm kernels vs their sequential-scan oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm import mlstm_chunkwise, mlstm_pallas, mlstm_ref, mlstm_step
from repro.kernels.rg_lru import rg_lru_pallas, rg_lru_ref, rg_lru_scan, rg_lru_step


def _lru_inputs(B, S, W, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    # log_a in [-2, 0): contraction
    log_a = -jnp.abs(jax.random.normal(ks[0], (B, S, W))) - 1e-3
    b = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    return log_a.astype(dtype), b.astype(dtype), h0.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,W", [(1, 64, 128), (2, 256, 128), (4, 128, 256)])
def test_rg_lru_pallas_vs_ref(B, S, W, dtype):
    log_a, b, h0 = _lru_inputs(B, S, W, dtype)
    got_h, got_l = rg_lru_pallas(log_a, b, h0, bb=1, bw=128, bs=64,
                                 interpret=True)
    want_h, want_l = rg_lru_ref(log_a, b, h0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got_h, np.float32),
                               np.asarray(want_h, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(got_l, np.float32),
                               np.asarray(want_l, np.float32), atol=tol,
                               rtol=tol)


def test_rg_lru_associative_vs_ref():
    log_a, b, h0 = _lru_inputs(2, 100, 64, jnp.float32, seed=1)  # ragged S
    got_h, got_l = rg_lru_scan(log_a, b, h0, impl="associative")
    want_h, want_l = rg_lru_ref(log_a, b, h0)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               atol=1e-4, rtol=1e-4)


def test_rg_lru_step_consistency():
    log_a, b, h0 = _lru_inputs(2, 8, 32, jnp.float32, seed=2)
    want_h, _ = rg_lru_ref(log_a, b, h0)
    h = h0
    for t in range(8):
        h = rg_lru_step(log_a[:, t], b[:, t], h)
        np.testing.assert_allclose(np.asarray(h), np.asarray(want_h[:, t]),
                                   atol=1e-4, rtol=1e-4)


def _mlstm_inputs(B, H, S, dk, dv, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, H, S, dk)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, S, dk)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, S, dv)).astype(dtype)
    # realistic gate ranges: log_f = log sigmoid(x), log_i = x
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, H, S)) + 2.0)
    log_i = jax.random.normal(ks[4], (B, H, S)) - 1.0
    return q, k, v, log_i.astype(jnp.float32), log_f.astype(jnp.float32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 128, 64, 64, 32), (2, 1, 96, 32, 64, 32), (1, 4, 256, 128, 128, 128),
])
def test_mlstm_chunkwise_vs_ref(B, H, S, dk, dv, chunk, dtype):
    q, k, v, li, lf = _mlstm_inputs(B, H, S, dk, dv, dtype)
    got_h, (gC, gn, gm) = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    want_h, (wC, wn, wm) = mlstm_ref(q, k, v, li, lf)
    tol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got_h, np.float32),
                               np.asarray(want_h, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(wm), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gC), np.asarray(wC), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 128, 64, 64, 64), (2, 2, 128, 128, 128, 32),
])
def test_mlstm_pallas_vs_ref(B, H, S, dk, dv, chunk):
    q, k, v, li, lf = _mlstm_inputs(B, H, S, dk, dv, jnp.float32, seed=3)
    got_h, (gC, gn, gm) = mlstm_pallas(q, k, v, li, lf, chunk=chunk,
                                       interpret=True)
    want_h, (wC, wn, wm) = mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gC), np.asarray(wC), atol=2e-3,
                               rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gm), np.asarray(wm), atol=1e-4)


def test_mlstm_step_matches_ref():
    B, H, S, dk, dv = 2, 2, 16, 32, 32
    q, k, v, li, lf = _mlstm_inputs(B, H, S, dk, dv, jnp.float32, seed=4)
    want_h, _ = mlstm_ref(q, k, v, li, lf)
    from repro.kernels.mlstm import init_state
    st = init_state(B, H, dk, dv)
    for t in range(S):
        h, st = mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                           li[:, :, t], lf[:, :, t], st)
        np.testing.assert_allclose(np.asarray(h),
                                   np.asarray(want_h[:, :, t]),
                                   atol=2e-3, rtol=2e-3)
