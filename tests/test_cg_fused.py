"""ISSUE-5 fused CG hot path.

Kernel-vs-oracle parity sweeps live in the shared registry harness
(``tests/test_kernel_registry.py``, ISSUE 8); this file keeps what the
harness can't express generically:

  * dot-epilogue consistency + <p, Ap> self-adjointness identity
    (normal_pap == the unfused scalar product against normal());
  * fused-vs-unfused CG convergence identity on 1 device (in-process)
    and 4 devices (subprocess, both channel-sum modes);
  * overlapped/chunked ring all-reduce bitwise parity with the plain
    ring, and the fused allreduce_overlap extras/compute contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_with_devices
from repro.kernels.cg_fused import cg_update, xpby_dot


def _cplx(key, shape):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, shape) +
            1j * jax.random.normal(k2, shape)).astype(jnp.complex64)


def test_dot_epilogue_matches_separate_dot():
    """The fused epilogue IS the scalar product: identical (to float
    tolerance) to computing the update then a separate vdot."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    p, ap, x, r = (_cplx(k, (4, 32, 32)) for k in ks)
    for impl in ("jnp", "pallas"):
        _, r2, rs = cg_update(0.25, p, ap, x, r, impl=impl)
        want = float(jnp.real(jnp.vdot(r2, r2)))
        np.testing.assert_allclose(float(rs), want, rtol=1e-4)
        w, d = xpby_dot(r, p, 0.5, impl=impl)
        np.testing.assert_allclose(float(d),
                                   float(jnp.real(jnp.vdot(w, w))),
                                   rtol=1e-4)


# ---------------------------------------------------------------------------
# <p, Ap> self-adjointness (the fused curvature scalar)
# ---------------------------------------------------------------------------

def test_normal_pap_matches_unfused_scalar_product():
    """normal_pap's piggybacked <p, Ap> = ||DG p||^2 + alpha ||p||^2 must
    equal the unfused udot(p, normal(p)) — the self-adjointness identity
    the single-collective CG iteration rests on."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import make_ops, sobolev_weight, udot, uinit
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    u0 = uinit(4, d["grid"])
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    p = {"rho": _cplx(ks[0], (d["grid"], d["grid"])),
         "chat": _cplx(ks[1], (4, d["grid"], d["grid"]))}
    alpha = 0.5
    pre = ops.precompute(u0)
    ap_f, pap = ops.normal_pap(
        pre, p, alpha,
        reducer=lambda prod, extras, compute: (prod, extras, compute()))
    ap_u = ops.normal(u0, p, alpha)
    want = float(jnp.real(udot(p, ap_u)))
    np.testing.assert_allclose(float(pap), want, rtol=2e-3)
    for k in ("rho", "chat"):
        np.testing.assert_allclose(np.asarray(ap_f[k]), np.asarray(ap_u[k]),
                                   atol=1e-3, rtol=1e-3)


def test_fused_cg_matches_unfused_single_device():
    """cg_fused == cg on the same normal system (convergence identity)."""
    from repro.nlinv import phantom
    from repro.nlinv.cg import cg, cg_fused
    from repro.nlinv.operators import (make_ops, sobolev_weight, udot,
                                       uinit, uzeros)
    d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=1, seed=2)
    g = d["grid"]
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(g))
    u0 = uinit(4, g)
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    rhs = {"rho": _cplx(ks[0], (g, g)), "chat": _cplx(ks[1], (4, g, g))}
    alpha = 0.5
    A = lambda du: ops.normal(u0, du, alpha)
    x_ref = cg(A, rhs, uzeros(4, g), iters=20, tol=1e-8)
    pre = ops.precompute(u0)
    pap = lambda p: ops.normal_pap(
        pre, p, alpha,
        reducer=lambda prod, extras, compute: (prod, extras, compute()))
    x_fused = cg_fused(pap, rhs, iters=20, tol=1e-8)
    scale = float(jnp.max(jnp.abs(x_ref["rho"])))
    err = float(jnp.max(jnp.abs(x_fused["rho"] - x_ref["rho"])))
    assert err < 1e-3 * scale, (err, scale)
    # and both solve the system
    res = jax.tree.map(lambda a, b: a - b, A(x_fused), rhs)
    rel = float(jnp.sqrt(jnp.real(udot(res, res))) /
                jnp.sqrt(jnp.real(udot(rhs, rhs))))
    assert rel < 1e-2, rel


def test_fused_frame_masks_unsampled_kspace():
    """The premasked DGH fast path must not backproject out-of-mask
    garbage in caller-supplied y: fused == unfused even when y carries
    energy at unsampled k-space locations."""
    from repro.nlinv import phantom
    from repro.nlinv.operators import sobolev_weight, uinit
    from repro.nlinv.recon import Reconstructor
    d = phantom.make_dataset(n=16, ncoils=2, nspokes=5, frames=1, seed=9)
    g = d["grid"]
    y = np.asarray(d["y"][0]).copy()
    y += 0.5 * (1.0 - np.asarray(d["masks"][0], np.float32))[None]  # junk
    args = [jnp.asarray(v) for v in
            (y, d["masks"][0], d["fov"], np.asarray(sobolev_weight(g)))]
    outs = {}
    for fused in (False, True):
        rec = Reconstructor(newton=3, cg_iters=5, channel_sum="full",
                            fused=fused)
        u0 = uinit(2, g)
        outs[fused] = rec.fn(*args, u0, u0)[1]
    err = float(jnp.max(jnp.abs(outs[True] - outs[False])))
    scale = float(jnp.max(jnp.abs(outs[False])))
    assert err < 1e-4 * scale, (err, scale)


# ---------------------------------------------------------------------------
# 4-device identities (subprocess)
# ---------------------------------------------------------------------------

FUSED_4DEV = """
from repro.nlinv import phantom
from repro.nlinv.operators import sobolev_weight, uinit
from repro.nlinv.recon import Reconstructor, pad_channels
from repro.core import Environment

d = phantom.make_dataset(n=24, ncoils=6, nspokes=7, frames=1, seed=3)
g = d["grid"]
comm = Environment().subgroup(4)
w = sobolev_weight(g)
yp = pad_channels(np.asarray(d["y"][0]), 4)

for mode in ("full", "crop"):
    outs = {}
    for fused in (False, True):
        rec = Reconstructor(comm, newton=4, cg_iters=8, channel_sum=mode,
                            fused=fused)
        y = rec.put_frame(yp)
        mask = rec.put_const(np.asarray(d["masks"][0]))
        fov = rec.put_const(np.asarray(d["fov"]))
        wd = rec.put_const(np.asarray(w))
        u0 = rec.init_carry(yp.shape[0], g)
        xr = jax.tree.map(lambda a: a + 0, u0)
        outs[fused] = rec.fn(y, mask, fov, wd, u0, xr)[1]
    err = float(jnp.max(jnp.abs(outs[True] - outs[False])))
    scale = float(jnp.max(jnp.abs(outs[False])))
    check(f"fused_matches_unfused_{mode}_4dev", err < 2e-3 * scale)
"""


def test_fused_cg_matches_unfused_4dev():
    run_with_devices(FUSED_4DEV, ndev=4)


OVERLAP_PARITY = """
from functools import partial
from repro.core import Environment, compat
from repro.core.comm import ring_allreduce, all_reduce_overlap
from jax.sharding import PartitionSpec as P

comm = Environment().subgroup(4)
mesh = comm.mesh
x = (np.random.randn(4, 8, 16) + 1j * np.random.randn(4, 8, 16)
     ).astype(np.complex64)

def run(body):
    sm = compat.shard_map(body, mesh=mesh, in_specs=P("data"),
                          out_specs=P(), check_vma=False)
    return np.asarray(jax.jit(sm)(x))

plain = run(lambda xl: ring_allreduce(xl[0], "data", 4))
chunked = run(lambda xl: ring_allreduce(xl[0], "data", 4, chunks=3))
check("chunked_ring_bitwise", np.array_equal(plain, chunked))

def overlapped(xl):
    red, _, out = all_reduce_overlap(
        xl[0], axis="data", p2p=True, chunks=2,
        compute=lambda: jnp.float32(1.0),
        group=comm.group, mesh_axes=("data",))
    return red + 0 * out
over = run(overlapped)
check("overlap_ring_bitwise", np.array_equal(plain, over))

# the psum schedule with a scalar piggyback agrees with separate psums
# (same collective payload ordering -> identical summation per element)
from jax import lax
def fused_psum(xl):
    red, (s,), _ = all_reduce_overlap(
        xl[0], axis="data", extras=(jnp.real(jnp.vdot(xl[0], xl[0])),),
        group=comm.group, mesh_axes=("data",))
    return red * (s / s)
def sep_psum(xl):
    red = lax.psum(xl[0], "data")
    s = lax.psum(jnp.real(jnp.vdot(xl[0], xl[0])), "data")
    return red * (s / s)
check("piggyback_matches_separate",
      np.allclose(run(fused_psum), run(sep_psum), rtol=1e-5, atol=1e-5))
"""


def test_overlapped_ring_allreduce_bitwise_parity_4dev():
    run_with_devices(OVERLAP_PARITY, ndev=4)


def test_allreduce_overlap_single_program_degenerate():
    from repro.core import Environment
    comm = Environment().subgroup(1)
    x = jnp.arange(16.0).reshape(4, 4)
    red, (s,), out = comm.allreduce_overlap(
        x, ((1, 3), (1, 3)), extras=(jnp.float32(3.0),),
        compute=lambda: jnp.float32(7.0))
    assert float(s) == 3.0 and float(out) == 7.0
    want = np.zeros((4, 4), np.float32)
    want[1:3, 1:3] = np.asarray(x)[1:3, 1:3]
    np.testing.assert_array_equal(np.asarray(red), want)
