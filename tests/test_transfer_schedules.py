"""Naive-parity suite for the ISSUE 6 transfer schedules.

Every new schedule — scatter+allgather broadcast, the direct-collective
``copy`` routes, the rs+ag reduce decomposition, the fused chunked FFT
transpose — must produce bitwise (or 1e-5) identical results to the
naive verb / numpy reference on 1, 2 and 4 devices.  Schedules that the
topology-aware auto would not pick on the host-simulated CPU mesh are
forced through ``comm.BCAST_SCHEDULE`` / ``comm.REDUCE_SCHEDULE`` so
both sides of every decision run everywhere.
"""

import pytest

from helpers import run_with_devices

PARITY = """
import repro.core.comm as C
import repro.lib.blas as B
import repro.lib.fft as F
from repro.core.plan import default_cache
from repro.core.runtime import DeviceGroup
from repro.core.segmented import Policy, segment, gather

g = DeviceGroup.all_devices()
n = g.ndev
rng = np.random.default_rng(0)

# --- broadcast: both schedules == the input, any device count ----------
x = (rng.standard_normal((64, 65))
     + 1j * rng.standard_normal((64, 65))).astype(np.complex64)
for sched in ("device_put", "scatter_allgather"):
    C.BCAST_SCHEDULE = sched
    s = C.broadcast(x, g)
    check(f"bcast {sched} policy", s.policy is Policy.CLONE)
    check(f"bcast {sched} parity",
          np.array_equal(np.asarray(gather(s)), x))
C.BCAST_SCHEDULE = None

# --- copy: every direct route == the rebuild fallback ------------------
def parity(src, route, **kw):
    got = C.copy_route(src, **kw)
    check(f"route {route} n={n}", got == route)
    out = C.copy(src, **kw)
    ref = segment(gather(src), src.group, mesh_axes=src.mesh_axes,
                  policy=kw.get("policy", src.policy) or src.policy,
                  dim=kw.get("dim", src.dim),
                  block=kw.get("block"), halo=kw.get("halo") or 0)
    check(f"copy {route} values",
          np.array_equal(np.asarray(gather(out)), np.asarray(gather(ref))))
    check(f"copy {route} meta",
          (out.policy, out.dim) == (ref.policy, ref.dim)
          and out.block == ref.block)
    return out

xs = rng.standard_normal((64, 5)).astype(np.float32)
nat = segment(xs, g)                       # 64 % n == 0: unpadded
cl = parity(nat, "replicate", policy=Policy.CLONE)
parity(cl, "clone_split", policy=Policy.NATURAL)
parity(cl, "clone_split", policy=Policy.BLOCK, block=2)
parity(nat, "alltoall", dim=1)             # dim 1 len 5: pads per rank
parity(nat, "block_pack", policy=Policy.BLOCK, block=2)
blk = segment(xs, g, policy=Policy.BLOCK, block=2)
parity(blk, "block_unpack", policy=Policy.NATURAL)

xp = rng.standard_normal((13, 8)).astype(np.float32)   # padded NATURAL
natp = segment(xp, g)
clp = parity(natp, "replicate", policy=Policy.CLONE)
check("replicate keeps orig_len", clp.orig_len == natp.orig_len)
parity(clp, "clone_split", policy=Policy.NATURAL)
parity(natp, "alltoall", dim=1)
if n > 1:
    # 12 rows, block=2: blocks-per-rank not a multiple of n at n in (2, 4)
    xu = rng.standard_normal((12, 3)).astype(np.float32)
    natu = segment(xu, g)
    check("unaligned BLOCK -> rebuild",
          C.copy_route(natu, policy=Policy.BLOCK, block=2) == "rebuild")
    blku = C.copy(natu, policy=Policy.BLOCK, block=2)
    check("rebuild values", np.array_equal(np.asarray(gather(blku)), xu))

# halo-only OVERLAP2D change: metadata only, zero bytes moved
xo = rng.standard_normal((16, 16)).astype(np.float32)
ov = segment(xo, g, policy=Policy.OVERLAP2D, halo=1)
check("halo-only route", C.copy_route(ov, halo=3) == "meta")
ov2 = C.copy(ov, halo=3)
check("halo-only is metadata", ov2.data is ov.data and ov2.halo == 3)
check("same-layout copy moves nothing",
      C.copy_route(ov) == "meta" and C.copy(ov).data is ov.data)
check("clone alias", C.copy_route(cl) == "alias"
      and C.copy(cl).data is cl.data)

# --- reduce / allreduce: rs_ag == psum == numpy ------------------------
xr = rng.standard_normal((4, 32, 32)).astype(np.float32)
sr = segment(xr, g)
got = {}
for sched in ("psum", "rs_ag"):
    C.REDUCE_SCHEDULE = sched
    got[sched] = np.asarray(C.reduce(sr))
    check(f"reduce {sched} vs numpy",
          np.allclose(got[sched], xr.sum(0), atol=1e-5))
    ar = np.asarray(gather(C.all_reduce(sr)))
    check(f"allreduce {sched} vs numpy", np.allclose(ar, xr.sum(0), atol=1e-5))
C.REDUCE_SCHEDULE = None
check("reduce schedules agree", np.allclose(got["psum"], got["rs_ag"],
                                            atol=1e-6))

# --- reduce_scatter: sum/max/min == numpy ------------------------------
for op, ref in (("sum", xr.sum(0)), ("max", xr.max(0)), ("min", xr.min(0))):
    rs = C.reduce_scatter(sr, op=op)
    check(f"reduce_scatter {op} policy", rs.policy is Policy.NATURAL)
    check(f"reduce_scatter {op} vs numpy",
          np.allclose(np.asarray(gather(rs)), ref, atol=1e-5))

# --- gemm_ksplit: rs_ag == psum == numpy -------------------------------
A = rng.standard_normal((32, 32)).astype(np.float32)
Bm = rng.standard_normal((32, 32)).astype(np.float32)
sA = segment(A, g, dim=1)
sB = segment(Bm, g, dim=0)
for sched in ("psum", "rs_ag"):
    C.REDUCE_SCHEDULE = sched
    check(f"gemm schedule {sched}",
          B.gemm_ksplit_schedule(sA, sB) == (sched if n > 1 else "psum"))
    out = np.asarray(B.gemm_ksplit(sA, sB).data)
    check(f"gemm {sched} vs numpy", np.allclose(out, A @ Bm, atol=1e-3))
C.REDUCE_SCHEDULE = None

# --- FFT: fused transpose == numpy fft2, verbs fallback too ------------
xf = (rng.standard_normal((4, 16, 16))
      + 1j * rng.standard_normal((4, 16, 16))).astype(np.complex64)
ref2 = np.fft.fft2(xf, axes=(-2, -1), norm="ortho")
for dim in (1, 2):
    sf = segment(xf, g, dim=dim)
    plan = F.plan_fft2_batched(sf)
    check(f"fft dim={dim} fused",
          plan.meta["schedule"] == "fused_transpose")
    out = plan(sf)
    check(f"fft dim={dim} layout",
          out.policy is sf.policy and out.dim == sf.dim)
    check(f"fft dim={dim} parity",
          np.allclose(np.asarray(gather(out)), ref2, atol=1e-5))

so = segment(xf, g, dim=1, policy=Policy.OVERLAP2D, halo=1)
plano = F.plan_fft2_batched(so)
outo = plano(so)
check("fft overlap2d layout",
      outo.policy is Policy.OVERLAP2D and outo.halo == 1)
check("fft overlap2d parity",
      np.allclose(np.asarray(gather(outo)), ref2, atol=1e-5))

xv = (rng.standard_normal((2, 16, 6))
      + 1j * rng.standard_normal((2, 16, 6))).astype(np.complex64)
sv = segment(xv, g, dim=1)
planv = F.plan_fft2_batched(sv)
check("fft fallback schedule",
      planv.meta["schedule"] == ("verbs" if 6 % n else "fused_transpose"))
check("fft fallback parity",
      np.allclose(np.asarray(gather(planv(sv))),
                  np.fft.fft2(xv, axes=(-2, -1), norm="ortho"), atol=1e-5))

# --- steady state: a second round of every verb builds nothing ---------
before = default_cache().snapshot()
C.broadcast(x, g)
C.copy(nat, policy=Policy.CLONE)
C.reduce(sr)
C.reduce_scatter(sr)
F.plan_fft2_batched(segment(xf, g, dim=1))
d = default_cache().delta(before)
check("steady state builds nothing", d["builds"] == 0 and d["hits"] > 0)
print("PARITY-OK")
"""


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_transfer_schedule_parity(ndev):
    out = run_with_devices(PARITY, ndev=ndev)
    assert "PARITY-OK" in out


def test_reduce_scatter_rejects_unknown_op():
    import numpy as np

    from repro.core import comm
    from repro.core.runtime import DeviceGroup
    from repro.core.segmented import segment

    g = DeviceGroup.all_devices()
    seg = segment(np.ones((2, 4, 4), dtype=np.float32), g)
    with pytest.raises(ValueError,
                       match=r"reduce_scatter supports .*'sum', 'max', "
                             r"'min'.*got 'prod'"):
        comm.reduce_scatter(seg, op="prod")


def test_copy_validates_layout_kwargs():
    import numpy as np

    from repro.core import comm
    from repro.core.segmented import Policy, segment

    seg = segment(np.ones((4, 4), dtype=np.float32))
    with pytest.raises(ValueError, match="copy to BLOCK requires block="):
        comm.copy(seg, policy=Policy.BLOCK)
    with pytest.raises(ValueError,
                       match="halo= is only meaningful for OVERLAP2D"):
        comm.copy(seg, policy=Policy.NATURAL, halo=2)
