"""Elastic re-shard: checkpoints written on one mesh restore onto any
other mesh (the scale-up/scale-down path for node failures)."""

import tempfile

from helpers import run_with_devices

ELASTIC = """
import tempfile, pathlib
from repro.ckpt import save, restore_sharded
from jax.sharding import NamedSharding, PartitionSpec as P

tmp = tempfile.mkdtemp()
# write from a 1-device view (host arrays)
tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
        "opt": {"m": np.ones((16,), np.float32)}}
save(tmp, 3, tree)

# restore onto an 8-device mesh with 2D sharding (elastic scale-UP)
from repro.core import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh, P("data", "model")),
      "opt": {"m": NamedSharding(mesh, P("data"))}}
like = {"w": jnp.zeros((8, 8), jnp.float32),
        "opt": {"m": jnp.zeros((16,), jnp.float32)}}
got, step = restore_sharded(tmp, like, sh)
check("step", step == 3)
check("values", np.allclose(np.asarray(got["w"]), tree["w"]))
check("sharded", len(got["w"].addressable_shards) == 8)

# scale-DOWN: re-save from the sharded tree, restore replicated
save(tmp, 4, got)
sh1 = jax.tree.map(lambda _: NamedSharding(mesh, P()), like)
got2, step2 = restore_sharded(tmp, like, sh1)
check("downshard", np.allclose(np.asarray(got2["w"]), tree["w"]))
"""


def test_elastic_reshard_8dev():
    run_with_devices(ELASTIC, ndev=8)


LIVE_CARRY = """
import tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import save, restore_sharded
from repro.core import compat
from repro.core.env import Environment
from repro.ft import migrate_carry
from repro.nlinv.recon import Reconstructor
from repro.nlinv.stream import FramePipeline

env = Environment()
comm4 = env.group()
check("starts on 4 devices", comm4.size == 4)
rng = np.random.default_rng(0)
F, J, g = 4, 4, 16
y = (rng.normal(size=(F, J, g, g)) +
     1j * rng.normal(size=(F, J, g, g))).astype(np.complex64)
masks = (rng.random(size=(F, g, g)) < 0.4).astype(np.float32)
fov = np.ones((g, g), np.float32)

# uninterrupted 4-device reference movie
rec4 = Reconstructor(comm4, newton=2, cg_iters=6)
ref, _ = FramePipeline(rec4, inflight=2).run(y, masks, fov)
ref = np.asarray(ref)

# first half on 4 devices, then checkpoint the LIVE pipeline carry
rec4b = Reconstructor(comm4, newton=2, cg_iters=6)
pipe4 = FramePipeline(rec4b, inflight=2)
first, _ = pipe4.run(y[:2], masks[:2], fov)
tmp = tempfile.mkdtemp()
save(tmp, 2, pipe4.last_carry)

# "restart" on HALF the machine: restore the carry replicated on a
# 2-device mesh, migrate it onto a survivor Reconstructor, resume
comm2 = env.subgroup(2)
mesh2 = compat.make_mesh((2,), ("data",))
like = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype),
                    pipe4.last_carry)
sh = jax.tree.map(lambda _: NamedSharding(mesh2, P()), like)
carry_host, step = restore_sharded(tmp, like, sh)
check("checkpoint step", step == 2)
rec2 = Reconstructor(comm2, newton=2, cg_iters=6)
carry2 = {"u": migrate_carry(rec2, carry_host["u"]),
          "x_ref": migrate_carry(rec2, carry_host["x_ref"])}
second, _ = FramePipeline(rec2, inflight=2).run(
    y[2:], masks[2:], fov, carry=carry2)

movie = np.concatenate([np.asarray(first), np.asarray(second)])
check("frame count", movie.shape[0] == F)
for f in range(F):
    rel = np.abs(movie[f] - ref[f]).max() / max(np.abs(ref[f]).max(), 1e-30)
    check(f"4dev->ckpt->2dev parity f{f} (rel={rel:.2e})", rel <= 1e-5)
"""


def test_live_pipeline_carry_roundtrip_4_to_2():
    """A FramePipeline carry checkpointed mid-stream on 4 devices
    restores onto 2 and resumes with parity vs the uninterrupted run
    (the serving-grade elastic path: device loss between frames)."""
    run_with_devices(LIVE_CARRY, ndev=4)
