"""Elastic re-shard: checkpoints written on one mesh restore onto any
other mesh (the scale-up/scale-down path for node failures)."""

import tempfile

from helpers import run_with_devices

ELASTIC = """
import tempfile, pathlib
from repro.ckpt import save, restore_sharded
from jax.sharding import NamedSharding, PartitionSpec as P

tmp = tempfile.mkdtemp()
# write from a 1-device view (host arrays)
tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
        "opt": {"m": np.ones((16,), np.float32)}}
save(tmp, 3, tree)

# restore onto an 8-device mesh with 2D sharding (elastic scale-UP)
from repro.core import compat
mesh = compat.make_mesh((4, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh, P("data", "model")),
      "opt": {"m": NamedSharding(mesh, P("data"))}}
like = {"w": jnp.zeros((8, 8), jnp.float32),
        "opt": {"m": jnp.zeros((16,), jnp.float32)}}
got, step = restore_sharded(tmp, like, sh)
check("step", step == 3)
check("values", np.allclose(np.asarray(got["w"]), tree["w"]))
check("sharded", len(got["w"].addressable_shards) == 8)

# scale-DOWN: re-save from the sharded tree, restore replicated
save(tmp, 4, got)
sh1 = jax.tree.map(lambda _: NamedSharding(mesh, P()), like)
got2, step2 = restore_sharded(tmp, like, sh1)
check("downshard", np.allclose(np.asarray(got2["w"]), tree["w"]))
"""


def test_elastic_reshard_8dev():
    run_with_devices(ELASTIC, ndev=8)
