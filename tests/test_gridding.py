"""The gridding library port (paper §3.2/§4) and baseline numerics:

  * Ram-Lak DCF symmetry (Cartesian grid and radial trajectory forms);
  * the FFT+degrid / grid+IFFT radial_ops pair stays adjoint — single
    device here, 4-device coil-NATURAL-segmented in the subprocess
    payload;
  * gridding_recon / adjoint_recon reconstruction quality on the
    phantom (the Fig. 10 baseline must produce a sane image);
  * the gridding plan is built once per (trajectory, group).

Kernel-vs-oracle parity and the degrid/grid adjoint dot-product test
live in the shared registry harness (``tests/test_kernel_registry.py``,
ISSUE 8).
"""

import jax.numpy as jnp
import numpy as np

from helpers import run_with_devices

from repro.lib.gridding import (plan_gridding, radial_trajectory,
                                ramlak_dcf_radial)
from repro.lib.plan import PlanCache
from repro.nlinv import phantom
from repro.nlinv.gridding import gridding_recon, radial_ops, ramlak_dcf


def _cplx(rng, shape):
    return (rng.standard_normal(shape) +
            1j * rng.standard_normal(shape)).astype(np.complex64)


# ---------------------------------------------------------------------------
# density compensation
# ---------------------------------------------------------------------------

def test_ramlak_dcf_cartesian_symmetry():
    """|k| is symmetric under k -> -k (and strictly positive)."""
    d = ramlak_dcf(32)
    assert d.shape == (32, 32) and (d > 0).all()
    # centered grid: index c+r mirrors c-r
    flipped = d[1:, 1:][::-1, ::-1]            # mirror about the center
    np.testing.assert_allclose(d[1:, 1:], flipped, atol=1e-6)


def test_ramlak_dcf_radial_symmetry():
    """Opposite trajectory points (k and -k) get identical weights."""
    g = 32
    traj = radial_trajectory(g, nspokes=7)
    c = g // 2
    mirrored = np.stack([2 * c - traj[:, 0], 2 * c - traj[:, 1]], 1)
    np.testing.assert_allclose(ramlak_dcf_radial(traj, g),
                               ramlak_dcf_radial(mirrored, g), atol=1e-6)
    assert (ramlak_dcf_radial(traj, g) > 0).all()


def test_radial_ops_forward_adjoint_pair():
    """The FFT+degrid / grid+IFFT pair stays adjoint."""
    rng = np.random.default_rng(3)
    g = 32
    ops = radial_ops(g, nspokes=7)
    imgs = _cplx(rng, (2, g, g))
    y = _cplx(rng, (2, ops.plan.nsamp_padded))
    lhs = complex(jnp.vdot(jnp.asarray(y), ops.forward(jnp.asarray(imgs))))
    rhs = complex(jnp.vdot(ops.adjoint(jnp.asarray(y)), jnp.asarray(imgs)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3)


# ---------------------------------------------------------------------------
# reconstruction numerics (Fig. 10 baseline)
# ---------------------------------------------------------------------------

def _nrmse_in_fov(img, truth, fov):
    m = np.asarray(fov) > 0
    a = np.abs(np.asarray(img))[m]
    b = np.abs(np.asarray(truth))[m]
    a = a / max(a.max(), 1e-9)
    b = b / max(b.max(), 1e-9)
    return float(np.sqrt(np.mean((a - b) ** 2)))


def test_gridding_recon_quality_cartesian():
    d = phantom.make_dataset(n=32, ncoils=4, nspokes=13, frames=1, seed=4)
    img = gridding_recon(jnp.asarray(d["y"][0]), jnp.asarray(d["masks"][0]),
                         jnp.asarray(d["fov"]))
    err = _nrmse_in_fov(img, d["rho"][0], d["fov"])
    assert err < 0.35, err            # streaky but recognizable (Fig. 10)
    assert np.isfinite(np.asarray(img)).all()


def test_adjoint_recon_quality_radial():
    """True-trajectory adjoint recon of the phantom: the degrid->grid
    roundtrip of the simulated acquisition must reconstruct the image
    about as well as the Cartesian-mask baseline."""
    d = phantom.make_dataset(n=32, ncoils=4, nspokes=13, frames=1, seed=5)
    g = d["grid"]
    ops = radial_ops(g, nspokes=13)
    # simulate the radial acquisition from the ground-truth coil images
    coil_imgs = jnp.asarray(d["rho"][0][None] * d["coils"])
    samples = ops.forward(coil_imgs)
    img = ops.recon(samples, jnp.asarray(d["fov"]))
    err = _nrmse_in_fov(img, d["rho"][0], d["fov"])
    assert err < 0.35, err
    assert np.isfinite(np.asarray(img)).all()


def test_gridding_plan_built_once():
    cache = PlanCache()
    g = 32
    traj = radial_trajectory(g, nspokes=5)
    p1 = plan_gridding(traj, g, cache=cache)
    p2 = plan_gridding(traj, g, cache=cache)
    assert p1 is p2 and cache.misses == 1 and cache.hits == 1
    # a different frame geometry is a different plan
    plan_gridding(radial_trajectory(g, nspokes=5, frame=1), g, cache=cache)
    assert cache.misses == 2


# ---------------------------------------------------------------------------
# distributed: coil-NATURAL segmentation on 4 devices (subprocess)
# ---------------------------------------------------------------------------

DIST = """
from repro.core import Environment
from repro.lib.gridding import plan_gridding, radial_trajectory
from repro.lib import fft as lfft

g, J, nspokes = 32, 4, 7
comm = Environment().subgroup(4)
traj = radial_trajectory(g, nspokes)
plan = plan_gridding(traj, g, comm=comm)

rng = np.random.default_rng(0)
cplx = lambda shape: (rng.standard_normal(shape)
                      + 1j * rng.standard_normal(shape)).astype(np.complex64)
gg = cplx((J, g, g))
y = cplx((J, plan.nsamp_padded))

seg_g = comm.container(gg)                 # coils NATURAL over 4 devices
seg_y = comm.container(y)

# segmented degrid/grid match the single-logical-array math
s_seg = comm.gather(plan.degrid(seg_g))
s_ref = plan.degrid(jnp.asarray(gg))
check("dist_degrid", np.allclose(np.asarray(s_seg), np.asarray(s_ref),
                                 atol=1e-4))
k_seg = comm.gather(plan.grid(seg_y))
k_ref = plan.grid(jnp.asarray(y))
check("dist_grid", np.allclose(np.asarray(k_seg), np.asarray(k_ref),
                               atol=1e-4))

# adjoint dot-product test ON the 4-device segmented containers
lhs = complex(comm.vdot(seg_y, plan.degrid(seg_g)))
rhs = complex(comm.vdot(plan.grid(seg_y), seg_g))
check("dist_adjoint_dot", abs(lhs - rhs) <= 1e-4 * max(abs(lhs), 1.0))

# distributed adjoint recon == single-device adjoint recon
fov = np.ones((g, g), np.float32)
img_d = plan.adjoint_recon(seg_y, fov)
img_1 = plan.adjoint_recon(jnp.asarray(y), fov)
check("dist_recon", np.allclose(np.asarray(img_d), np.asarray(img_1),
                                atol=1e-3))

# streaming plan-cache report on 4 devices: steady state builds nothing
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor
from repro.nlinv.stream import FrameStream
d = phantom.make_dataset(n=16, ncoils=4, nspokes=5, frames=3, seed=6)
rec = Reconstructor(comm, newton=2, cg_iters=4, channel_sum="crop")
_, rep = FrameStream(rec).run(d["y"], d["masks"], d["fov"])
pc = rep.summary()["plan_cache"]
check("stream_steady_builds_zero", pc["steady_builds"] == 0)
check("stream_hit_rate", pc["hit_rate"] > 0)
"""


def test_gridding_distributed_4dev():
    run_with_devices(DIST, ndev=4)
