"""Distributed NLINV == single-device NLINV (the paper's §3.2 contract).

4-device mesh, coils split across devices, rho CLONEd; both channel-sum
strategies (paper-faithful full-grid all-reduce and the cropped 2-D
section of kern_all_red_p2p_2d) must agree with the local result.
Also covers channel padding (J=6 on 4 devices).
"""

from helpers import run_with_devices

DIST = """
from repro.nlinv import phantom
from repro.nlinv.irgnm import irgnm, postprocess
from repro.nlinv.operators import make_ops, sobolev_weight, uinit
from repro.nlinv.recon import make_dist_reconstruct, pad_channels
from repro.core import DeviceGroup

d = phantom.make_dataset(n=24, ncoils=6, nspokes=7, frames=1, seed=3)
g = DeviceGroup.all_devices((4,), ("data",))
w = sobolev_weight(d["grid"])

ops = make_ops(d["masks"][0], d["fov"], w)
u_ref = irgnm(ops, jnp.asarray(d["y"][0]), uinit(6, d["grid"]),
              newton=5, cg_iters=20)
img_ref = postprocess(ops, u_ref)

yp = pad_channels(d["y"][0], 4)   # 6 -> 8 channels (zeros)
Jp = yp.shape[0]
for mode in ("full", "crop"):
    fn = make_dist_reconstruct(g, "data", newton=5, cg_iters=20,
                               channel_sum=mode)
    u0 = uinit(Jp, d["grid"])
    u, img = fn(jnp.asarray(yp), jnp.asarray(d["masks"][0]),
                jnp.asarray(d["fov"]), jnp.asarray(w), u0, u0)
    err = float(jnp.max(jnp.abs(img - img_ref)))
    scale = float(jnp.max(jnp.abs(img_ref)))
    check(f"dist_{mode}_matches_local", err < 2e-3 * scale)
"""


def test_distributed_nlinv_4dev():
    run_with_devices(DIST, ndev=4)
