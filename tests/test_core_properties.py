"""Property tests (hypothesis) for the segmented-container invariants.

Single-device mesh: the policies' math (padding, block-cyclic
permutations, reduce semantics) must be invariant to the device count, so
these run in-process on 1 device; true multi-shard layouts are covered by
test_core_multidevice.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (DeviceGroup, Policy, segment, gather, reduce,
                        all_reduce, blas)

# subset(1): robust to any ambient --xla_force_host_platform_device_count
G = DeviceGroup.subset(1, ("data",))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 33), m=st.integers(1, 5),
       policy=st.sampled_from([Policy.NATURAL, Policy.CLONE, Policy.BLOCK]),
       block=st.integers(1, 4))
def test_roundtrip(n, m, policy, block):
    x = np.random.randn(n, m).astype(np.float32)
    s = segment(x, G, policy=policy, block=block)
    assert np.allclose(gather(s), x)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 8), n=st.integers(1, 6))
def test_reduce_matches_numpy(b, n):
    x = np.random.randn(b, n, n).astype(np.float32)
    s = segment(x, G)
    assert np.allclose(reduce(s), x.sum(0), atol=1e-4)
    assert np.allclose(gather(all_reduce(s, "max")), x.max(0), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 40), a=st.floats(-3, 3, allow_nan=False))
def test_axpy_linearity(n, a):
    x = np.random.randn(n).astype(np.float32)
    y = np.random.randn(n).astype(np.float32)
    sx, sy = segment(x, G), segment(y, G)
    got = gather(blas.axpy(a, sx, sy))
    assert np.allclose(got, a * x + y, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 20))
def test_dot_conjugate_symmetry(n):
    x = (np.random.randn(n) + 1j * np.random.randn(n)).astype(np.complex64)
    y = (np.random.randn(n) + 1j * np.random.randn(n)).astype(np.complex64)
    sx, sy = segment(x, G), segment(y, G)
    d1 = complex(blas.dot(sx, sy))
    d2 = complex(blas.dot(sy, sx))
    assert abs(d1 - np.conj(d2)) < 1e-3
