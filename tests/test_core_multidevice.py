"""Multi-device semantics of the segmented containers + comm verbs.

Every MGPU primitive (Fig. 3) is checked on a real 8-device host mesh
against the numpy result on the unsegmented data: the paper's correctness
contract is that segmentation is transparent to the algorithm.
"""

from helpers import run_with_devices

CONTAINERS = """
from repro.core import (DeviceGroup, Policy, segment, gather, broadcast,
                        reduce, all_reduce, copy, all_to_all, reduce_scatter,
                        overlap2d_map)
g = DeviceGroup.all_devices((8,), ("data",))

x = np.random.randn(24, 5).astype(np.float32)
s = segment(x, g)
check("natural_roundtrip", np.allclose(gather(s), x))
check("natural_shards", len(set(d.device for d in s.data.addressable_shards)) == 8)

x2 = np.random.randn(21, 3).astype(np.float32)   # needs padding
s2 = segment(x2, g)
check("padded_roundtrip", np.allclose(gather(s2), x2))

sb = segment(x2, g, policy=Policy.BLOCK, block=2)
check("block_cyclic_roundtrip", np.allclose(gather(sb), x2))

sc = broadcast(x, g)
check("clone_replicated", all(np.allclose(np.asarray(sh.data), x)
                              for sh in sc.data.addressable_shards))

m = np.random.randn(8, 6, 6).astype(np.float32)   # one matrix per device
sm = segment(m, g)
r = reduce(sm)
check("reduce_sum", np.allclose(r, m.sum(0), atol=1e-5))
ar = all_reduce(sm)
check("all_reduce", np.allclose(gather(ar), m.sum(0), atol=1e-5))
check("all_reduce_max", np.allclose(gather(all_reduce(sm, "max")), m.max(0)))

cc = copy(s, policy=Policy.CLONE)
check("copy_to_clone", np.allclose(gather(cc), x))

xt = np.random.randn(8, 16, 4).astype(np.float32)
st = segment(xt, g)
s_t2 = all_to_all(st, new_dim=1)
check("all_to_all_resegment", np.allclose(gather(s_t2), xt))
check("all_to_all_dim", s_t2.dim == 1)

rs = reduce_scatter(sm)
check("reduce_scatter", np.allclose(gather(rs), m.sum(0), atol=1e-5))

xo = np.random.randn(32, 8).astype(np.float32)
so = segment(xo, g, policy=Policy.OVERLAP2D, halo=1)
ident = overlap2d_map(so, lambda ext: ext[1:-1])
check("overlap_identity", np.allclose(gather(ident), xo))
def stencil(ext):
    return ext[:-2] + ext[1:-1] + ext[2:]
got = gather(overlap2d_map(so, stencil))
pad = np.pad(xo, ((1, 1), (0, 0)))
want = pad[:-2] + pad[1:-1] + pad[2:]
check("overlap_stencil", np.allclose(got, want, atol=1e-5))
"""

INVOKE_BLAS_FFT = """
from repro.core import (DeviceGroup, Policy, segment, gather,
                        invoke_kernel, invoke_kernel_all, PassThrough,
                        barrier_fence)
from repro.lib import blas, fft
g = DeviceGroup.all_devices((8,), ("data",))

x = np.random.randn(16, 4).astype(np.float32)
y = np.random.randn(16, 4).astype(np.float32)
sx, sy = segment(x, g), segment(y, g)

z = blas.axpy(2.0, sx, sy)
check("axpy", np.allclose(gather(z), 2.0 * x + y, atol=1e-5))

xc = (np.random.randn(16, 4) + 1j * np.random.randn(16, 4)).astype(np.complex64)
yc = (np.random.randn(16, 4) + 1j * np.random.randn(16, 4)).astype(np.complex64)
d = blas.dot(segment(xc, g), segment(yc, g))
check("dot", np.allclose(d, np.vdot(xc, yc), atol=1e-4))

a = np.random.randn(8, 5, 6).astype(np.float32)
b = np.random.randn(8, 6, 7).astype(np.float32)
gm = blas.gemm_batched(segment(a, g), segment(b, g))
check("gemm_batched", np.allclose(gather(gm), a @ b, atol=1e-4))

A = np.random.randn(12, 32).astype(np.float32)
B = np.random.randn(32, 9).astype(np.float32)
sA = segment(A, g, dim=1)
sB = segment(B, g, dim=0)
gk = blas.gemm_ksplit(sA, sB)
check("gemm_ksplit_psum", np.allclose(gather(gk), A @ B, atol=1e-4))

# segmented batched FFT == numpy FFT (ortho, centered)
xf = (np.random.randn(8, 16, 16) + 1j * np.random.randn(8, 16, 16)).astype(np.complex64)
sf = segment(xf, g)
got = gather(fft.fft2_batched(sf, centered=True))
want = np.fft.fftshift(np.fft.fft2(np.fft.ifftshift(xf, axes=(-2, -1)),
                                   axes=(-2, -1), norm="ortho"), axes=(-2, -1))
check("fft2_batched", np.allclose(got, want, atol=1e-4))
inv = gather(fft.fft2_batched(fft.fft2_batched(sf, centered=True),
                              inverse=True, centered=True))
check("fft2_inverse", np.allclose(inv, xf, atol=1e-4))

# invoke_kernel_all forwards local ranges; dev_rank-dependent kernels
def scalekern(xl, yl):
    return xl * 2.0 + yl
got = invoke_kernel_all(scalekern, sx, sy, group=g)
check("invoke_all", np.allclose(gather(got), 2 * x + y, atol=1e-5))

# pass-through gives the kernel the full vector (P2P analogue)
def needs_all(xl, full):
    return xl + full.sum()
got = invoke_kernel_all(needs_all, sx, PassThrough(sx), group=g)
check("pass_through", np.allclose(gather(got), x + x.sum(), atol=1e-3))

# invoke on one rank masks the others
got = invoke_kernel(lambda xl: xl + 1.0, sx, rank=3, group=g)
arr = gather(got)
want = np.zeros_like(x); want[6:8] = x[6:8] + 1.0   # rank 3 owns rows 6:8
check("invoke_rank", np.allclose(arr, want, atol=1e-5))

barrier_fence(got.data, group=g)
check("barrier_fence", True)
"""

HIERARCHICAL = """
from repro.core import DeviceGroup, Policy, segment, gather, all_reduce
g = DeviceGroup.all_devices((2, 4), ("pod", "data"))
m = np.random.randn(8, 4, 6).astype(np.float32)
sm = segment(m, g, mesh_axes=("pod", "data"))
flat = gather(all_reduce(sm))
hier = gather(all_reduce(sm, hierarchical=True))
check("hier_matches_flat", np.allclose(flat, hier, atol=1e-5))
check("hier_correct", np.allclose(hier, m.sum(0), atol=1e-5))
"""


def test_segmented_containers_8dev():
    run_with_devices(CONTAINERS)


def test_invoke_blas_fft_8dev():
    run_with_devices(INVOKE_BLAS_FFT)


def test_hierarchical_allreduce_2x4():
    run_with_devices(HIERARCHICAL)
