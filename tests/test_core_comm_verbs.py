"""The extended verb set (windowed all-reduce, segmented vdot, metadata-
correct copy) plus the halo-exchange and hierarchical-psum contracts —
all checked on real multi-device host meshes against numpy references.

The COMMUNICATOR / COMMUNICATOR_1DEV payloads cover the object-oriented
surface (ISSUE 2): Environment/Communicator verb methods, the new
allgather + send_recv/shift p2p family, the ppermute-ring all-reduce
path, parity with the deprecated free functions, and the
seg_len/segments/all_to_all metadata fixes — on 4 and 1 device(s).
"""

from helpers import run_with_devices

VERBS = """
from repro.core import (DeviceGroup, Policy, segment, gather, broadcast,
                        all_reduce, all_reduce_window, vdot, copy, make_spmd)
g = DeviceGroup.all_devices((4,), ("data",))

# --- all_reduce_window: eager form (paper kern_all_red_p2p_2d) ---------
x = np.random.randn(8, 12, 12).astype(np.float32)
s = segment(x, g)
win = ((3, 9), (3, 9))
aw = all_reduce_window(s, win)
ref = np.zeros((12, 12), np.float32)
ref[3:9, 3:9] = x.sum(0)[3:9, 3:9]
check("window_eager", np.allclose(np.asarray(aw.data), ref, atol=1e-5))
check("window_eager_clone", aw.policy is Policy.CLONE)
full = all_reduce(s)
check("full_eager", np.allclose(np.asarray(full.data), x.sum(0), atol=1e-5))

# --- in-shard_map forms through make_spmd ------------------------------
UPOL = {"rho": Policy.CLONE, "chat": Policy.NATURAL}
rho = np.random.randn(12, 12).astype(np.float32)

def body(a, b):
    d = vdot(a, b, axis="data", policies=UPOL)
    w = all_reduce_window(b["chat"], win, axis="data", reduce_dim=0)
    return d, w

fn = make_spmd(body, g, in_policies=(UPOL, UPOL),
               out_policies=(Policy.CLONE, Policy.CLONE), check_vma=False)
d, w = fn({"rho": jnp.asarray(rho), "chat": jnp.asarray(x)},
          {"rho": jnp.asarray(rho), "chat": jnp.asarray(2 * x)})
want = np.vdot(rho, rho) + np.vdot(x, 2 * x)
check("vdot_local", np.allclose(float(d), want, rtol=1e-5))
ref2 = np.zeros((12, 12), np.float32)
ref2[3:9, 3:9] = (2 * x).sum(0)[3:9, 3:9]
check("window_local", np.allclose(np.asarray(w), ref2, atol=1e-5))

# eager vdot over a CLONE+NATURAL mixed pytree (no explicit collective)
u1 = {"rho": broadcast(rho, g), "chat": s}
u2 = {"rho": broadcast(rho, g), "chat": segment(2 * x, g)}
check("vdot_eager", np.allclose(float(vdot(u1, u2)), want, rtol=1e-5))

# complex scalar product (the CG entry of paper Table 1)
cx = (np.random.randn(8, 4) + 1j * np.random.randn(8, 4)).astype(np.complex64)
sc = segment(cx, g)
check("vdot_complex",
      np.allclose(complex(vdot({"c": sc}, {"c": sc})), np.vdot(cx, cx),
                  rtol=1e-5))

# axis=None: the single-device degenerate forms are the plain local math
loc = all_reduce_window(x, win, axis=None, reduce_dim=0)
refl = np.zeros((12, 12), np.float32)
refl[3:9, 3:9] = x.sum(0)[3:9, 3:9]
check("window_degenerate", np.allclose(np.asarray(loc), refl, atol=1e-5))

# --- copy metadata correctness ----------------------------------------
x2 = np.random.randn(10, 8).astype(np.float32)
s2 = segment(x2, g)                       # pads 10 -> 12 along dim 0
c1 = copy(s2, dim=1)                      # re-segment along dim 1
check("copy_dim_roundtrip", np.allclose(gather(c1), x2))
check("copy_dim_metadata", c1.dim == 1 and c1.orig_len == 8)
cl = broadcast(x2, g)
c2 = copy(cl, policy=Policy.NATURAL)      # CLONE -> split must re-pad
check("copy_clone_split", np.allclose(gather(c2), x2) and c2.orig_len == 10)
sb = segment(np.random.randn(21, 3).astype(np.float32), g,
             policy=Policy.BLOCK, block=2)
c3 = copy(sb, policy=Policy.NATURAL)      # away from BLOCK: clean metadata
check("copy_unblock", c3.block is None and c3.orig_len == 21
      and c3.policy is Policy.NATURAL)
try:
    copy(s2, halo=1)
    check("copy_halo_validated", False)
except ValueError:
    check("copy_halo_validated", True)
"""

OVERLAP = """
from repro.core import DeviceGroup, Policy, segment, gather, overlap2d_map
g = DeviceGroup.all_devices((4,), ("data",))

for h in (1, 2):
    x = np.random.randn(16, 5).astype(np.float32)
    s = segment(x, g, policy=Policy.OVERLAP2D, halo=h)
    width = 2 * h + 1

    def stencil(e):
        r = e.shape[0] - 2 * h
        return sum(e[k:k + r] for k in range(width))

    out = overlap2d_map(s, stencil)
    xp = np.pad(x, ((h, h), (0, 0)))          # edge shards see zeros
    ref = sum(xp[k:k + 16] for k in range(width))
    check(f"overlap_h{h}", np.allclose(gather(out), ref, atol=1e-5))
"""

HIER = """
from repro.core import DeviceGroup, segment, all_reduce
g = DeviceGroup.all_devices((2, 2), ("pod", "data"))   # pod crosses DCN

# leading dim tiles by n_ici=2: staged reduce-scatter/psum/all-gather path
x = np.random.randn(6, 4, 5).astype(np.float32)
s = segment(x, g, mesh_axes=("pod", "data"))
out = all_reduce(s, hierarchical=True)
check("hier_tiled", np.allclose(np.asarray(out.data), x.sum(0), atol=1e-5))

# leading dim 3 does not tile: must fall back to the flat psum
x2 = np.random.randn(6, 3, 5).astype(np.float32)
s2 = segment(x2, g, mesh_axes=("pod", "data"))
out2 = all_reduce(s2, hierarchical=True)
check("hier_fallback", np.allclose(np.asarray(out2.data), x2.sum(0),
                                   atol=1e-5))
"""


COMMUNICATOR = """
from repro.core import Environment, Policy
import repro.core as core

env = Environment()
comm = env.group((4,), ("data",))
check("env_repr", env.ndev == 4 and comm.size == 4 and comm.axis == "data")

# --- scatter -> gather round-trip across all four policies -------------
x = np.random.randn(10, 6).astype(np.float32)
for pol, kw in ((Policy.NATURAL, {}), (Policy.BLOCK, dict(block=2)),
                (Policy.CLONE, {}), (Policy.OVERLAP2D, dict(halo=1))):
    s = comm.scatter(x, policy=pol, **kw)
    check(f"roundtrip_{pol.value}", np.allclose(comm.gather(s), x))

# --- allgather vs jnp.concatenate of the per-rank segments -------------
xa = np.random.randn(8, 5, 3).astype(np.float32)
sa = comm.container(xa)
ag = sa.allgather()
check("allgather_clone", ag.policy is Policy.CLONE)
shards = sorted(sa.data.addressable_shards, key=lambda sh: sh.index[0].start)
ref = jnp.concatenate([jnp.asarray(np.asarray(sh.data)) for sh in shards],
                      axis=0)
check("allgather_concat", np.allclose(np.asarray(ag.data), np.asarray(ref)))
check("allgather_replicated", all(np.allclose(np.asarray(sh.data), xa)
                                  for sh in ag.data.addressable_shards))

# --- send_recv / shift ring identity (p2p verbs) -----------------------
xs = np.arange(16, dtype=np.float32).reshape(16, 1)
s = comm.container(xs)
r = s
for _ in range(4):
    r = r.shift(1)
check("shift_ring_identity", np.allclose(comm.gather(r), xs))
one = comm.gather(s.shift(1))
check("shift_rotates", np.allclose(one, np.roll(xs, 4, axis=0)))
open_ = comm.gather(s.shift(1, wrap=False))
want = np.roll(xs, 4, axis=0); want[:4] = 0
check("shift_open_boundary", np.allclose(open_, want))
perm = [(i, (i + 1) % 4) for i in range(4)]
check("send_recv_ring", np.allclose(comm.gather(comm.send_recv(s, perm)), one))
inv = [(d, sr) for (sr, d) in perm]
check("send_recv_inverse",
      np.allclose(comm.gather(comm.send_recv(comm.send_recv(s, perm), inv)), xs))
partial = comm.gather(s.send_recv([(0, 1), (1, 0)]))
wantp = np.zeros_like(xs)
wantp[0:4], wantp[4:8] = xs[4:8], xs[0:4]       # ranks 2,3 receive zeros
check("send_recv_zero_fill", np.allclose(partial, wantp))

# --- ppermute-ring all-reduce == psum all-reduce -----------------------
m = np.random.randn(8, 6, 6).astype(np.float32)
sm = comm.container(m)
check("p2p_allreduce", np.allclose(np.asarray(sm.allreduce(p2p=True).data),
                                   m.sum(0), atol=1e-5))
win = ((1, 5), (1, 5))
a = comm.allreduce_window(sm, win)
b = comm.allreduce_window(sm, win, p2p=True)
check("p2p_window_matches_psum",
      np.allclose(np.asarray(a.data), np.asarray(b.data), atol=1e-5))
check("p2p_max", np.allclose(np.asarray(sm.allreduce("max", p2p=True).data),
                             m.max(0)))

# --- in-shard_map forms of the new verbs through comm.spmd -------------
def body(xl):
    return comm.allgather(xl, axis="data"), comm.shift(xl, 1, axis="data")
fn = comm.spmd(body, in_policies=(Policy.NATURAL,),
               out_policies=(Policy.CLONE, Policy.NATURAL), check_vma=False)
full, shifted = fn(jnp.asarray(xa))
check("allgather_local", np.allclose(np.asarray(full), xa))
check("shift_local", np.allclose(np.asarray(shifted), np.roll(xa, 2, axis=0)))

# --- parity: communicator methods == deprecated free functions ---------
sf = core.segment(m, comm)            # shim accepts the communicator
check("parity_reduce", np.allclose(comm.reduce(sm), core.reduce(sf),
                                   atol=1e-6))
check("parity_allreduce", np.allclose(np.asarray(sm.allreduce().data),
                                      np.asarray(core.all_reduce(sf).data),
                                      atol=1e-6))
check("parity_reduce_scatter",
      np.allclose(comm.gather(comm.reduce_scatter(sm)),
                  core.gather(core.reduce_scatter(sf)), atol=1e-6))
check("parity_bcast", np.allclose(np.asarray(comm.bcast(m).data),
                                  np.asarray(core.broadcast(m, comm).data)))
u1 = {"rho": comm.bcast(m[0]), "chat": sm}
check("parity_vdot", np.allclose(float(comm.vdot(u1, u1)),
                                 float(core.vdot(u1, u1)), rtol=1e-6))
check("deprecation_marked",
      core.all_reduce.__deprecated__ == "Communicator.allreduce"
      and core.segment.__deprecated__ == "Communicator.container")

# --- metadata fixes: seg_len/segments + all_to_all ---------------------
sb = comm.container(np.random.randn(21, 3).astype(np.float32),
                    policy=Policy.BLOCK, block=2)
check("segments_block_remainder",
      [t[0] for t in sb.segments()] == [6, 6, 5, 4])
check("seg_len_block", sb.seg_len(3) == 4 and sb.seg_len() == 6)
so = comm.container(np.random.randn(16, 5).astype(np.float32),
                    policy=Policy.OVERLAP2D, halo=2)
check("segments_overlap_halo", [t[0] for t in so.segments()] == [6, 8, 8, 6])
sn = comm.container(np.random.randn(10, 3).astype(np.float32))
check("segments_natural_remainder",
      [t[0] for t in sn.segments()] == [3, 3, 3, 1])
xt = np.random.randn(10, 6, 3).astype(np.float32)
st = comm.container(xt)                    # pads 10 -> 12 along dim 0
t2 = st.alltoall(1)                        # pads 6 -> 8 along dim 1
check("alltoall_metadata", t2.dim == 1 and t2.orig_len == 6)
check("alltoall_roundtrip", np.allclose(comm.gather(t2), xt))
check("alltoall_back", np.allclose(comm.gather(t2.alltoall(0)), xt))

# --- fluent container forms -------------------------------------------
check("fluent_to_clone", sm.to(Policy.CLONE).policy is Policy.CLONE)
ident = so.halo_exchange(lambda e: e[2:-2])
check("fluent_halo_identity", np.allclose(comm.gather(ident),
                                          comm.gather(so)))
ext = so.halo_exchange()
check("fluent_halo_extended", ext.global_shape[0] == 16 + 4 * 4
      and ext.policy is Policy.NATURAL)
"""

COMMUNICATOR_1DEV = """
from repro.core import Environment, Policy
import repro.core as core

comm = Environment().subgroup(1)
x = np.random.randn(6, 4).astype(np.float32)
s = comm.container(x)
check("gather_1dev", np.allclose(comm.gather(s), x))
check("allgather_1dev", np.allclose(np.asarray(s.allgather().data), x))
check("shift_1dev_identity", np.allclose(comm.gather(s.shift(1)), x))
check("shift_1dev_open", np.allclose(comm.gather(s.shift(1, wrap=False)),
                                     np.zeros_like(x)))
check("send_recv_1dev", np.allclose(comm.gather(comm.send_recv(s, [(0, 0)])),
                                    x))
check("allreduce_1dev", np.allclose(np.asarray(s.allreduce().data), x.sum(0),
                                    atol=1e-6))
check("p2p_allreduce_1dev",
      np.allclose(np.asarray(s.allreduce(p2p=True).data), x.sum(0),
                  atol=1e-6))
# degenerate in-shard_map forms (axis=None -> plain local math)
check("local_allgather_none", np.allclose(comm.allgather(jnp.asarray(x)), x))
check("local_shift_none", np.allclose(core.comm.shift(jnp.asarray(x), 1),
                                      x))
# parity with the deprecated free functions on one device
sf = core.segment(x, comm)
check("parity_reduce_1dev", np.allclose(comm.reduce(s), core.reduce(sf),
                                        atol=1e-6))
check("parity_gather_1dev", np.allclose(comm.gather(s), core.gather(sf)))
"""


def test_comm_verbs_4dev():
    run_with_devices(VERBS, ndev=4)


def test_communicator_api_4dev():
    run_with_devices(COMMUNICATOR, ndev=4)


def test_communicator_api_1dev():
    run_with_devices(COMMUNICATOR_1DEV, ndev=1)


def test_overlap2d_halo_vs_numpy():
    run_with_devices(OVERLAP, ndev=4)


def test_hierarchical_psum_paths():
    run_with_devices(HIER, ndev=4)
