"""The extended verb set (windowed all-reduce, segmented vdot, metadata-
correct copy) plus the halo-exchange and hierarchical-psum contracts —
all checked on real multi-device host meshes against numpy references.
"""

from helpers import run_with_devices

VERBS = """
from repro.core import (DeviceGroup, Policy, segment, gather, broadcast,
                        all_reduce, all_reduce_window, vdot, copy, make_spmd)
g = DeviceGroup.all_devices((4,), ("data",))

# --- all_reduce_window: eager form (paper kern_all_red_p2p_2d) ---------
x = np.random.randn(8, 12, 12).astype(np.float32)
s = segment(x, g)
win = ((3, 9), (3, 9))
aw = all_reduce_window(s, win)
ref = np.zeros((12, 12), np.float32)
ref[3:9, 3:9] = x.sum(0)[3:9, 3:9]
check("window_eager", np.allclose(np.asarray(aw.data), ref, atol=1e-5))
check("window_eager_clone", aw.policy is Policy.CLONE)
full = all_reduce(s)
check("full_eager", np.allclose(np.asarray(full.data), x.sum(0), atol=1e-5))

# --- in-shard_map forms through make_spmd ------------------------------
UPOL = {"rho": Policy.CLONE, "chat": Policy.NATURAL}
rho = np.random.randn(12, 12).astype(np.float32)

def body(a, b):
    d = vdot(a, b, axis="data", policies=UPOL)
    w = all_reduce_window(b["chat"], win, axis="data", reduce_dim=0)
    return d, w

fn = make_spmd(body, g, in_policies=(UPOL, UPOL),
               out_policies=(Policy.CLONE, Policy.CLONE), check_vma=False)
d, w = fn({"rho": jnp.asarray(rho), "chat": jnp.asarray(x)},
          {"rho": jnp.asarray(rho), "chat": jnp.asarray(2 * x)})
want = np.vdot(rho, rho) + np.vdot(x, 2 * x)
check("vdot_local", np.allclose(float(d), want, rtol=1e-5))
ref2 = np.zeros((12, 12), np.float32)
ref2[3:9, 3:9] = (2 * x).sum(0)[3:9, 3:9]
check("window_local", np.allclose(np.asarray(w), ref2, atol=1e-5))

# eager vdot over a CLONE+NATURAL mixed pytree (no explicit collective)
u1 = {"rho": broadcast(rho, g), "chat": s}
u2 = {"rho": broadcast(rho, g), "chat": segment(2 * x, g)}
check("vdot_eager", np.allclose(float(vdot(u1, u2)), want, rtol=1e-5))

# complex scalar product (the CG entry of paper Table 1)
cx = (np.random.randn(8, 4) + 1j * np.random.randn(8, 4)).astype(np.complex64)
sc = segment(cx, g)
check("vdot_complex",
      np.allclose(complex(vdot({"c": sc}, {"c": sc})), np.vdot(cx, cx),
                  rtol=1e-5))

# axis=None: the single-device degenerate forms are the plain local math
loc = all_reduce_window(x, win, axis=None, reduce_dim=0)
refl = np.zeros((12, 12), np.float32)
refl[3:9, 3:9] = x.sum(0)[3:9, 3:9]
check("window_degenerate", np.allclose(np.asarray(loc), refl, atol=1e-5))

# --- copy metadata correctness ----------------------------------------
x2 = np.random.randn(10, 8).astype(np.float32)
s2 = segment(x2, g)                       # pads 10 -> 12 along dim 0
c1 = copy(s2, dim=1)                      # re-segment along dim 1
check("copy_dim_roundtrip", np.allclose(gather(c1), x2))
check("copy_dim_metadata", c1.dim == 1 and c1.orig_len == 8)
cl = broadcast(x2, g)
c2 = copy(cl, policy=Policy.NATURAL)      # CLONE -> split must re-pad
check("copy_clone_split", np.allclose(gather(c2), x2) and c2.orig_len == 10)
sb = segment(np.random.randn(21, 3).astype(np.float32), g,
             policy=Policy.BLOCK, block=2)
c3 = copy(sb, policy=Policy.NATURAL)      # away from BLOCK: clean metadata
check("copy_unblock", c3.block is None and c3.orig_len == 21
      and c3.policy is Policy.NATURAL)
try:
    copy(s2, halo=1)
    check("copy_halo_validated", False)
except ValueError:
    check("copy_halo_validated", True)
"""

OVERLAP = """
from repro.core import DeviceGroup, Policy, segment, gather, overlap2d_map
g = DeviceGroup.all_devices((4,), ("data",))

for h in (1, 2):
    x = np.random.randn(16, 5).astype(np.float32)
    s = segment(x, g, policy=Policy.OVERLAP2D, halo=h)
    width = 2 * h + 1

    def stencil(e):
        r = e.shape[0] - 2 * h
        return sum(e[k:k + r] for k in range(width))

    out = overlap2d_map(s, stencil)
    xp = np.pad(x, ((h, h), (0, 0)))          # edge shards see zeros
    ref = sum(xp[k:k + 16] for k in range(width))
    check(f"overlap_h{h}", np.allclose(gather(out), ref, atol=1e-5))
"""

HIER = """
from repro.core import DeviceGroup, segment, all_reduce
g = DeviceGroup.all_devices((2, 2), ("pod", "data"))   # pod crosses DCN

# leading dim tiles by n_ici=2: staged reduce-scatter/psum/all-gather path
x = np.random.randn(6, 4, 5).astype(np.float32)
s = segment(x, g, mesh_axes=("pod", "data"))
out = all_reduce(s, hierarchical=True)
check("hier_tiled", np.allclose(np.asarray(out.data), x.sum(0), atol=1e-5))

# leading dim 3 does not tile: must fall back to the flat psum
x2 = np.random.randn(6, 3, 5).astype(np.float32)
s2 = segment(x2, g, mesh_axes=("pod", "data"))
out2 = all_reduce(s2, hierarchical=True)
check("hier_fallback", np.allclose(np.asarray(out2.data), x2.sum(0),
                                   atol=1e-5))
"""


def test_comm_verbs_4dev():
    run_with_devices(VERBS, ndev=4)


def test_overlap2d_halo_vs_numpy():
    run_with_devices(OVERLAP, ndev=4)


def test_hierarchical_psum_paths():
    run_with_devices(HIER, ndev=4)
