"""Shared kernel-registry harness (ISSUE 8 tentpole).

ONE parametrized suite replaces the per-family parity boilerplate:
``repro.kernels.registry`` auto-discovers every registered spec, and
each spec is exercised the same way —

  * pallas-vs-oracle parity on the spec's exemplar samples (interpret
    mode on CPU), to the spec's declared tolerance;
  * fallback-path equivalence: ``impl="auto"`` off-TPU must resolve to
    the spec's documented fallback and match the oracle;
  * shape/dtype contract: outputs keep the oracle's leaf shapes/dtypes;
  * dispatch/kernel block agreement (the ISSUE-8 ``bm=32`` satellite):
    the Pallas entry's default block kwargs equal the spec's
    ``default_block``, and the bespoke ``_on_tpu``/``_divisible``
    plumbing is actually gone from every family's ops module;
  * arbitrary-shape sweeps (deterministic grid always; hypothesis fuzz
    when installed): non-divisible row counts and 0-/1-row edges hit
    the documented fallback and still match the oracle;
  * per-spec properties (adjointness, epilogue consistency, block-shape
    invariance) and registry completeness;
  * autotuner mechanics: env pin -> pinned choice, forced sweep ->
    choice from the spec's space, PlanCache-backed determinism.

Adding a kernel family = registering a spec; it inherits all of this.
"""

import importlib
import inspect
import os
import pkgutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels
from repro.core.plan import PlanCache
from repro.kernels import registry

SPECS = registry.specs()
IDS = [s.id for s in SPECS]
CASES = [(s, i) for s in SPECS for i in range(s.nsamples)]
CASE_IDS = [f"{s.id}-{i}" for s, i in CASES]

# deterministic stand-in for the hypothesis sweep (hypothesis is an
# optional dev dep): divisible, non-divisible, 1-row and 0-row cases
SHAPE_GRID = [(0, 32), (1, 32), (1, 1), (7, 128), (32, 33),
              (33, 128), (70, 8), (96, 128)]


@pytest.fixture(autouse=True)
def _fresh_choices():
    registry.reset_choices()
    yield
    registry.reset_choices()


def _case(spec, i):
    out = spec.samples(i)
    args, kw, want = out[:3]
    tol = out[3] if len(out) > 3 else spec.tol
    return args, kw, want, tol


def _np(x):
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        return np.asarray(x.astype(jnp.complex64))
    return np.asarray(x.astype(jnp.float32))


def _assert_close(got, want, tol, where=""):
    gl, wl = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(gl) == len(wl), where
    for g, w in zip(gl, wl):
        assert jnp.shape(g) == jnp.shape(w), \
            f"{where}: shape {jnp.shape(g)} != {jnp.shape(w)}"
        np.testing.assert_allclose(_np(g), _np(w), rtol=10 * tol, atol=tol,
                                   err_msg=where)


# -- parity + fallback + shape/dtype contract -------------------------------

@pytest.mark.parametrize("spec,i", CASES, ids=CASE_IDS)
def test_pallas_parity(spec, i):
    """The Pallas kernel (interpret mode off-TPU) matches the jnp oracle
    on every exemplar, to the spec tolerance."""
    args, kw, want, tol = _case(spec, i)
    assert spec.supports(spec.default_block, *args, **kw), \
        "exemplar samples must be pallas-eligible"
    got = spec.dispatch(*args, impl="pallas", **kw)
    _assert_close(got, want, tol, f"{spec.id} sample {i} (pallas)")


@pytest.mark.parametrize("spec,i", CASES, ids=CASE_IDS)
def test_fallback_equivalence(spec, i):
    """``impl='auto'`` off-TPU resolves to the spec's documented
    fallback and is numerically equivalent to the oracle."""
    args, kw, want, tol = _case(spec, i)
    impl, block = spec.resolve("auto", None, *args, **kw)
    if not registry.on_tpu():
        assert impl == spec.fallback, \
            f"{spec.id}: auto off-TPU resolved to {impl}"
        assert block == spec.default_block
    got = spec.dispatch(*args, impl="auto", **kw)
    _assert_close(got, want, tol, f"{spec.id} sample {i} ({impl})")


@pytest.mark.parametrize("spec,i", CASES, ids=CASE_IDS)
def test_output_dtypes_match_oracle(spec, i):
    args, kw, want, _ = _case(spec, i)
    got = spec.dispatch(*args, impl="pallas", **kw)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert jnp.asarray(g).dtype == jnp.asarray(w).dtype, spec.id


# -- arbitrary shapes hit the fallback and stay correct ---------------------

SHAPE_SPECS = [s for s in SPECS if s.shape_case is not None]
SHAPE_CASES = [(s, m, y) for s in SHAPE_SPECS for (m, y) in SHAPE_GRID]


@pytest.mark.parametrize(
    "spec,m,y", SHAPE_CASES,
    ids=[f"{s.id}-{m}x{y}" for s, m, y in SHAPE_CASES])
def test_arbitrary_shapes_fallback_and_match(spec, m, y):
    """Non-divisible/0-/1-row operand shapes: ``auto`` must route to the
    documented fallback off-TPU (never trip a kernel assert) and match
    the oracle to spec tolerance."""
    case = spec.shape_case(m * 1000 + y, m, y)
    if case is None:
        return                       # the draw is meaningless for the family
    args, kw, want = case[:3]
    impl, block = spec.resolve("auto", None, *args, **kw)
    if not registry.on_tpu():
        assert impl == spec.fallback
    got = spec.dispatch(*args, impl="auto", **kw)
    _assert_close(got, want, case[3] if len(case) > 3 else spec.tol,
                  f"{spec.id} shape ({m},{y})")
    # explicit pallas on an unsupported shape degrades safely too
    if not spec.supports(spec.default_block, *args, **kw):
        got2 = spec.dispatch(*args, impl="pallas", **kw)
        _assert_close(got2, want, case[3] if len(case) > 3 else spec.tol,
                      f"{spec.id} shape ({m},{y}) pallas-degrade")


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.parametrize("spec", SHAPE_SPECS,
                             ids=[s.id for s in SHAPE_SPECS])
    @given(m=st.integers(0, 96), y=st.integers(1, 144),
           seed=st.integers(0, 3))
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    def test_hypothesis_shapes_fallback_and_match(spec, m, y, seed):
        case = spec.shape_case(seed, m, y)
        if case is None:
            return
        args, kw, want = case[:3]
        impl, _ = spec.resolve("auto", None, *args, **kw)
        if not registry.on_tpu():
            assert impl == spec.fallback
        got = spec.dispatch(*args, impl="auto", **kw)
        _assert_close(got, want, case[3] if len(case) > 3 else spec.tol,
                      f"{spec.id} hyp ({m},{y})")
except ImportError:                             # optional dev dependency
    pass


# -- per-spec properties (adjointness, epilogues, invariances) --------------

PROPS = [(s, j) for s in SPECS for j in range(len(s.properties))]


@pytest.mark.parametrize("spec,j", PROPS,
                         ids=[f"{s.id}-prop{j}" for s, j in PROPS])
def test_spec_properties(spec, j):
    spec.properties[j]()


def test_adjoint_pairs_linked():
    """Specs declaring ``adjoint_of`` point at a registered spec of the
    same family (the gridding degrid/grid pair; adjointness itself is a
    spec property)."""
    pairs = [s for s in SPECS if s.adjoint_of]
    assert pairs, "expected at least the gridding adjoint pair"
    for s in pairs:
        other = registry.get(s.adjoint_of)
        assert other.family == s.family


# -- single source of truth for block shapes (the bm=32 satellite) ----------

@pytest.mark.parametrize("spec", SPECS, ids=IDS)
def test_dispatch_and_kernel_agree_on_blocks(spec):
    """The Pallas entry's default block kwargs ARE the spec's
    ``default_block`` — dispatch eligibility and the kernel's internal
    divisibility assert can never drift apart again."""
    sig = inspect.signature(spec.pallas)
    for arg, val in zip(spec.block_args, spec.default_block):
        assert sig.parameters[arg].default == val, \
            f"{spec.id}: kernel default {arg}=" \
            f"{sig.parameters[arg].default} != spec {val}"
    assert spec.default_block in spec.block_space
    assert all(len(b) == len(spec.block_args) for b in spec.block_space)


@pytest.mark.parametrize("family", sorted({s.family for s in SPECS}))
def test_bespoke_dispatch_plumbing_deleted(family):
    """The hand-rolled per-family backend plumbing is gone: ops modules
    define no ``_on_tpu``/``_divisible``/``_split``/``_planes`` of their
    own — the registry helpers are the single copy."""
    mod = importlib.import_module(f"repro.kernels.{family}.ops")
    src = inspect.getsource(mod)
    for name in ("def _on_tpu", "def _divisible", "def _split",
                 "def _planes"):
        assert name not in src, f"{family}.ops still defines {name}"


# -- completeness + factory surface -----------------------------------------

def test_registry_covers_every_family():
    """Every ``kernels/`` subpackage registers at least one spec, and
    every spec's family is a real subpackage (auto-discovery is total)."""
    pkg_dir = os.path.dirname(repro.kernels.__file__)
    subpkgs = {m.name for m in pkgutil.iter_modules([pkg_dir]) if m.ispkg}
    families = {s.family for s in SPECS}
    assert families == subpkgs, (families, subpkgs)


def test_get_impl_factory():
    fn = registry.get_impl("cg_fused.xpby_dot", impl="jnp")
    args, kw, want, tol = _case(registry.get("cg_fused.xpby_dot"), 0)
    _assert_close(fn(*args, **kw), want, tol, "get_impl")
    with pytest.raises(KeyError):
        registry.get("no_such.spec")


# -- autotuner mechanics ----------------------------------------------------

def test_autotune_default_off_tpu(monkeypatch):
    """Without a pin or forced sweep, off-TPU resolution is the spec
    default (never a sweep of interpret-mode kernels), cached in the
    tune PlanCache with zero steady-state rebuilds."""
    monkeypatch.delenv(registry.PIN_ENV, raising=False)
    monkeypatch.delenv(registry.TUNE_ENV, raising=False)
    spec = registry.get("cg_fused.cg_update")
    args, kw, _, _ = _case(spec, 0)
    cache = PlanCache()
    b1 = registry.autotune(spec.id, sample=lambda: (args, kw),
                           token=("t", 32), cache=cache)
    b2 = registry.autotune(spec.id, sample=lambda: (args, kw),
                           token=("t", 32), cache=cache)
    assert b1 == b2 == spec.default_block
    assert cache.misses == 1 and cache.hits == 1
    assert registry.choices("cg_fused")[spec.id]["source"] == "default"


def test_autotune_env_pin(monkeypatch):
    """REPRO_KERNEL_BLOCKS pins both the autotuner and trace-time
    ``block=None`` resolution — the deterministic-CI switch."""
    spec = registry.get("cg_fused.cg_update")
    monkeypatch.setenv(registry.PIN_ENV, "cg_fused.cg_update=64")
    assert registry.pinned_block(spec) == (64,)
    assert spec.pick_block(None) == (64,)
    cache = PlanCache()
    b = registry.autotune(spec.id, token=("pin",), cache=cache)
    assert b == (64,)
    assert registry.choices("cg_fused")[spec.id] == \
        {"block": "64", "source": "pinned"}
    # the global pin form
    monkeypatch.setenv(registry.PIN_ENV, "default")
    assert registry.pinned_block(spec) == spec.default_block
    # pins are part of the tune key: no stale reuse across pin changes
    b2 = registry.autotune(spec.id, token=("pin",), cache=cache)
    assert b2 == spec.default_block and cache.misses == 2


def test_autotune_forced_sweep(monkeypatch):
    """REPRO_KERNEL_TUNE=1 forces a real sweep even off-TPU: the winner
    comes from the spec's block space and the timing table lands in the
    cached plan meta."""
    monkeypatch.delenv(registry.PIN_ENV, raising=False)
    monkeypatch.setenv(registry.TUNE_ENV, "1")
    spec = registry.get("masked_allreduce.masked_sum")
    args, kw, _, _ = _case(spec, 0)
    cache = PlanCache()
    b = registry.autotune(spec.id, sample=lambda: (args, kw),
                          token=("sweep",), cache=cache, iters=1)
    assert b in spec.block_space
    assert registry.choices()[spec.id]["source"] == "swept"
    key = ("kernel_tune", spec.id, jax.default_backend(), ("sweep",), None)
    plan = cache.get_or_build(key, lambda: pytest.fail("must be cached"))
    assert plan.meta["table"], "sweep must record per-candidate timings"
    # the swept winner becomes the trace-time choice and the token
    assert spec.pick_block(None) == b
    assert (spec.id, b) in registry.choices_token(("masked_allreduce",))
