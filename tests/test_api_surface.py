"""Public-API snapshot (ISSUE 2 CI satellite).

``repro.core.__all__`` and the Environment/Communicator verb surface are
the library's stable contract; any drift (a renamed verb, a changed
parameter, a new export) must show up as an explicit diff of the
snapshots below rather than silently changing downstream code.
Runs in-process on whatever device count the host has — it inspects
signatures only.
"""

import inspect

import repro.core as core
from repro.core import Communicator, Environment

EXPECTED_ALL = [
    "compat",
    "Environment", "Communicator",
    "DeviceGroup", "current_group", "HW", "DCN_AXES",
    "Policy", "SegmentedArray", "segment", "gather", "overlap2d_map",
    "broadcast", "scatter", "reduce", "all_reduce", "all_reduce_window",
    "vdot", "copy", "all_to_all", "reduce_scatter", "hierarchical_psum",
    "invoke_kernel", "invoke_kernel_all", "make_spmd", "PassThrough",
    "dev_rank",
    "fence", "barrier", "barrier_fence", "ordered",
]

# Every public Communicator method and its exact parameter list (the
# MPI-like verb set of paper §2.3 + p2p + container/launchers).
EXPECTED_COMMUNICATOR = {
    "container": ("self", "x", "policy", "dim", "block", "halo"),
    "bcast": ("self", "x"),
    "scatter": ("self", "x", "policy", "dim", "block", "halo"),
    "gather": ("self", "seg"),
    "allgather": ("self", "x", "dim", "axis"),
    "reduce": ("self", "seg", "op"),
    "allreduce": ("self", "x", "op", "hierarchical", "p2p", "axis"),
    "allreduce_window": ("self", "x", "window", "op", "axis", "reduce_dim",
                         "hierarchical", "window_axes", "p2p"),
    "allreduce_overlap": ("self", "x", "window", "op", "axis", "reduce_dim",
                          "window_axes", "extras", "compute", "p2p",
                          "chunks", "hierarchical"),
    "reduce_scatter": ("self", "seg", "op"),
    "alltoall": ("self", "seg", "new_dim"),
    "vdot": ("self", "x", "y", "axis", "policies"),
    "copy": ("self", "seg", "policy", "kw"),
    "send_recv": ("self", "x", "perm", "axis"),
    "shift": ("self", "x", "offset", "wrap", "axis"),
    "barrier": ("self",),
    "fence": ("self", "arrays"),
    "barrier_fence": ("self", "arrays"),
    "invoke": ("self", "fn", "args", "rank", "kw"),
    "invoke_all": ("self", "fn", "args", "kw"),
    "spmd": ("self", "fn", "in_policies", "out_policies", "check_vma",
             "donate_argnums", "jit"),
}

EXPECTED_ENVIRONMENT = {
    "group": ("self", "shape", "axes"),
    "subgroup": ("self", "n", "axes"),
    "from_mesh": ("self", "mesh"),
    "survivor": ("self", "comm", "lost"),
}

# Old free function -> its replacement (the deprecation/migration table).
EXPECTED_DEPRECATIONS = {
    "current_group": "an explicit Environment()/Communicator",
    "segment": "Communicator.container",
    "gather": "Communicator.gather / SegmentedArray.gather",
    "overlap2d_map": "SegmentedArray.halo_exchange",
    "broadcast": "Communicator.bcast",
    "scatter": "Communicator.scatter",
    "reduce": "Communicator.reduce",
    "all_reduce": "Communicator.allreduce",
    "all_reduce_window": "Communicator.allreduce_window",
    "vdot": "Communicator.vdot",
    "copy": "Communicator.copy / SegmentedArray.to",
    "all_to_all": "Communicator.alltoall",
    "reduce_scatter": "Communicator.reduce_scatter",
    "invoke_kernel": "Communicator.invoke",
    "invoke_kernel_all": "Communicator.invoke_all",
    "make_spmd": "Communicator.spmd",
    "barrier": "Communicator.barrier",
    "barrier_fence": "Communicator.barrier_fence",
}


def _param_names(fn):
    return tuple(inspect.signature(fn).parameters)


def _public_methods(cls):
    return {n for n, m in inspect.getmembers(cls, inspect.isfunction)
            if not n.startswith("_")}


def test_core_all_snapshot():
    assert list(core.__all__) == EXPECTED_ALL
    for name in EXPECTED_ALL:
        assert hasattr(core, name), f"__all__ names missing attr {name}"


def test_communicator_method_surface():
    assert _public_methods(Communicator) == set(EXPECTED_COMMUNICATOR)
    for name, params in EXPECTED_COMMUNICATOR.items():
        got = _param_names(getattr(Communicator, name))
        assert got == params, f"Communicator.{name}: {got} != {params}"


def test_environment_method_surface():
    assert _public_methods(Environment) == set(EXPECTED_ENVIRONMENT)
    for name, params in EXPECTED_ENVIRONMENT.items():
        got = _param_names(getattr(Environment, name))
        assert got == params, f"Environment.{name}: {got} != {params}"


def test_deprecation_table():
    for name, repl in EXPECTED_DEPRECATIONS.items():
        fn = getattr(core, name)
        assert getattr(fn, "__deprecated__", None) == repl, name


def test_segmented_array_fluent_surface():
    from repro.core import SegmentedArray
    fluent = {"allreduce", "allreduce_window", "allgather", "alltoall",
              "reduce", "reduce_scatter", "gather", "to", "vdot", "shift",
              "send_recv", "halo_exchange", "invoke", "astype", "seg_len",
              "segments", "with_data"}
    assert fluent <= _public_methods(SegmentedArray)


# -- the repro.lib ported-library surface (paper §4) ------------------------

EXPECTED_LIB_ALL = ["blas", "fft", "gridding", "plan",
                    "Plan", "PlanCache", "default_cache", "plan_stats"]

def test_lib_all_snapshot():
    import repro.lib as lib
    assert list(lib.__all__) == EXPECTED_LIB_ALL
    for name in EXPECTED_LIB_ALL:
        assert hasattr(lib, name)


def test_lib_ports_expose_plan_builders():
    """Every ported library exposes its plan constructor(s) and the ops
    that go through the cache (the Plan/PlanCache acceptance contract)."""
    from repro.lib import blas, fft, gridding
    for name in ("plan_fft2", "plan_fft2_batched", "fft2", "fft2_batched"):
        assert callable(getattr(fft, name)), name
    for name in ("axpy", "dot", "norm2", "gemm_batched", "gemm_ksplit",
                 "axpy_dot", "axpy_norm2", "dot_allreduce",
                 "cg_update", "xpby_dot", "tree_axpy", "tree_vdot"):
        assert callable(getattr(blas, name)), name
    for name in ("plan_gridding", "radial_trajectory", "ramlak_dcf_radial"):
        assert callable(getattr(gridding, name)), name


def test_core_fft_blas_shims_removed():
    """The repro.core.fft / repro.core.blas DeprecationWarning shims were
    removed on schedule (README PR 4); repro.lib is the only surface."""
    import importlib
    for mod in ("repro.core.fft", "repro.core.blas"):
        try:
            importlib.import_module(mod)
        except ModuleNotFoundError:
            continue
        raise AssertionError(f"{mod} should have been removed")
    assert not hasattr(core, "fft") and not hasattr(core, "blas")


# -- the repro.bench benchmark-subsystem surface (ISSUE 4) ------------------

EXPECTED_BENCH_ALL = [
    "artifact", "compare", "harness", "models", "registry",
    "SCHEMA_VERSION", "ArtifactError", "load_artifact", "make_artifact",
    "run_key", "validate_artifact", "write_artifact",
    "Comparison", "compare_artifacts",
    "BenchContext", "Timing", "measure",
    "Scenario", "scenario", "scenarios",
]

# the harness/compare contracts scenario authors and CI scripts rely on
EXPECTED_BENCH_SIGNATURES = {
    "measure": ("fn", "args", "warmup", "iters", "cache", "kw"),
    "compare_artifacts": ("base", "new", "threshold_pct", "min_ms"),
    "make_artifact": ("runs", "sha", "host", "calibration_ms"),
    "scenario": ("figure", "name", "sizes", "devices"),
}

# every artifact run row must keep exactly these required fields (the
# compare tool and CI gate key off them)
EXPECTED_ARTIFACT_REQUIRED = ["scenario", "figure", "devices", "size",
                              "wall_ms", "compile_ms", "steady_ms"]


def test_bench_all_snapshot():
    import repro.bench as bench
    assert list(bench.__all__) == EXPECTED_BENCH_ALL
    for name in EXPECTED_BENCH_ALL:
        assert hasattr(bench, name), f"__all__ names missing attr {name}"


def test_bench_signatures():
    import repro.bench as bench
    for name, params in EXPECTED_BENCH_SIGNATURES.items():
        got = _param_names(getattr(bench, name))
        assert got == params, f"repro.bench.{name}: {got} != {params}"


def test_bench_artifact_schema_fields():
    from repro.bench.artifact import REQUIRED_FIELDS, SCHEMA_VERSION
    assert SCHEMA_VERSION == 1
    assert list(REQUIRED_FIELDS) == EXPECTED_ARTIFACT_REQUIRED


def test_bench_timing_fields():
    import dataclasses

    from repro.bench import Timing
    assert [f.name for f in dataclasses.fields(Timing)] == [
        "wall_ms", "compile_ms", "steady_ms", "p50_ms", "p95_ms",
        "jitter_ms", "iters", "warmup", "plan_cache"]


# -- the repro.serve serving-layer surface (ISSUE 7) ------------------------

EXPECTED_SERVE_ALL = [
    "Engine", "Request", "make_serve_steps",
    "AdmissionError", "Rejected", "ServeConfig", "Session",
    "StreamScheduler", "Workload",
    "LMDecodeWorkload", "NlinvStreamWorkload", "SlotPool",
    "stack_carries", "unstack_carry",
]

# the scheduler contract both workloads (and any future one) code against
EXPECTED_SCHEDULER = {
    "open": ("self", "client", "meta"),
    "submit": ("self", "session", "item"),
    "tick": ("self",),
    "drain": ("self",),
    "close": ("self", "session"),
    "report": ("self",),
}

EXPECTED_WORKLOAD_HOOKS = {
    "open_session": ("self", "session"),
    "enqueue": ("self", "session", "item"),
    "step": ("self", "batch", "width"),
    "close_session": ("self", "session"),
    "set_level": ("self", "level"),
    "counters": ("self",),
}


def test_serve_all_snapshot():
    import repro.serve as serve
    assert list(serve.__all__) == EXPECTED_SERVE_ALL
    for name in EXPECTED_SERVE_ALL:
        assert hasattr(serve, name), f"__all__ names missing attr {name}"


def test_serve_scheduler_surface():
    from repro.serve import StreamScheduler, Workload
    assert _public_methods(StreamScheduler) == set(EXPECTED_SCHEDULER)
    for name, params in EXPECTED_SCHEDULER.items():
        got = _param_names(getattr(StreamScheduler, name))
        assert got == params, f"StreamScheduler.{name}: {got} != {params}"
    for name, params in EXPECTED_WORKLOAD_HOOKS.items():
        got = _param_names(getattr(Workload, name))
        assert got == params, f"Workload.{name}: {got} != {params}"


# -- the repro.task task-graph surface (ISSUE 9) ----------------------------

EXPECTED_TASK_ALL = [
    "Task", "TaskGraph", "TaskError", "CycleError", "CrossGroupError",
    "placement_token",
    "Executor", "Pipeline", "TaskRun",
]

# the contract docs/task_graph.md codes against
EXPECTED_TASK_SIGNATURES = {
    "TaskGraph.add": ("self", "name", "fn", "inputs", "outputs", "group",
                      "kind"),
    "TaskGraph.copy": ("self", "name", "fn", "inputs", "outputs", "group"),
    "TaskGraph.validate": ("self", "feeds"),
    "TaskGraph.toposort": ("self", "feeds", "_validate"),
    "Executor.run": ("self", "graph", "feeds", "outputs", "fence"),
    "Pipeline.push": ("self", "graph", "feeds", "tag", "outputs"),
    "Pipeline.flush": ("self",),
}


def test_task_all_snapshot():
    import repro.task as task
    assert list(task.__all__) == EXPECTED_TASK_ALL
    for name in EXPECTED_TASK_ALL:
        assert hasattr(task, name), f"__all__ names missing attr {name}"


def test_task_signatures():
    import repro.task as task
    for path, params in EXPECTED_TASK_SIGNATURES.items():
        cls, meth = path.split(".")
        got = _param_names(getattr(getattr(task, cls), meth))
        assert got == params, f"repro.task.{path}: {got} != {params}"


def test_task_error_hierarchy():
    from repro.task import CrossGroupError, CycleError, TaskError
    assert issubclass(CycleError, TaskError)
    assert issubclass(CrossGroupError, TaskError)
    assert issubclass(TaskError, RuntimeError)


def test_stream_engines_share_contract():
    """FramePipeline is a drop-in for FrameStream: same run signature,
    same LatencyReport artifact."""
    from repro.nlinv.stream import FramePipeline, FrameStream
    assert _param_names(FramePipeline.run) == _param_names(FrameStream.run)


# -- the repro.kernels registry surface (ISSUE 8) ---------------------------

EXPECTED_KERNELSPEC_FIELDS = [
    "family", "name", "pallas", "ref", "fallback",
    "block_args", "default_block", "block_space", "supports", "tol",
    "layout", "samples", "nsamples", "shape_case", "properties",
    "adjoint_of", "dispatch",
]

EXPECTED_REGISTRY_SIGNATURES = {
    "register": ("spec",),
    "get": ("spec_id",),
    "specs": ("family",),
    "get_impl": ("spec_id", "impl"),
    "autotune": ("spec_id", "sample", "token", "cache", "iters"),
    "choices": ("family",),
    "choices_token": ("families",),
}

# one spec per kernel op: the §4 "porting a kernel is declaring a spec"
# contract — a new family that bypasses the registry fails this snapshot
EXPECTED_SPEC_IDS = [
    "cg_fused.cg_update", "cg_fused.xpby_dot",
    "coil_mult.coil_adjoint", "coil_mult.coil_forward",
    "coil_mult.coil_lincomb", "coil_mult.plane_mult",
    "flash_attention.flash_attention",
    "gridding.degrid", "gridding.grid_adjoint",
    "masked_allreduce.masked_sum",
    "mlstm.mlstm_scan",
    "rg_lru.rg_lru_scan",
]


def test_kernel_registry_surface():
    import dataclasses

    from repro.kernels import registry
    assert [f.name for f in dataclasses.fields(registry.KernelSpec)] == \
        EXPECTED_KERNELSPEC_FIELDS
    for name, params in EXPECTED_REGISTRY_SIGNATURES.items():
        got = _param_names(getattr(registry, name))
        assert got == params, f"registry.{name}: {got} != {params}"
    assert registry.PIN_ENV == "REPRO_KERNEL_BLOCKS"
    assert registry.TUNE_ENV == "REPRO_KERNEL_TUNE"


def test_kernel_registry_spec_ids():
    from repro.kernels import registry
    assert sorted(s.id for s in registry.specs()) == EXPECTED_SPEC_IDS


def test_serve_unified_scheduler():
    """Acceptance row: LM decode and NLINV streaming both run through
    the ONE StreamScheduler — the workloads are Workload subclasses and
    Engine drives the shared scheduler, with no bespoke decode loop."""
    from repro.serve import (Engine, LMDecodeWorkload, NlinvStreamWorkload,
                             Workload)
    assert issubclass(NlinvStreamWorkload, Workload)
    assert issubclass(LMDecodeWorkload, Workload)
    src = inspect.getsource(Engine)
    assert "StreamScheduler" in src and "LMDecodeWorkload" in src
    # the old bespoke driver internals are gone from the front door
    assert not hasattr(Engine, "_admit")
    assert "def _admit" not in src and "self.active" not in src
