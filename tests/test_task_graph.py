"""repro.task executor edge cases + pipelined parity (ISSUE 9).

The pure-graph checks (empty/single/cycle/race) run in-process on 1
device; the parity checks run the SAME movie through the task-graph
``FramePipeline`` and the two-stage ``FrameStream`` and demand the
images agree to 1e-5 on 1 and 4 devices (the executor must change the
schedule, never the math).
"""

import jax.numpy as jnp
import pytest
from helpers import run_with_devices

from repro.core import Environment, Policy
from repro.task import (CrossGroupError, CycleError, Executor, Pipeline,
                        TaskError, TaskGraph)


# -- graph construction / validation ----------------------------------------

def test_empty_graph():
    g = TaskGraph()
    assert len(g) == 0 and g.toposort() == ()
    assert Executor().run(g) == {}


def test_single_task_graph():
    g = TaskGraph()
    g.add("one", lambda: 41, outputs=("x",))
    ex = Executor()
    assert ex.run(g) == {"x": 41}
    assert [r.name for r in ex.trace] == ["one"]


def test_cycle_detection_raises():
    g = TaskGraph()
    g.add("a", lambda x: x, inputs=("b_out",), outputs=("a_out",))
    g.add("b", lambda x: x, inputs=("a_out",), outputs=("b_out",))
    with pytest.raises(CycleError, match="dependency cycle: a -> b -> a"):
        g.toposort()
    # the executor refuses before running anything
    with pytest.raises(CycleError):
        Executor().run(g)


def test_duplicate_producer_and_name_raise():
    g = TaskGraph()
    g.add("a", lambda: 1, outputs=("x",))
    with pytest.raises(TaskError, match="duplicate task name"):
        g.add("a", lambda: 2, outputs=("y",))
    with pytest.raises(TaskError, match="already produced"):
        g.add("b", lambda: 2, outputs=("x",))
    # failed adds are no-ops: the graph still has exactly one task
    assert len(g) == 1 and g.values() == ("x",)


def test_missing_feed_raises():
    g = TaskGraph()
    g.add("a", lambda x: x, inputs=("nowhere",), outputs=("y",))
    with pytest.raises(TaskError, match="no task produces and no feed"):
        Executor().run(g)


def test_output_arity_mismatch_raises():
    g = TaskGraph()
    g.add("a", lambda: 1, outputs=("x", "y"))
    with pytest.raises(TypeError, match="declares 2 outputs"):
        Executor().run(g)


# -- placement / cross-group races ------------------------------------------

def _two_groups():
    """Two 1-device groups with different named axes: same devices, but
    distinct placement identities (different group tokens)."""
    env = Environment()
    return env.subgroup(1, ("ga",)), env.subgroup(1, ("gb",))


def test_cross_group_race_raises():
    ga, gb = _two_groups()
    g = TaskGraph()
    g.add("produce", lambda: jnp.ones(4), outputs=("v",), group=ga)
    g.add("consume", lambda v: v + 1, inputs=("v",), outputs=("w",),
          group=gb)
    with pytest.raises(CrossGroupError, match="explicit copy/verb edge"):
        g.validate()


def test_cross_group_copy_edge_passes():
    ga, gb = _two_groups()
    g = TaskGraph()
    g.add("produce", lambda: jnp.ones(4), outputs=("v",), group=ga)
    g.copy("move", lambda v: v, inputs=("v",), outputs=("v_b",), group=gb)
    g.add("consume", lambda v: v + 1, inputs=("v_b",), outputs=("w",),
          group=gb)
    g.validate()
    out = Executor().run(g, outputs=("w",))
    assert float(out["w"][0]) == 2.0


def test_placement_single_device_group():
    """A graph placed entirely on a 1-device group runs device work
    through the group's own SPMD launcher."""
    comm = Environment().subgroup(1)
    fn = comm.spmd(lambda x: 2.0 * x, in_policies=(Policy.CLONE,),
                   out_policies=Policy.CLONE)
    g = TaskGraph()
    g.copy("up", lambda: jnp.arange(4.0), outputs=("x",), group=comm)
    g.add("scale", fn, inputs=("x",), outputs=("y",), group=comm)
    out = Executor().run(g)
    assert jnp.allclose(out["y"], 2.0 * jnp.arange(4.0))


# -- the rolling pipeline window --------------------------------------------

def test_pipeline_window_and_flush_order():
    pipe = Pipeline(inflight=2)
    g = TaskGraph()
    g.add("inc", lambda x: x + 1, inputs=("x",), outputs=("y",))
    vals, done = pipe.push(g, {"x": 0}, tag=0)
    assert done == [] and len(pipe) == 1
    chained = vals
    retired = []
    for f in range(1, 4):
        chained, done = pipe.push(g, {"x": chained["y"]}, tag=f)
        retired += done
    # frames retire oldest-first as they leave the inflight window
    assert [tag for tag, _ in retired] == [0, 1]
    assert [tag for tag, _ in pipe.flush()] == [2, 3]
    assert len(pipe) == 0
    assert chained["y"] == 4


def test_pipeline_rejects_empty_window():
    with pytest.raises(ValueError, match="inflight >= 1"):
        Pipeline(inflight=0)


# -- pipelined vs sequential parity -----------------------------------------

PARITY = """
from repro.core import DeviceGroup
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor
from repro.nlinv.stream import FramePipeline, FrameStream

d = phantom.make_dataset(n=%(n)d, ncoils=%(ncoils)d, nspokes=7,
                         frames=5, seed=11)
comm = DeviceGroup.all_devices((%(ndev)d,), ("data",)) \
    if %(ndev)d > 1 else None
rec = Reconstructor(comm, newton=3, cg_iters=6, channel_sum="crop")
seq, rep_s = FrameStream(rec, damping=0.9).run(d["y"], d["masks"], d["fov"])
pipe, rep_p = FramePipeline(rec, damping=0.9, inflight=3).run(
    d["y"], d["masks"], d["fov"])
err = float(jnp.max(jnp.abs(pipe - seq))) / float(jnp.max(jnp.abs(seq)))
print("REL_ERR", err)
check("parity_1e-5", err <= 1e-5)
check("report_frames", len(rep_p.frame_ms) == 5)
check("steady_builds_zero", sum(rep_p.frame_plan_builds[1:]) == 0)
"""


def test_pipelined_parity_1dev():
    run_with_devices(PARITY % dict(n=16, ncoils=2, ndev=1), ndev=1)


def test_pipelined_parity_4dev():
    run_with_devices(PARITY % dict(n=24, ncoils=4, ndev=4), ndev=4)
