"""Substrate tests: optimizer/trainer convergence, serving engine,
data pipeline determinism, checkpoint atomic/round-trip, fault tolerance,
gradient compression."""

import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import TokenPipeline
from repro.models import transformer
from repro.train import (adamw_init, adamw_update, make_train_state,
                         make_train_step, warmup_cosine)
from repro.train.grad_compress import compressed_psum, init_error_state


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(params, g, state, 0.05,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 0.11
    assert float(lr(100)) < float(lr(50)) < float(lr(11))


def test_train_loop_loss_decreases():
    """qwen3-smoke on the Markov pipeline: loss must drop (integration)."""
    cfg = dataclasses.replace(get_smoke("qwen3-0.6b"),
                              compute_dtype="float32")
    from repro.core import compat
    mesh = compat.make_mesh((1,), ("data",))
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step_fn, build = make_train_step(cfg, mesh, base_lr=1e-2, warmup=5,
                                     total=120, remat=False, donate=False)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq=32, seed=0)
    losses = []
    jstep = jax.jit(step_fn)
    with mesh:
        for i in range(60):
            tok, lab = pipe.batch_at(i)
            state, metrics = jstep(state, jnp.asarray(tok),
                                   jnp.asarray(lab), None)
            losses.append(float(metrics["loss"]))
    # steady descent from ln(256)=5.55 toward the ln(8)=2.08 entropy floor
    assert losses[-1] < losses[0] - 1.0, losses[::10]
    assert losses[-1] < min(losses[:10]), losses[::10]


def test_microbatch_accumulation_matches_full_batch():
    cfg = dataclasses.replace(get_smoke("llama3.2-3b"),
                              compute_dtype="float32")
    from repro.core import compat
    mesh = compat.make_mesh((1,), ("data",))
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    lab = jnp.roll(tok, -1, 1)
    s0 = make_train_state(cfg, jax.random.PRNGKey(0))
    full, _ = make_train_step(cfg, mesh, microbatches=1, remat=False,
                              donate=False)
    micro, _ = make_train_step(cfg, mesh, microbatches=4, remat=False,
                               donate=False)
    with mesh:
        s1, m1 = jax.jit(full)(s0, tok, lab, None)
        s2, m2 = jax.jit(micro)(s0, tok, lab, None)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 1e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5


def test_serving_engine_continuous_batching():
    from repro.serve import Engine
    cfg = dataclasses.replace(get_smoke("qwen3-0.6b"),
                              compute_dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch=2, max_len=64)
    rids = [eng.submit([1, 2, 3], max_new=5), eng.submit([4, 5], max_new=4),
            eng.submit([6], max_new=3)]
    done = eng.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert [len(r.out) for r in sorted(done, key=lambda r: r.rid)] == [5, 4, 3]
    # determinism: greedy decode reproduces
    eng2 = Engine(cfg, params, batch=2, max_len=64)
    for r in sorted(done, key=lambda r: r.rid):
        eng2.submit(r.prompt, max_new=r.max_new)
    done2 = eng2.run()
    for a, b in zip(sorted(done, key=lambda r: r.rid),
                    sorted(done2, key=lambda r: r.rid)):
        assert a.out == b.out
    # rids stay unique after a drain: a later submit must not collide
    # with an already-completed request's id
    late = eng.submit([7, 8], max_new=2)
    assert late not in rids
    (r,) = eng.run()
    assert r.rid == late and len(r.out) == 2


def test_pipeline_determinism_and_structure():
    p1 = TokenPipeline(vocab=64, batch=4, seq=16, seed=3)
    p2 = TokenPipeline(vocab=64, batch=4, seq=16, seed=3)
    t1, l1 = p1.batch_at(7)
    t2, l2 = p2.batch_at(7)
    assert np.array_equal(t1, t2) and np.array_equal(l1, l2)
    assert np.array_equal(t1[:, 1:], l1[:, :-1])
    # host sharding: different hosts, different data
    ph = TokenPipeline(vocab=64, batch=4, seq=16, seed=3, n_hosts=2,
                       host_id=1)
    th, _ = ph.batch_at(7)
    assert not np.array_equal(t1, th)
    # resumability
    p1.restore({"step": 5})
    a = next(p1)
    assert np.array_equal(a[0], p2.batch_at(5)[0])


def test_checkpoint_roundtrip_and_keep(tmp_path):
    from repro.ckpt import latest_step, list_steps, restore, save
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    for s in (1, 5, 9, 13):
        save(tmp_path, s, tree, keep=2)
    assert list_steps(tmp_path) == [9, 13]
    got, step = restore(tmp_path, tree)
    assert step == 13
    np.testing.assert_array_equal(got["a"], np.asarray(tree["a"]))
    np.testing.assert_array_equal(got["nested"]["b"],
                                  np.asarray(tree["nested"]["b"]))


def test_checkpoint_async(tmp_path):
    from repro.ckpt import restore, save
    tree = {"w": jnp.full((8, 8), 3.0)}
    t = save(tmp_path, 2, tree, blocking=False)
    t.join()
    got, _ = restore(tmp_path, tree)
    np.testing.assert_array_equal(got["w"], 3.0 * np.ones((8, 8)))


def test_restart_policy_resumes(tmp_path):
    from repro.ckpt import latest_step, restore, save
    from repro.ft import RestartPolicy, run_with_restarts
    crashes = {"n": 0}

    def loop(start):
        step = latest_step(tmp_path) or 0
        state = restore(tmp_path, {"x": jnp.zeros(())})[0] \
            if step else {"x": np.zeros(())}
        while step < 10:
            step += 1
            state = {"x": state["x"] + 1}
            save(tmp_path, step, state, keep=1)
            if step == 4 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("simulated node failure")
        return step

    final = run_with_restarts(loop, policy=RestartPolicy(max_restarts=2))
    assert final == 10
    got, s = restore(tmp_path, {"x": jnp.zeros(())})
    assert s == 10 and float(got["x"]) == 10.0   # no lost/duplicated work


def test_straggler_watchdog():
    from repro.ft import StragglerWatchdog
    w = StragglerWatchdog(threshold=2.0)
    for _ in range(20):
        assert not w.record(1.0)
    assert w.record(5.0)          # 5x median -> flagged
    assert not w.record(1.1)


def test_compressed_psum_single_device_accuracy():
    """On a 1-device mesh the compressed psum must equal the plain value
    within int8 quantization error, and error feedback must push the
    *accumulated* estimate toward exact."""
    from repro.core import compat
    mesh = compat.make_mesh((1,), ("d",))
    from jax.sharding import PartitionSpec as P
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01

    def run(gg, err):
        return compressed_psum(gg, "d", err)

    from repro.core.compat import shard_map
    f = shard_map(run, mesh=mesh, in_specs=(P(), P()),
                  out_specs=(P(), P()))
    out, err = f(g, jnp.zeros_like(g))
    q_err = float(jnp.abs(out - g).max())
    assert q_err < 0.01 * 2 / 127 + 1e-6        # block absmax / 127
    # error feedback: sum of two steps of the SAME gradient ~ 2g exactly
    out2, _ = f(g, err)
    total_err = float(jnp.abs((out + out2) - 2 * g).max())
    assert total_err < q_err * 1.01
