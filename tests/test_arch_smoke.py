"""Per-architecture smoke tests on reduced configs (assignment item f).

For every assigned arch: one forward/train step on CPU asserting output
shapes + no NaNs, plus the strongest cheap correctness check we have —
prefill+decode must reproduce the full-forward logits position by
position (exercises caches, rolling windows, recurrent states, MLA
latents and cross-attention end to end).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import frontends, transformer


def _f32(cfg):
    return dataclasses.replace(cfg, compute_dtype="float32")


def _inputs(cfg, B, S, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    enc = frontends.synthetic_frontend(cfg, B, ks[1])
    return tokens, enc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = _f32(get_smoke(arch))
    B, S = 2, 16
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    tokens, enc = _inputs(cfg, B, S)
    logits, _, aux = transformer.apply(cfg, params, tokens, enc=enc,
                                       mode="train")
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss(arch):
    cfg = _f32(get_smoke(arch))
    B, S = 2, 16
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    tokens, enc = _inputs(cfg, B, S)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, _, aux = transformer.apply(cfg, p, tokens, enc=enc,
                                           mode="train")
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
        return nll + 0.01 * aux

    l0, g = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, gg: p - 0.5 / max(float(gnorm), 1.0) * gg,
                           params, g)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), f"{arch}: loss did not decrease"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = _f32(get_smoke(arch))
    if cfg.n_experts:
        # top-k selection is discontinuous: with random (near-tied) routers,
        # fp accumulation-order noise across seq lengths flips experts.
        # Route to ALL experts here so the consistency check is exact while
        # still exercising dispatch/combine + caches (see test_moe.py for
        # dispatch correctness under real top-k).
        cfg = dataclasses.replace(cfg, top_k=cfg.n_experts,
                                  capacity_factor=1.0)
    B, S, pre = 1, 12, 6
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    tokens, enc = _inputs(cfg, B, S, seed=1)

    full_logits, _, _ = transformer.apply(cfg, params, tokens, enc=enc,
                                          mode="train")

    cache = transformer.init_cache(cfg, B, S, cfg.cdtype)
    pl, cache, _ = transformer.apply(cfg, params, tokens[:, :pre], enc=enc,
                                     mode="prefill", pos=0, cache=cache)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(full_logits[:, :pre]),
                               atol=2e-3, rtol=2e-3,
                               err_msg=f"{arch}: prefill != forward")

    for t in range(pre, S):
        dl, cache, _ = transformer.apply(cfg, params, tokens[:, t:t + 1],
                                         enc=None, mode="decode", pos=t,
                                         cache=cache)
        np.testing.assert_allclose(
            np.asarray(dl[:, 0]), np.asarray(full_logits[:, t]),
            atol=5e-3, rtol=5e-3, err_msg=f"{arch}: decode@{t} != forward")


def test_layer_grouping_covers_all_layers():
    for arch in ARCH_IDS:
        cfg = get_smoke(arch)
        groups = transformer.layer_groups(cfg)
        n = sum(len(u) * r for u, r in groups)
        assert n == cfg.n_layers, (arch, groups)
        full = get_smoke(arch)
        assert len(full.layer_kinds()) == full.n_layers


def test_param_counts_full_configs():
    """Full configs land in the advertised parameter band."""
    from repro.configs import get_config
    expect = {
        "xlstm-350m": (0.25e9, 0.60e9),
        "minicpm3-4b": (3.0e9, 5.0e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "gemma2-27b": (24e9, 30e9),
        "llama3.2-3b": (2.8e9, 4.0e9),
        "recurrentgemma-2b": (2.0e9, 3.2e9),
        "llama-3.2-vision-11b": (8.5e9, 12e9),
        "granite-moe-3b-a800m": (2.0e9, 4.0e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "whisper-tiny": (25e6, 80e6),
    }
    for arch, (lo, hi) in expect.items():
        n = transformer.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
