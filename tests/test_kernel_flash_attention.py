"""Flash-attention decode path vs the pure-jnp oracle.

Kernel-vs-oracle parity (causal/GQA/MQA, window, softcap, kv_len, bf16,
chunked fallback, block invariance) lives in the shared registry harness
(``tests/test_kernel_registry.py``, ISSUE 8); this file keeps the
decode_attention entry point — a separate single-row kernel with a
per-batch kv_len vector the generic harness can't express.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import attention_ref, decode_attention


def rand(shape, dtype, key):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def make_qkv(B, Hq, Hkv, S, T, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (rand((B, Hq, S, D), dtype, ks[0]),
            rand((B, Hkv, T, D), dtype, ks[1]),
            rand((B, Hkv, T, D), dtype, ks[2]))


def test_decode_matches_ref_last_row():
    B, Hq, Hkv, T, D = 2, 4, 2, 64, 32
    q, k, v = make_qkv(B, Hq, Hkv, 1, T, D, jnp.float32, seed=2)
    kv_len = jnp.array([40, 64])
    got = decode_attention(q, k, v, kv_len=kv_len)
    for b in range(B):
        want = attention_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                             causal=False, kv_len=int(kv_len[b]))
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want[0]),
                                   atol=2e-3, rtol=2e-3)
