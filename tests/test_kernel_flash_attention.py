"""Flash-attention kernel vs pure-jnp oracle: shape/dtype/flag sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (attention_ref, chunked_attention,
                                           decode_attention,
                                           flash_attention_pallas)


def rand(shape, dtype, key):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def make_qkv(B, Hq, Hkv, S, T, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (rand((B, Hq, S, D), dtype, ks[0]),
            rand((B, Hkv, T, D), dtype, ks[1]),
            rand((B, Hkv, T, D), dtype, ks[2]))


TOL = {jnp.float32: 2e-3, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,T,D", [
    (1, 2, 2, 128, 128, 64),      # MHA square
    (2, 4, 2, 128, 256, 64),      # GQA, T > S
    (1, 8, 1, 256, 256, 128),     # MQA
])
def test_pallas_matches_ref_causal(B, Hq, Hkv, S, T, D, dtype):
    q, k, v = make_qkv(B, Hq, Hkv, S, T, D, dtype)
    off = T - S
    got = flash_attention_pallas(q, k, v, causal=True, q_offset=off,
                                 bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 30.0),
                                            (96, 50.0)])
def test_pallas_window_softcap(window, softcap):
    q, k, v = make_qkv(1, 4, 4, 256, 256, 64, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 softcap=softcap, bq=64, bk=64,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=True, window=window,
                         softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_pallas_kv_len_padding():
    q, k, v = make_qkv(1, 2, 2, 128, 256, 64, jnp.float32)
    got = flash_attention_pallas(q, k, v, kv_len=200, causal=False,
                                 bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v, kv_len=200, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 128, None), (False, None, 20.0),
])
def test_chunked_matches_ref(dtype, causal, window, softcap):
    q, k, v = make_qkv(2, 4, 2, 96, 160, 32, dtype, seed=1)  # ragged T
    got = chunked_attention(q, k, v, causal=causal, window=window,
                            softcap=softcap, q_offset=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal, window=window,
                         softcap=softcap, q_offset=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_matches_ref_last_row():
    B, Hq, Hkv, T, D = 2, 4, 2, 64, 32
    q, k, v = make_qkv(B, Hq, Hkv, 1, T, D, jnp.float32, seed=2)
    kv_len = jnp.array([40, 64])
    got = decode_attention(q, k, v, kv_len=kv_len)
    for b in range(B):
        want = attention_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                             causal=False, kv_len=int(kv_len[b]))
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want[0]),
                                   atol=2e-3, rtol=2e-3)


def test_block_size_invariance():
    q, k, v = make_qkv(1, 2, 1, 256, 256, 64, jnp.float32, seed=3)
    a = flash_attention_pallas(q, k, v, causal=True, bq=128, bk=64,
                               interpret=True)
    b = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=128,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               rtol=1e-5)
