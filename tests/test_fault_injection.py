"""Fault-tolerant serving (ISSUE 10): deterministic fault injection,
client quarantine, task retries, deadline degradation, pipeline drain,
and elastic remesh.

In-process tests cover the injector's determinism contract and the
host-side policies (retry envelope, tick requeue, degradation ladder)
with stub workloads; the subprocess tests run the real NLINV serving
path under injection at 1/2/4 simulated devices and assert the blast
radius: the faulted client is quarantined, every other client's results
are IDENTICAL to an uninjected run, the pipeline drains past a poisoned
frame, and a live stream survives a device loss via the survivor remesh
with parity against the uninterrupted run.
"""

import inspect
import time

import numpy as np
import pytest

from repro.ft import (DeviceLossFault, FaultInjector, FaultSpec,
                      RestartPolicy, TransientFault, poison,
                      run_with_restarts)
from repro.serve import Rejected, ServeConfig, StreamScheduler, Workload
from repro.task import Executor, Pipeline, TaskGraph

from helpers import run_with_devices

SEED = 1234


# -- injector determinism contract ------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="gpu", kind="transient")
    with pytest.raises(ValueError):
        FaultSpec(site="task", kind="explode")
    with pytest.raises(ValueError):
        FaultSpec(site="task", kind="transient", prob=1.5)


def test_probabilistic_schedule_replays_from_seed():
    spec = FaultSpec(site="task", kind="straggle", prob=0.3, delay_ms=0.0)
    inj = FaultInjector([spec], seed=SEED)
    g = TaskGraph()
    g.add("noop", lambda: 0, outputs=("z",))
    with inj:
        for _ in range(40):
            Executor().run(g)
    first = list(inj.fired)
    assert first, "prob=0.3 over 40 calls should fire at least once"
    inj.reset()
    with inj:
        for _ in range(40):
            Executor().run(g)
    assert inj.fired == first
    # a different seed draws a different (in general) schedule, but is
    # itself deterministic
    other = FaultInjector([spec], seed=SEED + 1)
    with other:
        for _ in range(40):
            Executor().run(g)
    assert len(other.fired) != len(first) or other.fired != first or True


def test_scheduled_at_indices_and_max_fires():
    spec = FaultSpec(site="task", kind="straggle", at=(1, 3, 5),
                     delay_ms=0.0, max_fires=2)
    inj = FaultInjector([spec], seed=0)
    g = TaskGraph()
    g.add("noop", lambda: 0, outputs=("z",))
    with inj:
        for _ in range(8):
            Executor().run(g)
    assert [idx for _, _, idx, _ in inj.fired] == [1, 3]   # max_fires=2


def test_match_filters_call_stream():
    """``at`` indices count only the spec's OWN matching calls."""
    spec = FaultSpec(site="task", kind="straggle", match="solve",
                     at=(0,), delay_ms=0.0)
    inj = FaultInjector([spec], seed=0)
    g = TaskGraph()
    g.add("prep", lambda: 1, outputs=("a",))
    g.add("solve", lambda a: a + 1, inputs=("a",), outputs=("b",))
    with inj:
        Executor().run(g)
    assert inj.fired == [("task", "solve", 0, "straggle")]


def test_injector_not_reentrant():
    inj = FaultInjector([], seed=0)
    with inj:
        with pytest.raises(RuntimeError, match="not reentrant"):
            inj.__enter__()


def test_hooks_restored_after_exit():
    from repro.core import env as core_env
    from repro.serve import scheduler as serve_sched
    from repro.task import executor as task_exec
    before = (core_env.VERB_HOOK, task_exec.TASK_HOOK,
              serve_sched.STEP_HOOK)
    with FaultInjector([], seed=0):
        assert task_exec.TASK_HOOK is not None
    assert (core_env.VERB_HOOK, task_exec.TASK_HOOK,
            serve_sched.STEP_HOOK) == before


def test_poison_hits_inexact_leaves_only():
    import jax.numpy as jnp
    payload = {"y": jnp.ones((2, 2), jnp.complex64),
               "mask": np.ones((2, 2), bool),
               "n": 7, "tag": "frame0"}
    bad = poison(payload)
    assert np.isnan(np.asarray(bad["y"])).all()
    assert bad["mask"].dtype == bool and bad["mask"].all()
    assert bad["n"] == 7 and bad["tag"] == "frame0"


# -- executor retry envelope ------------------------------------------------

def _graph():
    g = TaskGraph()
    g.add("solve", lambda x: x * 2, inputs=("x",), outputs=("y",))
    return g


def test_executor_retries_transient_and_counts():
    ex = Executor(retry=RestartPolicy(max_restarts=2, backoff_s=0.0))
    with FaultInjector([FaultSpec(site="task", kind="transient",
                                  at=(0,))], seed=0):
        out = ex.run(_graph(), feeds={"x": 21})
    assert out == {"y": 42}
    assert ex.retried == 1
    assert [r.retries for r in ex.trace] == [1]


def test_executor_retry_exhaustion_raises():
    ex = Executor(retry=RestartPolicy(max_restarts=1, backoff_s=0.0))
    with FaultInjector([FaultSpec(site="task", kind="transient",
                                  at=(0, 1, 2))], seed=0):
        with pytest.raises(TransientFault):
            ex.run(_graph(), feeds={"x": 1})


def test_executor_device_loss_not_retried():
    ex = Executor(retry=RestartPolicy(max_restarts=5, backoff_s=0.0))
    with FaultInjector([FaultSpec(site="task", kind="device_loss",
                                  at=(0,), device=2)], seed=0):
        with pytest.raises(DeviceLossFault) as ei:
            ex.run(_graph(), feeds={"x": 1})
    assert ei.value.device == 2
    assert ex.retried == 0


def test_executor_without_policy_propagates():
    with FaultInjector([FaultSpec(site="task", kind="transient",
                                  at=(0,))], seed=0):
        with pytest.raises(TransientFault):
            Executor().run(_graph(), feeds={"x": 1})


# -- satellite: run_with_restarts default policy is not shared --------------

def test_run_with_restarts_fresh_default_policy():
    sig = inspect.signature(run_with_restarts)
    assert sig.parameters["policy"].default is None, \
        "mutable RestartPolicy() default would be shared across calls"
    calls = []

    def loop(start):
        calls.append(start)
        if len(calls) < 2:
            raise RuntimeError("boom")
        return 7

    seen = []
    assert run_with_restarts(
        loop, policy=RestartPolicy(backoff_s=0.0),
        on_restart=lambda n, e: seen.append(n)) == 7
    assert seen == [1]


# -- scheduler: transient tick requeue + Rejected accounting ----------------

class EchoWorkload(Workload):
    def open_session(self, session):
        return {}

    def step(self, batch, width):
        return [(item, False) for _, item in batch]


def test_scheduler_requeues_transient_step():
    sched = StreamScheduler(EchoWorkload())
    s = sched.open("scanner")
    sched.submit(s, "f0")
    with FaultInjector([FaultSpec(site="step", kind="transient",
                                  at=(0,))], seed=0):
        assert sched.tick() == 0          # fault absorbed, nothing lost
        assert len(s.pending) == 1
        assert sched.step_faults == 1
        assert sched.tick() == 1          # retry delivers
    assert s.results == ["f0"]
    assert sched.report()["aggregate"]["ft"]["step_faults"] == 1


class RejectingWorkload(Workload):
    def open_session(self, session):
        return {}

    def step(self, batch, width):
        return [(Rejected("poisoned") if i == 0 else item, False)
                for i, (_, item) in enumerate(batch)]


def test_rejected_counted_not_timed():
    sched = StreamScheduler(RejectingWorkload())
    a, b = sched.open("a"), sched.open("b")
    sched.submit(a, 1), sched.submit(b, 2)
    sched.tick()
    assert isinstance(a.results[0], Rejected) and b.results == [2]
    assert (a.poisoned, len(a.latency_ms)) == (1, 0)
    assert (b.poisoned, len(b.latency_ms)) == (0, 1)
    rep = sched.report()
    assert rep["clients"]["a"]["poisoned"] == 1
    assert rep["aggregate"]["ft"]["rejected_poisoned"] == 1


# -- scheduler: deadline enforcement + degradation ladder -------------------

class DialWorkload(Workload):
    """Sleep-controlled workload with one degraded operating point."""

    levels = 1

    def __init__(self):
        self.sleep_ms = 0.0
        self.level = 0
        self.set_levels: list = []

    def open_session(self, session):
        return {}

    def set_level(self, level):
        self.level = level
        self.set_levels.append(level)

    def step(self, batch, width):
        time.sleep(self.sleep_ms / 1e3)
        return [(item, False) for _, item in batch]


def test_degradation_ladder_steps_down_and_recovers():
    wl = DialWorkload()
    sched = StreamScheduler(wl, ServeConfig(
        buckets=(1, 2), deadline_ms=20.0, breach_ticks=2,
        recover_ticks=2, headroom=0.5))
    s = sched.open("scanner")

    wl.sleep_ms = 40.0                    # sustained breach
    for _ in range(4):
        sched.submit(s, 0)
        sched.tick()
    # rung 1 = operating point shed, rung 2 = bucket cap shed
    assert sched.rung == 2
    assert wl.set_levels[:1] == [1]
    assert sched._bucket_cap() == 1
    downs = [e for e in sched.events if e["dir"] == "down"]
    assert len(downs) == 2 and downs[0]["op_level"] == 1

    wl.sleep_ms = 0.0                     # sustained headroom
    for _ in range(4):
        sched.submit(s, 0)
        sched.tick()
    assert sched.rung == 0
    assert wl.level == 0                  # throughput back, then accuracy
    ups = [e for e in sched.events if e["dir"] == "up"]
    assert len(ups) == 2
    ft = sched.report()["aggregate"]["ft"]
    assert ft["degradation_events"] == 4 and ft["rung"] == 0


def test_ladder_bottoms_out_without_levels():
    class SlowEcho(EchoWorkload):
        def step(self, batch, width):
            time.sleep(2e-3)              # every tick breaches the budget
            return super().step(batch, width)

    sched = StreamScheduler(SlowEcho(), ServeConfig(
        buckets=(1, 2, 4), deadline_ms=0.5, breach_ticks=1,
        recover_ticks=99))
    s = sched.open("scanner")
    for _ in range(8):
        sched.submit(s, 0)
        sched.tick()
    assert sched.rung == sched._max_rung() == 2
    assert sched._bucket_cap() == 1       # fully shed, and stays there


# -- pipeline: drain past a poisoned frame ----------------------------------

def test_pipeline_drop_failed_drains():
    pipe = Pipeline(inflight=2, drop_failed=True)
    g = TaskGraph()
    g.add("inc", lambda x: x + 1, inputs=("x",), outputs=("y",))
    with FaultInjector([FaultSpec(site="task", kind="transient",
                                  at=(2,))], seed=0):
        done = []
        for f in range(5):
            _, retired = pipe.push(g, {"x": f}, tag=f)
            done += retired
        done += pipe.flush()
    assert [tag for tag, _ in done] == [0, 1, 3, 4]
    assert [tag for tag, _ in pipe.dropped] == [2]
    assert isinstance(pipe.dropped[0][1], TransientFault)


def test_pipeline_without_drop_failed_raises():
    pipe = Pipeline(inflight=2)
    g = TaskGraph()
    g.add("inc", lambda x: x + 1, inputs=("x",), outputs=("y",))
    with FaultInjector([FaultSpec(site="task", kind="transient",
                                  at=(0,))], seed=0):
        with pytest.raises(TransientFault):
            pipe.push(g, {"x": 0}, tag=0)


# -- the real serving path under injection (subprocess, multi-device) -------

SERVE_CHAOS = """
from repro.core.env import Environment
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor
from repro.serve import (NlinvStreamWorkload, Rejected, ServeConfig,
                         StreamScheduler)
from repro.ft import FaultInjector, FaultSpec, RestartPolicy

K, F = 3, 4
env = Environment()
comm = env.group()
datas = [phantom.make_dataset(n=16, ncoils=4, nspokes=7, frames=F, seed=s)
         for s in range(K)]

def run(specs, seed=1234, retry=None):
    rec = Reconstructor(comm, newton=2, cg_iters=6, channel_sum="crop")
    wl = NlinvStreamWorkload(rec, retry=retry)
    sched = StreamScheduler(wl, ServeConfig(buckets=(1, 2, 4)))
    ss = [sched.open(client=f"c{k}", grid=d["grid"], ncoils=4, fov=d["fov"])
          for k, d in enumerate(datas)]
    inj = FaultInjector(specs, seed=seed)
    with inj:
        for f in range(F):
            for k, d in enumerate(datas):
                sched.submit(ss[k], (d["y"][f], d["masks"][f]))
            while sched.tick() == 0 and any(
                    s.pending for s in sched.sessions.values()):
                pass
    return sched, ss, inj

ref_sched, ref, _ = run([])
check("clean run delivers all frames",
      all(len(s.results) == F for s in ref))

# (1) transient solve fault absorbed by the task retry: FULL parity
_, ss, inj = run([FaultSpec(site="task", kind="transient", match="solve",
                            at=(1,), max_fires=1)],
                 retry=RestartPolicy(max_restarts=2, backoff_s=0.0))
check("transient fired", inj.fired == [("task", "solve", 1, "transient")])
check("retry parity (all clients, all frames)",
      all(np.array_equal(np.asarray(ss[k].results[f]),
                         np.asarray(ref[k].results[f]))
          for k in range(K) for f in range(F)))

# (2) one client's tick items poisoned: that frame Rejected, the client
# recovers next tick, everyone else bitwise-identical
sched, ss, inj = run([FaultSpec(site="step", kind="corrupt", at=(1,),
                                pick=1, max_fires=1)])
check("corrupt fired once", [f[3] for f in inj.fired] == ["corrupt"])
check("poisoned frame rejected", isinstance(ss[1].results[1], Rejected))
check("client quarantine counted",
      ss[1].poisoned == 1 and
      sched.report()["aggregate"]["ft"]["quarantined"] == 1)
check("quarantined client keeps streaming",
      not isinstance(ss[1].results[2], Rejected) and
      not isinstance(ss[1].results[3], Rejected))
check("unaffected clients bitwise-identical",
      all(np.array_equal(np.asarray(ss[k].results[f]),
                         np.asarray(ref[k].results[f]))
          for k in (0, 2) for f in range(F)))
check("unaffected frames of the poisoned client identical",
      np.array_equal(np.asarray(ss[1].results[0]),
                     np.asarray(ref[1].results[0])))

# (3) transient STEP fault: tick requeues and the retry delivers parity
sched, ss, inj = run([FaultSpec(site="step", kind="transient", at=(1,),
                                max_fires=1)])
check("step fault counted", sched.step_faults == 1)
check("step-requeue parity",
      all(np.array_equal(np.asarray(ss[k].results[f]),
                         np.asarray(ref[k].results[f]))
          for k in range(K) for f in range(F)))

# (4) the schedule replays exactly from its seed
specs = [FaultSpec(site="task", kind="straggle", match="solve", prob=0.4,
                   delay_ms=0.0)]
_, _, a = run(specs, seed=7)
_, _, b = run(specs, seed=7)
check("seeded replay identical", a.fired == b.fired and len(a.fired) > 0)
"""


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_serving_chaos_parity(ndev):
    run_with_devices(SERVE_CHAOS, ndev)


PIPELINE_DRAIN = """
from repro.core.env import Environment
from repro.nlinv.recon import Reconstructor
from repro.nlinv.stream import FramePipeline
from repro.ft import FaultInjector, FaultSpec, RestartPolicy

env = Environment()
comm = env.group()
rec = Reconstructor(comm, newton=2, cg_iters=4)
rng = np.random.default_rng(0)
F, J, g = 5, 2, 16
y = rng.normal(size=(F, J, g, g)) + 1j * rng.normal(size=(F, J, g, g))
masks = (rng.random(size=(F, g, g)) < 0.4).astype(np.float32)
fov = np.ones((g, g), np.float32)

ref_imgs, _ = FramePipeline(rec, inflight=2).run(y, masks, fov)
ref = np.asarray(ref_imgs)

# retry absorbs a transient solve: parity, nothing dropped
with FaultInjector([FaultSpec(site="task", kind="transient", match="solve",
                              at=(1,), max_fires=1)], seed=1):
    pipe = FramePipeline(rec, inflight=2,
                         retry=RestartPolicy(max_restarts=2, backoff_s=0.0))
    imgs, rep = pipe.run(y, masks, fov)
check("retry parity", np.array_equal(np.asarray(imgs), ref))
check("nothing dropped", "dropped" not in rep.summary())

# without retry: the frame is DROPPED, the stream drains all F frames
with FaultInjector([FaultSpec(site="task", kind="transient", match="solve",
                              at=(2,), max_fires=1)], seed=1):
    pipe = FramePipeline(rec, inflight=2, drop_failed=True)
    imgs, rep = pipe.run(y, masks, fov)
s = rep.summary()
check("one frame reported dropped", s["dropped"] == [2])
check("movie stays frame-aligned", np.asarray(imgs).shape[0] == F)
check("dropped index freezes the previous image",
      np.array_equal(np.asarray(imgs)[2], np.asarray(imgs)[1]))
check("frames after the drop keep coming",
      np.isfinite(np.asarray(imgs)[3:]).all())
check("steady stats exclude the dropped frame",
      s["frames"] == F and len(s["dropped"]) == 1)
"""


@pytest.mark.parametrize("ndev", [1, 4])
def test_pipeline_drains_past_fault(ndev):
    run_with_devices(PIPELINE_DRAIN, ndev)


ELASTIC_REMESH = """
from repro.core.env import Environment
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor
from repro.serve import NlinvStreamWorkload, ServeConfig, StreamScheduler
from repro.ft import DeviceLossFault, FaultInjector, FaultSpec

K, F = 2, 4
env = Environment()
comm = env.group()
check("starts on 4 devices", comm.size == 4)
datas = [phantom.make_dataset(n=16, ncoils=4, nspokes=7, frames=F, seed=s)
         for s in range(K)]

def open_all(sched):
    return [sched.open(client=f"c{k}", grid=d["grid"], ncoils=4,
                       fov=d["fov"]) for k, d in enumerate(datas)]

def feed(sched, ss, f):
    for k, d in enumerate(datas):
        sched.submit(ss[k], (d["y"][f], d["masks"][f]))

# uninterrupted 4-device reference
rec = Reconstructor(comm, newton=2, cg_iters=6, channel_sum="crop")
sched = StreamScheduler(NlinvStreamWorkload(rec), ServeConfig(buckets=(1, 2)))
ref = open_all(sched)
for f in range(F):
    feed(sched, ref, f)
    sched.tick()

# chaos run: device 2 dies during tick 2; the handler mints a survivor
# group (devices 0,1) and migrates the live carries
rec = Reconstructor(comm, newton=2, cg_iters=6, channel_sum="crop")
wl = NlinvStreamWorkload(rec)
sched = StreamScheduler(wl, ServeConfig(buckets=(1, 2)))
ss = open_all(sched)
inj = FaultInjector([FaultSpec(site="task", kind="device_loss",
                               match="solve", at=(2,), device=2)], seed=0)
lost_at = None
with inj:
    for f in range(F):
        feed(sched, ss, f)
        try:
            sched.tick()
        except DeviceLossFault as e:
            lost_at = f
            survivor = env.survivor(wl.rec.comm, lost=(e.device, 3))
            wl.remesh(survivor, sessions=ss)
            # pending uploads lived on the lost group: resubmit + retick
            feed(sched, ss, f)
            sched.tick()
check("device loss hit tick 2", lost_at == 2)
check("survivor group has 2 devices", wl.rec.comm.size == 2)
check("remesh counted", wl.remeshes == 1 and
      sched.report()["aggregate"]["ft"]["remeshes"] == 1)
check("all frames delivered", all(len(s.results) == F for s in ss))

# parity: frames before the loss are bitwise vs the 4-device run; the
# migrated carry makes the survivor frames match within float tolerance
for k in range(K):
    for f in range(lost_at):
        check(f"pre-loss parity c{k}f{f}",
              np.array_equal(np.asarray(ss[k].results[f]),
                             np.asarray(ref[k].results[f])))
    for f in range(lost_at, F):
        a = np.asarray(ss[k].results[f]); b = np.asarray(ref[k].results[f])
        rel = np.abs(a - b).max() / max(np.abs(b).max(), 1e-30)
        check(f"post-remesh parity c{k}f{f} (rel={rel:.2e})", rel <= 1e-5)
"""


def test_elastic_remesh_survives_device_loss():
    run_with_devices(ELASTIC_REMESH, 4)
