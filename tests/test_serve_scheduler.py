"""The serving layer (ISSUE 7): scheduler admission/backpressure/
bucketing on a stub workload, SlotPool reclamation, the double-buffer
helper, latency_stats guards, and batched-vs-sequential NLINV parity
through the real scheduler on 1 (in-process) and 4 (subprocess)
devices — including mixed per-client frame phases."""

import numpy as np
import pytest

from helpers import run_with_devices
from repro.nlinv.stream import DoubleBuffer, latency_stats
from repro.serve import (AdmissionError, ServeConfig, SlotPool,
                         StreamScheduler, Workload)


class StubWorkload(Workload):
    """Records every scheduler interaction; items pass through as
    results, and an item equal to "last" completes its session."""

    def __init__(self):
        self.opened, self.closed, self.steps = [], [], []

    def open_session(self, session):
        self.opened.append(session.sid)
        return {}

    def step(self, batch, width):
        self.steps.append((tuple(s.sid for s, _ in batch), width))
        return [(item, item == "last") for _, item in batch]

    def close_session(self, session):
        self.closed.append(session.sid)


# ---------------------------------------------------------------------------
# scheduler control plane (no device work)
# ---------------------------------------------------------------------------

def test_admission_concurrency_queue_and_reject():
    wl = StubWorkload()
    sched = StreamScheduler(wl, ServeConfig(max_concurrency=2, max_queue=1))
    a, b = sched.open("a"), sched.open("b")
    assert a.admitted and b.admitted and wl.opened == [a.sid, b.sid]
    c = sched.open("c")                    # queued: concurrency is full
    assert not c.admitted and len(sched.waiting) == 1
    with pytest.raises(AdmissionError):    # queue is full too
        sched.open("d")
    # closing an admitted session admits the queued one
    sched.close(a)
    assert c.admitted and wl.closed == [a.sid]


def test_backpressure_sheds_past_queue_depth():
    sched = StreamScheduler(StubWorkload(), ServeConfig(queue_depth=2))
    s = sched.open("a")
    assert sched.submit(s, 1) and sched.submit(s, 2)
    assert not sched.submit(s, 3)          # shed, not queued
    assert s.rejected == 1 and len(s.pending) == 2
    sched.tick()                           # frees a slot in the queue
    assert sched.submit(s, 3)


def test_tick_batches_ready_sessions_at_bucketed_width():
    wl = StubWorkload()
    sched = StreamScheduler(wl, ServeConfig(buckets=(1, 2, 4)))
    ss = [sched.open(f"c{i}") for i in range(3)]
    for s in ss:
        sched.submit(s, "x")
    assert sched.tick() == 3
    (sids, width), = wl.steps
    assert sids == tuple(s.sid for s in ss) and width == 4   # 3 -> bucket 4
    assert sched.tick() == 0               # nothing ready


def test_done_result_closes_session_and_refills_from_queue():
    wl = StubWorkload()
    sched = StreamScheduler(wl, ServeConfig(max_concurrency=1, max_queue=4))
    a = sched.open("a")
    b = sched.open("b")                    # waits for a's slot
    sched.submit(a, "last")
    sched.tick()
    assert a.done and wl.closed == [a.sid]
    assert b.admitted                      # refilled at close
    sched.submit(b, "x")
    assert sched.drain() == 1
    assert b.results == ["x"] and not b.done


def test_overcommit_rotates_so_no_client_starves():
    wl = StubWorkload()
    sched = StreamScheduler(wl, ServeConfig(buckets=(1, 2)))
    ss = [sched.open(f"c{i}") for i in range(4)]
    for s in ss:
        for _ in range(2):
            sched.submit(s, "x")
    sched.drain()
    served = [sid for sids, _ in wl.steps for sid in sids]
    assert all(served.count(s.sid) == 2 for s in ss)


def test_report_latency_slo_and_single_sample_guard():
    sched = StreamScheduler(StubWorkload(),
                            ServeConfig(budget_ms=1e6))
    s = sched.open("a")
    sched.submit(s, "x")
    sched.tick()
    rep = sched.report()
    row = rep["clients"]["a"]
    assert row["frames"] == 1
    # single-sample window: no NaN/interp jitter, SLO met
    assert row["jitter_ms"] == 0.0 and row["p95_ms"] == row["p50_ms"]
    assert row["slo"]["met"] == 1.0
    assert rep["aggregate"]["frames"] == 1 and rep["aggregate"]["ticks"] == 1


def test_latency_stats_single_sample_guard():
    s = latency_stats([7.25])
    assert s["jitter_ms"] == 0.0
    assert s["p50_ms"] == s["p95_ms"] == 7.25
    assert latency_stats([])["jitter_ms"] == 0.0
    many = latency_stats([1.0, 2.0, 3.0, 10.0])
    assert many["p95_ms"] > many["p50_ms"] and many["jitter_ms"] > 0


def test_double_buffer_stage_take_discipline():
    log = []
    buf = DoubleBuffer(lambda f: (log.append(f), f)[1])
    with pytest.raises(RuntimeError):
        buf.take()                         # nothing staged
    buf.stage(0)
    assert buf.ready and log == [0]
    with pytest.raises(RuntimeError):
        buf.stage(1)                       # one slot only
    assert buf.take() == 0 and not buf.ready
    buf.stage(1)
    assert buf.take() == 1


# ---------------------------------------------------------------------------
# SlotPool reclamation (the serve/engine.py bug-sweep satellite)
# ---------------------------------------------------------------------------

def test_slot_pool_full_batch_exhaustion():
    pool = SlotPool(2)
    assert pool.assign() == 0 and pool.assign() == 1
    assert pool.available == 0 and pool.in_use == (0, 1)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.assign()


def test_slot_pool_mid_stream_completion_and_refill():
    pool = SlotPool(3)
    slots = [pool.assign() for _ in range(3)]
    pool.free(slots[1])                    # the middle request finishes
    assert pool.in_use == (0, 2)
    assert pool.assign() == 1              # lowest free slot is reused
    with pytest.raises(RuntimeError, match="not assigned"):
        pool.free(99)
    pool.free(0)
    with pytest.raises(RuntimeError, match="not assigned"):
        pool.free(0)                       # double free is loud


# ---------------------------------------------------------------------------
# batched-vs-sequential NLINV parity through the real scheduler
# ---------------------------------------------------------------------------

NLINV_PARITY = """
from repro.core import Environment
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor
from repro.nlinv.stream import stream_movie
from repro.serve import NlinvStreamWorkload, ServeConfig, StreamScheduler

comm = Environment().subgroup({ndev})
K, F = 3, 4
datas = [phantom.make_dataset(n=16, ncoils=4, nspokes=7, frames=F, seed=s)
         for s in range(K)]
rec = Reconstructor(comm, newton=2, cg_iters=4, channel_sum="crop")
sched = StreamScheduler(NlinvStreamWorkload(rec, damping=0.9),
                        ServeConfig(max_concurrency=4, buckets=(1, 2, 4)))
ss = [sched.open(client=f"c{{k}}", grid=datas[k]["grid"], ncoils=4,
                 fov=datas[k]["fov"]) for k in range(K)]
# mixed frame phases: client 0 skips tick 2 entirely
skipped = [(0, 2)]
for f in range(F):
    for k in range(K):
        if (k, f) not in skipped:
            assert sched.submit(ss[k], (datas[k]["y"][f],
                                        datas[k]["masks"][f]))
    sched.tick()
sched.drain()
for k in range(K):
    frames = [f for f in range(F) if (k, f) not in skipped]
    sub = dict(datas[k], y=datas[k]["y"][frames],
               masks=datas[k]["masks"][frames])
    ref, _ = stream_movie(sub, comm=comm, newton=2, cg_iters=4, damping=0.9)
    assert len(ss[k].results) == len(frames)
    for i in range(len(frames)):
        a, b = np.asarray(ss[k].results[i]), np.asarray(ref[i])
        err = np.abs(a - b).max() / max(np.abs(b).max(), 1e-30)
        check(f"client{{k}} frame{{i}} parity ({{err:.2e}})", err < 1e-5)
# plan bucketing: widths 2 and 4 (never 3) were compiled, and each
# bucket is a visible plan-cache entry keyed on its width
widths = {{key[3] for key in rec.plan_cache._plans
          if key[:2] == ("nlinv", "frame_batched")}}
check(f"bucketed widths {{sorted(widths)}}", widths == {{2, 4}})
"""


def _run_parity(ndev):
    out = run_with_devices(NLINV_PARITY.format(ndev=ndev), ndev)
    assert "FAIL" not in out


def test_scheduler_parity_1dev():
    _run_parity(1)


def test_scheduler_parity_4dev():
    _run_parity(4)
