"""NLINV correctness: operator adjointness, CG, IRGNM convergence,
reconstruction quality vs the gridding baseline (paper Fig. 10), and
Table-1 operator counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nlinv import phantom
from repro.nlinv.cg import cg
from repro.nlinv.gridding import gridding_recon
from repro.nlinv.irgnm import irgnm, postprocess
from repro.nlinv.operators import (make_ops, sobolev_weight, uaxpy, udot,
                                   uinit, uzeros)


@pytest.fixture(scope="module")
def small_data():
    return phantom.make_dataset(n=32, ncoils=4, nspokes=9, frames=1, seed=1)


def _rand_u(J, g, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    mk = lambda k, shape: (jax.random.normal(k, shape) +
                           1j * jax.random.normal(jax.random.split(k)[0],
                                                  shape)).astype(jnp.complex64)
    return {"rho": mk(ks[0], (g, g)), "chat": mk(ks[1], (J, g, g))}


def _ops(d):
    return make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))


def test_dg_adjointness(small_data):
    """<DG du, r> == <du, DG^H r> — the core linear-algebra invariant."""
    d = small_data
    ops = _ops(d)
    g, J = d["grid"], d["ncoils"]
    u0 = _rand_u(J, g, 0)
    du = _rand_u(J, g, 1)
    r = (jax.random.normal(jax.random.PRNGKey(2), (J, g, g)) +
         1j * jax.random.normal(jax.random.PRNGKey(3), (J, g, g))
         ).astype(jnp.complex64)
    lhs = jnp.vdot(r, ops.DG(u0, du))          # <r, DG du>
    rhs = udot(ops.DGH(u0, r), du)             # <DG^H r, du>
    np.testing.assert_allclose(complex(lhs), complex(rhs),
                               rtol=1e-3, atol=1e-3)


def test_dg_is_derivative_of_G(small_data):
    d = small_data
    ops = _ops(d)
    g, J = d["grid"], d["ncoils"]
    u0 = _rand_u(J, g, 4)
    du = _rand_u(J, g, 5)
    eps = 1e-3
    up = uaxpy(eps, du, u0)
    um = uaxpy(-eps, du, u0)
    fd = (ops.G(up) - ops.G(um)) / (2 * eps)
    an = ops.DG(u0, du)
    np.testing.assert_allclose(np.asarray(fd), np.asarray(an),
                               atol=2e-3, rtol=2e-2)


def test_cg_solves_normal_system(small_data):
    d = small_data
    ops = _ops(d)
    g, J = d["grid"], d["ncoils"]
    u0 = uinit(J, g)
    rhs = _rand_u(J, g, 6)
    alpha = 0.5
    A = lambda du: ops.normal(u0, du, alpha)
    x = cg(A, rhs, uzeros(J, g), iters=100, tol=1e-8)
    res = uaxpy(-1.0, A(x), rhs)
    rel = float(jnp.sqrt(jnp.real(udot(res, res))) /
                jnp.sqrt(jnp.real(udot(rhs, rhs))))
    assert rel < 1e-3, rel


def _nrmse_in_fov(img, truth, fov):
    m = np.asarray(fov) > 0
    a = np.abs(np.asarray(img))[m]
    b = np.abs(np.asarray(truth))[m]
    a = a / a.max()
    b = b / max(b.max(), 1e-9)
    return float(np.sqrt(np.mean((a - b) ** 2)))


def test_nlinv_beats_gridding(small_data):
    """Iterative recon removes radial streaking (Fig. 10)."""
    d = small_data
    ops = _ops(d)
    y = jnp.asarray(d["y"][0])
    u = irgnm(ops, y, uinit(d["ncoils"], d["grid"]), newton=8, cg_iters=30)
    img = postprocess(ops, u)
    grid_img = gridding_recon(y, jnp.asarray(d["masks"][0]),
                              jnp.asarray(d["fov"]))
    e_nlinv = _nrmse_in_fov(img, d["rho"][0], d["fov"])
    e_grid = _nrmse_in_fov(grid_img, d["rho"][0], d["fov"])
    assert e_nlinv < 0.6 * e_grid, (e_nlinv, e_grid)
    assert e_nlinv < 0.12, e_nlinv


def test_table1_operator_counts(small_data):
    """Count FFTs/pointwise ops per operator — must match paper Table 1
    structure: G: 2 FFT; DG: 2 FFT; DG^H: 2 FFT + 1 channel-sum."""
    d = small_data
    ops = _ops(d)
    g, J = d["grid"], d["ncoils"]
    u0 = uinit(J, g)
    du = _rand_u(J, g, 7)
    r = ops.G(u0)

    def _count(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "fft":
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):          # nested closed jaxpr
                    n += _count(v.jaxpr)
                elif hasattr(v, "eqns"):
                    n += _count(v)
        return n

    def count_ffts(fn, *args):
        return _count(jax.make_jaxpr(fn)(*args).jaxpr)

    # coils() has 1 FFT; G = coils + forward FFT = 2 (Table 1 row F)
    assert count_ffts(ops.G, u0) == 2
    # DG: two coil transforms share W -> 3 raw FFT calls, 2 unique batches
    assert count_ffts(lambda a, b: ops.DG(a, b), u0, du) == 3
    # DG^H: inverse FFT + W^H FFT + coils = 3 (2 after caching c0)
    assert count_ffts(lambda a, b: ops.DGH(a, b), u0, r) == 3
