"""The streaming real-time frame engine (nlinv.stream.FrameStream):

  * numerically identical to the blocking reconstruct_movie loop (same
    Newton carry / damped temporal regularization chain),
  * per-frame wall-clock no worse than the blocking loop on a 4-device
    channel-split reconstruction (the double-buffered transfer overlap
    must not cost anything),
  * records the per-frame latency report artifact.
"""

import json
import pathlib
import re
import tempfile

from helpers import run_with_devices

# test-run artifact goes to tmp: only the benchmark harness writes the
# tracked benchmarks/out/ SLO evidence, so test runs keep the tree clean
ARTIFACT = str(pathlib.Path(tempfile.gettempdir())
               / "nlinv_stream_latency_4dev.json")

STREAM = """
import json, pathlib, time
from repro.core import DeviceGroup
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor, reconstruct_movie
from repro.nlinv.stream import FrameStream

d = phantom.make_dataset(n=24, ncoils=4, nspokes=7, frames=4, seed=5)
g = DeviceGroup.all_devices((4,), ("data",))
rec = Reconstructor(g, newton=3, cg_iters=6, channel_sum="crop")
eng = FrameStream(rec, damping=0.9)

movie, rep = eng.run(d["y"], d["masks"], d["fov"])
ref = reconstruct_movie(d, newton=3, cg_iters=6,
                        frame_fn=rec.fn)      # blocking baseline, same math
err = float(jnp.max(jnp.abs(movie - ref)))
scale = float(jnp.max(jnp.abs(ref)))
check("stream_matches_blocking", err < 1e-4 * scale)

# warm wall-clock comparison: stream must be no worse than the loop.
# Best-of-2 per engine: a shared CI box can stall either run, only a
# systematic slowdown should fail this.
def timed(fn):
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best

dt_stream = timed(lambda: eng.run(d["y"], d["masks"], d["fov"],
                                  report_path=%(artifact)r)[0])
dt_block = timed(lambda: reconstruct_movie(d, newton=3, cg_iters=6,
                                           frame_fn=rec.fn))
print("STREAM_S", dt_stream, "BLOCK_S", dt_block)
check("stream_not_slower", dt_stream <= dt_block * 1.5)

p = pathlib.Path(%(artifact)r)
check("artifact_written", p.exists())
s = json.loads(p.read_text())
check("artifact_fields", all(k in s for k in
      ("mean_ms", "p95_ms", "jitter_ms", "fps", "frame_ms", "devices")))
check("artifact_devices", s["devices"] == 4)
print("LAT", json.dumps(s))
""" % {"artifact": ARTIFACT}


def test_stream_engine_4dev_latency_artifact():
    out = run_with_devices(STREAM, ndev=4)
    m = re.search(r"STREAM_S ([\d.e-]+) BLOCK_S ([\d.e-]+)", out)
    print(f"stream={float(m.group(1)):.3f}s blocking={float(m.group(2)):.3f}s")
    report = json.loads(pathlib.Path(ARTIFACT).read_text())
    assert report["frames"] == 4
    assert report["mean_ms"] > 0


SINGLE = """
from repro.nlinv import phantom
from repro.nlinv.recon import Reconstructor, reconstruct_movie
from repro.nlinv.stream import FrameStream

d = phantom.make_dataset(n=16, ncoils=2, nspokes=5, frames=2, seed=7)
rec = Reconstructor(newton=2, cg_iters=4, channel_sum="full")
movie, rep = FrameStream(rec).run(d["y"], d["masks"], d["fov"])
ref = reconstruct_movie(d, newton=2, cg_iters=4)
err = float(jnp.max(jnp.abs(movie - ref)))
check("degenerate_matches", err < 1e-5 * float(jnp.max(jnp.abs(ref))))
check("report_frames", len(rep.frame_ms) == 2)
"""


def test_stream_engine_single_device_degenerate():
    run_with_devices(SINGLE, ndev=1)
