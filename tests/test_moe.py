"""MoE sort-based capacity dispatch vs a dense per-token reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.models import moe
from repro.models.layers import ACTS


def _cfg(**kw):
    base = get_smoke("granite-moe-3b-a800m")
    return dataclasses.replace(base, compute_dtype="float32", **kw)


def _dense_reference(cfg, p, x):
    """Route every token through its top_k experts directly (no capacity)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    E = p["router"].shape[1]
    logits = xf @ p["router"]
    logits = jnp.where(jnp.arange(E) < cfg.n_experts, logits, -1e30)
    gates = jax.nn.softmax(logits, -1)
    topw, tope = jax.lax.top_k(gates, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    a = ACTS[cfg.act]
    out = jnp.zeros_like(xf)
    for e in range(E):
        h = a(xf @ p["experts"]["gate"][e]) * (xf @ p["experts"]["up"][e])
        oe = h @ p["experts"]["down"][e]
        w = jnp.sum(jnp.where(tope == e, topw, 0.0), axis=-1)
        out = out + w[:, None] * oe
    return out.reshape(B, S, d)


@pytest.mark.parametrize("E,k,pad", [(8, 2, 1), (8, 3, 1), (6, 2, 4)])
def test_dispatch_matches_dense(E, k, pad):
    cfg = _cfg(n_experts=E, top_k=k, capacity_factor=float(E) / k)
    p = moe.init(cfg, jax.random.PRNGKey(0), pad_to=pad)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 10, cfg.d_model))
    got, aux = moe.apply(cfg, p, x)
    want = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    assert float(aux["dropped"]) == 0.0


def test_padded_experts_never_selected():
    cfg = _cfg(n_experts=6, top_k=2)
    p = moe.init(cfg, jax.random.PRNGKey(0), pad_to=4)   # 6 -> 8 experts
    assert p["router"].shape[1] == 8
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    logits = jnp.where(jnp.arange(8) < 6, logits, -1e30)
    _, tope = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    assert int(tope.max()) < 6


def test_capacity_drops_are_reported():
    cfg = _cfg(n_experts=8, top_k=2, capacity_factor=0.1)
    p = moe.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux = moe.apply(cfg, p, x)
    assert float(aux["dropped"]) > 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), S=st.integers(2, 17))
def test_combine_weights_sum_to_one(seed, S):
    cfg = _cfg()
    p = moe.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, S, cfg.d_model))
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    E = p["router"].shape[1]
    logits = jnp.where(jnp.arange(E) < cfg.n_experts, logits, -1e30)
    gates = jax.nn.softmax(logits, -1)
    topw, _ = jax.lax.top_k(gates, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, atol=1e-6)
