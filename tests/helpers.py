"""Test helpers.

Multi-device semantics need >1 device, but XLA locks the host device
count at first jax init — and smoke tests/benches must see 1 device.  So
multi-device tests run their payload in a subprocess with
``--xla_force_host_platform_device_count=N`` (never set globally).
"""

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

PREAMBLE = """
import os, sys
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
np.random.seed(0)
def check(name, ok):
    if not ok:
        print("FAIL:", name); sys.exit(1)
    print("ok:", name)
"""


def run_with_devices(code: str, ndev: int = 8, timeout: int = 600) -> str:
    env = os.environ.copy()
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", PREAMBLE + code], env=env,
                       capture_output=True, text=True, timeout=timeout,
                       cwd=str(REPO))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
