"""The repro.lib plan/plan-cache substrate (paper §4 library ports):

  * PlanCache behaviour: keying, LRU eviction, hit/miss counters,
    cross-group isolation;
  * plan-cached fft/blas correctness vs the direct math, including the
    fused axpy+dot, dot+allreduce and cg_update/xpby_dot epilogues;
  * the streaming engine's plan-cache report: frame 0 builds, steady
    state is all hits (4-device run lives in test_gridding.py);
  * the kernel-registry block autotuner (ISSUE 8): the chosen block is
    part of the plan identity, the decision itself is plan-cached, and
    the steady state builds nothing.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Environment
from repro.lib import blas as lblas
from repro.lib import fft as lfft
from repro.lib.plan import Plan, PlanCache, default_cache, group_token


def _mk(seed=0, shape=(4, 16, 16)):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) +
            1j * rng.standard_normal(shape)).astype(np.complex64)


# ---------------------------------------------------------------------------
# PlanCache mechanics
# ---------------------------------------------------------------------------

def test_cache_keying_and_hits():
    cache = PlanCache(maxsize=8)
    built = []

    def builder(tag):
        def b():
            built.append(tag)
            return Plan(key=("k", tag), fn=lambda: tag)
        return b

    p1 = cache.get_or_build(("k", "a"), builder("a"))
    p2 = cache.get_or_build(("k", "a"), builder("a"))
    assert p1 is p2 and built == ["a"]
    assert (cache.hits, cache.misses) == (1, 1)
    cache.get_or_build(("k", "b"), builder("b"))
    assert built == ["a", "b"]
    assert cache.stats()["hit_rate"] == pytest.approx(1 / 3, abs=1e-3)


def test_cache_lru_eviction():
    cache = PlanCache(maxsize=2)
    mk = lambda k: (lambda: Plan(key=k, fn=lambda: k))
    cache.get_or_build(("a",), mk(("a",)))
    cache.get_or_build(("b",), mk(("b",)))
    cache.get_or_build(("a",), mk(("a",)))     # refresh a: b becomes LRU
    cache.get_or_build(("c",), mk(("c",)))     # evicts b
    assert cache.evictions == 1
    assert ("a",) in cache and ("c",) in cache and ("b",) not in cache
    # re-requesting the evicted key rebuilds it
    cache.get_or_build(("b",), mk(("b",)))
    assert cache.misses == 4 and len(cache) == 2


def test_cache_cross_group_isolation():
    """Plans bound to different groups never collide, even for identical
    shapes — the group token is part of every key."""
    env = Environment()
    c1 = env.group((1,), ("data",))
    c2 = env.group((1,), ("model",))           # same device, different mesh
    assert group_token(c1) != group_token(c2)

    cache = PlanCache()
    x1 = c1.container(_mk())
    x2 = c2.container(_mk())
    p1 = lfft.plan_fft2_batched(x1, cache=cache)
    p2 = lfft.plan_fft2_batched(x2, cache=cache)
    assert p1 is not p2
    assert cache.misses == 2 and cache.hits == 0
    # same geometry + same group -> hit
    assert lfft.plan_fft2_batched(x1, cache=cache) is p1
    assert cache.hits == 1


# ---------------------------------------------------------------------------
# fft port
# ---------------------------------------------------------------------------

def test_fft2_plain_matches_numpy_and_caches():
    cache = PlanCache()
    x = _mk(1)
    got = lfft.fft2(jnp.asarray(x), centered=True, cache=cache)
    want = np.fft.fftshift(
        np.fft.fft2(np.fft.ifftshift(x, axes=(-2, -1)), axes=(-2, -1),
                    norm="ortho"), axes=(-2, -1))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)
    lfft.fft2(jnp.asarray(x), centered=True, cache=cache)
    assert cache.misses == 1 and cache.hits == 1


def test_fft2_batched_roundtrip_segmented():
    comm = Environment().subgroup(1)
    x = _mk(2)
    seg = comm.container(x)
    k = lfft.fft2_batched(seg, centered=True)
    back = lfft.fft2_batched(k, inverse=True, centered=True)
    np.testing.assert_allclose(np.asarray(comm.gather(back)), x, atol=1e-4)


def test_fft2_batched_inplane_split_matches_batch_split():
    """A container split inside the transform plane (transpose
    algorithm) must equal the batch-split result."""
    comm = Environment().subgroup(1)
    x = _mk(3)
    want = lfft.fft2_batched(comm.container(x), centered=True)
    got = lfft.fft2_batched(comm.container(x, dim=1), centered=True)
    np.testing.assert_allclose(np.asarray(comm.gather(got)),
                               np.asarray(comm.gather(want)), atol=1e-4)


# ---------------------------------------------------------------------------
# blas port (fused epilogues)
# ---------------------------------------------------------------------------

def test_blas_axpy_dot_fused_matches_split():
    comm = Environment().subgroup(1)
    x, y = comm.container(_mk(4)), comm.container(_mk(5))
    w, d = lblas.axpy_dot(2.0 - 1.0j, x, y, y)
    w_ref = lblas.axpy(2.0 - 1.0j, x, y)
    np.testing.assert_allclose(np.asarray(w.data), np.asarray(w_ref.data),
                               atol=1e-5)
    np.testing.assert_allclose(
        complex(d), complex(jnp.vdot(y.data, w_ref.data)), rtol=1e-5)
    w2, n = lblas.axpy_norm2(-0.5, x, y)
    np.testing.assert_allclose(
        float(n), float(jnp.real(jnp.vdot(w2.data, w2.data))), rtol=1e-5)


def test_blas_dot_allreduce_matches_vdot():
    comm = Environment().subgroup(1)
    x, y = comm.container(_mk(6)), comm.container(_mk(7))
    got = lblas.dot_allreduce(x, y)
    np.testing.assert_allclose(complex(got),
                               complex(jnp.vdot(x.data, y.data)), rtol=1e-4)


def test_blas_gemm_plans():
    comm = Environment().subgroup(1)
    cache = PlanCache()
    a = np.random.default_rng(8).standard_normal((4, 5, 6)).astype(np.float32)
    b = np.random.default_rng(9).standard_normal((4, 6, 7)).astype(np.float32)
    got = lblas.gemm_batched(comm.container(a), comm.container(b),
                             cache=cache)
    np.testing.assert_allclose(np.asarray(comm.gather(got)), a @ b,
                               atol=1e-4)
    lblas.gemm_batched(comm.container(a), comm.container(b), cache=cache)
    assert cache.hits == 1   # second call reuses the plan


# ---------------------------------------------------------------------------
# fused cg_update / xpby_dot entries (the CG hot-path plans)
# ---------------------------------------------------------------------------

def test_blas_cg_update_fused_matches_split():
    """One plan-cached pass == the three-pass unfused update, on a
    CLONE+NATURAL pytree (the (rho, chat) layout of NLINV)."""
    from repro.core import Policy
    comm = Environment().subgroup(1)
    cache = PlanCache()
    mk = lambda s: {"rho": comm.container(_mk(s, (8, 8)),
                                          policy=Policy.CLONE),
                    "chat": comm.container(_mk(s + 1))}
    p, ap, x, r = mk(20), mk(22), mk(24), mk(26)
    alpha = 0.375
    x2, r2, rs = lblas.cg_update(alpha, p, ap, x, r, cache=cache)
    for kk in ("rho", "chat"):
        np.testing.assert_allclose(
            np.asarray(x2[kk].data),
            np.asarray(x[kk].data) + alpha * np.asarray(p[kk].data),
            atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(r2[kk].data),
            np.asarray(r[kk].data) - alpha * np.asarray(ap[kk].data),
            atol=1e-5)
    want_rs = sum(float(np.vdot(np.asarray(r2[kk].data),
                                np.asarray(r2[kk].data)).real)
                  for kk in ("rho", "chat"))
    np.testing.assert_allclose(float(rs), want_rs, rtol=1e-5)
    # second call with the same layouts is a pure cache hit
    lblas.cg_update(0.5, p, ap, x, r, cache=cache)
    assert cache.hits == 1 and cache.misses == 1


def test_blas_xpby_dot_fused_matches_split():
    comm = Environment().subgroup(1)
    x, y = comm.container(_mk(30)), comm.container(_mk(31))
    beta = 0.625
    w, d = lblas.xpby_dot(x, y, beta)
    want = np.asarray(x.data) + beta * np.asarray(y.data)
    np.testing.assert_allclose(np.asarray(w.data), want, atol=1e-5)
    np.testing.assert_allclose(float(d), float(np.vdot(want, want).real),
                               rtol=1e-5)


def test_blas_tree_forms_shared_with_nlinv():
    """operators.uaxpy/udot are the lib.blas tree forms — one
    implementation for single-device and distributed paths."""
    from repro.nlinv.operators import uaxpy, udot
    x = {"rho": jnp.asarray(_mk(40, (4, 4))), "chat": jnp.asarray(_mk(41))}
    y = {"rho": jnp.asarray(_mk(42, (4, 4))), "chat": jnp.asarray(_mk(43))}
    got = uaxpy(0.5, x, y)
    want = lblas.tree_axpy(0.5, x, y)
    np.testing.assert_allclose(np.asarray(got["chat"]),
                               np.asarray(want["chat"]), atol=1e-6)
    np.testing.assert_allclose(complex(udot(x, y)),
                               complex(lblas.tree_vdot(x, y)), rtol=1e-5)


# ---------------------------------------------------------------------------
# kernel-registry autotuner determinism (ISSUE 8)
# ---------------------------------------------------------------------------

def test_cg_plans_embed_autotuned_blocks(monkeypatch):
    """The resolved (bm,) block choice is part of the cg plan identity
    (a key element) and surfaced in plan.meta — a changed tuning choice
    or pin builds a distinct plan instead of silently reusing a stale
    program."""
    from repro.kernels import registry as kreg
    monkeypatch.setenv(kreg.PIN_ENV, "default")
    kreg.reset_choices()
    comm = Environment().subgroup(1)
    cache = PlanCache()
    mk = lambda s: comm.container(_mk(s))
    p, ap, x, r = mk(50), mk(51), mk(52), mk(53)
    lblas.cg_update(0.25, p, ap, x, r, cache=cache)
    (plan,) = cache._plans.values()
    blocks = plan.meta["kernel_blocks"]["cg_fused.cg_update"]
    assert blocks == kreg.get("cg_fused.cg_update").default_block
    assert blocks in plan.key


def test_autotuner_determinism_zero_steady_state_builds(monkeypatch):
    """Same spec + geometry + pin -> the same cached decision and plan:
    after the first call, repeats are pure hits in BOTH the tune cache
    and the plan cache (zero steady-state rebuilds)."""
    from repro.kernels import registry as kreg
    from repro.lib.plan import seg_token
    monkeypatch.setenv(kreg.PIN_ENV, "default")
    kreg.reset_choices()
    comm = Environment().subgroup(1)
    cache = PlanCache()
    x, y = comm.container(_mk(60)), comm.container(_mk(61))

    before = kreg.tune_cache().snapshot()
    lblas.xpby_dot(x, y, 0.5, cache=cache)
    assert kreg.tune_cache().delta(before)["builds"] <= 1

    steady = kreg.tune_cache().snapshot()
    for beta in (0.5, 0.25, 0.125):
        lblas.xpby_dot(x, y, beta, cache=cache)
    d = kreg.tune_cache().delta(steady)
    assert d["builds"] == 0 and d["hits"] == 3, d
    assert cache.misses == 1 and cache.hits == 3

    # the decision itself is deterministic: same (spec, token, pin)
    # always resolves to the same block tuple
    tok = ("blas", seg_token(x))
    b1 = kreg.autotune("cg_fused.xpby_dot", token=tok)
    b2 = kreg.autotune("cg_fused.xpby_dot", token=tok)
    assert b1 == b2 == kreg.get("cg_fused.xpby_dot").default_block


# ---------------------------------------------------------------------------
# the streaming engine's plan-cache report (1-device; 4-device in
# test_gridding.py rides the subprocess payload)
# ---------------------------------------------------------------------------

def test_stream_reports_zero_steady_state_builds():
    from repro.nlinv import phantom
    from repro.nlinv.recon import Reconstructor
    from repro.nlinv.stream import FrameStream
    d = phantom.make_dataset(n=16, ncoils=2, nspokes=5, frames=3, seed=3)
    rec = Reconstructor(newton=2, cg_iters=4, channel_sum="full")
    _, rep = FrameStream(rec).run(d["y"], d["masks"], d["fov"])
    s = rep.summary()
    pc = s["plan_cache"]
    assert len(pc["frame_builds"]) == 3
    assert pc["steady_builds"] == 0, pc
    assert all(b == 0 for b in pc["frame_builds"][1:]), pc
    assert pc["hit_rate"] > 0
