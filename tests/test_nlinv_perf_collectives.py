"""NLINV §Perf evidence: the cropped channel-sum (TPU analogue of the
paper's kern_all_red_p2p_2d 2-D-section transfer) moves ~4x fewer bytes
per all-reduce than the paper-faithful full-grid reduction.  Verified on
the compiled HLO of the distributed reconstruction."""

import re

from helpers import run_with_devices

MEASURE = """
from repro.core import DeviceGroup
from repro.nlinv.recon import make_dist_reconstruct
from repro.nlinv.operators import sobolev_weight, uinit
from repro.nlinv import phantom
from repro.launch.roofline import parse_collectives

d = phantom.make_dataset(n=32, ncoils=8, nspokes=7, frames=1)
g = DeviceGroup.all_devices((8,), ("data",))
w = sobolev_weight(d["grid"])
u0 = uinit(8, d["grid"])

def wire_bytes(mode):
    fn = make_dist_reconstruct(g, "data", newton=3, cg_iters=5,
                               channel_sum=mode)
    low = fn.lower(jnp.asarray(d["y"][0]), jnp.asarray(d["masks"][0]),
                   jnp.asarray(d["fov"]), jnp.asarray(w), u0, u0)
    txt = low.compile().as_text()
    colls = parse_collectives(txt)
    # image-sized all-reduces only (the rho partial sums; ignore the
    # tiny CG scalar products)
    return sum(c["wire_bytes"] for c in colls
               if c["kind"] == "all-reduce" and c["bytes"] >= 4096)

full = wire_bytes("full")
crop = wire_bytes("crop")
print("FULL", int(full), "CROP", int(crop))
check("crop_reduces_bytes", crop * 2 < full)
check("about_4x", 3.0 < full / max(crop, 1) < 6.0)
"""


def test_cropped_allreduce_moves_4x_fewer_bytes():
    out = run_with_devices(MEASURE, ndev=8)
    m = re.search(r"FULL (\d+) CROP (\d+)", out)
    full, crop = int(m.group(1)), int(m.group(2))
    ratio = full / max(crop, 1)
    print(f"full={full} crop={crop} ratio={ratio:.2f}")
    assert ratio > 3.0
