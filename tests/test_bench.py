"""The repro.bench subsystem: artifact schema round-trip, registry
completeness + determinism, compare-tool gating, harness discipline,
and one in-process scenario execution.

The full sweep CLI (subprocess per device count) is exercised once with
the cheapest figure; everything else runs in-process on whatever device
count the host has.
"""

import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.bench import (ArtifactError, BenchContext, compare_artifacts,
                         load_artifact, make_artifact, measure, run_key,
                         scenarios, validate_artifact, write_artifact)
from repro.bench.compare import format_report
from repro.bench.compare import main as compare_main
from repro.bench.registry import DEVICE_COUNTS, SIZES

REPO = pathlib.Path(__file__).resolve().parents[1]

# benchmarks/*.py script -> the registry figure(s) it fronts; every
# script must stay a thin entry point over registered scenarios.
SCRIPT_FIGURES = {
    "fig4_algorithms.py": {"fig4"},
    "fig5_transfers.py": {"fig5"},
    "fig6_nlinv.py": {"fig6", "stream", "gridding"},
    "fig89_operators.py": {"fig89"},
    "table1_operators.py": {"table1"},
    "lm_steps.py": {"lm"},
    "serve_streams.py": {"serve"},
}

# the acceptance sweep: these figures must be registered with tiny-CI
# coverage at 1 AND 4 devices
CI_FIGURES = ("fig4", "fig5", "fig6", "fig89", "table1", "gridding",
              "stream", "serve")


def _fake_run(scenario="figX.thing", figure="figX", devices=1, size="tiny",
              steady=1.0, **kw):
    run = {"scenario": scenario, "figure": figure, "devices": devices,
           "size": size, "wall_ms": 10.0, "compile_ms": 5.0,
           "steady_ms": steady}
    run.update(kw)
    return run


# ---------------------------------------------------------------------------
# artifact schema
# ---------------------------------------------------------------------------

def test_artifact_round_trip(tmp_path):
    runs = [_fake_run(devices=1, steady=4.0),
            _fake_run(devices=4, steady=2.0),
            _fake_run(scenario="figX.other", devices=1, steady=0.5,
                      extra={"model_eff2": 1.0}, plan_cache={"steady": {}})]
    art = make_artifact(runs, sha="0" * 40, host={"platform": "cpu"})
    # speedup vs the 1-device run of the same (scenario, size)
    assert art["scenarios"]["figX.thing@d4@tiny"]["speedup_vs_1dev"] == 2.0
    assert "speedup_vs_1dev" not in art["scenarios"]["figX.thing@d1@tiny"]
    path = write_artifact(tmp_path / "a.json", art)
    assert load_artifact(path) == art
    # deterministic serialization
    assert path.read_text() == json.dumps(art, indent=2, sort_keys=True) + "\n"


def test_artifact_validation_rejects_malformed():
    good = make_artifact([_fake_run()], sha="x", host={})
    with pytest.raises(ArtifactError):
        validate_artifact({**good, "schema_version": 99})
    with pytest.raises(ArtifactError):
        validate_artifact({**good, "schema": "something-else"})
    with pytest.raises(ArtifactError):
        validate_artifact({**good, "git_sha": ""})
    run = _fake_run()
    del run["steady_ms"]
    with pytest.raises(ArtifactError, match="steady_ms"):
        make_artifact([run], sha="x", host={})
    with pytest.raises(ArtifactError, match="type"):
        make_artifact([_fake_run(steady="fast")], sha="x", host={})
    # key must match the run's own identity
    art = make_artifact([_fake_run()], sha="x", host={})
    art["scenarios"]["wrong@d1@tiny"] = art["scenarios"].pop(
        "figX.thing@d1@tiny")
    with pytest.raises(ArtifactError, match="identity"):
        validate_artifact(art)


def test_artifact_rejects_duplicate_runs():
    with pytest.raises(ArtifactError, match="duplicate"):
        make_artifact([_fake_run(), _fake_run()], sha="x", host={})


def test_artifact_load_rejects_non_json(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("not json {")
    with pytest.raises(ArtifactError, match="JSON"):
        load_artifact(p)


# ---------------------------------------------------------------------------
# compare tool
# ---------------------------------------------------------------------------

def _two_artifacts(base_steady, new_steady, **newkw):
    base = make_artifact([_fake_run(steady=base_steady)], sha="a", host={})
    new = make_artifact([_fake_run(steady=new_steady, **newkw)],
                        sha="b", host={})
    return base, new


def test_compare_pass_and_regression():
    base, new = _two_artifacts(4.0, 4.2)
    cmp = compare_artifacts(base, new, threshold_pct=25.0)
    assert cmp.ok and not cmp.regressions and cmp.unchanged

    base, new = _two_artifacts(4.0, 8.0)      # injected 2x slowdown
    cmp = compare_artifacts(base, new, threshold_pct=75.0)
    assert not cmp.ok
    assert cmp.regressions[0]["ratio"] == 2.0


def test_compare_improvement_and_noise_floor():
    base, new = _two_artifacts(4.0, 1.0)
    cmp = compare_artifacts(base, new)
    assert cmp.ok and cmp.improvements

    # both sub-floor: pure noise territory (model-only rows report 0.0)
    base, new = _two_artifacts(0.0, 0.0)
    cmp = compare_artifacts(base, new)
    assert cmp.ok and cmp.below_floor


def test_compare_sub_floor_base_cannot_hide_a_regression():
    """The base is clamped UP to the floor, not skipped: a 0.1ms row
    blowing up to 500ms must fail even though 0.1 < min_ms."""
    base, new = _two_artifacts(0.1, 500.0)
    cmp = compare_artifacts(base, new, threshold_pct=75.0, min_ms=1.0)
    assert not cmp.ok and cmp.regressions[0]["new_ms"] == 500.0
    # ...while sub-floor jitter that stays near the floor does not flake
    base, new = _two_artifacts(0.1, 0.9)
    cmp = compare_artifacts(base, new, threshold_pct=75.0, min_ms=1.0)
    assert cmp.ok and not cmp.regressions


def test_compare_gates_per_client_p95():
    """Serve scenarios: the worst-client p95 (extra.client_p95_ms) is
    gated with the same threshold — a starved client fails the compare
    even when the mean tick stayed fast."""
    p95 = lambda v: {"extra": {"client_p95_ms": v}}
    base, new = _two_artifacts(4.0, 4.0, **p95(20.0))
    base["scenarios"]["figX.thing@d1@tiny"]["extra"] = {"client_p95_ms": 8.0}
    cmp = compare_artifacts(base, new, threshold_pct=25.0)
    assert not cmp.ok and not cmp.regressions
    assert cmp.p95_regressions[0]["ratio"] == 2.5
    assert "P95 REGRESSION" in format_report(cmp)
    # within threshold: passes
    base["scenarios"]["figX.thing@d1@tiny"]["extra"] = {"client_p95_ms": 18.0}
    assert compare_artifacts(base, new, threshold_pct=25.0).ok
    # rows without the column (every non-serve scenario) are ignored
    b2, n2 = _two_artifacts(4.0, 4.0)
    assert compare_artifacts(b2, n2).p95_regressions == []
    # machine-speed calibration scales the new p95 like the steady state
    b3 = make_artifact([_fake_run(steady=4.0, **p95(10.0))], sha="a",
                       host={}, calibration_ms=1.0)
    n3 = make_artifact([_fake_run(steady=12.0, **p95(30.0))], sha="b",
                       host={}, calibration_ms=3.0)
    assert compare_artifacts(b3, n3).ok      # 3x slower host cancels out


def test_compare_normalizes_by_machine_speed():
    """A uniformly slower host moves calibration and scenarios together
    and must not regress; a code slowdown (calibration unmoved) must."""
    base = make_artifact([_fake_run(steady=4.0)], sha="a", host={},
                         calibration_ms=10.0)
    # whole sweep 3x slower (neighbor contention): 3x steady, 3x cal
    slow_host = make_artifact([_fake_run(steady=12.0)], sha="b", host={},
                              calibration_ms=30.0)
    cmp = compare_artifacts(base, slow_host, threshold_pct=75.0)
    assert cmp.ok and cmp.scale == pytest.approx(1 / 3, abs=1e-4)

    # genuine 3x code regression: steady up, calibration unchanged
    slow_code = make_artifact([_fake_run(steady=12.0)], sha="c", host={},
                              calibration_ms=10.0)
    cmp = compare_artifacts(base, slow_code, threshold_pct=75.0)
    assert not cmp.ok and cmp.regressions[0]["ratio"] == 3.0

    # artifacts without calibration compare raw (back-compat)
    nocal = make_artifact([_fake_run(steady=4.0)], sha="d", host={})
    assert compare_artifacts(nocal, nocal).scale == 1.0


def test_artifact_rejects_bad_calibration():
    with pytest.raises(ArtifactError, match="calibration"):
        make_artifact([_fake_run()], sha="x", host={}, calibration_ms=0.0)
    with pytest.raises(ArtifactError, match="calibration"):
        make_artifact([_fake_run()], sha="x", host={}, calibration_ms=-1)


def test_compare_new_and_missing_scenarios():
    one = make_artifact([_fake_run()], sha="a", host={})
    two = make_artifact([_fake_run(),
                         _fake_run(scenario="figX.added")], sha="b", host={})
    cmp = compare_artifacts(one, two)
    assert cmp.ok and cmp.new == ["figX.added@d1@tiny"]
    cmp = compare_artifacts(two, one)
    assert cmp.ok and cmp.missing == ["figX.added@d1@tiny"]


def test_compare_cli_exit_codes(tmp_path):
    """The acceptance gate: non-zero exit on an injected 2x slowdown."""
    base, new = _two_artifacts(4.0, 8.0)
    pb, pn = tmp_path / "base.json", tmp_path / "new.json"
    write_artifact(pb, base)
    write_artifact(pn, new)
    assert compare_main([str(pb), str(pn), "--threshold", "75"]) == 1
    assert compare_main([str(pb), str(pb)]) == 0
    # missing scenarios fail only when asked to
    two = make_artifact([_fake_run(steady=4.0),
                         _fake_run(scenario="figX.gone")], sha="c", host={})
    pt = tmp_path / "two.json"
    write_artifact(pt, two)
    assert compare_main([str(pt), str(pb)]) == 0
    assert compare_main([str(pt), str(pb), "--fail-on-missing"]) == 1


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_keys_deterministic_and_wellformed():
    a, b = scenarios(), scenarios()
    assert list(a) == list(b) == sorted(a)
    for key, sc in a.items():
        assert key == f"{sc.figure}.{sc.name}"
        assert set(sc.sizes) <= set(SIZES) and sc.sizes
        assert set(sc.devices) <= set(DEVICE_COUNTS) and sc.devices


def test_registry_rejects_duplicates():
    from repro.bench.registry import scenario as register
    some = next(iter(scenarios().values()))
    with pytest.raises(ValueError, match="duplicate"):
        register(some.figure, some.name)(lambda ctx: {})


def test_registry_tolerates_blank_docstrings():
    from repro.bench.registry import _REGISTRY
    from repro.bench.registry import scenario as register

    def fn(ctx):
        """   """
    register("figtest", "blank_doc")(fn)
    try:
        assert _REGISTRY["figtest.blank_doc"].doc == ""
    finally:
        del _REGISTRY["figtest.blank_doc"]


def test_registry_covers_every_benchmark_script():
    figures = {sc.figure for sc in scenarios().values()}
    for script, figs in SCRIPT_FIGURES.items():
        assert (REPO / "benchmarks" / script).exists(), script
        assert figs <= figures, f"{script}: {figs - figures} unregistered"


def test_ci_figures_cover_tiny_at_1_and_4_devices():
    by_figure = {}
    for sc in scenarios().values():
        by_figure.setdefault(sc.figure, []).append(sc)
    for fig in CI_FIGURES:
        scs = by_figure[fig]
        assert any("tiny" in sc.sizes and {1, 4} <= set(sc.devices)
                   for sc in scs), f"{fig} lacks tiny coverage at 1+4 devices"


def test_benchmark_scripts_are_thin():
    """The old per-script timing/argparse code must not creep back."""
    for script in list(SCRIPT_FIGURES) + ["run.py"]:
        text = (REPO / "benchmarks" / script).read_text()
        assert "repro.bench" in text, f"{script} bypasses repro.bench"
        assert "argparse" not in text and "perf_counter" not in text, \
            f"{script} regrew its own harness"
        assert len(text.splitlines()) < 30, f"{script} is not thin"


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

def test_measure_separates_compile_from_steady():
    import jax.numpy as jnp
    from repro.lib.plan import PlanCache

    cache = PlanCache()
    t = measure(lambda: jnp.arange(8) * 2, warmup=1, iters=4, cache=cache)
    assert t.compile_ms >= 0 and t.steady_ms >= 0
    assert t.iters == 4 and t.warmup == 1
    # steady_ms is the best (minimum) sample; percentiles sit above it
    assert t.p95_ms >= t.p50_ms >= t.steady_ms
    assert t.wall_ms >= t.compile_ms
    d = t.as_dict()
    assert d["plan_cache"]["steady"]["builds"] == 0

    with pytest.raises(ValueError):
        measure(lambda: None, iters=0)


def test_measure_reports_plan_cache_regions():
    """Setup region pays the plan build; the steady region is all hits."""
    import numpy as np
    from repro.core import Environment
    from repro.lib import fft as lfft
    from repro.lib.plan import PlanCache

    comm = Environment().subgroup(1)
    x = comm.container(np.ones((2, 8, 8), np.complex64))
    cache = PlanCache()
    t = measure(lambda: lfft.fft2_batched(x, cache=cache).data,
                warmup=1, iters=3, cache=cache)
    assert t.plan_cache["setup"]["builds"] >= 1
    assert t.plan_cache["steady"]["builds"] == 0
    assert t.plan_cache["steady"]["hit_rate"] == 1.0


def test_scenario_runs_in_process(tmp_path):
    """One real scenario through BenchContext -> schema-valid artifact."""
    from repro.core import Environment

    sc = scenarios()["gridding.plan_cold_vs_hit"]
    ctx = BenchContext(size="tiny", devices=1,
                       comm=Environment().subgroup(1),
                       out_dir=tmp_path, warmup=1, iters=2)
    res = dict(sc.fn(ctx))
    assert res["compile_ms"] > res["steady_ms"]   # cold build >> LRU hit
    run = {"scenario": sc.key, "figure": sc.figure, "devices": 1,
           "size": "tiny", **res}
    art = make_artifact([run], sha="t", host={})
    assert run_key(run) in art["scenarios"]


# ---------------------------------------------------------------------------
# sweep CLI (one subprocess, cheapest figure)
# ---------------------------------------------------------------------------

def test_compare_tooling_is_jax_free():
    """`python -m repro.bench.compare` (and artifact validation) must
    load on hosts without jax — harness/models imports stay lazy."""
    code = (
        "import sys\n"
        "sys.modules['jax'] = None   # poison: any 'import jax' raises\n"
        "import repro.bench.compare\n"
        "from repro.bench import make_artifact, validate_artifact, "
        "compare_artifacts\n"
        "run = dict(scenario='f.x', figure='f', devices=1, size='tiny',\n"
        "           wall_ms=1.0, compile_ms=1.0, steady_ms=1.0)\n"
        "art = make_artifact([run], sha='s', host={})\n"
        "assert compare_artifacts(art, art).ok\n"
        "print('jax-free OK')\n")
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=60,
                       env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 0 and "jax-free OK" in r.stdout, r.stderr


def test_run_cli_rejects_unknown_figure(tmp_path):
    out = tmp_path / "bench.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.bench.run", "--size", "tiny",
         "--devices", "1", "--only", "fig99", "--out", str(out)],
        capture_output=True, text=True, timeout=120,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")}, cwd=str(REPO))
    assert r.returncode != 0
    assert "unknown figure" in r.stderr
    assert not out.exists()     # a typo must never write an empty baseline


def test_run_cli_emits_valid_artifact(tmp_path):
    out = tmp_path / "bench.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.bench.run", "--size", "tiny",
         "--devices", "1", "--only", "gridding", "--out", str(out)],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")}, cwd=str(REPO))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    art = load_artifact(out)
    assert "gridding.plan_cold_vs_hit@d1@tiny" in art["scenarios"]
    assert art["host"]["device_count"] == 1
