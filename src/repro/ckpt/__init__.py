from .checkpoint import (latest_step, list_steps, restore, restore_sharded,
                         save)

__all__ = ["save", "restore", "restore_sharded", "list_steps", "latest_step"]
