"""Checkpointing: atomic, async, keep-N, elastic re-shard on restore.

Layout: <dir>/step_<n>/ {meta.json, arrays.npz} committed via tmp-dir
rename (a partially written checkpoint is never visible).  Leaves are
stored by tree path, so restore works across code refactors that keep
param names, and ``restore_sharded`` re-lays-out every leaf onto an
arbitrary new mesh (elastic scaling: any device count -> any other).
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import time

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(ckpt_dir, step: int, tree, *, keep: int = 3, blocking=True):
    """Atomic checkpoint of an arbitrary pytree of arrays."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    host = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        host[_path_str(path)] = np.asarray(jax.device_get(leaf))

    def _write():
        tmp = ckpt_dir / f".tmp_step_{step}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **host)
        meta = {"step": step, "time": time.time(),
                "keys": sorted(host.keys())}
        (tmp / "meta.json").write_text(json.dumps(meta))
        final = ckpt_dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic commit
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
    for tmp in ckpt_dir.glob(".tmp_step_*"):   # crashed writers
        if time.time() - tmp.stat().st_mtime > 3600:
            shutil.rmtree(tmp, ignore_errors=True)


def list_steps(ckpt_dir) -> list[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "meta.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir):
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, tree_like, step: int | None = None):
    """Restore as host numpy arrays shaped like ``tree_like``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step}" / "arrays.npz")
    paths = {_path_str(p): i for i, (p, _) in enumerate(
        jax.tree_util.tree_leaves_with_path(tree_like))}
    leaves = [None] * len(paths)
    for key, idx in paths.items():
        leaves[idx] = data[key]
    tdef = jax.tree.structure(tree_like)
    return jax.tree.unflatten(tdef, leaves), step


def restore_sharded(ckpt_dir, tree_like, shardings, step=None):
    """Elastic restore: lay every leaf out onto the (possibly different)
    current mesh — checkpoints are mesh-agnostic."""
    host_tree, step = restore(ckpt_dir, tree_like, step)
    dev = jax.tree.map(
        lambda x, sh, like: jax.device_put(
            np.asarray(x, dtype=like.dtype), sh),
        host_tree, shardings, tree_like)
    return dev, step
