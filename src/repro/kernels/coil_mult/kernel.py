"""Coil-sensitivity pointwise ops as Pallas TPU kernels.

The paper maps single pixels to GPU threads for these ops ("custom CUDA
kernels handle the point-wise operations", §3.2).  The TPU shape: tile
the image plane into VMEM rows and run the complex arithmetic on the
VPU.  Complex values travel as separate re/im planes — (X, Y) f32 arrays
tile the (8,128) VREG lanes natively, unlike an interleaved (...,2)
layout.

  coil_forward: grid (J, X/bx)          z_j = c_j * x
  coil_adjoint: grid (X/bx, J) with J the sequential `arbitrary` axis —
                the Sum_j accumulates in VMEM scratch (one pass over the
                channel dim, fused with the M_Omega mask: the arithmetic
                half of the paper's kern_all_red_p2p_2d).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params


def _fwd_kernel(cr, ci, xr, xi, zr, zi):
    a, b = cr[0], ci[0]
    c, d = xr[...], xi[...]
    zr[0] = a * c - b * d
    zi[0] = a * d + b * c


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def coil_forward_pallas(cr, ci, xr, xi, *, bx=32, interpret=True):
    J, X, Y = cr.shape
    bx = min(bx, X)
    assert X % bx == 0
    grid = (J, X // bx)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0)),
            pl.BlockSpec((bx, Y), lambda j, i: (i, 0)),
            pl.BlockSpec((bx, Y), lambda j, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((J, X, Y), cr.dtype)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(cr, ci, xr, xi)


def _lincomb_kernel(ar, ai, xr, xi, br, bi, yr, yi, s, zr, zi):
    # out_j = s * (a*x_j + b*y_j): one VMEM pass over both coil stacks
    a, b = ar[...], ai[...]
    c, d = xr[0], xi[0]
    e, f = br[...], bi[...]
    g, h = yr[0], yi[0]
    re = a * c - b * d + e * g - f * h
    im = a * d + b * c + e * h + f * g
    zr[0] = s[...] * re
    zi[0] = s[...] * im


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def coil_lincomb_pallas(ar, ai, xr, xi, br, bi, yr, yi, s, *,
                        bx=32, interpret=True):
    """out_j = s * (a*x_j + b*y_j); planes (X, Y), stacks (J, X, Y)."""
    J, X, Y = xr.shape
    bx = min(bx, X)
    assert X % bx == 0
    grid = (J, X // bx)
    plane = pl.BlockSpec((bx, Y), lambda j, i: (i, 0))
    stack = pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0))
    return pl.pallas_call(
        _lincomb_kernel,
        grid=grid,
        in_specs=[plane, plane, stack, stack,
                  plane, plane, stack, stack, plane],
        out_specs=[stack, stack],
        out_shape=[jax.ShapeDtypeStruct((J, X, Y), xr.dtype)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(ar, ai, xr, xi, br, bi, yr, yi, s)


def _scale_mult_kernel(ar, ai, xr, xi, s, zr, zi):
    # out_j = s * (a * x_j): the one-term lincomb (G's fov*(rho*c))
    a, b = ar[...], ai[...]
    c, d = xr[0], xi[0]
    zr[0] = s[...] * (a * c - b * d)
    zi[0] = s[...] * (a * d + b * c)


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def coil_scale_mult_pallas(ar, ai, xr, xi, s, *, bx=32, interpret=True):
    """out_j = s * (a * x_j) — coil_lincomb's one-term form, its own
    kernel so the b=None case pays no zero-operand traffic."""
    J, X, Y = xr.shape
    bx = min(bx, X)
    assert X % bx == 0
    grid = (J, X // bx)
    plane = pl.BlockSpec((bx, Y), lambda j, i: (i, 0))
    stack = pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0))
    return pl.pallas_call(
        _scale_mult_kernel,
        grid=grid,
        in_specs=[plane, plane, stack, stack, plane],
        out_specs=[stack, stack],
        out_shape=[jax.ShapeDtypeStruct((J, X, Y), xr.dtype)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(ar, ai, xr, xi, s)


def _plane_mult_kernel(zr, zi, m, outr, outi):
    outr[0] = zr[0] * m[...]
    outi[0] = zi[0] * m[...]


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def plane_mult_pallas(zr, zi, m, *, bx=32, interpret=True):
    """out_j = z_j * m (real plane broadcast over the coil dim)."""
    J, X, Y = zr.shape
    bx = min(bx, X)
    assert X % bx == 0
    grid = (J, X // bx)
    plane = pl.BlockSpec((bx, Y), lambda j, i: (i, 0))
    stack = pl.BlockSpec((1, bx, Y), lambda j, i: (j, i, 0))
    return pl.pallas_call(
        _plane_mult_kernel,
        grid=grid,
        in_specs=[stack, stack, plane],
        out_specs=[stack, stack],
        out_shape=[jax.ShapeDtypeStruct((J, X, Y), zr.dtype)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(zr, zi, m)


def _adj_kernel(cr, ci, zr, zi, m, outr, outi, accr, acci, *, nj):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    a, b = cr[0], ci[0]                      # conj(c) = a - ib
    c, d = zr[0], zi[0]
    accr[...] += a * c + b * d
    acci[...] += a * d - b * c

    @pl.when(j == nj - 1)
    def _final():
        outr[...] = accr[...] * m[...]
        outi[...] = acci[...] * m[...]


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def coil_adjoint_pallas(cr, ci, zr, zi, mask, *, bx=32, interpret=True):
    J, X, Y = cr.shape
    bx = min(bx, X)
    assert X % bx == 0
    grid = (X // bx, J)
    kern = functools.partial(_adj_kernel, nj=J)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bx, Y), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, bx, Y), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, bx, Y), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, bx, Y), lambda i, j: (j, i, 0)),
            pl.BlockSpec((bx, Y), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bx, Y), lambda i, j: (i, 0)),
            pl.BlockSpec((bx, Y), lambda i, j: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((X, Y), cr.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((bx, Y), jnp.float32)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(cr, ci, zr, zi, mask)
