from .ops import coil_forward, coil_adjoint
from .kernel import coil_forward_pallas, coil_adjoint_pallas
from .ref import coil_forward_ref, coil_adjoint_ref

__all__ = ["coil_forward", "coil_adjoint", "coil_forward_pallas",
           "coil_adjoint_pallas", "coil_forward_ref", "coil_adjoint_ref"]
