from .ops import coil_forward, coil_adjoint, coil_lincomb, plane_mult
from .kernel import (coil_forward_pallas, coil_adjoint_pallas,
                     coil_lincomb_pallas, plane_mult_pallas)
from .ref import (coil_forward_ref, coil_adjoint_ref, coil_lincomb_ref,
                  plane_mult_ref)

__all__ = ["coil_forward", "coil_adjoint", "coil_lincomb", "plane_mult",
           "coil_forward_pallas", "coil_adjoint_pallas",
           "coil_lincomb_pallas", "plane_mult_pallas",
           "coil_forward_ref", "coil_adjoint_ref", "coil_lincomb_ref",
           "plane_mult_ref"]
