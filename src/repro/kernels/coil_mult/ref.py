"""Pure-jnp oracle for the coil-sensitivity pointwise operators
(the paper's custom CUDA kernels: C and the channel-summed C^H)."""

import jax.numpy as jnp


def coil_forward_ref(coils, x):
    """z_j = c_j * x.  coils: (J, X, Y) complex, x: (X, Y) complex."""
    return coils * x[None]


def coil_adjoint_ref(coils, z, mask=None):
    """Sum_j conj(c_j) * z_j, optionally masked (M_Omega fused)."""
    out = jnp.sum(jnp.conj(coils) * z, axis=0)
    if mask is not None:
        out = out * mask
    return out
