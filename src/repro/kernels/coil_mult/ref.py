"""Pure-jnp oracle for the coil-sensitivity pointwise operators
(the paper's custom CUDA kernels: C and the channel-summed C^H)."""

import jax.numpy as jnp


def coil_forward_ref(coils, x):
    """z_j = c_j * x.  coils: (J, X, Y) complex, x: (X, Y) complex."""
    return coils * x[None]


def coil_adjoint_ref(coils, z, mask=None):
    """Sum_j conj(c_j) * z_j, optionally masked (M_Omega fused)."""
    out = jnp.sum(jnp.conj(coils) * z, axis=0)
    if mask is not None:
        out = out * mask
    return out


def coil_lincomb_ref(a, x, b=None, y=None, scale=None):
    """Generalized coil linear combination in one pass:

        out_j = scale * (a * x_j + b * y_j)

    ``a``/``b``: (X, Y) complex planes, ``x``/``y``: (J, X, Y) coil
    stacks, ``scale``: (X, Y) real plane (or None).  ``b=None`` drops the
    second term.  Covers the NLINV pointwise chains ``fov*(rho*c)`` (G)
    and ``fov*(drho*c0 + rho0*dc)`` (DG) without intermediates."""
    out = a[None] * x
    if b is not None:
        out = out + b[None] * y
    if scale is not None:
        out = scale[None] * out
    return out


def plane_mult_ref(z, m):
    """Broadcast real-plane multiply ``z_j * m`` (the mask / FOV / Sobolev
    weight application fused as one pointwise pass)."""
    return z * m[None] if z.ndim == m.ndim + 1 else z * m
