"""jit'd complex-array wrappers with backend dispatch for the coil ops."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import coil_adjoint_pallas, coil_forward_pallas
from .ref import coil_adjoint_ref, coil_forward_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def _split(x):
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def coil_forward(coils, x, impl="auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return coil_forward_ref(coils, x)
    cr, ci = _split(coils)
    xr, xi = _split(x)
    zr, zi = coil_forward_pallas(cr, ci, xr, xi, interpret=not _on_tpu())
    return (zr + 1j * zi).astype(coils.dtype)


def coil_adjoint(coils, z, mask=None, impl="auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return coil_adjoint_ref(coils, z, mask)
    cr, ci = _split(coils)
    zr, zi = _split(z)
    m = jnp.ones(coils.shape[1:], jnp.float32) if mask is None \
        else jnp.asarray(mask, jnp.float32)
    outr, outi = coil_adjoint_pallas(cr, ci, zr, zi, m,
                                     interpret=not _on_tpu())
    return (outr + 1j * outi).astype(coils.dtype)
