"""jit'd complex-array wrappers with backend dispatch for the coil ops."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import (coil_adjoint_pallas, coil_forward_pallas,
                     coil_lincomb_pallas, coil_scale_mult_pallas,
                     plane_mult_pallas)
from .ref import (coil_adjoint_ref, coil_forward_ref, coil_lincomb_ref,
                  plane_mult_ref)


def _on_tpu():
    return jax.default_backend() == "tpu"


def _split(x):
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def coil_forward(coils, x, impl="auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return coil_forward_ref(coils, x)
    cr, ci = _split(coils)
    xr, xi = _split(x)
    zr, zi = coil_forward_pallas(cr, ci, xr, xi, interpret=not _on_tpu())
    return (zr + 1j * zi).astype(coils.dtype)


def coil_lincomb(a, x, b=None, y=None, scale=None, impl="auto"):
    """out_j = scale * (a * x_j + b * y_j) in one fused pass — the
    generalized coil pointwise chain of NLINV's G/DG (``fov*(rho*c)``,
    ``fov*(drho*c0 + rho0*dc)``) without materialized intermediates."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return coil_lincomb_ref(a, x, b, y, scale)
    J, X, Y = x.shape
    ar, ai = _split(jnp.broadcast_to(a, (X, Y)))
    xr, xi = _split(x)
    # scale=None streams a ones plane through the kernel; acceptable
    # because every hot-path caller (G/DG) passes the FOV scale — only
    # b=None is frequent enough to warrant its own kernel variant.
    s = jnp.ones((X, Y), jnp.float32) if scale is None \
        else jnp.asarray(scale, jnp.float32)
    if b is None:
        zr, zi = coil_scale_mult_pallas(ar, ai, xr, xi, s,
                                        interpret=not _on_tpu())
        return (zr + 1j * zi).astype(x.dtype)
    br, bi = _split(jnp.broadcast_to(b, (X, Y)))
    yr, yi = _split(y)
    zr, zi = coil_lincomb_pallas(ar, ai, xr, xi, br, bi, yr, yi, s,
                                 interpret=not _on_tpu())
    return (zr + 1j * zi).astype(x.dtype)


def plane_mult(z, m, impl="auto"):
    """z_j * m: the mask / FOV / Sobolev-weight broadcast multiply as one
    fused pointwise pass over the coil stack."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp" or z.ndim != m.ndim + 1:
        return plane_mult_ref(z, jnp.asarray(m, jnp.float32))
    zr, zi = _split(z)
    outr, outi = plane_mult_pallas(zr, zi, jnp.asarray(m, jnp.float32),
                                   interpret=not _on_tpu())
    return (outr + 1j * outi).astype(z.dtype)


def coil_adjoint(coils, z, mask=None, impl="auto"):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return coil_adjoint_ref(coils, z, mask)
    cr, ci = _split(coils)
    zr, zi = _split(z)
    m = jnp.ones(coils.shape[1:], jnp.float32) if mask is None \
        else jnp.asarray(mask, jnp.float32)
    outr, outi = coil_adjoint_pallas(cr, ci, zr, zi, m,
                                     interpret=not _on_tpu())
    return (outr + 1j * outi).astype(coils.dtype)
