"""jit'd complex-array wrappers with registry dispatch for the coil ops.

All four ops share one tiling contract: a (J, X, Y) coil stack blocked
``bx`` rows of X at a time (declared once in the specs below).  The
``supports`` rules also close a hole the old hand-rolled dispatch had:
``auto`` now falls back to the jnp ref for X that doesn't tile instead
of tripping the kernel's divisibility assert on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import registry as kreg
from ..registry import KernelSpec, dim_divisible, on_tpu, split
from .kernel import (coil_adjoint_pallas, coil_forward_pallas,
                     coil_lincomb_pallas, coil_scale_mult_pallas,
                     plane_mult_pallas)
from .ref import (coil_adjoint_ref, coil_forward_ref, coil_lincomb_ref,
                  plane_mult_ref)

_LAYOUT = "(J, X, Y) complex stack -> re/im f32, bx-row blocks of X"
_SPACE = ((8,), (16,), (32,), (64,), (128,))


def _cplx(key, shape):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def _forward_samples(i):
    j, x, y = [(4, 32, 32), (6, 64, 128)][i]
    kc, kx = jax.random.split(jax.random.PRNGKey(300 + i))
    coils, img = _cplx(kc, (j, x, y)), _cplx(kx, (x, y))
    return (coils, img), {}, coil_forward_ref(coils, img)


def _forward_shape_case(seed, m, y):
    if m == 0:
        return None                       # an empty coil plane is not a case
    kc, kx = jax.random.split(jax.random.PRNGKey(seed))
    coils, img = _cplx(kc, (3, m, y)), _cplx(kx, (m, y))
    return (coils, img), {}, coil_forward_ref(coils, img)


def _adjoint_samples(i):
    j, x, y = [(4, 32, 32), (6, 64, 128)][i]
    kc, kz, km = jax.random.split(jax.random.PRNGKey(310 + i), 3)
    coils, z = _cplx(kc, (j, x, y)), _cplx(kz, (j, x, y))
    mask = None if i == 0 else \
        (jax.random.uniform(km, (x, y)) > 0.5).astype(jnp.float32)
    return (coils, z), {"mask": mask}, coil_adjoint_ref(coils, z, mask)


def _adjoint_shape_case(seed, m, y):
    if m == 0:
        return None
    kc, kz = jax.random.split(jax.random.PRNGKey(seed))
    coils, z = _cplx(kc, (3, m, y)), _cplx(kz, (3, m, y))
    return (coils, z), {}, coil_adjoint_ref(coils, z, None)


def _lincomb_samples(i):
    j, x, y = [(4, 32, 32), (6, 64, 64)][i]
    ka, kx, kb, ky, ks = jax.random.split(jax.random.PRNGKey(320 + i), 5)
    a, xs = _cplx(ka, (x, y)), _cplx(kx, (j, x, y))
    if i == 0:                            # b=None scale-mult variant
        scale = jax.random.uniform(ks, (x, y), jnp.float32)
        kw = {"scale": scale}
        return (a, xs), kw, coil_lincomb_ref(a, xs, scale=scale)
    b, ys = _cplx(kb, (x, y)), _cplx(ky, (j, x, y))
    scale = jax.random.uniform(ks, (x, y), jnp.float32)
    kw = {"b": b, "y": ys, "scale": scale}
    return (a, xs), kw, coil_lincomb_ref(a, xs, b, ys, scale)


def _plane_samples(i):
    j, x, y = [(4, 32, 32), (8, 64, 64)][i]
    kz, km = jax.random.split(jax.random.PRNGKey(330 + i))
    z = _cplx(kz, (j, x, y))
    m = jax.random.uniform(km, (x, y), jnp.float32)
    return (z, m), {}, plane_mult_ref(z, m)


def _plane_shape_case(seed, m, y):
    if m == 0:
        return None
    kz, km = jax.random.split(jax.random.PRNGKey(seed))
    z = _cplx(kz, (3, m, y))
    mk = jax.random.uniform(km, (m, y), jnp.float32)
    return (z, mk), {}, plane_mult_ref(z, mk)


COIL_FORWARD = kreg.register(KernelSpec(
    family="coil_mult", name="coil_forward",
    pallas=coil_forward_pallas, ref=coil_forward_ref, fallback="jnp",
    block_args=("bx",), default_block=(32,), block_space=_SPACE,
    supports=lambda block, coils, x, **kw:
        coils.ndim == 3 and x.ndim == 2 and
        dim_divisible(coils.shape[1], block[0]) and coils.shape[0] > 0,
    tol=1e-5, layout=_LAYOUT,
    samples=_forward_samples, nsamples=2,
    shape_case=_forward_shape_case,
))

COIL_ADJOINT = kreg.register(KernelSpec(
    family="coil_mult", name="coil_adjoint",
    pallas=coil_adjoint_pallas, ref=coil_adjoint_ref, fallback="jnp",
    block_args=("bx",), default_block=(32,), block_space=_SPACE,
    supports=lambda block, coils, z, mask=None, **kw:
        coils.ndim == 3 and z.ndim == 3 and
        dim_divisible(coils.shape[1], block[0]) and coils.shape[0] > 0,
    tol=1e-4, layout=_LAYOUT,
    samples=_adjoint_samples, nsamples=2,
    shape_case=_adjoint_shape_case,
))

COIL_LINCOMB = kreg.register(KernelSpec(
    family="coil_mult", name="coil_lincomb",
    pallas=coil_lincomb_pallas, ref=coil_lincomb_ref, fallback="jnp",
    block_args=("bx",), default_block=(32,), block_space=_SPACE,
    supports=lambda block, a, x, b=None, y=None, scale=None, **kw:
        x.ndim == 3 and dim_divisible(x.shape[1], block[0]) and
        x.shape[0] > 0,
    tol=1e-5, layout=_LAYOUT,
    samples=_lincomb_samples, nsamples=2,
))

PLANE_MULT = kreg.register(KernelSpec(
    family="coil_mult", name="plane_mult",
    pallas=plane_mult_pallas, ref=plane_mult_ref, fallback="jnp",
    block_args=("bx",), default_block=(32,), block_space=_SPACE,
    supports=lambda block, z, m, **kw:
        z.ndim == m.ndim + 1 and z.ndim == 3 and
        dim_divisible(z.shape[1], block[0]) and z.shape[0] > 0,
    tol=1e-5, layout=_LAYOUT,
    samples=_plane_samples, nsamples=2,
    shape_case=_plane_shape_case,
))


def coil_forward(coils, x, impl="auto", block=None):
    impl, block = COIL_FORWARD.resolve(impl, block, coils, x)
    if impl != "pallas":
        return coil_forward_ref(coils, x)
    cr, ci = split(coils)
    xr, xi = split(x)
    zr, zi = coil_forward_pallas(cr, ci, xr, xi,
                                 bx=block[0], interpret=not on_tpu())
    return (zr + 1j * zi).astype(coils.dtype)


COIL_FORWARD.dispatch = coil_forward


def coil_lincomb(a, x, b=None, y=None, scale=None, impl="auto", block=None):
    """out_j = scale * (a * x_j + b * y_j) in one fused pass — the
    generalized coil pointwise chain of NLINV's G/DG (``fov*(rho*c)``,
    ``fov*(drho*c0 + rho0*dc)``) without materialized intermediates."""
    impl, block = COIL_LINCOMB.resolve(impl, block, a, x, b=b, y=y,
                                       scale=scale)
    if impl != "pallas":
        return coil_lincomb_ref(a, x, b, y, scale)
    J, X, Y = x.shape
    ar, ai = split(jnp.broadcast_to(a, (X, Y)))
    xr, xi = split(x)
    # scale=None streams a ones plane through the kernel; acceptable
    # because every hot-path caller (G/DG) passes the FOV scale — only
    # b=None is frequent enough to warrant its own kernel variant.
    s = jnp.ones((X, Y), jnp.float32) if scale is None \
        else jnp.asarray(scale, jnp.float32)
    if b is None:
        zr, zi = coil_scale_mult_pallas(ar, ai, xr, xi, s,
                                        bx=block[0], interpret=not on_tpu())
        return (zr + 1j * zi).astype(x.dtype)
    br, bi = split(jnp.broadcast_to(b, (X, Y)))
    yr, yi = split(y)
    zr, zi = coil_lincomb_pallas(ar, ai, xr, xi, br, bi, yr, yi, s,
                                 bx=block[0], interpret=not on_tpu())
    return (zr + 1j * zi).astype(x.dtype)


COIL_LINCOMB.dispatch = coil_lincomb


def plane_mult(z, m, impl="auto", block=None):
    """z_j * m: the mask / FOV / Sobolev-weight broadcast multiply as one
    fused pointwise pass over the coil stack."""
    impl, block = PLANE_MULT.resolve(impl, block, z, m)
    if impl != "pallas":
        return plane_mult_ref(z, jnp.asarray(m, jnp.float32))
    zr, zi = split(z)
    outr, outi = plane_mult_pallas(zr, zi, jnp.asarray(m, jnp.float32),
                                   bx=block[0], interpret=not on_tpu())
    return (outr + 1j * outi).astype(z.dtype)


PLANE_MULT.dispatch = plane_mult


def coil_adjoint(coils, z, mask=None, impl="auto", block=None):
    impl, block = COIL_ADJOINT.resolve(impl, block, coils, z, mask=mask)
    if impl != "pallas":
        return coil_adjoint_ref(coils, z, mask)
    cr, ci = split(coils)
    zr, zi = split(z)
    m = jnp.ones(coils.shape[1:], jnp.float32) if mask is None \
        else jnp.asarray(mask, jnp.float32)
    outr, outi = coil_adjoint_pallas(cr, ci, zr, zi, m,
                                     bx=block[0], interpret=not on_tpu())
    return (outr + 1j * outi).astype(coils.dtype)


COIL_ADJOINT.dispatch = coil_adjoint
