"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage is a kernel triplet: kernel.py (pl.pallas_call +
BlockSpec VMEM tiling), ops.py (jit'd wrapper registering one or more
:class:`~repro.kernels.registry.KernelSpec` entries), ref.py (pure-jnp
oracle).  ``registry.py`` is the shared surface: spec-driven dispatch
(backend routing + block eligibility + block-size choice), a
PlanCache-backed block autotuner, and auto-discovery that the shared
parity/property harness in ``tests/test_kernel_registry.py`` runs on.

  flash_attention   tiled online-softmax attention (causal/window/softcap/GQA)
  mlstm             chunkwise matrix-memory mLSTM (xLSTM)
  rg_lru            blocked linear recurrence (RecurrentGemma)
  coil_mult         NLINV coil pointwise C / fused channel-summed C^H
  gridding          separable-matrix (de)gridding as MXU matmuls
  cg_fused          single-pass CG updates with dot epilogues
  masked_allreduce  fused masked partial-image sum (kern_all_red_p2p_2d)
"""

from . import registry

__all__ = ["registry"]
