"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage is a kernel triplet: kernel.py (pl.pallas_call +
BlockSpec VMEM tiling), ops.py (jit'd wrapper with backend dispatch),
ref.py (pure-jnp oracle used by the allclose sweeps in tests/).

  flash_attention   tiled online-softmax attention (causal/window/softcap/GQA)
  mlstm             chunkwise matrix-memory mLSTM (xLSTM)
  rg_lru            blocked linear recurrence (RecurrentGemma)
  coil_mult         NLINV coil pointwise C / fused channel-summed C^H
  masked_allreduce  fused masked partial-image sum (kern_all_red_p2p_2d)
"""
