"""Declarative kernel-family registry + block-size autotuner (paper §4).

The §4 framework claim — porting new GPU kernels is cheap — rests on
every Pallas family sharing ONE dispatch/test surface instead of each
hand-rolling its own ``_on_tpu``/``_planes``/divisibility plumbing and
hard-coding block shapes.  A :class:`KernelSpec` declares, per kernel
op:

  * the planes/layout contract (``layout``) and the Pallas entry
    (``pallas``) with its named block arguments (``block_args``);
  * the block-shape space the autotuner may sweep (``block_space``)
    and the default choice (``default_block``) — the single source of
    truth the dispatch divisibility check is derived from (the
    ``bm=32`` constant that used to live in both ``cg_fused/ops.py``
    and ``cg_fused/kernel.py``);
  * the jnp ref oracle (``ref``), the CPU fallback rule (``fallback``:
    the impl name ``auto`` routes to off-TPU or when ``supports`` says
    the operands don't tile), and the parity tolerance (``tol``);
  * exemplar inputs (``samples``) and arbitrary-shape generators
    (``shape_case``) that the shared harness in
    ``tests/test_kernel_registry.py`` discovers and sweeps — one
    parametrized parity/fallback/property suite for every family.

Block-size autotuning is a *plan-build* concern (the MGPU plan idiom:
decide once, execute per frame): :func:`autotune` sweeps a spec's block
space on the live backend, caches the winner in a PlanCache keyed on
(spec, backend, shape token, pin), and records it as the spec's current
choice so both plan keys (:func:`choices_token`) and bench artifacts
(:func:`choices`) expose it.  ``REPRO_KERNEL_BLOCKS`` pins choices for
deterministic CI (``default`` pins every spec to its default, or
``family.op=AxB,...`` per spec); ``REPRO_KERNEL_TUNE=1`` forces sweeps
even off-TPU (interpret mode — test/diagnostic use only).
"""

from __future__ import annotations

import dataclasses
import importlib
import math
import os
import pkgutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.plan import Plan, PlanCache

PIN_ENV = "REPRO_KERNEL_BLOCKS"
TUNE_ENV = "REPRO_KERNEL_TUNE"


# ---------------------------------------------------------------------------
# shared backend/plane helpers — the ONE copy of the per-family plumbing
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def split(x):
    """Complex array -> (re, im) f32 planes."""
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def planes(x):
    """Complex (..., Y) -> two (M, Y) f32 row planes (the re/im VREG
    layout every row-blocked kernel family shares)."""
    y = x.shape[-1]
    return [v.reshape(-1, y) for v in split(x)]


def rows(x) -> int:
    """Flattened row count of the (..., Y) -> (M, Y) plane layout."""
    return math.prod(x.shape[:-1])


def rows_divisible(x, bm: int, min_ndim: int = 2) -> bool:
    """THE row-block eligibility rule: flattened rows positive and
    divisible by ``min(bm, rows)`` — mirrors the kernels' own
    ``assert M % bm == 0`` after their ``bm = min(bm, M)`` clamp, so
    dispatch and kernel agree by construction (0 rows never tile)."""
    m = rows(x)
    return x.ndim >= min_ndim and m > 0 and m % min(bm, m) == 0


def dim_divisible(n: int, b: int) -> bool:
    """Single-dimension form of the same clamp-then-divide rule."""
    return n > 0 and n % min(b, n) == 0


# ---------------------------------------------------------------------------
# KernelSpec + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KernelSpec:
    """One registered kernel op: contract, entries, block space, oracle.

    ``supports(block, *args, **kw)`` receives the *dispatch-level*
    operands and decides Pallas eligibility for a concrete block choice;
    ``samples(i)`` returns ``(args, kw, want[, tol])`` exemplars for the
    shared harness; ``shape_case(seed, m, y)`` maps an arbitrary
    (rows, lanes) draw onto family-appropriate operands (or None when
    the draw is meaningless for the family); ``properties`` are
    zero-argument invariant checks (adjointness, epilogue consistency,
    block invariance) the harness runs per spec.
    """

    family: str
    name: str
    pallas: Callable
    ref: Callable
    fallback: str
    block_args: tuple
    default_block: tuple
    block_space: tuple
    supports: Callable
    tol: float
    layout: str = ""
    samples: Callable | None = None
    nsamples: int = 2
    shape_case: Callable | None = None
    properties: tuple = ()
    adjoint_of: str | None = None
    dispatch: Callable | None = None

    @property
    def id(self) -> str:
        return f"{self.family}.{self.name}"

    def pick_block(self, block) -> tuple:
        """Explicit caller block > env pin > current (tuned) choice >
        spec default.  Trace-safe: pure Python on static shapes."""
        if block is not None:
            b = (block,) if isinstance(block, int) else tuple(block)
            if len(b) != len(self.block_args):
                raise ValueError(
                    f"{self.id}: block {b} != arity of {self.block_args}")
            return b
        pin = pinned_block(self)
        if pin is not None:
            return pin
        return current_block(self)

    def resolve(self, impl: str, block, *args, **kw):
        """Resolve ``(impl, block)`` for dispatch: ``auto`` runs Pallas
        on TPU when the operands tile, else the declared fallback; an
        explicit ``pallas`` also degrades to the fallback on shapes the
        kernel cannot tile (never an assert on the hot path)."""
        block = self.pick_block(block)
        if impl == "auto":
            impl = ("pallas" if on_tpu() and self.supports(block, *args, **kw)
                    else self.fallback)
        elif impl == "pallas" and not self.supports(block, *args, **kw):
            impl = self.fallback
        return impl, block

    def block_kw(self, block) -> dict:
        """The chosen block as the Pallas entry's keyword arguments."""
        return dict(zip(self.block_args, block))


_REGISTRY: dict[str, KernelSpec] = {}
_CHOICES: dict[str, dict] = {}
_LOCK = threading.Lock()
_TUNE_CACHE = PlanCache(maxsize=512)
_ensured = False


def register(spec: KernelSpec) -> KernelSpec:
    """Register a spec (idempotent per id; last registration wins)."""
    with _LOCK:
        _REGISTRY[spec.id] = spec
    return spec


def _ensure_all() -> None:
    """Import every ``kernels/`` subpackage so registration is complete
    (auto-discovery: a new family registers by merely existing)."""
    global _ensured
    if _ensured:
        return
    pkg_dir = os.path.dirname(__file__)
    for m in pkgutil.iter_modules([pkg_dir]):
        if m.ispkg:
            importlib.import_module(f"{__package__}.{m.name}")
    _ensured = True


def get(spec_id: str) -> KernelSpec:
    _ensure_all()
    try:
        return _REGISTRY[spec_id]
    except KeyError:
        raise KeyError(f"unknown kernel spec {spec_id!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def specs(family: str | None = None) -> list[KernelSpec]:
    _ensure_all()
    out = [s for s in _REGISTRY.values()
           if family is None or s.family == family]
    return sorted(out, key=lambda s: s.id)


def get_impl(spec_id: str, impl: str = "auto") -> Callable:
    """The spec's dispatch entry with the impl pre-bound — the factory
    the model/solver layers call instead of importing family modules."""
    spec = get(spec_id)
    if spec.dispatch is None:
        raise ValueError(f"{spec_id} has no dispatch attached")

    def bound(*args, **kw):
        kw.setdefault("impl", impl)
        return spec.dispatch(*args, **kw)

    bound.__name__ = f"{spec.name}[{impl}]"
    return bound


# ---------------------------------------------------------------------------
# pinning + current choices
# ---------------------------------------------------------------------------

def pinned_block(spec: KernelSpec) -> tuple | None:
    """The env-pinned block for a spec, or None.  ``default`` pins every
    spec to its default; ``family.op=AxB`` pins one spec."""
    raw = os.environ.get(PIN_ENV, "").strip()
    if not raw:
        return None
    if raw == "default":
        return spec.default_block
    for part in raw.split(","):
        name, _, val = part.partition("=")
        if name.strip() == spec.id and val:
            b = tuple(int(v) for v in val.split("x"))
            if len(b) != len(spec.block_args):
                raise ValueError(f"{PIN_ENV} pin {part!r}: expected "
                                 f"{len(spec.block_args)} dims "
                                 f"({spec.block_args})")
            return b
    return None


def current_block(spec: KernelSpec) -> tuple:
    """Pin > last autotuned choice > spec default."""
    pin = pinned_block(spec)
    if pin is not None:
        return pin
    with _LOCK:
        c = _CHOICES.get(spec.id)
    return tuple(c["block"]) if c else spec.default_block


def choices(family: str | None = None) -> dict:
    """JSON-able snapshot of every (selected) spec's current block
    choice and where it came from — what bench scenarios put in
    ``extra.kernel_blocks``."""
    out = {}
    for spec in specs(family):
        pin = pinned_block(spec)
        with _LOCK:
            c = _CHOICES.get(spec.id)
        if pin is not None:
            blk, src = pin, "pinned"
        elif c is not None:
            blk, src = tuple(c["block"]), c["source"]
        else:
            blk, src = spec.default_block, "default"
        out[spec.id] = {"block": "x".join(str(v) for v in blk),
                        "source": src}
    return out


def choices_token(families) -> tuple:
    """Hashable (spec id, current block) pairs for the given families —
    plan keys include this so a changed tuning choice (or pin) builds a
    distinct plan instead of silently reusing a stale one."""
    toks = []
    for fam in families:
        for spec in specs(fam):
            toks.append((spec.id, current_block(spec)))
    return tuple(sorted(toks))


def reset_choices() -> None:
    """Drop recorded choices (tests); pins and the tune cache remain."""
    with _LOCK:
        _CHOICES.clear()


def tune_cache() -> PlanCache:
    """The PlanCache backing the autotuner (its hit/miss counters are
    the 'zero steady-state rebuilds' evidence)."""
    return _TUNE_CACHE


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def autotune(spec_id: str, sample: Callable | None = None, *,
             token: tuple = (), cache: PlanCache | None = None,
             iters: int = 3) -> tuple:
    """Resolve (and on TPU, sweep) the block choice for a spec at one
    problem geometry.

    ``sample`` is a zero-arg thunk returning ``(args, kw)`` concrete
    operands — only invoked when a sweep actually runs, so callers may
    pass a lazily-built zeros payload.  ``token`` is the hashable
    geometry identity the sweep result is cached under.  Pinned specs
    and off-TPU backends resolve immediately (pin / default) — sweeps
    of interpret-mode kernels would measure the interpreter, not the
    hardware — unless ``REPRO_KERNEL_TUNE=1`` forces one.  The winner
    is recorded as the spec's current choice (see
    :func:`current_block` / :func:`choices_token`).
    """
    spec = get(spec_id)
    cache = _TUNE_CACHE if cache is None else cache
    pin = pinned_block(spec)
    backend = jax.default_backend()
    key = ("kernel_tune", spec.id, backend, tuple(token), pin)

    def build():
        table: dict[str, float] = {}
        if pin is not None:
            choice, source = pin, "pinned"
        elif (sample is None or len(spec.block_space) <= 1
              or not (on_tpu() or os.environ.get(TUNE_ENV, "0") == "1")):
            choice, source = spec.default_block, "default"
        else:
            args, kw = sample()
            cands = [b for b in spec.block_space
                     if spec.supports(tuple(b), *args, **kw)]
            for b in cands:
                b = tuple(b)
                run = lambda: spec.dispatch(*args, impl="pallas",
                                            block=b, **kw)
                jax.block_until_ready(run())          # compile outside
                best = float("inf")
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(run())
                    best = min(best, time.perf_counter() - t0)
                table["x".join(str(v) for v in b)] = round(best * 1e3, 4)
            if table:
                win = min(table, key=table.get)
                choice = tuple(int(v) for v in win.split("x"))
                source = "swept"
            else:
                choice, source = spec.default_block, "unsupported"
        return Plan.value(key, tuple(choice),
                          lib="kernels", op=f"tune.{spec.id}",
                          meta={"block": tuple(choice), "source": source,
                                "table": table})

    plan = cache.get_or_build(key, build)
    choice = tuple(plan.meta["block"])
    with _LOCK:
        _CHOICES[spec.id] = {"block": choice,
                             "source": plan.meta["source"]}
    return choice
