"""mLSTM chunkwise-parallel form (pure JAX) + backend dispatch.

The chunkwise form turns the sequential cell into per-chunk matmuls
(MXU work) plus one state hand-off per chunk — the linear-attention
factorization that makes mLSTM trainable at sequence length.  The Pallas
kernel (kernel.py) runs the same math with the state in VMEM scratch.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .. import registry as kreg
from ..registry import KernelSpec, dim_divisible, on_tpu
from .kernel import mlstm_pallas
from .ref import init_state, mlstm_ref

NEG = -1e30


def _unroll_default() -> bool:
    # see flash_attention.ops._unroll_default (dry-run cost honesty)
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk=128):
    """Chunkwise-parallel mLSTM.  Shapes as in ref.py."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = init_state(B, H, dk, dv)
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        padf = lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, pad)] +
                                 [(0, 0)] * (x.ndim - 3))
        q, k, v, log_i, log_f = map(padf, (q, k, v, log_i, log_f))
    Sp = S + pad
    nC = Sp // L

    qf = q.astype(jnp.float32) * (dk ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    lf = log_f.astype(jnp.float32)
    if pad:  # padded steps: f=1 (log 1 = 0), i = -inf -> no-ops
        mask = jnp.arange(Sp) < S
        li = jnp.where(mask, li, NEG)
        lf = jnp.where(mask, lf, 0.0)

    def chunk_fn(carry, xs):
        C, n, m = carry                      # (B,H,dk,dv), (B,H,dk), (B,H)
        qc, kc, vc, lic, lfc = xs            # (B,H,L,*)
        c = jnp.cumsum(lfc, axis=-1)         # inclusive logf cumsum
        # intra-chunk log weights W[t,s] = c_t - c_s + li_s  (s <= t)
        Wmat = c[..., :, None] - c[..., None, :] + lic[..., None, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        Wmat = jnp.where(tri, Wmat, NEG)
        m_intra = jnp.max(Wmat, axis=-1)                   # (B,H,L)
        m_inter = c + m[..., None]                         # (B,H,L)
        m_t = jnp.maximum(m_intra, m_inter)
        D = jnp.exp(Wmat - m_t[..., None])                 # decay matrix
        scores = jnp.einsum("bhtk,bhsk->bhts", qc, kc) * D
        h_num = jnp.einsum("bhts,bhsv->bhtv", scores, vc)
        h_num += jnp.exp(m_inter - m_t)[..., None] * \
            jnp.einsum("bhtk,bhkv->bhtv", qc, C)
        n_t = jnp.einsum("bhts,bhsk->bhtk", D, kc)
        n_t += jnp.exp(m_inter - m_t)[..., None] * n[..., None, :]
        den = jnp.maximum(jnp.abs(jnp.einsum("bhtk,bhtk->bht", qc, n_t)),
                          jnp.exp(-m_t))
        h = h_num / den[..., None]
        # -- state hand-off
        cL = c[..., -1:]                                    # (B,H,1)
        w_out = cL - c + lic                                # (B,H,L)
        m_new = jnp.maximum(cL[..., 0] + m, jnp.max(w_out, axis=-1))
        scale_old = jnp.exp(cL[..., 0] + m - m_new)
        wk = jnp.exp(w_out - m_new[..., None])
        C = scale_old[..., None, None] * C + \
            jnp.einsum("bhs,bhsk,bhsv->bhkv", wk, kc, vc)
        n = scale_old[..., None] * n + jnp.einsum("bhs,bhsk->bhk", wk, kc)
        return (C, n, m_new), h

    xs = tuple(x.reshape(B, H, nC, L, *x.shape[3:]).transpose(
        2, 0, 1, 3, *range(4, x.ndim + 1)) for x in (qf, kf, vf, li, lf))
    (C, n, m), hs = jax.lax.scan(chunk_fn, state, xs,
                                 unroll=nC if _unroll_default() else 1)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sp, dv)[:, :, :S]
    return h.astype(v.dtype), (C, n, m)


def _gated(seed, b, h, s, dk, dv):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, h, s, dk))
    k = jax.random.normal(ks[1], (b, h, s, dk))
    v = jax.random.normal(ks[2], (b, h, s, dv))
    li = jax.random.normal(ks[3], (b, h, s)) - 1.0
    lf = -jnp.abs(jax.random.normal(ks[4], (b, h, s))) * 0.1
    return q, k, v, li, lf


def _mlstm_samples(i):
    b, h, s, dk, dv = [(1, 2, 256, 64, 64), (2, 1, 96, 32, 64)][i]
    args = _gated(600 + i, b, h, s, dk, dv)
    return args, {}, mlstm_ref(*args)


def _mlstm_shape_case(seed, m, y):
    if m == 0:
        return None
    args = _gated(seed, 1, 2, m, max(8, min(y, 64)), 32)
    return args, {}, mlstm_ref(*args)


MLSTM = kreg.register(KernelSpec(
    family="mlstm", name="mlstm_scan",
    pallas=mlstm_pallas, ref=mlstm_ref, fallback="chunkwise",
    block_args=("chunk",), default_block=(128,),
    block_space=((32,), (64,), (128,), (256,)),
    # the kernel starts from zero state only (prior state folds in via
    # the chunkwise path) and does not pad S
    supports=lambda block, q, k, v, log_i, log_f, state=None, **kw:
        state is None and dim_divisible(q.shape[2], block[0]),
    tol=2e-3,
    layout="(B, H, S, D) heads; time split into `chunk` MXU chunks",
    samples=_mlstm_samples, nsamples=2,
    shape_case=_mlstm_shape_case,
))


def mlstm_scan(q, k, v, log_i, log_f, state=None, impl="auto", chunk=None,
               block=None):
    if block is None:
        env = os.environ.get("REPRO_MLSTM_CHUNK")
        if chunk is not None:
            block = (chunk,)
        elif env is not None:
            block = (int(env),)
    impl, block = MLSTM.resolve(impl, block, q, k, v, log_i, log_f,
                                state=state)
    if impl == "pallas":
        return mlstm_pallas(q, k, v, log_i, log_f, state,
                            chunk=block[0], interpret=not on_tpu())
    if impl == "chunkwise":
        return mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk=block[0])
    if impl == "ref":
        return mlstm_ref(q, k, v, log_i, log_f, state)
    raise ValueError(impl)


MLSTM.dispatch = mlstm_scan


def mlstm_step(q, k, v, log_i, log_f, state):
    """Single decode step; q,k (B,H,dk), v (B,H,dv), gates (B,H)."""
    C, n, m = state
    dk = q.shape[-1]
    qf = q.astype(jnp.float32) * (dk ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, log_i)
    fs = jnp.exp(log_f + m - m_new)
    is_ = jnp.exp(log_i - m_new)
    C = fs[..., None, None] * C + is_[..., None, None] * \
        kf[..., :, None] * vf[..., None, :]
    n = fs[..., None] * n + is_[..., None] * kf
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(v.dtype)
    return h, (C, n, m_new)
