from .ops import mlstm_scan, mlstm_chunkwise, mlstm_step
from .ref import mlstm_ref, init_state
from .kernel import mlstm_pallas

__all__ = ["mlstm_scan", "mlstm_chunkwise", "mlstm_step", "mlstm_ref",
           "mlstm_pallas", "init_state"]
