"""Chunkwise mLSTM as a Pallas TPU kernel.

Same factorization as ops.mlstm_chunkwise, with the inter-chunk state
(C, n, m) carried in VMEM scratch across the sequential chunk grid axis.
Intra-chunk work is three MXU matmuls (q k^T, scores v, D k); the decay
matrix D is built on VPU from cumulative log-gates.

  grid = (B, H, S/L)            semantics (parallel, parallel, arbitrary)
  blocks: q,k (1,1,L,dk)  v (1,1,L,dv)  gates (1,1,L)
  scratch: C (dk, dv) f32, n (1, dk) f32, m (1, 1) f32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,
            h_ref, Cout_ref, nout_ref, mout_ref,
            C_ref, n_ref, m_ref, *, L: int, nc: int, dk: int, dv: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0, 0].astype(jnp.float32) * (dk ** -0.5)     # (L, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)                  # (L,)
    lf = lf_ref[0, 0].astype(jnp.float32)
    C, n, m = C_ref[...], n_ref[0], m_ref[0, 0]

    c = jnp.cumsum(lf)                                     # (L,)
    W = c[:, None] - c[None, :] + li[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    W = jnp.where(tri, W, NEG)
    m_intra = jnp.max(W, axis=1)
    m_inter = c + m
    m_t = jnp.maximum(m_intra, m_inter)
    D = jnp.exp(W - m_t[:, None])
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * D
    h_num = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    h_num += jnp.exp(m_inter - m_t)[:, None] * jax.lax.dot_general(
        q, C, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_t = jax.lax.dot_general(D, k, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    n_t += jnp.exp(m_inter - m_t)[:, None] * n[None, :]
    den = jnp.maximum(jnp.abs(jnp.sum(q * n_t, axis=1)), jnp.exp(-m_t))
    h_ref[0, 0] = (h_num / den[:, None]).astype(h_ref.dtype)

    # -- state hand-off
    cL = c[L - 1]
    w_out = cL - c + li
    m_new = jnp.maximum(cL + m, jnp.max(w_out))
    wk = jnp.exp(w_out - m_new)
    C_new = jnp.exp(cL + m - m_new) * C + jax.lax.dot_general(
        k * wk[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_new = jnp.exp(cL + m - m_new) * n + jnp.sum(k * wk[:, None], axis=0)
    C_ref[...] = C_new
    n_ref[0] = n_new
    m_ref[0, 0] = m_new

    @pl.when(ci == nc - 1)
    def _final():
        Cout_ref[0, 0] = C_new
        nout_ref[0, 0] = n_new
        mout_ref[0, 0] = m_new.reshape(1)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_pallas(q, k, v, log_i, log_f, state=None, *, chunk=128,
                 interpret=True):
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    if state is not None and any(
            jnp.any(jnp.asarray(s) != 0) for s in jax.tree.leaves(state)):
        raise NotImplementedError(
            "mlstm_pallas starts from zero state; fold prior state via ops")
    kernel = functools.partial(_kernel, L=L, nc=nc, dk=dk, dv=dv)
    grid = (B, H, nc)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, h, c: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dv), v.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dk), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_i, log_f)
    return h, (C, n, m[..., 0])
