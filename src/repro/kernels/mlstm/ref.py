"""Pure-jnp oracle for the mLSTM cell (xLSTM): sequential stabilized scan.

Shapes: q, k (B, H, S, dk); v (B, H, S, dv); log_i, log_f (B, H, S).
State: C (B, H, dk, dv), n (B, H, dk), m (B, H); stored state is scaled
so that C_true = C * exp(m) (log-space stabilization from the paper).

    m_t = max(log_f_t + m_{t-1}, log_i_t)
    C_t = exp(log_f_t + m_{t-1} - m_t) C_{t-1} + exp(log_i_t - m_t) k_t v_t^T
    n_t = exp(log_f_t + m_{t-1} - m_t) n_{t-1} + exp(log_i_t - m_t) k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))
"""

import jax
import jax.numpy as jnp
import numpy as np


def mlstm_ref(q, k, v, log_i, log_f, state=None):
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = init_state(B, H, dk, dv)
    C0, n0, m0 = state
    qf = q.astype(jnp.float32) * (dk ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    li, lf = log_i.astype(jnp.float32), log_f.astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, lit, lft = xs
        m_new = jnp.maximum(lft + m, lit)
        fs = jnp.exp(lft + m - m_new)[..., None]
        is_ = jnp.exp(lit - m_new)[..., None]
        C = fs[..., None] * C + is_[..., None] * kt[..., :, None] * vt[..., None, :]
        n = fs * n + is_ * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (jnp.moveaxis(qf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(li, 2, 0),
          jnp.moveaxis(lf, 2, 0))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 2).astype(v.dtype)
    return h, (C, n, m)


def init_state(B, H, dk, dv):
    return (jnp.zeros((B, H, dk, dv), jnp.float32),
            jnp.zeros((B, H, dk), jnp.float32),
            jnp.zeros((B, H), jnp.float32))
