"""RG-LRU linear recurrence as a Pallas TPU kernel.

Blocked over (batch, width); the time dimension is the trailing
`arbitrary` grid axis, so the carried state h lives in VMEM scratch
across time-chunks.  Inside a chunk, a fori_loop walks the bs time steps
on VPU registers — elementwise FMA, no MXU.  This is the TPU-native shape
of the scan: HBM traffic is exactly one read of (log_a, b) and one write
of h per element, which is the roofline floor for a first-order
recurrence.

  grid = (B/bb, W/bw, S/bs)   semantics (parallel, parallel, arbitrary)
  blocks: (bb, bs, bw) in VMEM; scratch h (bb, bw) f32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params


def _kernel(log_a_ref, b_ref, h0_ref, h_ref, hlast_ref, hs_ref, *,
            bs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        hs_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        a = jnp.exp(log_a_ref[:, t, :].astype(jnp.float32))
        h = a * h + b_ref[:, t, :].astype(jnp.float32)
        h_ref[:, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, hs_ref[...])
    hs_ref[...] = h

    @pl.when(si == ns - 1)
    def _final():
        hlast_ref[...] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bw", "bs", "interpret"))
def rg_lru_pallas(log_a, b, h0, *, bb=8, bw=128, bs=256, interpret=True):
    B, S, W = b.shape
    bb, bw, bs = min(bb, B), min(bw, W), min(bs, S)
    assert B % bb == 0 and W % bw == 0 and S % bs == 0
    ns = S // bs
    grid = (B // bb, W // bw, ns)
    kernel = functools.partial(_kernel, bs=bs, ns=ns)
    h, hlast = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, bw), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((bb, bs, bw), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((bb, bw), lambda i, j, t: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bs, bw), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((bb, bw), lambda i, j, t: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), b.dtype),
            jax.ShapeDtypeStruct((B, W), b.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bb, bw), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b, h0)
    return h, hlast
