from .ops import rg_lru_scan, rg_lru_step
from .kernel import rg_lru_pallas
from .ref import rg_lru_ref

__all__ = ["rg_lru_scan", "rg_lru_step", "rg_lru_pallas", "rg_lru_ref"]
