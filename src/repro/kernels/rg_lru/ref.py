"""Pure-jnp oracle for the RG-LRU linear recurrence (RecurrentGemma).

    h_t = a_t * h_{t-1} + b_t,   a_t = exp(log_a_t)

log_a, b: (B, S, W); h0: (B, W).  Returns (h: (B, S, W), h_last).
"""

import jax
import jax.numpy as jnp


def rg_lru_ref(log_a, b, h0):
    def step(h, ab):
        la, bt = ab
        h = jnp.exp(la) * h + bt
        return h, h

    xs = (jnp.moveaxis(log_a, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32))
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype), h_last.astype(b.dtype)
