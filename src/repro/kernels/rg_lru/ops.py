"""jit'd wrapper for the RG-LRU scan with backend dispatch.

  pallas       TPU kernel (interpret on CPU),
  associative  jax.lax.associative_scan (log-depth; XLA path used on CPU
               and for the dry-run — same FLOP/byte class),
  ref          sequential lax.scan oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rg_lru_pallas
from .ref import rg_lru_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def rg_lru_scan(log_a, b, h0, impl="auto"):
    """h_t = exp(log_a_t) h_{t-1} + b_t.  Shapes: (B,S,W), h0 (B,W)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "associative"
    if impl == "pallas":
        return rg_lru_pallas(log_a, b, h0, interpret=not _on_tpu())
    if impl == "associative":
        return _assoc(log_a, b, h0)
    if impl == "ref":
        return rg_lru_ref(log_a, b, h0)
    raise ValueError(impl)


def _assoc(log_a, b, h0):
    laf = log_a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    # fold h0 into the first step: b_0 <- a_0 * h0 + b_0
    bf = bf.at[:, 0].add(jnp.exp(laf[:, 0]) * h0.astype(jnp.float32))

    def combine(x, y):
        (la1, b1), (la2, b2) = x, y
        return la1 + la2, b1 * jnp.exp(la2) + b2

    la_c, h = jax.lax.associative_scan(combine, (laf, bf), axis=1)
    return h.astype(b.dtype), h[:, -1].astype(b.dtype)


def rg_lru_step(log_a, b, h):
    """Single decode step: (B,W) each."""
    return (jnp.exp(log_a.astype(jnp.float32)) * h.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(b.dtype)
