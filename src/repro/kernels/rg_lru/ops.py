"""jit'd wrapper for the RG-LRU scan with registry dispatch.

  pallas       TPU kernel (interpret on CPU),
  associative  jax.lax.associative_scan (log-depth; XLA path used on CPU
               and for the dry-run — same FLOP/byte class),
  ref          sequential lax.scan oracle.

The (bb, bw, bs) batch/width/time tile triple lives in the registry
spec (autotunable), not in this wrapper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import registry as kreg
from ..registry import KernelSpec, dim_divisible, on_tpu
from .kernel import rg_lru_pallas
from .ref import rg_lru_ref


def _lru_inputs(seed, b, s, w, dtype=jnp.float32):
    ka, kb, kh = jax.random.split(jax.random.PRNGKey(seed), 3)
    log_a = -jnp.abs(jax.random.normal(ka, (b, s, w))) * 0.1
    bb = jax.random.normal(kb, (b, s, w))
    h0 = jax.random.normal(kh, (b, w))
    return (log_a.astype(dtype), bb.astype(dtype), h0.astype(dtype))


def _lru_samples(i):
    if i == 2:  # bf16 coverage (was a bespoke parity case)
        args = _lru_inputs(702, 2, 256, 128, jnp.bfloat16)
        return args, {}, rg_lru_ref(*args), 5e-2
    b, s, w = [(1, 64, 128), (2, 512, 256)][i]
    args = _lru_inputs(700 + i, b, s, w)
    return args, {}, rg_lru_ref(*args)


def _lru_shape_case(seed, m, y):
    if m == 0:
        return None
    args = _lru_inputs(seed, 2, m, y)
    return args, {}, rg_lru_ref(*args)


RG_LRU = kreg.register(KernelSpec(
    family="rg_lru", name="rg_lru_scan",
    pallas=rg_lru_pallas, ref=rg_lru_ref, fallback="associative",
    block_args=("bb", "bw", "bs"), default_block=(8, 128, 256),
    block_space=((8, 128, 128), (8, 128, 256), (8, 128, 512),
                 (4, 128, 256), (8, 256, 256)),
    supports=lambda block, log_a, b, h0, **kw:
        dim_divisible(log_a.shape[0], block[0]) and
        dim_divisible(log_a.shape[2], block[1]) and
        dim_divisible(log_a.shape[1], block[2]),
    tol=1e-4,
    layout="(B, S, W) gated scan; (bb, bs, bw) VMEM tiles, time arbitrary",
    samples=_lru_samples, nsamples=3,
    shape_case=_lru_shape_case,
))


def rg_lru_scan(log_a, b, h0, impl="auto", block=None):
    """h_t = exp(log_a_t) h_{t-1} + b_t.  Shapes: (B,S,W), h0 (B,W)."""
    impl, block = RG_LRU.resolve(impl, block, log_a, b, h0)
    if impl == "pallas":
        return rg_lru_pallas(log_a, b, h0, bb=block[0], bw=block[1],
                             bs=block[2], interpret=not on_tpu())
    if impl == "associative":
        return _assoc(log_a, b, h0)
    if impl == "ref":
        return rg_lru_ref(log_a, b, h0)
    raise ValueError(impl)


RG_LRU.dispatch = rg_lru_scan


def _assoc(log_a, b, h0):
    laf = log_a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    # fold h0 into the first step: b_0 <- a_0 * h0 + b_0
    bf = bf.at[:, 0].add(jnp.exp(laf[:, 0]) * h0.astype(jnp.float32))

    def combine(x, y):
        (la1, b1), (la2, b2) = x, y
        return la1 + la2, b1 * jnp.exp(la2) + b2

    la_c, h = jax.lax.associative_scan(combine, (laf, bf), axis=1)
    return h.astype(b.dtype), h[:, -1].astype(b.dtype)


def rg_lru_step(log_a, b, h):
    """Single decode step: (B,W) each."""
    return (jnp.exp(log_a.astype(jnp.float32)) * h.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(b.dtype)
