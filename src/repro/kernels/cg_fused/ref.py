"""Pure-jnp oracle for the fused CG vector updates.

The unfused CG body makes three full passes over the iterate pytree
(``x += a*p``, ``r -= a*Ap``, ``rs = <r, r>``) plus a fourth for
``p = r + b*p``.  The fused forms below are the single-pass semantics the
Pallas kernels implement; on non-TPU backends they ARE the hot path
(XLA fuses the expression into one loop over the operands)."""

import jax.numpy as jnp


def cg_update_ref(alpha, p, ap, x, r):
    """Single-pass CG update: ``x' = x + alpha*p``, ``r' = r - alpha*Ap``
    and the residual dot-product epilogue ``rs = sum |r'|^2`` (real f32),
    over ONE array (callers map it over the iterate pytree).  ``rs`` is a
    local partial on segmented operands — the caller reduces it."""
    x2 = x + alpha * p
    r2 = r - alpha * ap
    return x2, r2, jnp.real(jnp.vdot(r2, r2)).astype(jnp.float32)


def xpby_dot_ref(x, y, beta):
    """Fused ``w = x + beta*y`` with the ``sum |w|^2`` dot epilogue (the
    CG search-direction step ``p = r + beta*p``)."""
    w = x + beta * y
    return w, jnp.real(jnp.vdot(w, w)).astype(jnp.float32)
