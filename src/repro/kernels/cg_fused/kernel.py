"""Fused CG vector updates as Pallas TPU kernels.

The 2017 follow-up ("Accelerated Computing in MRI", Schaetz et al.)
attributes a large share of its real-time NLINV win to fusing the CG
pointwise/vector chains into single kernels.  The TPU shape of that
optimization: one pass over VMEM-resident row tiles performs both vector
updates AND accumulates the dot-product epilogue in scratch, instead of
three separate passes (axpy, axpy, dot) over HBM.

Complex values travel as separate re/im planes — (M, Y) f32 arrays tile
the (8,128) VREG lanes natively (same convention as ``coil_mult`` /
``gridding``).  The iterate pytree's leaves are flattened to (M, Y) by
``ops.py``; the grid walks row blocks sequentially (``arbitrary``) so
the scalar epilogue accumulates across blocks in SMEM scratch.

  cg_update: x' = x + a*p, r' = r - a*Ap, rs = sum |r'|^2
  xpby_dot:  w  = x + b*y,                d  = sum |w|^2
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params


def _scalar_spec():
    return pl.BlockSpec(memory_space=pltpu.SMEM)


def _cg_update_kernel(alpha, pr, pi, apr, api, xr, xi, rr, ri,
                      xro, xio, rro, rio, rso, acc, *, nblk):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[0, 0] = 0.0

    a = alpha[0]
    xro[...] = xr[...] + a * pr[...]
    xio[...] = xi[...] + a * pi[...]
    r2r = rr[...] - a * apr[...]
    r2i = ri[...] - a * api[...]
    rro[...] = r2r
    rio[...] = r2i
    acc[0, 0] += jnp.sum(r2r * r2r) + jnp.sum(r2i * r2i)

    @pl.when(i == nblk - 1)
    def _final():
        rso[0] = acc[0, 0]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def cg_update_pallas(alpha, pr, pi, apr, api, xr, xi, rr, ri, *,
                     bm=32, interpret=True):
    """Planes are (M, Y) f32; ``alpha`` is a (1,) f32 array (SMEM).
    Returns (xr', xi', rr', ri', rs) with ``rs`` a (1,) f32."""
    M, Y = pr.shape
    bm = min(bm, M)
    assert M % bm == 0
    nblk = M // bm
    row = pl.BlockSpec((bm, Y), lambda i: (i, 0))
    kern = functools.partial(_cg_update_kernel, nblk=nblk)
    return pl.pallas_call(
        kern,
        grid=(nblk,),
        in_specs=[_scalar_spec()] + [row] * 8,
        out_specs=[row] * 4 + [_scalar_spec()],
        out_shape=[jax.ShapeDtypeStruct((M, Y), pr.dtype)] * 4 +
                  [jax.ShapeDtypeStruct((1,), jnp.float32)],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(alpha, pr, pi, apr, api, xr, xi, rr, ri)


def _xpby_kernel(beta, xr, xi, yr, yi, wro, wio):
    b = beta[0]
    wro[...] = xr[...] + b * yr[...]
    wio[...] = xi[...] + b * yi[...]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def xpby_pallas(beta, xr, xi, yr, yi, *, bm=32, interpret=True):
    """``w = x + b*y`` without the dot epilogue — the CG search-direction
    step, whose epilogue the solver discards (an opaque pallas_call is
    not DCE-able, so the no-epilogue form is its own kernel)."""
    M, Y = xr.shape
    bm = min(bm, M)
    assert M % bm == 0
    row = pl.BlockSpec((bm, Y), lambda i: (i, 0))
    return pl.pallas_call(
        _xpby_kernel,
        grid=(M // bm,),
        in_specs=[_scalar_spec()] + [row] * 4,
        out_specs=[row] * 2,
        out_shape=[jax.ShapeDtypeStruct((M, Y), xr.dtype)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(beta, xr, xi, yr, yi)


def _xpby_dot_kernel(beta, xr, xi, yr, yi, wro, wio, do, acc, *, nblk):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc[0, 0] = 0.0

    b = beta[0]
    wr = xr[...] + b * yr[...]
    wi = xi[...] + b * yi[...]
    wro[...] = wr
    wio[...] = wi
    acc[0, 0] += jnp.sum(wr * wr) + jnp.sum(wi * wi)

    @pl.when(i == nblk - 1)
    def _final():
        do[0] = acc[0, 0]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def xpby_dot_pallas(beta, xr, xi, yr, yi, *, bm=32, interpret=True):
    """Planes are (M, Y) f32; ``beta`` is a (1,) f32 array (SMEM).
    Returns (wr, wi, d) with ``d`` a (1,) f32."""
    M, Y = xr.shape
    bm = min(bm, M)
    assert M % bm == 0
    nblk = M // bm
    row = pl.BlockSpec((bm, Y), lambda i: (i, 0))
    kern = functools.partial(_xpby_dot_kernel, nblk=nblk)
    return pl.pallas_call(
        kern,
        grid=(nblk,),
        in_specs=[_scalar_spec()] + [row] * 4,
        out_specs=[row] * 2 + [_scalar_spec()],
        out_shape=[jax.ShapeDtypeStruct((M, Y), xr.dtype)] * 2 +
                  [jax.ShapeDtypeStruct((1,), jnp.float32)],
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(beta, xr, xi, yr, yi)
