"""Complex-array wrappers with backend dispatch for the fused CG steps.

On TPU the single-pass Pallas kernels run natively; elsewhere the ref
path is used directly (it is the same single-expression fusion, which
XLA compiles to one loop — interpret-mode Pallas would only slow the
hot path down).  Shapes are arbitrary: leaves are flattened to (M, Y)
row planes for the kernels and restored afterwards.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import cg_update_pallas, xpby_dot_pallas, xpby_pallas
from .ref import cg_update_ref, xpby_dot_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def _split(x):
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def _planes(x):
    """Complex (..., Y) -> two (M, Y) f32 planes."""
    y = x.shape[-1]
    return [v.reshape(-1, y) for v in _split(x)]


def _divisible(x, bm=32):
    """Mirror of the kernels' row-block check (bm must match kernel.py's
    default): flattened row count divisible by min(bm, rows)."""
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return x.ndim >= 2 and m % min(bm, m) == 0


def cg_update(alpha, p, ap, x, r, impl="auto"):
    """Fused ``x' = x + alpha*p``, ``r' = r - alpha*Ap`` with the
    ``rs = sum |r'|^2`` epilogue; one pass over the operands.
    Returns ``(x', r', rs)``; ``rs`` is a real f32 scalar (a local
    partial when the operands are shards)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp" or not _divisible(p):
        return cg_update_ref(alpha, p, ap, x, r)
    a = jnp.reshape(jnp.real(alpha).astype(jnp.float32), (1,))
    planes = [*_planes(p), *_planes(ap), *_planes(x), *_planes(r)]
    pr, pi, apr, api, xr, xi, rr, ri = planes
    xr2, xi2, rr2, ri2, rs = cg_update_pallas(
        a, pr, pi, apr, api, xr, xi, rr, ri, interpret=not _on_tpu())
    x2 = (xr2 + 1j * xi2).reshape(x.shape).astype(x.dtype)
    r2 = (rr2 + 1j * ri2).reshape(r.shape).astype(r.dtype)
    return x2, r2, rs[0]


def xpby_dot(x, y, beta, impl="auto", with_dot=True):
    """Fused ``w = x + beta*y`` with the ``d = sum |w|^2`` epilogue (the
    CG search-direction step).  Returns ``(w, d)``; ``with_dot=False``
    skips the epilogue entirely (``d`` is None) — callers that discard
    it must not pay for an un-DCE-able in-kernel reduction."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp" or not _divisible(x):
        if not with_dot:
            return x + beta * y, None
        return xpby_dot_ref(x, y, beta)
    b = jnp.reshape(jnp.real(beta).astype(jnp.float32), (1,))
    xr, xi = _planes(x)
    yr, yi = _planes(y)
    if not with_dot:
        wr, wi = xpby_pallas(b, xr, xi, yr, yi, interpret=not _on_tpu())
        return (wr + 1j * wi).reshape(x.shape).astype(x.dtype), None
    wr, wi, d = xpby_dot_pallas(b, xr, xi, yr, yi, interpret=not _on_tpu())
    w = (wr + 1j * wi).reshape(x.shape).astype(x.dtype)
    return w, d[0]
