"""Complex-array wrappers with registry dispatch for the fused CG steps.

On TPU the single-pass Pallas kernels run natively; elsewhere the ref
path is used directly (it is the same single-expression fusion, which
XLA compiles to one loop — interpret-mode Pallas would only slow the
hot path down).  Shapes are arbitrary: leaves are flattened to (M, Y)
row planes for the kernels and restored afterwards.  Backend routing,
the row-block eligibility rule, and the block-size choice all come
from the shared :mod:`repro.kernels.registry` specs below — the row
block ``bm`` lives in ONE place (``default_block``) instead of being
duplicated between this module and ``kernel.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import registry as kreg
from ..registry import KernelSpec, on_tpu, planes, rows_divisible
from .kernel import cg_update_pallas, xpby_dot_pallas, xpby_pallas
from .ref import cg_update_ref, xpby_dot_ref


def _cplx(key, shape):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def _cg_update_case(keys, shape, alpha=0.37):
    p, ap, x, r = (_cplx(k, shape) for k in keys)
    a = jnp.float32(alpha)
    return (a, p, ap, x, r), {}, cg_update_ref(a, p, ap, x, r)


def _cg_update_samples(i):
    shape = [(32, 32), (4, 16, 48), (96, 128)][i]
    keys = jax.random.split(jax.random.PRNGKey(100 + i), 4)
    return _cg_update_case(keys, shape)


def _cg_update_shape_case(seed, m, y):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    return _cg_update_case(keys, (m, y))


def _xpby_case(keys, shape, beta=0.61):
    x, y = (_cplx(k, shape) for k in keys)
    b = jnp.float32(beta)
    return (x, y, b), {}, xpby_dot_ref(x, y, b)


def _xpby_samples(i):
    shape = [(32, 48), (2, 32, 64)][i]
    keys = jax.random.split(jax.random.PRNGKey(200 + i), 2)
    return _xpby_case(keys, shape)


def _xpby_shape_case(seed, m, y):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    return _xpby_case(keys, (m, y))


def _xpby_nodot_consistency(seed=0):
    """Property: the no-epilogue variant returns the identical ``w``
    (the separate kernel exists only because the opaque in-kernel dot
    cannot be DCE'd)."""
    args, _, _ = _xpby_samples(seed % 2)
    w_dot, d = xpby_dot(*args, impl="pallas")
    w_only, none = xpby_dot(*args, impl="pallas", with_dot=False)
    assert none is None and d is not None
    assert jnp.allclose(w_dot, w_only, atol=1e-6)


CG_UPDATE = kreg.register(KernelSpec(
    family="cg_fused", name="cg_update",
    pallas=cg_update_pallas, ref=cg_update_ref, fallback="jnp",
    block_args=("bm",), default_block=(32,),
    block_space=((8,), (16,), (32,), (64,), (128,)),
    supports=lambda block, alpha, p, ap, x, r, **kw:
        rows_divisible(p, block[0]),
    tol=1e-4,
    layout="complex leaves -> re/im (M, Y) f32 row planes, bm-row blocks",
    samples=_cg_update_samples, nsamples=3,
    shape_case=_cg_update_shape_case,
))

XPBY_DOT = kreg.register(KernelSpec(
    family="cg_fused", name="xpby_dot",
    pallas=xpby_dot_pallas, ref=xpby_dot_ref, fallback="jnp",
    block_args=("bm",), default_block=(32,),
    block_space=((8,), (16,), (32,), (64,), (128,)),
    supports=lambda block, x, y, beta, **kw: rows_divisible(x, block[0]),
    tol=1e-4,
    layout="complex leaves -> re/im (M, Y) f32 row planes, bm-row blocks",
    samples=_xpby_samples, nsamples=2,
    shape_case=_xpby_shape_case,
    properties=(_xpby_nodot_consistency,),
))


def cg_update(alpha, p, ap, x, r, impl="auto", block=None):
    """Fused ``x' = x + alpha*p``, ``r' = r - alpha*Ap`` with the
    ``rs = sum |r'|^2`` epilogue; one pass over the operands.
    Returns ``(x', r', rs)``; ``rs`` is a real f32 scalar (a local
    partial when the operands are shards)."""
    impl, block = CG_UPDATE.resolve(impl, block, alpha, p, ap, x, r)
    if impl != "pallas":
        return cg_update_ref(alpha, p, ap, x, r)
    a = jnp.reshape(jnp.real(alpha).astype(jnp.float32), (1,))
    pr, pi, apr, api, xr, xi, rr, ri = [
        *planes(p), *planes(ap), *planes(x), *planes(r)]
    xr2, xi2, rr2, ri2, rs = cg_update_pallas(
        a, pr, pi, apr, api, xr, xi, rr, ri,
        bm=block[0], interpret=not on_tpu())
    x2 = (xr2 + 1j * xi2).reshape(x.shape).astype(x.dtype)
    r2 = (rr2 + 1j * ri2).reshape(r.shape).astype(r.dtype)
    return x2, r2, rs[0]


CG_UPDATE.dispatch = cg_update


def xpby_dot(x, y, beta, impl="auto", with_dot=True, block=None):
    """Fused ``w = x + beta*y`` with the ``d = sum |w|^2`` epilogue (the
    CG search-direction step).  Returns ``(w, d)``; ``with_dot=False``
    skips the epilogue entirely (``d`` is None) — callers that discard
    it must not pay for an un-DCE-able in-kernel reduction."""
    impl, block = XPBY_DOT.resolve(impl, block, x, y, beta)
    if impl != "pallas":
        if not with_dot:
            return x + beta * y, None
        return xpby_dot_ref(x, y, beta)
    b = jnp.reshape(jnp.real(beta).astype(jnp.float32), (1,))
    xr, xi = planes(x)
    yr, yi = planes(y)
    if not with_dot:
        wr, wi = xpby_pallas(b, xr, xi, yr, yi,
                             bm=block[0], interpret=not on_tpu())
        return (wr + 1j * wi).reshape(x.shape).astype(x.dtype), None
    wr, wi, d = xpby_dot_pallas(b, xr, xi, yr, yi,
                                bm=block[0], interpret=not on_tpu())
    w = (wr + 1j * wi).reshape(x.shape).astype(x.dtype)
    return w, d[0]


XPBY_DOT.dispatch = xpby_dot
