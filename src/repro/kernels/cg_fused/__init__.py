from .kernel import cg_update_pallas, xpby_dot_pallas
from .ops import cg_update, xpby_dot
from .ref import cg_update_ref, xpby_dot_ref

__all__ = [
    "cg_update", "xpby_dot",
    "cg_update_pallas", "xpby_dot_pallas",
    "cg_update_ref", "xpby_dot_ref",
]
