from .ops import flash_attention, chunked_attention, decode_attention
from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "chunked_attention", "decode_attention",
           "flash_attention_pallas", "attention_ref"]
