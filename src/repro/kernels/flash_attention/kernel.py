"""Flash attention as a Pallas TPU kernel.

TPU-native design (NOT a CUDA port): the grid's innermost dimension
iterates KV blocks *sequentially* per core (TPU grids are sequential over
the trailing `arbitrary` dimension), so the online-softmax running state
(m, l, acc) lives in VMEM scratch that persists across KV steps — the TPU
analogue of a CUDA thread-block's shared-memory accumulator, but sized to
VMEM and MXU tiles:

  grid = (B, Hq, nQ, nK)        semantics (parallel, parallel, parallel, arbitrary)
  q block   (1, 1, bq, D)       VMEM, MXU-aligned bq, D multiples of 128
  k/v block (1, 1, bk, D)       indexed by kv head = q head // group
  scratch   acc (bq, D) f32, m/l (bq, 128) f32

Causal + sliding-window blocks that are fully masked are skipped via
``pl.when`` (no MXU work), which is what makes the causal kernel ~2x
cheaper — block-level skipping replaces CUDA's early-exit warps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref, *,
               bq: int, bk: int, nk: int, causal: bool,
               window: int | None, softcap: float | None,
               q_offset: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this q/k block
    q_lo = q_offset + qi * bq
    k_lo = ki * bk

    # block-level skip: block is live unless fully masked
    live = True
    if causal:
        live = jnp.asarray(k_lo <= q_lo + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, (q_lo - (k_lo + bk - 1)) < window)
    live = jnp.logical_and(live, k_lo < kv_len_ref[0])

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len_ref[0]
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, (q_pos - k_pos) < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)                     # rescale old acc
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_cur

    @pl.when(ki == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "q_offset",
                              "scale", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, kv_len=None, *, causal=True, window=None,
                           softcap=None, q_offset=0, scale=None,
                           bq=128, bk=128, interpret=True):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D).  S % bq == 0, T % bk == 0.

    ``interpret=True`` runs the kernel body on CPU for validation; on a
    real TPU backend pass ``interpret=False``.
    """
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = float(1.0 / np.sqrt(D)) if scale is None else float(scale)
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    kv_len = jnp.full((1,), T if kv_len is None else kv_len, jnp.int32)

    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, scale=scale)

    grid = (B, Hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j, *_: (b, h, i, 0)),
                pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, *_: (b, h // g, j, 0)),
                pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j, *_: (b, h // g, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, Dv),
                                   lambda b, h, i, j, *_: (b, h, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, Dv), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, Dv), q.dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(kv_len, q, k, v)
