"""Pure-jnp oracle for flash attention (naive O(S^2) materialization).

Semantics shared by all implementations:
  - GQA: Hq = g * Hkv, query head h attends with kv head h // g.
  - causal mask with absolute positions: q position = q_offset + i.
  - optional sliding window: attend iff 0 <= q_pos - k_pos < window.
  - optional logit softcap (gemma2): l = cap * tanh(l / cap).
  - optional kv_len: keys at positions >= kv_len are masked (padding /
    decode with a partially-filled cache).
All accumulation in float32.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(s, 1e-30)


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  kv_len=None, q_offset=0, scale=None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D) -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    g = Hq // Hkv
    scale = (1.0 / np.sqrt(D)) if scale is None else scale

    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1)

    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = q_offset + jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    if kv_len is not None:
        mask &= k_pos < kv_len
    logits = jnp.where(mask[None, None], logits, -1e30)
    out = jnp.einsum("bhst,bhtd->bhsd", _softmax(logits), vf)
    return out.astype(q.dtype)
