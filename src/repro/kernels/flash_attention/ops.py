"""jit'd public wrapper for flash attention with registry dispatch.

  impl="pallas"   the TPU Pallas kernel (interpret=True on CPU),
  impl="chunked"  pure-JAX online-softmax over KV blocks (lax.scan) —
                  identical memory behaviour to the kernel (no S^2
                  materialization); the CPU/dry-run path,
  impl="naive"    the O(S^2) oracle (small shapes only),
  impl="auto"     pallas on TPU (when S/T tile), chunked elsewhere.

The model layer always calls ``flash_attention``/``decode_attention``;
which backend runs is a deployment decision, not a model change.  The
(bq, bk) tile pair is a registry spec field (autotunable, env-pinnable)
rather than a constant baked into this wrapper.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .. import registry as kreg
from ..registry import KernelSpec, dim_divisible, on_tpu
from .kernel import flash_attention_pallas
from .ref import attention_ref


def _unroll_default() -> bool:
    # Dry-run costing sets this: XLA's HloCostAnalysis counts a while
    # body once, so the KV-chunk scan must be unrolled for the compiled
    # FLOP/byte numbers to reflect the real work (roofline honesty).
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def _qkv(seed, b, hq, hkv, s, t, d, dtype=jnp.float32):
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(kq, (b, hq, s, d), dtype),
            jax.random.normal(kk, (b, hkv, t, d), dtype),
            jax.random.normal(kv_, (b, hkv, t, d), dtype))


def _flash_samples(i):
    # causal MHA / causal GQA with q_offset / window+softcap /
    # kv_len+non-causal / bf16 — the coverage the bespoke parity file had
    if i == 0:
        args = _qkv(500, 1, 2, 2, 128, 128, 64)
        kw = {"causal": True}
    elif i == 1:
        args = _qkv(501, 2, 4, 2, 128, 256, 64)
        kw = {"causal": True, "q_offset": 128}
    elif i == 2:
        args = _qkv(502, 1, 4, 4, 256, 256, 64)
        kw = {"causal": True, "window": 64, "softcap": 30.0}
    elif i == 3:
        args = _qkv(503, 1, 2, 2, 128, 256, 64)
        kw = {"causal": False, "kv_len": 200}
    else:
        args = _qkv(504, 1, 2, 2, 128, 128, 64, jnp.bfloat16)
        kw = {"causal": True}
    tol = 2e-2 if i == 4 else 2e-3
    return args, kw, attention_ref(*args, **kw), tol


def _flash_shape_case(seed, m, y):
    if m == 0:
        return None                      # zero-length sequences are invalid
    d = max(8, min(y, 64))
    args = _qkv(seed, 1, 2, 2, m, m, d)
    kw = {"causal": True}
    return args, kw, attention_ref(*args, **kw)


def _block_invariance(seed=0):
    """Property: the online-softmax result is tile-shape independent —
    any (bq, bk) in the spec space produces the same output."""
    args, kw, want, tol = _flash_samples(seed % 2)
    a = flash_attention(*args, impl="pallas", block=(128, 64), **kw)
    b = flash_attention(*args, impl="pallas", block=(64, 128), **kw)
    assert jnp.max(jnp.abs(a.astype(jnp.float32) -
                           b.astype(jnp.float32))) < 1e-5


FLASH = kreg.register(KernelSpec(
    family="flash_attention", name="flash_attention",
    pallas=flash_attention_pallas, ref=attention_ref, fallback="chunked",
    block_args=("bq", "bk"), default_block=(128, 128),
    block_space=((64, 64), (64, 128), (128, 64), (128, 128),
                 (128, 256), (256, 128), (256, 256)),
    supports=lambda block, q, k, v, **kw:
        dim_divisible(q.shape[2], block[0]) and
        dim_divisible(k.shape[2], block[1]),
    tol=2e-3,
    layout="(B, H, S, D) heads; Q rows x KV cols tiled (bq, bk)",
    samples=_flash_samples, nsamples=5,
    shape_case=_flash_shape_case,
    properties=(_block_invariance,),
))


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    kv_len=None, q_offset=0, scale=None, impl="auto",
                    block_q=None, block_k=None, block=None):
    if block is None and (block_q is not None or block_k is not None):
        cur = FLASH.pick_block(None)
        block = (block_q or cur[0], block_k or cur[1])
    impl, block = FLASH.resolve(impl, block, q, k, v)
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, kv_len, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale, bq=block[0], bk=block[1],
            interpret=not on_tpu())
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, kv_len=kv_len,
                                 q_offset=q_offset, scale=scale,
                                 block_k=block[1])
    if impl == "naive":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, kv_len=kv_len,
                             q_offset=q_offset, scale=scale)
    raise ValueError(impl)


FLASH.dispatch = flash_attention


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      kv_len=None, q_offset=0, scale=None, block_k=None):
    """Online-softmax attention scanning KV in blocks (pure JAX)."""
    if block_k is None:
        block_k = int(os.environ.get("REPRO_BLOCK_K", "512"))
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = (1.0 / np.sqrt(D)) if scale is None else scale
    bk = min(block_k, T)
    # pad T to a block multiple; padded keys are masked via kv_len
    Tp = -(-T // bk) * bk
    eff_len = jnp.asarray(T if kv_len is None else kv_len, jnp.int32)
    if Tp != T:
        pad = [(0, 0), (0, 0), (0, Tp - T), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nk = Tp // bk

    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(B, Hkv, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nk, bk, Dv).transpose(2, 0, 1, 3, 4)

    q_pos = q_offset + jnp.arange(S)
    m0 = jnp.full((B, Hq, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, S), jnp.float32)
    acc0 = jnp.zeros((B, Hq, S, Dv), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp                                  # (B,Hkv,bk,D)
        kj = jnp.repeat(kj.astype(jnp.float32), g, axis=1)
        vj = jnp.repeat(vj.astype(jnp.float32), g, axis=1)
        s = jnp.einsum("bhsd,bhtd->bhst", qf, kj)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bk + jnp.arange(bk)
        mask = (k_pos[None, :] < eff_len)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhst,bhtd->bhsd", p, vj)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nk), kb, vb),
        unroll=nk if _unroll_default() else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(q, k, v, *, kv_len, window=None, softcap=None,
                     scale=None, k_positions=None):
    """Single-token decode: q (B, Hq, 1, D) against a (B, Hkv, T, D) cache.

    One pass, memory-bound.  By default cache slot t holds absolute
    position t and positions >= kv_len are masked; a rolling (windowed)
    cache passes explicit ``k_positions`` (B, T) with -1 for empty slots.
    The query's absolute position is kv_len - 1.
    """
    B, Hq, _, D = q.shape
    _, Hkv, T, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = (1.0 / np.sqrt(D)) if scale is None else scale
    # grouped-query einsums, NOT jnp.repeat: repeat breaks GSPMD's
    # propagation of a sequence-sharded cache (it would gather T).
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhtd->bhgt", qf, kf)            # (B,Hkv,g,T)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.asarray(kv_len, jnp.int32) - 1
    if k_positions is None:
        k_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    else:
        k_pos = jnp.asarray(k_positions, jnp.int32)
    k_pos = k_pos[:, None, None, :]                      # (B,1,1,T)
    qp = jnp.reshape(jnp.broadcast_to(q_pos, (B,)), (-1, 1, 1, 1))
    mask = (k_pos >= 0) & (k_pos <= qp)
    if window is not None:
        mask = mask & ((qp - k_pos) < window)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, vf) / jnp.maximum(
        jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return out.reshape(B, Hq, 1, Dv).astype(q.dtype)
