"""jit'd public wrapper for flash attention with backend dispatch.

  impl="pallas"   the TPU Pallas kernel (interpret=True on CPU),
  impl="chunked"  pure-JAX online-softmax over KV blocks (lax.scan) —
                  identical memory behaviour to the kernel (no S^2
                  materialization); the CPU/dry-run path,
  impl="naive"    the O(S^2) oracle (small shapes only),
  impl="auto"     pallas on TPU, chunked elsewhere.

The model layer always calls ``flash_attention``/``decode_attention``;
which backend runs is a deployment decision, not a model change.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import flash_attention_pallas
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _unroll_default() -> bool:
    # Dry-run costing sets this: XLA's HloCostAnalysis counts a while
    # body once, so the KV-chunk scan must be unrolled for the compiled
    # FLOP/byte numbers to reflect the real work (roofline honesty).
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    kv_len=None, q_offset=0, scale=None, impl="auto",
                    block_q=128, block_k=128):
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "chunked"
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, kv_len, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale, bq=block_q, bk=block_k,
            interpret=not _on_tpu())
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, kv_len=kv_len,
                                 q_offset=q_offset, scale=scale,
                                 block_k=block_k)
    if impl == "naive":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, kv_len=kv_len,
                             q_offset=q_offset, scale=scale)
    raise ValueError(impl)


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      kv_len=None, q_offset=0, scale=None, block_k=None):
    """Online-softmax attention scanning KV in blocks (pure JAX)."""
    if block_k is None:
        block_k = int(os.environ.get("REPRO_BLOCK_K", "512"))
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = (1.0 / np.sqrt(D)) if scale is None else scale
    bk = min(block_k, T)
    # pad T to a block multiple; padded keys are masked via kv_len
    Tp = -(-T // bk) * bk
    eff_len = jnp.asarray(T if kv_len is None else kv_len, jnp.int32)
    if Tp != T:
        pad = [(0, 0), (0, 0), (0, Tp - T), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    nk = Tp // bk

    qf = q.astype(jnp.float32) * scale
    kb = k.reshape(B, Hkv, nk, bk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nk, bk, Dv).transpose(2, 0, 1, 3, 4)

    q_pos = q_offset + jnp.arange(S)
    m0 = jnp.full((B, Hq, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, S), jnp.float32)
    acc0 = jnp.zeros((B, Hq, S, Dv), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp                                  # (B,Hkv,bk,D)
        kj = jnp.repeat(kj.astype(jnp.float32), g, axis=1)
        vj = jnp.repeat(vj.astype(jnp.float32), g, axis=1)
        s = jnp.einsum("bhsd,bhtd->bhst", qf, kj)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = j * bk + jnp.arange(bk)
        mask = (k_pos[None, :] < eff_len)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhst,bhtd->bhsd", p, vj)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nk), kb, vb),
        unroll=nk if _unroll_default() else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(q, k, v, *, kv_len, window=None, softcap=None,
                     scale=None, k_positions=None):
    """Single-token decode: q (B, Hq, 1, D) against a (B, Hkv, T, D) cache.

    One pass, memory-bound.  By default cache slot t holds absolute
    position t and positions >= kv_len are masked; a rolling (windowed)
    cache passes explicit ``k_positions`` (B, T) with -1 for empty slots.
    The query's absolute position is kv_len - 1.
    """
    B, Hq, _, D = q.shape
    _, Hkv, T, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = (1.0 / np.sqrt(D)) if scale is None else scale
    # grouped-query einsums, NOT jnp.repeat: repeat breaks GSPMD's
    # propagation of a sequence-sharded cache (it would gather T).
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhtd->bhgt", qf, kf)            # (B,Hkv,g,T)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.asarray(kv_len, jnp.int32) - 1
    if k_positions is None:
        k_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    else:
        k_pos = jnp.asarray(k_positions, jnp.int32)
    k_pos = k_pos[:, None, None, :]                      # (B,1,1,T)
    qp = jnp.reshape(jnp.broadcast_to(q_pos, (B,)), (-1, 1, 1, 1))
    mask = (k_pos >= 0) & (k_pos <= qp)
    if window is not None:
        mask = mask & ((qp - k_pos) < window)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, vf) / jnp.maximum(
        jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return out.reshape(B, Hq, 1, Dv).astype(q.dtype)
