"""Masked G-way partial sum as a Pallas TPU kernel — the compute half of
the paper's ``kern_all_red_p2p_2d``.

The CUDA original has each GPU read its 3 peers' buffers over PCIe P2P
and sum 4 pointers inside one kernel, masking to the 2-D section that
M_Omega keeps.  TPUs expose no cross-chip loads at this level, so the
transport is a shard_map psum (ICI) — see ops.masked_psum_crop — and
this kernel fuses what remains local: sum the G gathered partials + mask
in one VMEM pass (instead of G adds + 1 mask kernel = 2x HBM traffic).

  grid (X/bx,): block (G, bx, Y) re/im in VMEM, sum over axis 0 on VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params


def _kernel(pr, pi, m, outr, outi):
    outr[...] = jnp.sum(pr[...], axis=0) * m[...]
    outi[...] = jnp.sum(pi[...], axis=0) * m[...]


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def masked_sum_pallas(pr, pi, mask, *, bx=32, interpret=True):
    G, X, Y = pr.shape
    bx = min(bx, X)
    assert X % bx == 0
    return pl.pallas_call(
        _kernel,
        grid=(X // bx,),
        in_specs=[
            pl.BlockSpec((G, bx, Y), lambda i: (0, i, 0)),
            pl.BlockSpec((G, bx, Y), lambda i: (0, i, 0)),
            pl.BlockSpec((bx, Y), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bx, Y), lambda i: (i, 0)),
            pl.BlockSpec((bx, Y), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((X, Y), pr.dtype)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(pr, pi, mask)
