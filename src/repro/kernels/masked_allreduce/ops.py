"""Wrappers: local fused masked-sum (registry dispatch) and the
distributed ``masked_psum_crop`` — the full TPU adaptation of the
paper's P2P all-reduce: crop to the M_Omega section (4x fewer bytes,
the grid is doubled), psum over the ICI axis, re-pad."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .. import registry as kreg
from ..registry import KernelSpec, dim_divisible, on_tpu
from .kernel import masked_sum_pallas
from .ref import masked_sum_ref


def _case(seed, g, x, y):
    kp, km = jax.random.split(jax.random.PRNGKey(seed))
    kr, ki = jax.random.split(kp)
    partials = (jax.random.normal(kr, (g, x, y)) +
                1j * jax.random.normal(ki, (g, x, y))).astype(jnp.complex64)
    mask = (jax.random.uniform(km, (x, y)) > 0.4).astype(jnp.float32)
    return (partials, mask), {}, masked_sum_ref(partials, mask)


def _masked_samples(i):
    g, x, y = [(4, 32, 32), (2, 96, 128)][i]
    return _case(800 + i, g, x, y)


def _masked_shape_case(seed, m, y):
    if m == 0:
        return None
    return _case(seed, 3, m, y)


MASKED_SUM = kreg.register(KernelSpec(
    family="masked_allreduce", name="masked_sum",
    pallas=masked_sum_pallas, ref=masked_sum_ref, fallback="jnp",
    block_args=("bx",), default_block=(32,),
    block_space=((8,), (16,), (32,), (64,), (128,)),
    supports=lambda block, partials, mask, **kw:
        partials.ndim == 3 and partials.shape[0] > 0 and
        dim_divisible(partials.shape[1], block[0]),
    tol=1e-4,
    layout="(G, X, Y) partial stack -> re/im f32, bx-row blocks of X",
    samples=_masked_samples, nsamples=2,
    shape_case=_masked_shape_case,
))


def masked_sum(partials, mask, impl="auto", block=None):
    """partials (G, X, Y) complex -> mask * sum_g (local, fused)."""
    impl, block = MASKED_SUM.resolve(impl, block, partials, mask)
    if impl != "pallas":
        return masked_sum_ref(partials, mask)
    pr = jnp.real(partials).astype(jnp.float32)
    pi = jnp.imag(partials).astype(jnp.float32)
    outr, outi = masked_sum_pallas(pr, pi, jnp.asarray(mask, jnp.float32),
                                   bx=block[0], interpret=not on_tpu())
    return (outr + 1j * outi).astype(partials.dtype)


MASKED_SUM.dispatch = masked_sum


def masked_psum_crop(x, mask, axis):
    """Distributed form (call inside shard_map): each shard holds one
    partial (X, Y); only the centered FOV quarter crosses the wire."""
    g = x.shape[-1]
    q = g // 4
    crop = lax.psum(x[..., q:3 * q, q:3 * q], axis)
    out = jnp.zeros_like(x).at[..., q:3 * q, q:3 * q].set(
        crop * mask[q:3 * q, q:3 * q])
    return out
