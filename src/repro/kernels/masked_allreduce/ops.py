"""Wrappers: local fused masked-sum (Pallas/jnp dispatch) and the
distributed ``masked_psum_crop`` — the full TPU adaptation of the
paper's P2P all-reduce: crop to the M_Omega section (4x fewer bytes,
the grid is doubled), psum over the ICI axis, re-pad."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernel import masked_sum_pallas
from .ref import masked_sum_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def masked_sum(partials, mask, impl="auto"):
    """partials (G, X, Y) complex -> mask * sum_g (local, fused)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        return masked_sum_ref(partials, mask)
    pr = jnp.real(partials).astype(jnp.float32)
    pi = jnp.imag(partials).astype(jnp.float32)
    outr, outi = masked_sum_pallas(pr, pi, jnp.asarray(mask, jnp.float32),
                                   interpret=not _on_tpu())
    return (outr + 1j * outi).astype(partials.dtype)


def masked_psum_crop(x, mask, axis):
    """Distributed form (call inside shard_map): each shard holds one
    partial (X, Y); only the centered FOV quarter crosses the wire."""
    g = x.shape[-1]
    q = g // 4
    crop = lax.psum(x[..., q:3 * q, q:3 * q], axis)
    out = jnp.zeros_like(x).at[..., q:3 * q, q:3 * q].set(
        crop * mask[q:3 * q, q:3 * q])
    return out
