from .ops import masked_sum, masked_psum_crop
from .kernel import masked_sum_pallas
from .ref import masked_sum_ref

__all__ = ["masked_sum", "masked_psum_crop", "masked_sum_pallas",
           "masked_sum_ref"]
