"""Pure-jnp oracle for the masked partial-image reduction
(paper §3.2: kern_all_red_p2p_2d + the M_Omega mask applied right after)."""

import jax.numpy as jnp


def masked_sum_ref(partials, mask):
    """partials: (G, X, Y) complex partial images; mask: (X, Y) ->
    mask * Sum_g partials_g."""
    return mask * jnp.sum(partials, axis=0)
