"""Complex-array wrappers + interpolation-matrix builder for the
gridding kernels, with backend dispatch (Pallas on TPU, jnp matmul
elsewhere; both compute the identical separable operator)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import degrid_pallas, grid_pallas


def _on_tpu():
    return jax.default_backend() == "tpu"


def _split(x):
    return jnp.real(x).astype(jnp.float32), jnp.imag(x).astype(jnp.float32)


def interp_matrices(traj, grid: int, pad_to: int = 128):
    """Dense separable bilinear interpolation matrices for a trajectory.

    traj: (S, 2) float (x, y) points in grid units.  Returns (Ax, Ay)
    float32 numpy arrays of shape (Sp, grid) with Sp = S padded up to a
    multiple of ``pad_to`` — padded rows are all-zero, so they sample
    (and scatter) nothing.  Two nonzeros per row; periodic wrap matches
    the ``ref.py`` oracle.  This runs ONCE per trajectory, at plan-build
    time (the MGPU plan idiom: precompute geometry, execute per frame).
    """
    t = np.asarray(traj, np.float64)
    S = t.shape[0]
    Sp = -(-S // pad_to) * pad_to
    i0 = np.floor(t).astype(np.int64)
    f = (t - i0).astype(np.float32)
    rows = np.arange(S)

    def one_axis(idx, frac):
        A = np.zeros((Sp, grid), np.float32)
        A[rows, idx % grid] = 1.0 - frac
        # += : the two corners coincide when grid == 1 (degenerate)
        np.add.at(A, (rows, (idx + 1) % grid), frac)
        return A

    return one_axis(i0[:, 0], f[:, 0]), one_axis(i0[:, 1], f[:, 1])


def _degrid_jnp(ax, ay, g):
    # out[j, s] = sum_v (ax @ g_j)[s, v] * ay[s, v]
    return jnp.einsum("su,juv,sv->js", ax, g, ay)


def _grid_jnp(ax, ay, y):
    # g_j = ax^T @ (y_j[:, None] * ay)
    return jnp.einsum("su,js,sv->juv", ax, y, ay)


def degrid(g, ax, ay, impl: str = "auto"):
    """g: (J, X, Y) complex grid -> (J, Sp) complex samples (padded rows
    read zero)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    ax = jnp.asarray(ax)
    ay = jnp.asarray(ay)
    if impl == "jnp":
        return _degrid_jnp(ax, ay, g)
    gr, gi = _split(g)
    outr, outi = degrid_pallas(ax, ay, gr, gi, interpret=not _on_tpu())
    return (outr + 1j * outi).astype(g.dtype)


def grid_adjoint(y, ax, ay, impl: str = "auto"):
    """Adjoint: y (J, Sp) complex samples -> (J, X, Y) complex grid."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    ax = jnp.asarray(ax)
    ay = jnp.asarray(ay)
    if impl == "jnp":
        return _grid_jnp(ax, ay, y)
    yr, yi = _split(y)
    outr, outi = grid_pallas(ax, ay, yr, yi, interpret=not _on_tpu())
    return (outr + 1j * outi).astype(y.dtype)
