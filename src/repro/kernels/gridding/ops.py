"""Complex-array wrappers + interpolation-matrix builder for the
gridding kernels, with registry dispatch (Pallas on TPU, jnp matmul
elsewhere; both compute the identical separable operator).  The specs
declare the sample-block tiling ``bs`` and link ``grid_adjoint`` to
``degrid`` as its adjoint; the spec samples check both against the
independent per-sample gather/scatter oracle in ``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import registry as kreg
from ..registry import KernelSpec, dim_divisible, on_tpu, split
from .kernel import degrid_pallas, grid_pallas
from .ref import degrid_ref, grid_ref


def interp_matrices(traj, grid: int, pad_to: int = 128):
    """Dense separable bilinear interpolation matrices for a trajectory.

    traj: (S, 2) float (x, y) points in grid units.  Returns (Ax, Ay)
    float32 numpy arrays of shape (Sp, grid) with Sp = S padded up to a
    multiple of ``pad_to`` — padded rows are all-zero, so they sample
    (and scatter) nothing.  Two nonzeros per row; periodic wrap matches
    the ``ref.py`` oracle.  This runs ONCE per trajectory, at plan-build
    time (the MGPU plan idiom: precompute geometry, execute per frame).
    """
    t = np.asarray(traj, np.float64)
    S = t.shape[0]
    Sp = -(-S // pad_to) * pad_to
    i0 = np.floor(t).astype(np.int64)
    f = (t - i0).astype(np.float32)
    rows = np.arange(S)

    def one_axis(idx, frac):
        A = np.zeros((Sp, grid), np.float32)
        A[rows, idx % grid] = 1.0 - frac
        # += : the two corners coincide when grid == 1 (degenerate)
        np.add.at(A, (rows, (idx + 1) % grid), frac)
        return A

    return one_axis(i0[:, 0], f[:, 0]), one_axis(i0[:, 1], f[:, 1])


def _degrid_jnp(ax, ay, g):
    # out[j, s] = sum_v (ax @ g_j)[s, v] * ay[s, v]
    return jnp.einsum("su,juv,sv->js", ax, g, ay)


def _grid_jnp(ax, ay, y):
    # g_j = ax^T @ (y_j[:, None] * ay)
    return jnp.einsum("su,js,sv->juv", ax, y, ay)


def _traj(seed, s, grid):
    return jax.random.uniform(jax.random.PRNGKey(seed), (s, 2),
                              jnp.float32, 0.0, float(grid))


def _cplx(key, shape):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) +
            1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def _degrid_samples(i):
    (j, grid, s) = [(2, 16, 200), (3, 32, 640)][i]
    traj = _traj(400 + i, s, grid)
    ax, ay = interp_matrices(traj, grid)
    g = _cplx(jax.random.PRNGKey(410 + i), (j, grid, grid))
    want = jnp.zeros((j, ax.shape[0]), g.dtype)
    want = want.at[:, :s].set(degrid_ref(g, traj))
    return (g, ax, ay), {}, want


def _grid_samples(i):
    (j, grid, s) = [(2, 16, 200), (3, 32, 640)][i]
    traj = _traj(420 + i, s, grid)
    ax, ay = interp_matrices(traj, grid)
    sp = ax.shape[0]
    y = jnp.zeros((j, sp), jnp.complex64)
    y = y.at[:, :s].set(_cplx(jax.random.PRNGKey(430 + i), (j, s)))
    want = grid_ref(y[:, :s], traj, grid)
    return (y, ax, ay), {}, want


def _adjointness(seed=0):
    """Property: <degrid(g), y> == <g, grid_adjoint(y)> on every impl —
    the separable matrices really are transposes of each other."""
    (g, ax, ay), _, _ = _degrid_samples(0)
    y = _cplx(jax.random.PRNGKey(seed + 440), (g.shape[0], ax.shape[0]))
    for impl in ("jnp", "pallas"):
        lhs = jnp.vdot(degrid(g, ax, ay, impl=impl), y)
        rhs = jnp.vdot(g, grid_adjoint(y, ax, ay, impl=impl))
        assert jnp.abs(lhs - rhs) / max(1.0, jnp.abs(lhs)) < 1e-4, impl


DEGRID = kreg.register(KernelSpec(
    family="gridding", name="degrid",
    pallas=degrid_pallas, ref=degrid_ref, fallback="jnp",
    block_args=("bs",), default_block=(128,),
    block_space=((64,), (128,), (256,), (512,)),
    supports=lambda block, g, ax, ay, **kw:
        g.shape[0] > 0 and dim_divisible(ax.shape[0], block[0]),
    tol=1e-3,
    layout="(Sp, grid) separable matrices; samples blocked bs at a time",
    samples=_degrid_samples, nsamples=2,
    properties=(_adjointness,),
))

GRID_ADJOINT = kreg.register(KernelSpec(
    family="gridding", name="grid_adjoint",
    pallas=grid_pallas, ref=grid_ref, fallback="jnp",
    block_args=("bs",), default_block=(128,),
    block_space=((64,), (128,), (256,), (512,)),
    supports=lambda block, y, ax, ay, **kw:
        y.shape[0] > 0 and dim_divisible(ax.shape[0], block[0]),
    tol=1e-3,
    layout="(Sp, grid) separable matrices; samples blocked bs at a time",
    samples=_grid_samples, nsamples=2,
    adjoint_of="gridding.degrid",
))


def degrid(g, ax, ay, impl: str = "auto", block=None):
    """g: (J, X, Y) complex grid -> (J, Sp) complex samples (padded rows
    read zero)."""
    ax = jnp.asarray(ax)
    ay = jnp.asarray(ay)
    impl, block = DEGRID.resolve(impl, block, g, ax, ay)
    if impl != "pallas":
        return _degrid_jnp(ax, ay, g)
    gr, gi = split(g)
    outr, outi = degrid_pallas(ax, ay, gr, gi,
                               bs=block[0], interpret=not on_tpu())
    return (outr + 1j * outi).astype(g.dtype)


DEGRID.dispatch = degrid


def grid_adjoint(y, ax, ay, impl: str = "auto", block=None):
    """Adjoint: y (J, Sp) complex samples -> (J, X, Y) complex grid."""
    ax = jnp.asarray(ax)
    ay = jnp.asarray(ay)
    impl, block = GRID_ADJOINT.resolve(impl, block, y, ax, ay)
    if impl != "pallas":
        return _grid_jnp(ax, ay, y)
    yr, yi = split(y)
    outr, outi = grid_pallas(ax, ay, yr, yi,
                             bs=block[0], interpret=not on_tpu())
    return (outr + 1j * outi).astype(y.dtype)


GRID_ADJOINT.dispatch = grid_adjoint
