"""Pure-jnp oracle for non-Cartesian (radial) gridding/degridding.

Direct per-sample bilinear interpolation on the periodic k-space grid:
``degrid`` gathers the four corner cells around each trajectory point,
``grid`` (the exact adjoint) scatter-adds with the same weights.  The
Pallas kernels compute the identical operator through dense separable
interpolation matrices; this module is the independent reference they
are tested against.
"""

from __future__ import annotations

import jax.numpy as jnp


def _corners(traj, grid: int):
    """Integer corners + fractional weights of each trajectory point on
    the periodic grid.  traj: (S, 2) float (x, y) in grid units."""
    t = jnp.asarray(traj, jnp.float32)
    i0 = jnp.floor(t).astype(jnp.int32)
    f = t - i0
    ix0, iy0 = i0[:, 0] % grid, i0[:, 1] % grid
    ix1, iy1 = (ix0 + 1) % grid, (iy0 + 1) % grid
    fx, fy = f[:, 0], f[:, 1]
    return (ix0, ix1, iy0, iy1, fx, fy)


def degrid_ref(g, traj):
    """Sample the Cartesian k-space at the trajectory (forward interp).

    g: (J, X, Y) complex grid, traj: (S, 2) -> (J, S) complex samples.
    """
    grid = g.shape[-1]
    ix0, ix1, iy0, iy1, fx, fy = _corners(traj, grid)
    return ((1 - fx) * (1 - fy) * g[:, ix0, iy0]
            + fx * (1 - fy) * g[:, ix1, iy0]
            + (1 - fx) * fy * g[:, ix0, iy1]
            + fx * fy * g[:, ix1, iy1])


def grid_ref(y, traj, grid: int):
    """Adjoint of ``degrid_ref``: scatter-add samples onto the grid.

    y: (J, S) complex samples -> (J, X, Y) complex grid.
    """
    y = jnp.asarray(y)
    ix0, ix1, iy0, iy1, fx, fy = _corners(traj, grid)
    out = jnp.zeros(y.shape[:-1] + (grid, grid), y.dtype)
    out = out.at[:, ix0, iy0].add(((1 - fx) * (1 - fy)) * y)
    out = out.at[:, ix1, iy0].add((fx * (1 - fy)) * y)
    out = out.at[:, ix0, iy1].add(((1 - fx) * fy) * y)
    out = out.at[:, ix1, iy1].add((fx * fy) * y)
    return out
