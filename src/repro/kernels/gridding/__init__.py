from .ops import degrid, grid_adjoint, interp_matrices
from .kernel import degrid_pallas, grid_pallas
from .ref import degrid_ref, grid_ref

__all__ = ["degrid", "grid_adjoint", "interp_matrices",
           "degrid_pallas", "grid_pallas", "degrid_ref", "grid_ref"]
