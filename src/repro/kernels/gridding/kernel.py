"""Radial gridding/degridding as Pallas TPU kernels.

The GPU formulation of the paper era scatters each sample with atomics;
TPUs have no atomics, so the plan layer factors the bilinear
interpolation into *separable dense matrices* ``Ax (S, X)`` / ``Ay (S,
Y)`` (two nonzeros per row, built once per trajectory at plan-build
time) and the kernels become MXU matmuls:

  degrid:  out[j, s] = sum_v (Ax @ g_j)[s, v] * Ay[s, v]
  grid:    g_j       = Ax^T @ (y_j[:, None] * Ay)       (exact adjoint)

Complex data travels as separate re/im planes — (.., Y) f32 arrays tile
the (8, 128) VREG lanes natively.  The sample dim is tiled in blocks of
``bs``; ``grid`` accumulates over sample blocks in VMEM scratch (the
sequential ``arbitrary`` grid axis), mirroring the coil_adjoint kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_tpu_compiler_params


def _degrid_kernel(ax, ay, gr, gi, outr, outi):
    a = ax[...]                              # (bs, X)
    tr = jnp.dot(a, gr[0], preferred_element_type=jnp.float32)   # (bs, Y)
    ti = jnp.dot(a, gi[0], preferred_element_type=jnp.float32)
    w = ay[...]                              # (bs, Y)
    outr[0] = jnp.sum(tr * w, axis=1)
    outi[0] = jnp.sum(ti * w, axis=1)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def degrid_pallas(ax, ay, gr, gi, *, bs=128, interpret=True):
    """Sample the grid at the trajectory.  ax: (S, X), ay: (S, Y),
    gr/gi: (J, X, Y) f32 -> (J, S) f32 re/im.  S must tile by ``bs``."""
    S, X = ax.shape
    Y = ay.shape[1]
    J = gr.shape[0]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    return pl.pallas_call(
        _degrid_kernel,
        grid=(J, S // bs),
        in_specs=[
            pl.BlockSpec((bs, X), lambda j, s: (s, 0)),
            pl.BlockSpec((bs, Y), lambda j, s: (s, 0)),
            pl.BlockSpec((1, X, Y), lambda j, s: (j, 0, 0)),
            pl.BlockSpec((1, X, Y), lambda j, s: (j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs), lambda j, s: (j, s)),
            pl.BlockSpec((1, bs), lambda j, s: (j, s)),
        ],
        out_shape=[jax.ShapeDtypeStruct((J, S), jnp.float32)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(ax, ay, gr, gi)


def _grid_kernel(ax, ay, yr, yi, outr, outi, accr, acci, *, ns):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    w = ay[...]                              # (bs, Y)
    at = ax[...].T                           # (X, bs)
    accr[...] += jnp.dot(at, yr[0][:, None] * w,
                         preferred_element_type=jnp.float32)
    acci[...] += jnp.dot(at, yi[0][:, None] * w,
                         preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _final():
        outr[0] = accr[...]
        outi[0] = acci[...]


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def grid_pallas(ax, ay, yr, yi, *, bs=128, interpret=True):
    """Adjoint: scatter samples onto the grid.  yr/yi: (J, S) f32 ->
    (J, X, Y) f32 re/im, accumulated over sample blocks in VMEM."""
    S, X = ax.shape
    Y = ay.shape[1]
    J = yr.shape[0]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    kern = functools.partial(_grid_kernel, ns=S // bs)
    return pl.pallas_call(
        kern,
        grid=(J, S // bs),
        in_specs=[
            pl.BlockSpec((bs, X), lambda j, s: (s, 0)),
            pl.BlockSpec((bs, Y), lambda j, s: (s, 0)),
            pl.BlockSpec((1, bs), lambda j, s: (j, s)),
            pl.BlockSpec((1, bs), lambda j, s: (j, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, X, Y), lambda j, s: (j, 0, 0)),
            pl.BlockSpec((1, X, Y), lambda j, s: (j, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((J, X, Y), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((X, Y), jnp.float32)] * 2,
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ax, ay, yr, yi)
