"""Conjugate gradient on the (rho, chat) pytree (inner solver of eq. 3).

lax.while_loop with max-iteration + relative-residual stopping.  Two
bodies share the loop scaffolding:

``cg``        the unfused baseline: every scalar product goes through
              ``dot`` (the distributed path passes the bound
              ``Communicator.vdot`` — the paper's 'scalar products of
              all data' CG entry in Table 1), and the vector updates are
              three separate ``uaxpy`` passes.

``cg_fused``  the hot path (2017 follow-up's kernel-fusion + overlap
              optimizations): the operator application returns
              ``<p, A p>`` fused into the channel-sum collective
              (``NlinvOps.normal_pap``), the ``x``/``r`` updates run as
              ONE pass with the ``r·r`` dot epilogue accumulated in the
              same kernel (``kernels.cg_fused``), and the search
              direction update is the fused ``p = r + beta*p`` step.
              Per iteration that is 2 collectives instead of 3 and one
              traversal of the iterate pytree instead of four; starting
              from ``x0 = 0`` also skips the initial operator
              application entirely (``A(0) = 0`` exactly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.cg_fused import ops as _fused_ops
from .operators import uaxpy, udot


def cg(A, rhs, x0, *, iters: int = 30, tol: float = 1e-6, dot=udot):
    """Solve A x = rhs, A SPD (normal operator + alpha I)."""
    r0 = uaxpy(-1.0, A(x0), rhs)
    p0 = r0
    rs0 = jnp.real(dot(r0, r0))
    thresh = tol * tol * rs0

    def cond(state):
        i, x, r, p, rs = state
        return jnp.logical_and(i < iters, rs > thresh)

    def body(state):
        i, x, r, p, rs = state
        Ap = A(p)
        alpha = rs / jnp.maximum(jnp.real(dot(p, Ap)), 1e-30)
        x = uaxpy(alpha, p, x)
        r = uaxpy(-alpha, Ap, r)
        rs_new = jnp.real(dot(r, r))
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = uaxpy(beta, p, r)
        return i + 1, x, r, p, rs_new

    _, x, _, _, _ = jax.lax.while_loop(cond, body, (0, x0, r0, p0, rs0))
    return x


def _tree_sum(parts):
    return sum(jax.tree.leaves(parts))


def _fused_update(alpha, p, ap, x, r, rs_sum):
    """Per-leaf single-pass updates; the per-leaf rs partials are merged
    by ``rs_sum`` (policy-aware on the distributed path)."""
    outs = jax.tree.map(
        lambda p_, ap_, x_, r_: _fused_ops.cg_update(alpha, p_, ap_, x_, r_),
        p, ap, x, r)
    x2 = jax.tree.map(lambda o: o[0], outs,
                      is_leaf=lambda o: isinstance(o, tuple))
    r2 = jax.tree.map(lambda o: o[1], outs,
                      is_leaf=lambda o: isinstance(o, tuple))
    parts = jax.tree.map(lambda o: o[2], outs,
                         is_leaf=lambda o: isinstance(o, tuple))
    return x2, r2, rs_sum(parts)


def _fused_xpby(r, p, beta):
    return jax.tree.map(
        lambda r_, p_: _fused_ops.xpby_dot(r_, p_, beta,
                                           with_dot=False)[0], r, p)


def cg_fused(apply_pap, rhs, *, iters: int = 30, tol: float = 1e-6,
             rs_sum=None, x0=None):
    """Fused-hot-path CG.

    ``apply_pap(p) -> (A p, <p, A p>)`` — the operator application with
    the curvature scalar fused into its own collective
    (``NlinvOps.normal_pap``).  ``rs_sum(partials_pytree) -> scalar``
    merges per-leaf ``sum |.|^2`` partials into the global residual norm
    (the ``Communicator.vdot`` policy reduction on the distributed path;
    default: plain sum — the single-program form).  ``x0=None`` starts
    at zero, for which ``r0 = rhs`` exactly (no operator application).
    """
    if rs_sum is None:
        rs_sum = _tree_sum
    if x0 is None:
        x = jax.tree.map(jnp.zeros_like, rhs)
        r0 = rhs
    else:
        x = x0
        ax0, _ = apply_pap(x0)
        r0 = uaxpy(-1.0, ax0, rhs)
    rs0 = rs_sum(jax.tree.map(
        lambda l: jnp.real(jnp.vdot(l, l)).astype(jnp.float32), r0))
    thresh = tol * tol * rs0

    def cond(state):
        i, x, r, p, rs = state
        return jnp.logical_and(i < iters, rs > thresh)

    def body(state):
        i, x, r, p, rs = state
        ap, pap = apply_pap(p)
        alpha = rs / jnp.maximum(jnp.real(pap), 1e-30)
        x, r, rs_new = _fused_update(alpha, p, ap, x, r, rs_sum)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = _fused_xpby(r, p, beta)
        return i + 1, x, r, p, rs_new

    _, x, _, _, _ = jax.lax.while_loop(cond, body, (0, x, r0, r0, rs0))
    return x
