"""Conjugate gradient on the (rho, chat) pytree (inner solver of eq. 3).

lax.while_loop with max-iteration + relative-residual stopping; all
scalar products go through ``dot`` so the distributed path can reduce
them through the bound ``Communicator.vdot`` (the paper's 'scalar
products of all data' CG entry in Table 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .operators import uaxpy, udot


def cg(A, rhs, x0, *, iters: int = 30, tol: float = 1e-6, dot=udot):
    """Solve A x = rhs, A SPD (normal operator + alpha I)."""
    r0 = uaxpy(-1.0, A(x0), rhs)
    p0 = r0
    rs0 = jnp.real(dot(r0, r0))
    thresh = tol * tol * rs0

    def cond(state):
        i, x, r, p, rs = state
        return jnp.logical_and(i < iters, rs > thresh)

    def body(state):
        i, x, r, p, rs = state
        Ap = A(p)
        alpha = rs / jnp.maximum(jnp.real(dot(p, Ap)), 1e-30)
        x = uaxpy(alpha, p, x)
        r = uaxpy(-alpha, Ap, r)
        rs_new = jnp.real(dot(r, r))
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = uaxpy(beta, p, r)
        return i + 1, x, r, p, rs_new

    _, x, _, _, _ = jax.lax.while_loop(cond, body, (0, x0, r0, p0, rs0))
    return x
