"""Real-time streaming frame engine (the paper's raison d'être: §1's
latency-bounded "real-time applications", and the 2017 follow-up's
streaming NLINV service).

Temporal regularization makes frame *f+1* depend on the damped solution
of frame *f*, so frames cannot be reconstructed in parallel — but the
host→device transfer of the *next* acquisition can overlap the Newton
iterations of the current one.  ``FrameStream``:

  * double-buffers acquisition upload: while the solver of frame ``f``
    is in flight (JAX dispatch is asynchronous), frame ``f+1``'s coil
    data is already being scattered (NATURAL over the group) and its
    sampling mask broadcast — through the ``Communicator`` verbs
    (``container``/``bcast``), never raw device_put+specs;
  * donates the Newton carry (``x0``/``x_ref``) to the solver so XLA
    reuses the two largest buffers frame-to-frame
    (``Reconstructor.fn_donate_carry``);
  * records per-frame wall-clock latency and jitter — the real-time
    budget of the application — into a ``LatencyReport`` artifact.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..lib.plan import default_cache
from ..task import Executor, Pipeline, TaskGraph
from .operators import sobolev_weight
from .recon import Reconstructor, pad_channels


def latency_stats(samples_ms) -> dict:
    """Steady-state latency statistics over per-call wall-clock samples
    (milliseconds).  Shared between the streaming LatencyReport and the
    ``repro.bench`` timing harness so every latency number in the repo
    is computed one way."""
    arr = np.asarray(list(samples_ms), dtype=np.float64)
    if arr.size == 0:
        arr = np.zeros(1)
    mean = float(arr.mean())
    if arr.size < 2:
        # a single-sample window has no spread: the percentiles ARE the
        # sample and the jitter is exactly zero — never interpolation
        # noise (a one-frame client in the serving report must not show
        # phantom jitter).
        one = round(float(arr[0]), 3)
        p50, p95, jitter = one, one, 0.0
    else:
        p50 = round(float(np.percentile(arr, 50)), 3)
        p95 = round(float(np.percentile(arr, 95)), 3)
        jitter = round(float(arr.std()), 3)
    return {
        "mean_ms": round(mean, 3),
        "p50_ms": p50,
        "p95_ms": p95,
        "jitter_ms": jitter,
        "fps": round(1e3 / max(mean, 1e-9), 2),
    }


def upload_frame(rec: "Reconstructor", y, mask):
    """Stage one acquisition onto the group: coil data NATURAL-scattered,
    sampling mask broadcast — the single upload step both the streaming
    loop and the serving scheduler issue (always through the verbs,
    never raw device_put+specs).  ``y`` must already be channel-padded
    to the group size."""
    return rec.put_frame(np.asarray(y)), rec.put_const(np.asarray(mask))


class DoubleBuffer:
    """One-slot-ahead host→device staging.

    JAX dispatch is asynchronous, so an upload issued right after a
    solver launch lands while the solve is still in flight.  ``stage``
    issues the upload for the NEXT item; ``take`` hands over the staged
    device buffers (exactly once).  ``FrameStream`` primes it with frame
    0 and restages behind every launch; the serving scheduler keeps one
    per session and stages at enqueue time, so every client's next frame
    rides behind the current batched tick."""

    def __init__(self, upload):
        self._upload = upload
        self._slot = None

    @property
    def ready(self) -> bool:
        return self._slot is not None

    def stage(self, *args) -> None:
        if self._slot is not None:
            raise RuntimeError("DoubleBuffer.stage: slot already staged "
                               "(take() the in-flight item first)")
        self._slot = self._upload(*args)

    def take(self):
        if self._slot is None:
            raise RuntimeError("DoubleBuffer.take: nothing staged")
        slot, self._slot = self._slot, None
        return slot


@dataclasses.dataclass
class LatencyReport:
    """Per-frame wall-clock of one streaming run (milliseconds), plus
    the plan-cache evidence that the steady state builds nothing."""

    frame_ms: list[float]
    devices: int
    grid: int
    ncoils: int
    # plans built while each frame was processed (library-port cache
    # misses; frame 0 pays them all, steady-state frames must show 0)
    frame_plan_builds: list[int] = dataclasses.field(default_factory=list)
    plan_stats: dict = dataclasses.field(default_factory=dict)
    # frames the pipeline DROPPED (dispatch failure under
    # ``drop_failed``): frozen in the movie, excluded from the latency
    # statistics — a dropped frame has no latency, it has an error
    dropped: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        """First frame pays compilation; steady-state stats exclude it
        (and dropped frames, which never completed)."""
        gone = set(self.dropped)
        completed = [t for i, t in enumerate(self.frame_ms)
                     if i not in gone]
        if not completed:
            completed = [0.0]
        steady = completed[1:] if len(completed) > 1 else completed
        out = {
            "frames": len(self.frame_ms),
            "devices": self.devices,
            "grid": self.grid,
            "ncoils": self.ncoils,
            "first_frame_ms": round(completed[0], 3),
            **latency_stats(steady),
            "frame_ms": [round(t, 3) for t in self.frame_ms],
        }
        if self.dropped:
            out["dropped"] = list(self.dropped)
        if self.frame_plan_builds:
            out["plan_cache"] = dict(
                self.plan_stats,
                frame_builds=list(self.frame_plan_builds),
                steady_builds=int(sum(self.frame_plan_builds[1:])))
        return out

    def save(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.summary(), indent=2) + "\n")
        return path


class FrameStream:
    """Streaming movie reconstruction over a ``Reconstructor``."""

    def __init__(self, recon: Reconstructor, *, damping: float = 0.9,
                 donate_carry: bool = True):
        self.recon = recon
        self.damping = damping
        self.donate_carry = donate_carry
        self.last_carry = None      # {"u", "x_ref"} after run() (fenced)
        self._damp = jax.jit(
            lambda u: jax.tree.map(lambda a: damping * a, u))

    def run(self, y, masks, fov, *, weight=None, carry=None,
            report_path=None) -> tuple[jax.Array, LatencyReport]:
        """Reconstruct a movie: y (F, J, X, Y), masks (F, X, Y).

        Returns (images (F, X, Y), LatencyReport).  Writes the report
        artifact to ``report_path`` when given.  ``carry`` resumes from
        a previous run's ``last_carry`` (checkpoint restore / elastic
        continuation) instead of a cold ``init_carry``; with
        ``donate_carry`` the passed-in buffers are donated to frame 0.
        """
        rec = self.recon
        y = np.asarray(y)
        F = y.shape[0]
        g = y.shape[-1]
        y = pad_channels(y, rec.comm.size, axis=1)
        J = y.shape[1]
        if weight is None:
            weight = sobolev_weight(g)

        fov_d = rec.put_const(np.asarray(fov))
        w_d = rec.put_const(np.asarray(weight))
        if carry is None:
            u = rec.init_carry(J, g)
            # x_ref starts equal to u but must be a distinct buffer:
            # both are donated to the solver every frame.
            x_ref = jax.tree.map(lambda a: a + 0, u)
        else:
            u, x_ref = carry["u"], carry["x_ref"]
        fn = rec.fn_donate_carry if self.donate_carry else rec.fn

        cache = getattr(rec, "plan_cache", default_cache())
        run_start = cache.snapshot()
        images, frame_ms, frame_builds = [], [], []
        # prime the double buffer with frame 0
        buf = DoubleBuffer(lambda f: upload_frame(rec, y[f], masks[f]))
        buf.stage(0)
        for f in range(F):
            t0 = time.perf_counter()
            builds0 = cache.builds
            yd, md = buf.take()
            u, img = fn(yd, md, fov_d, w_d, u, x_ref)
            # the solver is now in flight; upload frame f+1 behind it
            if f + 1 < F:
                buf.stage(f + 1)
            x_ref = self._damp(u)
            img.block_until_ready()
            frame_ms.append((time.perf_counter() - t0) * 1e3)
            # plans built during this frame: geometry setup (frame 0
            # traces the solver, building its fft/frame plans); the
            # steady state must be all hits — the report proves it.
            frame_builds.append(cache.builds - builds0)
            images.append(img)

        self.last_carry = jax.block_until_ready(
            {"u": u, "x_ref": x_ref})
        # report per-RUN counter deltas, not the process-global
        # cumulative stats — the artifact must describe this stream.
        run = cache.delta(run_start)
        report = LatencyReport(frame_ms, rec.comm.size, g, J,
                               frame_plan_builds=frame_builds,
                               plan_stats=run)
        if report_path is not None:
            report.save(report_path)
        return jnp.stack(images), report


def frame_graph(rec: "Reconstructor", take_upload, damp) -> TaskGraph:
    """One streamed frame of the NLINV program as a :class:`TaskGraph`.

    Four nodes, all placed on the reconstructor's group:

      ``upload``  (copy edge) host→device staging of the acquisition —
                  takes the double-buffered slot and restages the next
                  frame behind the in-flight work;
      ``solve``   the Newton/CG stage (``Reconstructor.fn_solve``);
      ``damp``    the temporal-regularization reference for frame f+1;
      ``crop``    the readout/channel-combination stage
                  (``Reconstructor.fn_image``).

    Cross-frame dependencies enter as feeds: ``u_prev``/``xref_prev``
    are the previous frame's (possibly still in-flight) ``u``/``xref``
    values, plus the replicated constants ``fov``/``weight``.  The
    :class:`repro.task.Pipeline` keeps several of these graphs in
    flight, so the upload of frame f+2, the solve of frame f+1 and the
    crop of frame f all sit on the device queue concurrently — the
    multi-stage schedule of arXiv:1701.08361 §3 instead of the rigid
    two-stage overlap."""
    g = TaskGraph()
    g.copy("upload", take_upload, outputs=("y", "mask"), group=rec.comm)
    g.add("solve", rec.fn_solve,
          inputs=("y", "mask", "fov", "weight", "u_prev", "xref_prev"),
          outputs=("u",), group=rec.comm)
    g.add("damp", damp, inputs=("u",), outputs=("xref",), group=rec.comm)
    g.add("crop", rec.fn_image, inputs=("mask", "fov", "weight", "u"),
          outputs=("img",), group=rec.comm)
    return g


class FramePipeline:
    """Task-graph pipelined streaming reconstruction (ISSUE 9).

    Same contract as :class:`FrameStream` — ``run(y, masks, fov) ->
    (images, LatencyReport)``, numerically the same movie — but the
    frame program runs as a :class:`repro.task.TaskGraph` through a
    rolling :class:`repro.task.Pipeline`: up to ``inflight`` frames'
    graphs stay dispatched-but-unfenced, so the host never stalls on
    frame f before issuing the upload/solve of frames f+1..f+inflight-1.
    Frames are still *sequentially dependent* (temporal regularization:
    frame f+1's solve consumes frame f's damped carry), so the device
    work cannot parallelize — what pipelining removes is the per-frame
    host fence and the dispatch/upload bubble behind it.

    ``frame_ms`` in the report is completion-to-completion time (the
    throughput view): with several frames in flight a per-frame
    dispatch-to-ready latency would double-count overlapped work.

    Fault tolerance: ``retry`` (a ``repro.ft.RestartPolicy``) arms the
    executor's transient-task retry; ``drop_failed=True`` turns a frame
    whose dispatch still fails into a DROP instead of a crash — the
    movie freezes on the last good image for that index, the carry
    keeps pointing at the last good frame (temporal regularization
    continues from it), and ``report.dropped`` lists the indices.  A
    real-time consumer prefers a repeated frame over a dead stream.
    """

    def __init__(self, recon: Reconstructor, *, damping: float = 0.9,
                 inflight: int = 2, retry=None, drop_failed: bool = False):
        self.recon = recon
        self.damping = damping
        self.inflight = inflight
        self.retry = retry
        self.drop_failed = drop_failed
        self.last_carry = None      # {"u", "x_ref"} after run() (fenced)
        self._damp = jax.jit(
            lambda u: jax.tree.map(lambda a: damping * a, u))

    def run(self, y, masks, fov, *, weight=None, carry=None,
            report_path=None) -> tuple[jax.Array, LatencyReport]:
        rec = self.recon
        y = np.asarray(y)
        F = y.shape[0]
        g = y.shape[-1]
        y = pad_channels(y, rec.comm.size, axis=1)
        J = y.shape[1]
        if weight is None:
            weight = sobolev_weight(g)

        fov_d = rec.put_const(np.asarray(fov))
        w_d = rec.put_const(np.asarray(weight))
        if carry is None:
            u = rec.init_carry(J, g)
            x_ref = jax.tree.map(lambda a: a + 0, u)
        else:
            u, x_ref = carry["u"], carry["x_ref"]

        cache = getattr(rec, "plan_cache", default_cache())
        run_start = cache.snapshot()
        buf = DoubleBuffer(lambda f: upload_frame(rec, y[f], masks[f]))
        buf.stage(0)
        pipe = Pipeline(Executor(retry=self.retry),
                        inflight=self.inflight,
                        drop_failed=self.drop_failed)
        images: dict[int, jax.Array] = {}
        frame_ms = [0.0] * F
        frame_builds = [0] * F
        t0 = last = time.perf_counter()
        prev = {"u": u, "xref": x_ref}

        def retire(steps):
            nonlocal last
            for f_done, vals in steps:
                now = time.perf_counter()
                frame_ms[f_done] = (now - last) * 1e3
                last = now
                images[f_done] = vals["img"]

        for f in range(F):
            def take_upload(f=f):
                yd, md = buf.take()
                # restage: frame f+1's scatter/bcast issue behind the
                # solve dispatched right after this node
                if f + 1 < F:
                    buf.stage(f + 1)
                return yd, md

            builds0 = cache.builds
            vals, done = pipe.push(
                frame_graph(rec, take_upload, self._damp),
                feeds={"fov": fov_d, "weight": w_d,
                       "u_prev": prev["u"], "xref_prev": prev["xref"]},
                tag=f, outputs=("u", "xref", "img"))
            frame_builds[f] = cache.builds - builds0
            if vals is None:
                # frame f dropped (drop_failed): the fault may have hit
                # before or after the upload node ran, so resync the
                # double buffer to hold exactly frame f+1's acquisition;
                # prev still points at the last good carry — the next
                # solve regularizes against the last delivered frame
                if buf.ready:
                    buf.take()
                if f + 1 < F:
                    buf.stage(f + 1)
                continue
            prev = {"u": vals["u"], "xref": vals["xref"]}
            retire(done)
        retire(pipe.flush())
        self.last_carry = jax.block_until_ready(
            {"u": prev["u"], "x_ref": prev["xref"]})

        dropped = [f for f, _ in pipe.dropped]
        if len(dropped) == F:
            raise RuntimeError(
                f"every frame dropped ({F} dispatch failures) — "
                f"nothing to freeze on; first: {pipe.dropped[0][1]!r}")
        # freeze-frame: a dropped index repeats the last delivered
        # image (leading drops repeat zeros — no frame shipped yet)
        shaped = next(img for f, img in sorted(images.items()))
        prev_img = jnp.zeros_like(shaped)
        movie = []
        for f in range(F):
            prev_img = images.get(f, prev_img)
            movie.append(prev_img)

        report = LatencyReport(frame_ms, rec.comm.size, g, J,
                               frame_plan_builds=frame_builds,
                               plan_stats=cache.delta(run_start),
                               dropped=dropped)
        if report_path is not None:
            report.save(report_path)
        return jnp.stack(movie), report


def stream_movie(data, *, comm=None, newton=7, cg_iters=30, damping=0.9,
                 channel_sum="crop", fused=True, report_path=None,
                 pipelined=False, inflight=2):
    """Convenience wrapper: dataset dict -> (images, LatencyReport).
    ``comm`` is a Communicator (or DeviceGroup; None = 1 device);
    ``fused=False`` is the unfused escape hatch; ``pipelined=True``
    runs the task-graph :class:`FramePipeline` (``inflight`` frames on
    the device queue) instead of the two-stage :class:`FrameStream`."""
    rec = Reconstructor(comm, newton=newton, cg_iters=cg_iters,
                        channel_sum=channel_sum, fused=fused)
    if pipelined:
        eng = FramePipeline(rec, damping=damping, inflight=inflight)
    else:
        eng = FrameStream(rec, damping=damping)
    return eng.run(data["y"], data["masks"], data["fov"],
                   report_path=report_path)
