"""Reconstruction drivers, built entirely on the repro.core
Environment/Communicator layer (the paper's §3.2 decomposition as
policies and group-bound verbs, not specs).

Coil data ``y`` and the coil coefficients ``chat`` are NATURAL-segmented
across the communicator's group, the image ``rho`` and acquisition
geometry are CLONEd, the channel sum in DG^H is
``comm.allreduce_window`` (the paper's ``kern_all_red_p2p_2d``
4x-fewer-bytes trick when windowed to the centered FOV quarter), and the
CG scalar products are ``comm.vdot`` over the CLONE+NATURAL mixed
pytree.  ``Reconstructor`` is the one frame-solver API; a 1-device
``Communicator`` is the degenerate case — the same program with no-op
collectives.

``channel_sum`` strategy:

  full   all-reduce the whole doubled grid (paper-faithful baseline)
  crop   M_Omega zeroes everything outside the centered FOV quarter, so
         only that 2-D window is reduced and scattered back (the paper's
         kern_all_red_p2p_2d insight; 4x fewer bytes on the wire).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.env import Communicator, Environment
from ..core.runtime import DeviceGroup
from ..core.segmented import Policy
from ..kernels import registry as _kreg
from ..lib.plan import Plan, default_cache, group_token

# the kernel families the frame program traces through; their current
# block choices are part of the frame-plan identity
_KERNEL_FAMILIES = ("cg_fused", "coil_mult", "masked_allreduce")
from .irgnm import irgnm, irgnm_fused
from .operators import make_ops, sobolev_weight, uinit

# Segmentation of the unknown pytree u = {rho, chat} (paper §3.2).
U_POLICIES = {"rho": Policy.CLONE, "chat": Policy.NATURAL}

# The same decomposition with a leading client-batch dim stacked on: the
# serving layer solves B independent frames in ONE launch, so the coil
# split moves to dim 1 of y/chat while rho/mask stay replicated with
# their batch dim intact.
U_POLICIES_BATCHED = {"rho": Policy.CLONE, "chat": (Policy.NATURAL, 1)}


def _as_communicator(comm, axis: str) -> Communicator:
    """Normalize comm=None | DeviceGroup | Communicator to a Communicator.

    A bare DeviceGroup is bound to ``axis`` (the coil-split axis), so
    multi-axis groups keep splitting coils over that one axis; an
    explicit Communicator carries its own mesh_axes and wins over
    ``axis``.
    """
    if comm is None:
        return Environment().subgroup(1, (axis,))
    if isinstance(comm, DeviceGroup):
        return Communicator(comm, (axis,))
    return comm


class Reconstructor:
    """One NLINV frame solver over a Communicator.

    The compiled function (``.fn``) maps
    ``(y, mask, fov, weight, x0, x_ref) -> (u, image)`` with ``y``/
    ``chat`` coil-segmented and everything else replicated.  ``__call__``
    forwards to it.  ``.fn_donate_carry`` is the same program with the
    Newton carry ``(x0, x_ref)`` buffers donated — the streaming engine's
    steady-state path.

    ``fused=True`` (default) runs the fused hot path (``irgnm_fused``:
    hoisted Newton-point constants, single-pass CG update kernels, the
    ``<p, Ap>`` scalar piggybacked on the channel-sum collective and the
    dchat FFT branch overlapped with it); ``fused=False`` is the unfused
    escape hatch with the original verb-per-op body.  ``overlap`` picks
    the fused reduction schedule: ``"psum"`` (one variadic all-reduce)
    or ``"p2p"`` (the chunked ``kern_all_red_p2p_2d`` ppermute ring with
    compute interleaved between transfer rounds).
    """

    def __init__(self, comm: Communicator | DeviceGroup | None = None,
                 axis: str = "data", *, newton: int = 7, cg_iters: int = 30,
                 channel_sum: str = "crop", hierarchical: bool = False,
                 fused: bool = True, overlap: str = "psum"):
        if channel_sum not in ("full", "crop"):
            raise ValueError(f"channel_sum must be full|crop: {channel_sum}")
        if overlap not in ("psum", "p2p"):
            raise ValueError(f"overlap must be psum|p2p: {overlap}")
        self.comm = _as_communicator(comm, axis)
        self.axis = self.comm.axis
        self.newton, self.cg_iters = newton, cg_iters
        self.channel_sum, self.hierarchical = channel_sum, hierarchical
        self.fused, self.overlap = fused, overlap
        self.plan_cache = default_cache()

    @property
    def group(self) -> DeviceGroup:
        return self.comm.group

    # -- the shard-local frame program (pure jnp + communicator verbs) ----
    def _frame_solve(self, y, mask, fov, weight, x0, x_ref):
        """Newton/CG stage only: acquisition -> solved ``u``.  The task
        pipeline (``repro.task``) runs this and ``_frame_image`` as
        separate graph nodes so the crop/readout of frame ``f-1`` and
        the solve of frame ``f`` are independently schedulable."""
        crop = self.channel_sum == "crop"

        ops = make_ops(mask, fov, weight)
        if self.fused:
            # Fused hot path: windowed channel sum + <p, Ap> piggyback +
            # overlapped dchat branch as ONE reducer hook, and the
            # residual-norm partials merged with the vdot policy rules
            # (rho CLONE counted once, chat NATURAL psum'd).
            def reducer(prod, extras, compute):
                g = prod.shape[-1]
                q = g // 4
                win = ((q, 3 * q), (q, 3 * q)) if crop else None
                return self.comm.allreduce_overlap(
                    prod, win, axis=self.axis, extras=extras,
                    compute=compute, p2p=self.overlap == "p2p",
                    hierarchical=self.hierarchical)

            def rs_sum(parts):
                nat = self.comm.allreduce(parts["chat"], axis=self.axis)
                return parts["rho"] + nat
            u = irgnm_fused(ops, y, x0, x_ref, newton=self.newton,
                            cg_iters=self.cg_iters, reducer=reducer,
                            rs_sum=rs_sum)
        else:
            def csum(prod):
                g = prod.shape[-1]
                q = g // 4
                win = ((q, 3 * q), (q, 3 * q)) if crop else None
                return self.comm.allreduce_window(
                    prod, win, axis=self.axis, reduce_dim=0,
                    hierarchical=self.hierarchical)

            def dot(a, b):
                return self.comm.vdot(a, b, axis=self.axis,
                                      policies=U_POLICIES)

            u = irgnm(ops, y, x0, x_ref, newton=self.newton,
                      cg_iters=self.cg_iters, channel_sum=csum, dot=dot)
        return u

    def _frame_image(self, mask, fov, weight, u):
        """Crop/readout stage: solved ``u`` -> displayed image (the
        root-sum-of-squares channel combination)."""
        ops = make_ops(mask, fov, weight)
        c = ops.coils(u["chat"])
        rss = self.comm.allreduce_window(jnp.abs(c) ** 2, None,
                                         axis=self.axis, reduce_dim=0)
        return u["rho"] * jnp.sqrt(rss)

    def _frame(self, y, mask, fov, weight, x0, x_ref):
        u = self._frame_solve(y, mask, fov, weight, x0, x_ref)
        return u, self._frame_image(mask, fov, weight, u)

    def _build(self, donate: bool):
        clone = Policy.CLONE
        in_pol = (Policy.NATURAL, clone, clone, clone,
                  U_POLICIES, U_POLICIES)
        return self.comm.spmd(self._frame,
                              in_policies=in_pol,
                              out_policies=(U_POLICIES, clone),
                              check_vma=False,
                              donate_argnums=(4, 5) if donate else ())

    # -- the batched frame program (serving layer: B clients, one launch) -
    def _frame_batched(self, y, mask, fov, weight, x0, x_ref):
        """B independent frame solves in one SPMD program: vmap the
        shard-local body over a leading client-batch dim.  All verbs in
        ``_frame`` (windowed channel sum, piggybacked scalars, vdot) are
        vmap-safe, so the collectives of B solves coalesce into one
        rendezvous each — the amortization the multi-stream service is
        built on."""
        return jax.vmap(self._frame, in_axes=(0, 0, None, None, 0, 0))(
            y, mask, fov, weight, x0, x_ref)

    def _build_batched(self, donate: bool):
        clone = Policy.CLONE
        in_pol = ((Policy.NATURAL, 1), clone, clone, clone,
                  U_POLICIES_BATCHED, U_POLICIES_BATCHED)
        return self.comm.spmd(self._frame_batched,
                              in_policies=in_pol,
                              out_policies=(U_POLICIES_BATCHED, clone),
                              check_vma=False,
                              donate_argnums=(4, 5) if donate else ())

    def _plan_batched(self, width: int, donate: bool):
        """Batched plans key on the batch WIDTH: the scheduler buckets
        widths to a small set, and every bucket's compile shows up as
        one visible plan build (never a silent recompile)."""
        key = ("nlinv", "frame_batched", group_token(self.comm), int(width),
               self.newton, self.cg_iters, self.channel_sum,
               self.hierarchical, self.fused, self.overlap, bool(donate),
               _kreg.choices_token(_KERNEL_FAMILIES))
        return self.plan_cache.get_or_build(
            key, lambda: Plan(key=key, fn=self._build_batched(donate),
                              lib="nlinv", op="frame_batched"))

    def _plan(self, donate: bool):
        """The frame program as a library plan: keyed on the solver
        configuration + group so the streaming engine's steady state is
        pure cache hits (and the hit/miss counters prove it)."""
        key = ("nlinv", "frame", group_token(self.comm), self.newton,
               self.cg_iters, self.channel_sum, self.hierarchical,
               self.fused, self.overlap, bool(donate),
               _kreg.choices_token(_KERNEL_FAMILIES))
        return self.plan_cache.get_or_build(
            key, lambda: Plan(key=key, fn=self._build(donate),
                              lib="nlinv", op="frame"))

    # -- staged plans (the task-graph pipeline's nodes) -------------------
    def _build_solve(self, donate: bool):
        clone = Policy.CLONE
        in_pol = (Policy.NATURAL, clone, clone, clone,
                  U_POLICIES, U_POLICIES)
        return self.comm.spmd(self._frame_solve, in_policies=in_pol,
                              out_policies=U_POLICIES, check_vma=False,
                              donate_argnums=(4, 5) if donate else ())

    def _build_image(self):
        clone = Policy.CLONE
        return self.comm.spmd(self._frame_image,
                              in_policies=(clone, clone, clone,
                                           U_POLICIES),
                              out_policies=clone, check_vma=False)

    def _plan_stage(self, stage: str, builder):
        key = ("nlinv", stage, group_token(self.comm), self.newton,
               self.cg_iters, self.channel_sum, self.hierarchical,
               self.fused, self.overlap,
               _kreg.choices_token(_KERNEL_FAMILIES))
        return self.plan_cache.get_or_build(
            key, lambda: Plan(key=key, fn=builder(), lib="nlinv",
                              op=stage))

    @property
    def fn_solve(self):
        """Newton/CG stage of the frame program (``u`` only) — the
        ``solve`` node of the task-graph pipeline.  Not donated: with
        several frames in flight the carry of frame ``f-1`` is still a
        live input of ``damp`` when frame ``f`` dispatches."""
        return self._plan_stage("frame_solve",
                                lambda: self._build_solve(False)).fn

    @property
    def fn_image(self):
        """Crop/readout stage ``(mask, fov, weight, u) -> image`` — the
        ``crop`` node of the task-graph pipeline."""
        return self._plan_stage("frame_image", self._build_image).fn

    @property
    def fn(self):
        return self._plan(donate=False).fn

    @property
    def fn_donate_carry(self):
        return self._plan(donate=True).fn

    def fn_batched(self, width: int, *, donate: bool = False):
        """The B-client frame program for batch width ``width``:
        ``(y (B,J,X,Y), mask (B,X,Y), fov, weight, u (B,...), x_ref
        (B,...)) -> (u, images (B,X,Y))``.  Plan-cached per width."""
        return self._plan_batched(width, donate).fn

    def __call__(self, y, mask, fov, weight, x0, x_ref):
        return self.fn(y, mask, fov, weight, x0, x_ref)

    # -- carry/constant placement through the verbs -----------------------
    def init_carry(self, ncoils: int, grid: int):
        """Device-placed Newton carry (rho=1 CLONE, chat=0 NATURAL)."""
        u = uinit(ncoils, grid)
        return {"rho": self.comm.bcast(u["rho"]).data,
                "chat": self.comm.container(u["chat"]).data}

    def put_frame(self, y):
        """Segment one frame of coil data onto the group (coil dim 0)."""
        return self.comm.container(y).data

    def put_const(self, x):
        """Replicate a per-frame constant (mask/fov/weight)."""
        return self.comm.bcast(x).data


@functools.lru_cache(maxsize=None)
def _single_device_reconstructor(newton: int, cg_iters: int) -> Reconstructor:
    # "full" channel sum: bit-identical to the classic unsegmented solver.
    return Reconstructor(newton=newton, cg_iters=cg_iters,
                         channel_sum="full")


def reconstruct_frame(y, mask, fov, weight, x0, x_ref, *,
                      newton=7, cg_iters=30):
    """Single-device NLINV for one frame — the degenerate Reconstructor.
    y: (J, X, Y)."""
    rec = _single_device_reconstructor(newton, cg_iters)
    return rec(y, mask, fov, weight, x0, x_ref)


def make_dist_reconstruct(comm, axis: str = "data", *,
                          newton=7, cg_iters=30, channel_sum="crop",
                          fused=True):
    """Compiled distributed NLINV: coils split over ``axis`` (paper §3.2).
    ``comm`` may be a Communicator or a DeviceGroup.  Returns the jitted
    frame function (kept for callers that want the bare callable; new
    code should hold the ``Reconstructor``)."""
    return Reconstructor(comm, axis, newton=newton, cg_iters=cg_iters,
                         channel_sum=channel_sum, fused=fused).fn


def pad_channels(y, nseg, axis: int = 0):
    """Zero-pad the coil dim to a multiple of the group size (zero
    channels are exact no-ops for all NLINV sums)."""
    J = y.shape[axis]
    Jp = -(-J // nseg) * nseg
    if Jp == J:
        return y
    pad = np.zeros(y.shape[:axis] + (Jp - J,) + y.shape[axis + 1:], y.dtype)
    return np.concatenate([y, pad], axis=axis)


def reconstruct_movie(data, *, newton=7, cg_iters=30, damping=0.9,
                      frame_fn=None):
    """Blocking sequential movie loop (frames depend on x_ref: no frame
    parallelism, paper §3.2).  Returns (F, X, Y) images.  This is the
    latency baseline; ``repro.nlinv.stream.FrameStream`` is the
    transfer-overlapped real-time engine.
    """
    y, masks, fov = data["y"], data["masks"], data["fov"]
    F, J, g, _ = y.shape
    weight = sobolev_weight(g)
    u = uinit(J, g)
    x_ref = u
    images = []
    for f in range(F):
        if frame_fn is None:
            u, img = reconstruct_frame(
                jnp.asarray(y[f]), jnp.asarray(masks[f]), jnp.asarray(fov),
                jnp.asarray(weight), u, x_ref,
                newton=newton, cg_iters=cg_iters)
        else:
            u, img = frame_fn(y[f], masks[f], fov, weight, u, x_ref)
        x_ref = jax.tree.map(lambda a: damping * a, u)
        images.append(img)
    return jnp.stack(images)
