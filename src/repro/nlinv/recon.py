"""Reconstruction drivers: single-device, distributed (channel-split),
and the real-time movie loop with temporal regularization.

The distributed path is the paper's §3.2 decomposition: coil channels
segmented across the device group (MGPU segmented container), the image
rho CLONEd, and the channel sum in DG^H executed as a block-wise
all-reduce.  ``channel_sum`` strategy:

  full   psum of the whole doubled grid (paper-faithful baseline)
  crop   M_Omega zeroes everything outside the centered FOV quarter, so
         only that 2-D section is reduced (the paper's kern_all_red_p2p_2d
         insight; 4x fewer bytes on the wire) and the result re-padded.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.runtime import DeviceGroup
from .irgnm import irgnm, postprocess
from .operators import make_ops, sobolev_weight, udot, uinit


def _csum_full(axis):
    return lambda prod: lax.psum(jnp.sum(prod, axis=0), axis)


def _csum_crop(axis):
    def cs(prod):
        g = prod.shape[-1]
        q = g // 4
        local = jnp.sum(prod, axis=0)
        crop = lax.psum(local[q:3 * q, q:3 * q], axis)
        return jnp.zeros_like(local).at[q:3 * q, q:3 * q].set(crop)
    return cs


def _dist_dot(axis):
    def dot(x, y):
        local = jnp.vdot(x["chat"], y["chat"])
        return jnp.vdot(x["rho"], y["rho"]) + lax.psum(local, axis)
    return dot


@functools.partial(jax.jit, static_argnames=("newton", "cg_iters"))
def reconstruct_frame(y, mask, fov, weight, x0, x_ref, *,
                      newton=7, cg_iters=30):
    """Single-device NLINV for one frame.  y: (J, X, Y)."""
    ops = make_ops(mask, fov, weight)
    u = irgnm(ops, y, x0, x_ref, newton=newton, cg_iters=cg_iters)
    return u, postprocess(ops, u)


def make_dist_reconstruct(group: DeviceGroup, axis: str = "data", *,
                          newton=7, cg_iters=30, channel_sum="crop"):
    """shard_map'd NLINV: coils split over ``axis`` (paper §3.2)."""
    mesh = group.mesh
    cs = {"full": _csum_full, "crop": _csum_crop}[channel_sum](axis)
    dot = _dist_dot(axis)

    def frame(y, mask, fov, weight, x0, x_ref):
        ops = make_ops(mask, fov, weight)
        u = irgnm(ops, y, x0, x_ref, newton=newton, cg_iters=cg_iters,
                  channel_sum=cs, dot=dot)
        c = ops.coils(u["chat"])
        rss = lax.psum(jnp.sum(jnp.abs(c) ** 2, axis=0), axis)
        img = u["rho"] * jnp.sqrt(rss)
        return u, img

    uspec = {"rho": P(), "chat": P(axis)}
    fn = jax.shard_map(
        frame, mesh=mesh,
        in_specs=(P(axis), P(), P(), P(), uspec, uspec),
        out_specs=(uspec, P()), check_vma=False)
    return jax.jit(fn)


def pad_channels(y, nseg):
    """Zero-pad the coil dim to a multiple of the group size (zero
    channels are exact no-ops for all NLINV sums)."""
    J = y.shape[0]
    Jp = -(-J // nseg) * nseg
    if Jp == J:
        return y
    return np.concatenate(
        [y, np.zeros((Jp - J,) + y.shape[1:], y.dtype)], axis=0)


def reconstruct_movie(data, *, newton=7, cg_iters=30, damping=0.9,
                      frame_fn=None):
    """Sequential movie loop (frames depend on x_ref: no pipelining,
    paper §3.2).  Returns (F, X, Y) images."""
    y, masks, fov = data["y"], data["masks"], data["fov"]
    F, J, g, _ = y.shape
    weight = sobolev_weight(g)
    u = uinit(J, g)
    x_ref = u
    images = []
    for f in range(F):
        if frame_fn is None:
            u, img = reconstruct_frame(
                jnp.asarray(y[f]), jnp.asarray(masks[f]), jnp.asarray(fov),
                jnp.asarray(weight), u, x_ref,
                newton=newton, cg_iters=cg_iters)
        else:
            u, img = frame_fn(y[f], masks[f], fov, weight, u, x_ref)
        x_ref = jax.tree.map(lambda a: damping * a, u)
        images.append(img)
    return jnp.stack(images)
