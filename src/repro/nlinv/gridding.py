"""Gridding baseline + radial forward/adjoint operator pair (paper
Fig. 10 comparison; the §3 radial-trajectory workload).

Two acquisition models share this module:

* **Cartesian-mask approximation** (the historic path): ``gridding_recon``
  reconstructs from on-grid masked k-space — IFFT of the density-
  compensated samples, root-sum-of-squares channel combination.  Fast
  but shows the streaking artefacts of radial undersampling that NLINV
  removes.

* **True radial trajectory** (via ``repro.lib.gridding``): ``radial_ops``
  builds the plan-cached distributed operator pair —
  ``forward`` (image coils -> off-grid samples: FFT then degrid) and
  ``adjoint`` (samples -> image coils: grid then IFFT) — with the coil
  dim NATURAL-segmented over a Communicator when given.
  ``gridding_recon_radial`` is the corresponding DCF-adjoint-RSS
  baseline image.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.segmented import SegmentedArray
from ..lib import fft as lfft
from ..lib.gridding import (GriddingPlan, plan_gridding, radial_trajectory,
                            ramlak_dcf_radial)


def ramlak_dcf(grid: int) -> np.ndarray:
    """Ram-Lak style radial density compensation |k| on the Cartesian
    grid (symmetric under k -> -k)."""
    k = np.fft.fftshift(np.fft.fftfreq(grid))
    ky, kx = np.meshgrid(k, k, indexing="ij")
    r = np.sqrt(kx ** 2 + ky ** 2)
    return (r / max(r.max(), 1e-9)).astype(np.float32) + 1e-3


def gridding_recon(y, mask, fov):
    """y: (J, X, Y) masked Cartesian k-space -> (X, Y) magnitude image
    (DCF + IFFT + RSS; plan-cached FFT through ``repro.lib.fft``)."""
    dcf = jnp.asarray(ramlak_dcf(y.shape[-1]))
    imgs = lfft.fft2(y * (mask * dcf)[None], inverse=True, centered=True)
    rss = jnp.sqrt(jnp.sum(jnp.abs(imgs) ** 2, axis=0))
    return fov * rss


# ---------------------------------------------------------------------------
# true radial trajectory (the lib.gridding port)
# ---------------------------------------------------------------------------

class RadialOps:
    """Distributed forward/adjoint pair for one radial geometry.

    ``forward``: coil images (J, X, Y) -> trajectory samples (J, Sp)
    (centered FFT then degridding); ``adjoint`` is its exact adjoint
    (gridding then inverse FFT).  Both accept a plain array or a
    coil-NATURAL ``SegmentedArray`` — the gridding itself is coil-local,
    so the pair introduces no communication beyond the caller's channel
    sums (paper §3.2's decomposition carried to the non-Cartesian case).
    """

    def __init__(self, plan: GriddingPlan, comm=None):
        self.plan = plan
        self.comm = comm

    def _fft(self, x, inverse: bool):
        if isinstance(x, SegmentedArray):
            return lfft.fft2_batched(x, inverse=inverse, centered=True)
        return lfft.fft2(x, inverse=inverse, centered=True)

    def forward(self, coil_imgs):
        """(J, X, Y) coil images -> (J, Sp) radial k-space samples."""
        return self.plan.degrid(self._fft(coil_imgs, inverse=False))

    def adjoint(self, samples, density_comp: bool = False):
        """(J, Sp) samples -> (J, X, Y) coil images (exact adjoint of
        ``forward``; DCF optional — adjoint stays exact without it)."""
        return self._fft(self.plan.grid(samples,
                                        density_comp=density_comp),
                         inverse=True)

    def recon(self, samples, fov):
        """DCF-adjoint-RSS baseline image (Fig. 10)."""
        return self.plan.adjoint_recon(samples, fov)


def radial_ops(grid: int, nspokes: int, frame: int = 0, *, comm=None,
               nsamp: int | None = None) -> RadialOps:
    """Plan-cached radial operator pair for one acquisition geometry.

    The trajectory, interpolation matrices and DCF are built once per
    (geometry, group) and cached; calling this again for the same frame
    geometry is a plan-cache hit.
    """
    traj = radial_trajectory(grid, nspokes, frame=frame, nsamp=nsamp)
    return RadialOps(plan_gridding(traj, grid, comm=comm), comm=comm)


def gridding_recon_radial(samples, grid: int, nspokes: int, fov, *,
                          frame: int = 0, comm=None):
    """Radial baseline reconstruction: samples (J, Sp) (plain or
    coil-NATURAL segmented) -> (X, Y) magnitude image."""
    return radial_ops(grid, nspokes, frame=frame, comm=comm).recon(
        samples, fov)
