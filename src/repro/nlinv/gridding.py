"""Non-iterative (gridding) baseline — paper Fig. 10 comparison.

Adjoint reconstruction: IFFT of the density-compensated sampled k-space,
root-sum-of-squares channel combination.  Fast but shows the streaking
artefacts of radial undersampling that NLINV removes.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .operators import ifft2c


def ramlak_dcf(grid: int) -> np.ndarray:
    """Ram-Lak style radial density compensation |k| on the grid."""
    k = np.fft.fftshift(np.fft.fftfreq(grid))
    ky, kx = np.meshgrid(k, k, indexing="ij")
    r = np.sqrt(kx ** 2 + ky ** 2)
    return (r / max(r.max(), 1e-9)).astype(np.float32) + 1e-3


def gridding_recon(y, mask, fov):
    """y: (J, X, Y) sampled k-space -> (X, Y) magnitude image."""
    dcf = jnp.asarray(ramlak_dcf(y.shape[-1]))
    imgs = ifft2c(y * (mask * dcf)[None])
    rss = jnp.sqrt(jnp.sum(jnp.abs(imgs) ** 2, axis=0))
    return fov * rss
