from . import cg, gridding, irgnm, operators, phantom, recon, stream
from .recon import Reconstructor
from .stream import (FramePipeline, FrameStream, LatencyReport,
                     stream_movie)

__all__ = ["cg", "gridding", "irgnm", "operators", "phantom", "recon",
           "stream", "Reconstructor", "FramePipeline", "FrameStream",
           "LatencyReport", "stream_movie"]
