from . import cg, gridding, irgnm, operators, phantom, recon

__all__ = ["cg", "gridding", "irgnm", "operators", "phantom", "recon"]
