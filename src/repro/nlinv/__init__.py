from . import cg, gridding, irgnm, operators, phantom, recon, stream
from .recon import Reconstructor
from .stream import FrameStream, LatencyReport, stream_movie

__all__ = ["cg", "gridding", "irgnm", "operators", "phantom", "recon",
           "stream", "Reconstructor", "FrameStream", "LatencyReport",
           "stream_movie"]
