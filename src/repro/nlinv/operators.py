"""NLINV operators (paper §3.1, eq. 2-3).

    F = P_k . DTFT . M_Omega . C . W^{-1}

Unknowns u = (rho, c_hat_j): image + coil coefficients in the weighted
Fourier domain; c_j = W(c_hat_j) = IFFT(w . c_hat_j) with the Sobolev
weight w(k) = (1 + s|k|^2)^{-l} encoding coil smoothness.

The operator count per application matches the paper's Table 1:
  G   (=F):   2 FFT-batches, 4 pointwise, 1 dot with mask
  DG:         2 FFT-batches, 5 pointwise
  DG^H:       2 FFT-batches, 4 pointwise, 1 channel-sum, 1 all-reduce

All functions are pure jnp on (J, X, Y) coil stacks, jit/shard_map-safe;
the distributed path segments J across devices (paper's decomposition)
and the channel-sum in DG^H becomes the block-wise all-reduce.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..lib.fft import fft2 as _cfft2


def sobolev_weight(grid: int, s: float = 32.0, l: int = 4) -> np.ndarray:
    """w(k) = (1 + s |k|^2)^{-l/2} on the centered grid (Uecker 2008)."""
    k = np.fft.fftshift(np.fft.fftfreq(grid))  # centered, cycles/sample
    ky, kx = np.meshgrid(k, k, indexing="ij")
    k2 = (kx ** 2 + ky ** 2) * 4.0             # normalize to ~[-1,1]^2
    return ((1.0 + s * k2) ** (-l / 2.0)).astype(np.float32)


def fft2c(x):
    return _cfft2(x, inverse=False, centered=True)


def ifft2c(x):
    return _cfft2(x, inverse=True, centered=True)


@dataclasses.dataclass(frozen=True)
class NlinvOps:
    """Closure over the acquisition geometry of one frame."""
    mask: jnp.ndarray      # (X, Y) P_k sampling mask (float 0/1)
    fov: jnp.ndarray       # (X, Y) M_Omega
    weight: jnp.ndarray    # (X, Y) Sobolev w

    # -- variable transform ------------------------------------------------
    def coils(self, chat):
        """c_j = W(c_hat_j): weighted k-space -> smooth image coils."""
        return ifft2c(chat * self.weight)

    def coils_adj(self, c):
        """W^H."""
        return fft2c(c) * self.weight

    # -- forward model -----------------------------------------------------
    def G(self, u):
        """u = {rho (X,Y), chat (J,X,Y)} -> sampled k-space (J,X,Y)."""
        c = self.coils(u["chat"])
        img = self.fov * (u["rho"][None] * c)
        return self.mask[None] * fft2c(img)

    def DG(self, u0, du):
        """Directional derivative at u0."""
        c0 = self.coils(u0["chat"])
        dc = self.coils(du["chat"])
        img = self.fov * (du["rho"][None] * c0 + u0["rho"][None] * dc)
        return self.mask[None] * fft2c(img)

    def DGH(self, u0, r, *, channel_sum=None):
        """Adjoint of DG applied to residual r (J,X,Y).

        ``channel_sum``: override for the Sum_j reduction — the
        distributed path passes the all-reduce of the paper's P2P kernel.
        """
        c0 = self.coils(u0["chat"])
        z = self.fov[None] * ifft2c(self.mask[None] * r)
        prod = jnp.conj(c0) * z
        if channel_sum is None:
            drho = jnp.sum(prod, axis=0)
        else:
            drho = channel_sum(prod)
        dchat = self.coils_adj(jnp.conj(u0["rho"])[None] * z)
        return {"rho": drho, "chat": dchat}

    def normal(self, u0, du, alpha, *, channel_sum=None):
        """(DG^H DG + alpha I) du — the CG system matrix (eq. 3 LHS)."""
        out = self.DGH(u0, self.DG(u0, du), channel_sum=channel_sum)
        return {"rho": out["rho"] + alpha * du["rho"],
                "chat": out["chat"] + alpha * du["chat"]}


def make_ops(mask, fov, weight) -> NlinvOps:
    return NlinvOps(jnp.asarray(mask, jnp.float32),
                    jnp.asarray(fov, jnp.float32),
                    jnp.asarray(weight, jnp.float32))


# -- pytree algebra for (rho, chat) ----------------------------------------

def uzeros(J, grid, dtype=jnp.complex64):
    return {"rho": jnp.zeros((grid, grid), dtype),
            "chat": jnp.zeros((J, grid, grid), dtype)}


def uinit(J, grid, dtype=jnp.complex64):
    """Paper/Uecker init: rho = 1, chat = 0."""
    return {"rho": jnp.ones((grid, grid), dtype),
            "chat": jnp.zeros((J, grid, grid), dtype)}


def uaxpy(a, x, y):
    return jax.tree.map(lambda u, v: a * u + v, x, y)


def udot(x, y):
    """<x, y> with conjugation, summed over both components (real part
    is what CG uses; kept complex for adjointness tests)."""
    return (jnp.vdot(x["rho"], y["rho"]) +
            jnp.vdot(x["chat"], y["chat"]))
