"""NLINV operators (paper §3.1, eq. 2-3).

    F = P_k . DTFT . M_Omega . C . W^{-1}

Unknowns u = (rho, c_hat_j): image + coil coefficients in the weighted
Fourier domain; c_j = W(c_hat_j) = IFFT(w . c_hat_j) with the Sobolev
weight w(k) = (1 + s|k|^2)^{-l} encoding coil smoothness.

The operator count per application matches the paper's Table 1:
  G   (=F):   2 FFT-batches, 4 pointwise, 1 dot with mask
  DG:         2 FFT-batches, 5 pointwise
  DG^H:       2 FFT-batches, 4 pointwise, 1 channel-sum, 1 all-reduce

All functions are pure jnp on (J, X, Y) coil stacks, jit/shard_map-safe;
the distributed path segments J across devices (paper's decomposition)
and the channel-sum in DG^H becomes the block-wise all-reduce.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.coil_mult import (coil_adjoint, coil_forward, coil_lincomb,
                                 plane_mult)
from ..lib.blas import tree_axpy, tree_vdot
from ..lib.fft import fft2 as _cfft2


def sobolev_weight(grid: int, s: float = 32.0, l: int = 4) -> np.ndarray:
    """w(k) = (1 + s |k|^2)^{-l/2} on the centered grid (Uecker 2008)."""
    k = np.fft.fftshift(np.fft.fftfreq(grid))  # centered, cycles/sample
    ky, kx = np.meshgrid(k, k, indexing="ij")
    k2 = (kx ** 2 + ky ** 2) * 4.0             # normalize to ~[-1,1]^2
    return ((1.0 + s * k2) ** (-l / 2.0)).astype(np.float32)


def fft2c(x):
    return _cfft2(x, inverse=False, centered=True)


def ifft2c(x):
    return _cfft2(x, inverse=True, centered=True)


@dataclasses.dataclass(frozen=True)
class NlinvOps:
    """Closure over the acquisition geometry of one frame."""
    mask: jnp.ndarray      # (X, Y) P_k sampling mask (float 0/1)
    fov: jnp.ndarray       # (X, Y) M_Omega
    weight: jnp.ndarray    # (X, Y) Sobolev w

    # -- variable transform ------------------------------------------------
    def coils(self, chat):
        """c_j = W(c_hat_j): weighted k-space -> smooth image coils."""
        return ifft2c(chat * self.weight)

    def coils_adj(self, c):
        """W^H."""
        return fft2c(c) * self.weight

    # -- forward model -----------------------------------------------------
    def G(self, u):
        """u = {rho (X,Y), chat (J,X,Y)} -> sampled k-space (J,X,Y)."""
        c = self.coils(u["chat"])
        img = self.fov * (u["rho"][None] * c)
        return self.mask[None] * fft2c(img)

    def DG(self, u0, du):
        """Directional derivative at u0."""
        c0 = self.coils(u0["chat"])
        dc = self.coils(du["chat"])
        img = self.fov * (du["rho"][None] * c0 + u0["rho"][None] * dc)
        return self.mask[None] * fft2c(img)

    def DGH(self, u0, r, *, channel_sum=None):
        """Adjoint of DG applied to residual r (J,X,Y).

        ``channel_sum``: override for the Sum_j reduction — the
        distributed path passes the all-reduce of the paper's P2P kernel.
        """
        c0 = self.coils(u0["chat"])
        z = self.fov[None] * ifft2c(self.mask[None] * r)
        prod = jnp.conj(c0) * z
        if channel_sum is None:
            drho = jnp.sum(prod, axis=0)
        else:
            drho = channel_sum(prod)
        dchat = self.coils_adj(jnp.conj(u0["rho"])[None] * z)
        return {"rho": drho, "chat": dchat}

    def normal(self, u0, du, alpha, *, channel_sum=None):
        """(DG^H DG + alpha I) du — the CG system matrix (eq. 3 LHS)."""
        out = self.DGH(u0, self.DG(u0, du), channel_sum=channel_sum)
        return {"rho": out["rho"] + alpha * du["rho"],
                "chat": out["chat"] + alpha * du["chat"]}

    # -- fused hot path (2017 follow-up: kernel fusion + comm overlap) -----
    #
    # Same math as G/DG/DGH, restructured for the per-frame latency
    # budget: the Newton-point constants (c0 = W(chat0), conj planes) are
    # precomputed ONCE per linearization instead of re-derived inside
    # every CG iteration; the pointwise chains run through the
    # generalized ``coil_mult`` kernel family instead of materializing
    # intermediates; and the DG^H channel reduction is a fused collective
    # (scalar piggyback + overlapped dchat branch) injected by the
    # caller.  Exactness notes: the forward/derivative outputs are
    # supported on ``mask`` (0/1), so DG^H inside the normal operator may
    # skip the re-mask (mask^2 = mask); A(0) = 0 exactly, so CG may start
    # from r0 = rhs without applying the operator.

    def precompute(self, u0):
        """Per-Newton-point constants hoisted out of the CG loop (the
        paper's Table 1 assumes c0 is precomputed; the unfused methods
        re-derive it per operator application)."""
        return {"rho0": u0["rho"], "rho0c": jnp.conj(u0["rho"]),
                "c0": self.coils(u0["chat"])}

    def G_fused(self, u, c0=None):
        """Forward model through the fused pointwise chain."""
        c = self.coils(u["chat"]) if c0 is None else c0
        img = coil_lincomb(u["rho"], c, scale=self.fov)
        return plane_mult(fft2c(img), self.mask)

    def DG_fused(self, pre, du):
        """Derivative at the precomputed Newton point ``pre``."""
        dc = self.coils(du["chat"])
        img = coil_lincomb(du["rho"], pre["c0"], pre["rho0"], dc,
                           scale=self.fov)
        return plane_mult(fft2c(img), self.mask)

    def DGH_fused(self, pre, r, *, reducer, extras=(), premasked=True):
        """Adjoint of DG with the fused reduction schedule.

        ``reducer(prod, extras, compute)`` performs the cross-device
        channel sum of the locally channel-summed ``prod`` (windowed on
        the distributed path), reduces ``extras`` in the same collective
        and overlaps the independent ``compute`` branch (the dchat FFT
        chain) with the transfer; it returns
        ``(drho, extras_out, dchat)``.  ``premasked=True`` asserts ``r``
        is mask-supported (true for residuals and DG outputs) and skips
        the re-mask.  Returns ``({rho, chat}, extras_out)``.
        """
        rin = r if premasked else plane_mult(r, self.mask)
        z = plane_mult(ifft2c(rin), self.fov)
        prod = coil_adjoint(pre["c0"], z)            # local Sum_j conj(c0)*z

        def dchat():
            return plane_mult(fft2c(coil_forward(z, pre["rho0c"])),
                              self.weight)

        drho, extras_out, dchat_out = reducer(prod, tuple(extras), dchat)
        return {"rho": drho, "chat": dchat_out}, extras_out

    def normal_pap(self, pre, du, alpha, *, reducer):
        """Fused normal operator application returning BOTH ``A du`` and
        the CG curvature scalar ``<du, A du>`` for one extra collective
        of zero: by self-adjointness

            <du, (DG^H DG + alpha I) du> = ||DG du||^2 + alpha ||du||^2,

        so the scalar needs only local partials — the segmented part
        rides the channel-sum collective via ``extras`` (paper Table 1's
        'scalar products of all data' without its own all-reduce).
        Returns ``(A du, pap)``.
        """
        dgp = self.DG_fused(pre, du)
        nat = (jnp.real(jnp.vdot(dgp, dgp)) +
               alpha * jnp.real(jnp.vdot(du["chat"], du["chat"])))
        clone = alpha * jnp.real(jnp.vdot(du["rho"], du["rho"]))
        out, (nat_red,) = self.DGH_fused(pre, dgp, reducer=reducer,
                                         extras=(nat,))
        pap = nat_red + clone
        ap = {"rho": out["rho"] + alpha * du["rho"],
              "chat": out["chat"] + alpha * du["chat"]}
        return ap, pap


def make_ops(mask, fov, weight) -> NlinvOps:
    return NlinvOps(jnp.asarray(mask, jnp.float32),
                    jnp.asarray(fov, jnp.float32),
                    jnp.asarray(weight, jnp.float32))


# -- pytree algebra for (rho, chat) ----------------------------------------

def uzeros(J, grid, dtype=jnp.complex64):
    return {"rho": jnp.zeros((grid, grid), dtype),
            "chat": jnp.zeros((J, grid, grid), dtype)}


def uinit(J, grid, dtype=jnp.complex64):
    """Paper/Uecker init: rho = 1, chat = 0."""
    return {"rho": jnp.ones((grid, grid), dtype),
            "chat": jnp.zeros((J, grid, grid), dtype)}


def uaxpy(a, x, y):
    """a*x + y — routed through ``repro.lib.blas.tree_axpy`` so the
    single-device and distributed paths share one implementation."""
    return tree_axpy(a, x, y)


def udot(x, y):
    """<x, y> with conjugation, summed over both components (real part
    is what CG uses; kept complex for adjointness tests).  Routed
    through ``repro.lib.blas.tree_vdot``."""
    return tree_vdot(x, y)


def local_reducer(prod, extras, compute):
    """The single-program degenerate of the fused DG^H reduction hook:
    no collective, the overlapped branch just runs."""
    return prod, tuple(extras), compute() if compute is not None else None
