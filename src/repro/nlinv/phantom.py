"""Synthetic MRI data: Shepp-Logan phantom, birdcage-style coil
sensitivities, radial sampling masks, and the k-space simulator.

Matches the paper's acquisition model: matrix size N (192..384 in the
paper), grid doubled to 2N for the non-periodic PSF convolution, J coil
channels (32 compressed to 8-12), radial spokes with golden-angle
interleaving across frames (real-time FLASH).
"""

from __future__ import annotations

import numpy as np

# (intensity, a, b, x0, y0, phi) — standard Shepp-Logan ellipses
_ELLIPSES = [
    (1.0, 0.69, 0.92, 0.0, 0.0, 0.0),
    (-0.8, 0.6624, 0.874, 0.0, -0.0184, 0.0),
    (-0.2, 0.11, 0.31, 0.22, 0.0, -18.0),
    (-0.2, 0.16, 0.41, -0.22, 0.0, 18.0),
    (0.1, 0.21, 0.25, 0.0, 0.35, 0.0),
    (0.1, 0.046, 0.046, 0.0, 0.1, 0.0),
    (0.1, 0.046, 0.046, 0.0, -0.1, 0.0),
    (0.1, 0.046, 0.023, -0.08, -0.605, 0.0),
    (0.1, 0.023, 0.023, 0.0, -0.606, 0.0),
    (0.1, 0.023, 0.046, 0.06, -0.605, 0.0),
]


def shepp_logan(n: int, motion: float = 0.0) -> np.ndarray:
    """(n, n) complex64 phantom; ``motion`` perturbs ellipse positions
    (simulates the beating-heart frames of the paper's movies)."""
    y, x = np.mgrid[-1:1:n * 1j, -1:1:n * 1j]
    img = np.zeros((n, n), np.float32)
    for i, (a, ea, eb, x0, y0, phi) in enumerate(_ELLIPSES):
        dx = motion * 0.05 * np.sin(2 * np.pi * motion + i)
        th = np.deg2rad(phi)
        xr = (x - x0 - dx) * np.cos(th) + (y - y0) * np.sin(th)
        yr = -(x - x0 - dx) * np.sin(th) + (y - y0) * np.cos(th)
        img[(xr / ea) ** 2 + (yr / eb) ** 2 <= 1.0] += a
    return img.astype(np.complex64)


def birdcage_coils(n: int, ncoils: int) -> np.ndarray:
    """(J, n, n) complex64 smooth sensitivities on a ring (birdcage-like)."""
    y, x = np.mgrid[-1:1:n * 1j, -1:1:n * 1j]
    coils = []
    for j in range(ncoils):
        th = 2 * np.pi * j / ncoils
        cx, cy = 1.3 * np.cos(th), 1.3 * np.sin(th)
        r2 = (x - cx) ** 2 + (y - cy) ** 2
        mag = np.exp(-r2 / 1.8)
        pha = np.exp(1j * (th + 0.5 * (x * np.cos(th) + y * np.sin(th))))
        coils.append(mag * pha)
    c = np.stack(coils).astype(np.complex64)
    rss = np.sqrt((np.abs(c) ** 2).sum(0, keepdims=True))
    return (c / np.maximum(rss, 1e-6)).astype(np.complex64)


def radial_mask(grid: int, nspokes: int, frame: int = 0) -> np.ndarray:
    """(grid, grid) bool Cartesian mask of ``nspokes`` radial lines.

    Golden-angle rotation per frame gives the interleaved acquisition of
    the paper's real-time protocol (P_k after gridding: on-grid samples).
    """
    ga = np.pi * (3 - np.sqrt(5.0))
    mask = np.zeros((grid, grid), bool)
    c = grid // 2
    rr = np.arange(-c, c, 0.5)
    for s in range(nspokes):
        th = s * np.pi / nspokes + frame * ga
        xs = np.clip(np.round(c + rr * np.cos(th)).astype(int), 0, grid - 1)
        ys = np.clip(np.round(c + rr * np.sin(th)).astype(int), 0, grid - 1)
        mask[ys, xs] = True
    return mask


def fov_mask(grid: int) -> np.ndarray:
    """M_Omega: restrict to the centered FOV (grid is doubled -> half)."""
    m = np.zeros((grid, grid), np.float32)
    q = grid // 4
    m[q:3 * q, q:3 * q] = 1.0
    return m


def make_dataset(n: int = 96, ncoils: int = 8, nspokes: int = 11,
                 frames: int = 1, noise: float = 1e-4, seed: int = 0):
    """Full synthetic acquisition.  Returns dict with doubled-grid arrays:
    y (frames, J, 2n, 2n) sampled k-space, masks, ground truth."""
    rng = np.random.default_rng(seed)
    grid = 2 * n
    q = grid // 4
    coils_small = birdcage_coils(n, ncoils)
    out_y, out_masks, truths = [], [], []
    coils = np.zeros((ncoils, grid, grid), np.complex64)
    coils[:, q:3 * q, q:3 * q] = coils_small
    for f in range(frames):
        rho = np.zeros((grid, grid), np.complex64)
        rho[q:3 * q, q:3 * q] = shepp_logan(n, motion=float(f) / max(frames, 1))
        mask = radial_mask(grid, nspokes, frame=f)
        ksp = np.fft.fftshift(
            np.fft.fft2(np.fft.ifftshift(rho[None] * coils, axes=(-2, -1)),
                        axes=(-2, -1), norm="ortho"), axes=(-2, -1))
        ksp *= mask[None]
        ksp += noise * (rng.standard_normal(ksp.shape) +
                        1j * rng.standard_normal(ksp.shape)).astype(np.complex64)
        ksp *= mask[None]
        out_y.append(ksp.astype(np.complex64))
        out_masks.append(mask)
        truths.append(rho)
    return {
        "y": np.stack(out_y),                  # (F, J, grid, grid)
        "masks": np.stack(out_masks),          # (F, grid, grid)
        "coils": coils,                        # (J, grid, grid) truth
        "rho": np.stack(truths),               # (F, grid, grid) truth
        "fov": fov_mask(grid),
        "grid": grid, "n": n, "ncoils": ncoils,
    }
