"""Iteratively Regularized Gauss-Newton Method (paper eq. 3).

    (DG^H DG + alpha_n I)(x_{n+1} - x_n)
        = DG^H (y - G(x_n)) - alpha_n (x_n - x_ref)

with alpha_n = alpha0 * q^n and the previous frame as x_ref (temporal
regularization — the reason movie frames cannot be pipelined, §3.2).

The two cross-device reduction points are injected: ``channel_sum`` (the
Σ_j in DG^H) and ``dot`` (the CG scalar products).  The defaults are the
local single-program math; ``recon.Reconstructor`` passes its bound
``Communicator``'s verbs (``comm.allreduce_window`` / ``comm.vdot``),
which is the only way device communication ever enters this solver.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.coil_mult import plane_mult
from .cg import cg, cg_fused
from .operators import local_reducer, uaxpy, udot, uzeros


def irgnm(ops, y, x0, x_ref=None, *, newton: int = 7, cg_iters: int = 30,
          alpha0: float = 1.0, q: float = 1.0 / 3.0,
          channel_sum=None, dot=None):
    """Returns the solution pytree u = {rho, chat}."""
    if dot is None:
        dot = udot
    x = x0
    if x_ref is None:
        x_ref = x0   # pull toward the initial guess (rho=1, chat=0);
        # movies pass the (damped) previous frame instead — paper §3.2.
    alpha = jnp.asarray(alpha0, jnp.float32)
    for n in range(newton):
        r = uaxpy(-1.0, ops.G(x), y)                       # y - G(x)
        rhs = ops.DGH(x, r, channel_sum=channel_sum)
        rhs = uaxpy(alpha, uaxpy(-1.0, x, x_ref), rhs)     # - a (x - ref)
        A = lambda du: ops.normal(x, du, alpha, channel_sum=channel_sum)
        dx = cg(A, rhs, jax.tree.map(jnp.zeros_like, x),
                iters=cg_iters, dot=dot)
        x = uaxpy(1.0, dx, x)
        alpha = alpha * q
    return x


def irgnm_fused(ops, y, x0, x_ref=None, *, newton: int = 7,
                cg_iters: int = 30, alpha0: float = 1.0, q: float = 1.0 / 3.0,
                reducer=None, rs_sum=None):
    """IRGNM on the fused hot path (same Newton/regularization schedule
    as :func:`irgnm`, same math, restructured per the 2017 follow-up):

    * the Newton-point constants (``c0``/conj planes) are precomputed
      once per linearization (``NlinvOps.precompute``) instead of
      re-derived inside every CG operator application;
    * the CG body runs the single-pass update kernels with the
      ``<p, A p>`` scalar fused into the channel-sum collective
      (``cg_fused`` + ``NlinvOps.normal_pap``) and starts from the exact
      ``r0 = rhs`` (``A(0) = 0``);
    * ``reducer`` is the fused DG^H reduction hook (windowed channel sum
      + scalar piggyback + overlapped dchat branch); ``rs_sum`` the
      policy-aware residual-norm reduction.  The defaults are the
      single-program degenerates, so this function is also the 1-device
      fast path.
    """
    if reducer is None:
        reducer = local_reducer
    x = x0
    if x_ref is None:
        x_ref = x0
    # DGH_fused skips the re-mask (premasked residuals); G_fused output
    # is masked by construction, so masking y ONCE here makes every
    # residual mask-supported for arbitrary caller data (a no-op when y
    # is already sampled k-space) — exactness, not an assumption.
    y = plane_mult(y, ops.mask)
    alpha = jnp.asarray(alpha0, jnp.float32)
    for n in range(newton):
        pre = ops.precompute(x)
        r = uaxpy(-1.0, ops.G_fused(x, c0=pre["c0"]), y)   # y - G(x), masked
        rhs, _ = ops.DGH_fused(pre, r, reducer=reducer)
        rhs = uaxpy(alpha, uaxpy(-1.0, x, x_ref), rhs)     # - a (x - ref)
        pap = lambda p: ops.normal_pap(pre, p, alpha, reducer=reducer)
        dx = cg_fused(pap, rhs, iters=cg_iters, rs_sum=rs_sum)
        x = uaxpy(1.0, dx, x)
        alpha = alpha * q
    return x


def postprocess(ops, u):
    """rho * |c| normalization: the displayed image (RSS-weighted)."""
    c = ops.coils(u["chat"])
    rss = jnp.sqrt(jnp.sum(jnp.abs(c) ** 2, axis=0))
    return u["rho"] * rss
