"""Iteratively Regularized Gauss-Newton Method (paper eq. 3).

    (DG^H DG + alpha_n I)(x_{n+1} - x_n)
        = DG^H (y - G(x_n)) - alpha_n (x_n - x_ref)

with alpha_n = alpha0 * q^n and the previous frame as x_ref (temporal
regularization — the reason movie frames cannot be pipelined, §3.2).

The two cross-device reduction points are injected: ``channel_sum`` (the
Σ_j in DG^H) and ``dot`` (the CG scalar products).  The defaults are the
local single-program math; ``recon.Reconstructor`` passes its bound
``Communicator``'s verbs (``comm.allreduce_window`` / ``comm.vdot``),
which is the only way device communication ever enters this solver.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cg import cg
from .operators import uaxpy, udot, uzeros


def irgnm(ops, y, x0, x_ref=None, *, newton: int = 7, cg_iters: int = 30,
          alpha0: float = 1.0, q: float = 1.0 / 3.0,
          channel_sum=None, dot=None):
    """Returns the solution pytree u = {rho, chat}."""
    if dot is None:
        dot = udot
    x = x0
    if x_ref is None:
        x_ref = x0   # pull toward the initial guess (rho=1, chat=0);
        # movies pass the (damped) previous frame instead — paper §3.2.
    alpha = jnp.asarray(alpha0, jnp.float32)
    for n in range(newton):
        r = uaxpy(-1.0, ops.G(x), y)                       # y - G(x)
        rhs = ops.DGH(x, r, channel_sum=channel_sum)
        rhs = uaxpy(alpha, uaxpy(-1.0, x, x_ref), rhs)     # - a (x - ref)
        A = lambda du: ops.normal(x, du, alpha, channel_sum=channel_sum)
        dx = cg(A, rhs, jax.tree.map(jnp.zeros_like, x),
                iters=cg_iters, dot=dot)
        x = uaxpy(1.0, dx, x)
        alpha = alpha * q
    return x


def postprocess(ops, u):
    """rho * |c| normalization: the displayed image (RSS-weighted)."""
    c = ops.coils(u["chat"])
    rss = jnp.sqrt(jnp.sum(jnp.abs(c) ** 2, axis=0))
    return u["rho"] * rss
