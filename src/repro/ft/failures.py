"""Fault tolerance: restart policy, preemption flush, straggler watchdog.

On a 1000+-node fleet the launcher's contract is: (1) any step may die —
resume from the last complete checkpoint with bounded lost work; (2) a
preemption signal flushes a checkpoint before exit; (3) persistent
stragglers are detected from step-time statistics and reported to the
scheduler for replacement (detection is in-band; replacement is the
cluster manager's job)."""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

from ..ckpt import latest_step, restore_sharded, save


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0


def run_with_restarts(train_loop: Callable[[int], int], *,
                      policy: Optional[RestartPolicy] = None,
                      on_restart: Optional[Callable[[int, Exception], None]]
                      = None) -> int:
    """``train_loop(start_step) -> final_step``; re-enter after failures.

    The loop is responsible for reloading state from the checkpoint dir
    (resume_or_init) — this wrapper only supplies the retry envelope.
    """
    # a fresh default per call: RestartPolicy is a mutable dataclass, so
    # a default instance in the signature would be shared (and mutable)
    # across every call site in the process
    policy = RestartPolicy() if policy is None else policy
    restarts = 0
    backoff = policy.backoff_s
    last_step = 0
    while True:
        try:
            return train_loop(last_step)
        except Exception as e:  # noqa: BLE001 — any step failure
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            if on_restart:
                on_restart(restarts, e)
            time.sleep(backoff)
            backoff *= policy.backoff_mult


def resume_or_init(ckpt_dir, tree_like, shardings, init_fn):
    """Latest checkpoint if present, else ``init_fn()`` (cold start)."""
    if latest_step(ckpt_dir) is not None:
        return restore_sharded(ckpt_dir, tree_like, shardings)
    return init_fn(), 0


class PreemptionGuard:
    """SIGTERM -> flush a final checkpoint before the scheduler kills us."""

    def __init__(self):
        self.preempted = False
        self._orig = signal.signal(signal.SIGTERM, self._handler)

    def _handler(self, signum, frame):
        self.preempted = True

    def maybe_flush(self, ckpt_dir, step, state) -> bool:
        if self.preempted:
            save(ckpt_dir, step, state, blocking=True)
            return True
        return False


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the rolling median.

    The paper's real-time constraint (bounded per-frame latency) is the
    same contract: a straggling device shows up as a slow collective for
    *everyone*, so wall-clock per step is the right signal.
    """
    threshold: float = 2.0
    window: int = 50
    _times: list = dataclasses.field(default_factory=list)
    flagged: int = 0

    def record(self, step_time: float) -> bool:
        times = sorted(self._times[-self.window:])
        slow = bool(times) and len(times) >= 5 and \
            step_time > self.threshold * times[len(times) // 2]
        self._times.append(step_time)
        if slow:
            self.flagged += 1
        return slow

    @property
    def median(self) -> float:
        t = sorted(self._times[-self.window:])
        return t[len(t) // 2] if t else 0.0
