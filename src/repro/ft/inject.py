"""Deterministic fault injection for the real-time serving path.

The serving stack has exactly three host-side choke points every piece
of work passes through:

  ``verb``   Communicator verb dispatch (``container``/``bcast``/
             ``scatter``/``gather``/``copy``/``allreduce`` — every
             payload entering or moving across the group);
  ``task``   ``repro.task.Executor`` task dispatch (every node of every
             frame/tick graph, immediately before its ``fn`` runs);
  ``step``   ``StreamScheduler`` handing a batch to ``Workload.step``
             (every serving tick, with the per-client items visible).

A :class:`FaultInjector` installs itself at all three (module-level
hook variables — ``core.env.VERB_HOOK``, ``task.executor.TASK_HOOK``,
``serve.scheduler.STEP_HOOK`` — so the lower layers never import this
package) and fires :class:`FaultSpec` faults:

  ``transient``    raise :class:`TransientFault` (retryable — the
                   Executor retry policy and the scheduler's tick
                   requeue both key off ``exc.transient``);
  ``corrupt``      poison every inexact array leaf of the payload with
                   NaN (what a flaky link or DMA error looks like to
                   the math — the quarantine path's input);
  ``straggle``     sleep ``delay_ms`` before dispatch (a slow device /
                   contended link; feeds the deadline ladder);
  ``device_loss``  raise :class:`DeviceLossFault` carrying the unhealthy
                   device index (NOT retryable — the caller remeshes via
                   ``Environment.survivor`` + ``ft.remesh``).

Every decision is a pure function of ``(seed, spec index, per-spec call
index)`` — independent of wall clock, dict order, or cross-site
interleaving — so a chaos run replays *exactly* from its seed:
``inj.reset()`` rewinds the counters and the same program produces the
same ``fired`` log.  The seed defaults to ``$REPRO_FAULT_SEED`` (CI pins
it), else 0.

>>> from repro.task import Executor, TaskGraph
>>> g = TaskGraph()
>>> _ = g.add("inc", lambda x: x + 1, inputs=("x",), outputs=("y",))
>>> inj = FaultInjector([FaultSpec(site="task", kind="transient",
...                                at=(0,))], seed=7)
>>> with inj:
...     try:
...         Executor().run(g, feeds={"x": 1})
...     except TransientFault as e:
...         print(e)
injected transient at task:inc#0
>>> inj.fired
[('task', 'inc', 0, 'transient')]
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import jax
import numpy as np

SEED_ENV = "REPRO_FAULT_SEED"

SITES = ("verb", "task", "step")
KINDS = ("transient", "corrupt", "straggle", "device_loss")


class FaultError(RuntimeError):
    """Base class of every injected failure."""

    transient = False


class TransientFault(FaultError):
    """A retryable failure (link hiccup, preempted kernel): retry
    policies and the scheduler's tick requeue key off ``transient``."""

    transient = True


class DeviceLossFault(FaultError):
    """A device (group member) went unhealthy: not retryable — the
    handler mints a survivor submesh and remeshes the live streams."""

    def __init__(self, msg: str, device: int = 0):
        super().__init__(msg)
        self.device = device


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: where (``site`` + ``match``), what
    (``kind``), and when (explicit call indices ``at`` and/or
    probability ``prob`` per matching call, capped at ``max_fires``).

    ``at`` indices count this spec's OWN matching calls at its site
    (0-based), so ``match="solve", at=(2,)`` means "the third dispatch
    of a task whose name contains 'solve'" regardless of what else runs.
    ``pick`` narrows a ``corrupt`` at the ``step`` site to one batch
    position (one client); default poisons the whole payload.
    """

    site: str
    kind: str
    prob: float = 0.0
    at: tuple = ()
    match: str = ""
    delay_ms: float = 1.0
    pick: Optional[int] = None
    device: int = 0
    max_fires: Optional[int] = None

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"site must be one of {SITES}: {self.site!r}")
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}: {self.kind!r}")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob must be in [0, 1]: {self.prob}")


def _poison_leaf(a):
    """NaN-fill one array leaf (inexact dtypes only; elementwise so
    shardings are preserved)."""
    if isinstance(a, np.ndarray) and np.issubdtype(a.dtype, np.inexact):
        return np.full_like(a, np.nan)
    if isinstance(a, jax.Array) and np.issubdtype(a.dtype, np.inexact):
        return a * np.asarray(np.nan, a.dtype)
    return a


def poison(payload):
    """NaN-poison every inexact array leaf of a payload pytree
    (non-array leaves — sessions, strings, ints — pass through)."""
    return jax.tree.map(_poison_leaf, payload)


class FaultInjector:
    """Seed-scheduled chaos at the three serving choke points.

    Use as a context manager: ``with FaultInjector(specs, seed=s):``
    installs the hooks, the body runs under injection, exit always
    restores the previous hooks.  ``fired`` is the replay log —
    ``(site, name, spec-local call index, kind)`` per fired fault.
    """

    def __init__(self, specs, seed: Optional[int] = None):
        self.specs = tuple(specs)
        if seed is None:
            seed = int(os.environ.get(SEED_ENV, "0"))
        self.seed = int(seed)
        self.fired: list[tuple] = []
        self._seen = [0] * len(self.specs)    # matching calls per spec
        self._fires = [0] * len(self.specs)
        self._saved = None

    def reset(self) -> None:
        """Rewind to the start of the schedule: the same program then
        replays the exact same faults (determinism contract)."""
        self.fired = []
        self._seen = [0] * len(self.specs)
        self._fires = [0] * len(self.specs)

    def _decide(self, i: int, spec: FaultSpec, idx: int) -> bool:
        if spec.max_fires is not None and self._fires[i] >= spec.max_fires:
            return False
        if idx in spec.at:
            return True
        if spec.prob > 0.0:
            # pure function of (seed, spec index, spec-local call index):
            # replay-exact and independent of cross-site interleaving
            r = np.random.default_rng([self.seed, i, idx]).random()
            return bool(r < spec.prob)
        return False

    def fire(self, site: str, name: str, payload=None):
        """Account one call at ``site`` and apply every matching spec.
        Returns the (possibly corrupted) payload; raises for
        ``transient`` / ``device_loss`` fires."""
        for i, spec in enumerate(self.specs):
            if spec.site != site or spec.match not in name:
                continue
            idx = self._seen[i]
            self._seen[i] += 1
            if not self._decide(i, spec, idx):
                continue
            self._fires[i] += 1
            self.fired.append((site, name, idx, spec.kind))
            where = f"{site}:{name}#{idx}"
            if spec.kind == "transient":
                raise TransientFault(f"injected transient at {where}")
            if spec.kind == "device_loss":
                raise DeviceLossFault(
                    f"injected device loss at {where} "
                    f"(device {spec.device})", device=spec.device)
            if spec.kind == "straggle":
                time.sleep(spec.delay_ms / 1e3)
            elif spec.kind == "corrupt":
                if spec.pick is not None and isinstance(payload, list):
                    payload = [poison(p) if j == spec.pick else p
                               for j, p in enumerate(payload)]
                else:
                    payload = poison(payload)
        return payload

    # -- hook plumbing ----------------------------------------------------
    def _on_verb(self, name, payload):
        return self.fire("verb", name, payload)

    def _on_task(self, task, args):
        return self.fire("task", task.name, args)

    def _on_step(self, workload, batch):
        return self.fire("step", type(workload).__name__, batch)

    def __enter__(self) -> "FaultInjector":
        from ..core import env as _env
        from ..serve import scheduler as _sched
        from ..task import executor as _exec
        if self._saved is not None:
            raise RuntimeError("FaultInjector is not reentrant")
        self._saved = (_env.VERB_HOOK, _exec.TASK_HOOK, _sched.STEP_HOOK)
        _env.VERB_HOOK = self._on_verb
        _exec.TASK_HOOK = self._on_task
        _sched.STEP_HOOK = self._on_step
        return self

    def __exit__(self, *exc) -> None:
        from ..core import env as _env
        from ..serve import scheduler as _sched
        from ..task import executor as _exec
        _env.VERB_HOOK, _exec.TASK_HOOK, _sched.STEP_HOOK = self._saved
        self._saved = None
