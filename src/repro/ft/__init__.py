"""Fault tolerance: the offline restart envelope (``failures``), the
serving-path chaos plane (``inject``) and elastic remesh (``remesh``).
See ``docs/fault_tolerance.md`` for the programming guide."""

from .failures import (PreemptionGuard, RestartPolicy, StragglerWatchdog,
                       resume_or_init, run_with_restarts)
from .inject import (DeviceLossFault, FaultError, FaultInjector, FaultSpec,
                     TransientFault, poison)
from .remesh import migrate_carry, pad_rows

__all__ = ["PreemptionGuard", "RestartPolicy", "StragglerWatchdog",
           "resume_or_init", "run_with_restarts",
           "DeviceLossFault", "FaultError", "FaultInjector", "FaultSpec",
           "TransientFault", "poison",
           "migrate_carry", "pad_rows"]
