from .failures import (PreemptionGuard, RestartPolicy, StragglerWatchdog,
                       resume_or_init, run_with_restarts)

__all__ = ["PreemptionGuard", "RestartPolicy", "StragglerWatchdog",
           "resume_or_init", "run_with_restarts"]
