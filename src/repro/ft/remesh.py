"""Elastic remesh: continue live streams on a survivor submesh.

When a device is marked unhealthy (a :class:`~repro.ft.DeviceLossFault`
from the injector, or a real health signal), the recovery path is:

  1. ``Environment.survivor(comm, lost)`` mints a Communicator over the
     group's remaining devices;
  2. a new ``Reconstructor`` (or any group-bound program) is built on
     it — plan keys include the group token, so nothing stale is reused;
  3. every live Newton carry is re-placed onto the survivor group with
     :func:`migrate_carry` — the replicated ``rho`` re-broadcasts, the
     coil-segmented ``chat`` re-scatters through the same topology-aware
     upload routes ``put_frame`` always uses, zero-padding the coil dim
     to the survivor group size (zero channels are exact no-ops for all
     NLINV sums, so the continued stream matches the uninterrupted one).

``NlinvStreamWorkload.remesh`` drives steps 2–3 for a whole scheduler's
worth of sessions; this module holds the carry-level mechanics so the
checkpoint restore path (``repro.ckpt`` + ``resume_or_init``) can reuse
them: a carry restored from disk is migrated exactly like a live one.
"""

from __future__ import annotations

import numpy as np


def pad_rows(a: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad dim 0 of ``a`` up to ``rows`` (no-op when already
    there)."""
    if a.shape[0] >= rows:
        return a
    pad = np.zeros((rows - a.shape[0],) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad], axis=0)


def migrate_carry(rec, u: dict, pad_to: int | None = None) -> dict:
    """Re-place one ``{rho, chat}`` Newton carry onto ``rec``'s group.

    ``rho`` is replicated (CLONE) — re-broadcast; ``chat`` is
    coil-segmented (NATURAL dim 0) — re-scattered, with its coil dim
    zero-padded to ``pad_to`` (default: the next multiple of the new
    group size).  Works on live carries and on host trees restored from
    a checkpoint alike (the leaves only need ``np.asarray``).
    """
    rho = np.asarray(u["rho"])
    chat = np.asarray(u["chat"])
    size = rec.comm.size
    rows = pad_to if pad_to is not None else -(-chat.shape[0] // size) * size
    if rows % size:
        raise ValueError(
            f"carry migration needs the coil dim padded to a multiple of "
            f"the survivor group size {size}; got pad_to={pad_to}")
    chat = pad_rows(chat, rows)
    return {"rho": rec.put_const(rho), "chat": rec.put_frame(chat)}
