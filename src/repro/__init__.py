"""repro — a multi-GPU programming library for real-time applications.

Layers (see docs/architecture.md): ``repro.core`` (segmented containers
+ Environment/Communicator verbs), ``repro.kernels`` (Pallas TPU
kernels), ``repro.lib`` (plan-cached library ports), ``repro.nlinv``
(the real-time NLINV workload), ``repro.task`` (dependency-driven
task-graph executor), ``repro.serve`` (the multi-stream service) and
``repro.bench`` (scenario registry + artifacts).

Kept import-light: importing ``repro`` pulls no JAX-heavy modules.
"""
