"""libblas port — plan-cached segmented BLAS (paper §4, Fig. 4).

MGPU's libblas consolidates CUBLAS under the segmented-container
interface; the port here adds the plan layer: every operation is a
:class:`repro.lib.plan.Plan` keyed on the operand layout (shape, dtype,
policy, group), compiled once and cached.  On top of the paper's
verb-per-op set it provides the two fused epilogues a CG-style solver
actually wants on the hot path:

``axpy_dot``       w = a*x + y and <z, w> in ONE compiled program (the
                   classic fused AXPY+DOT epilogue — saves a full pass
                   over w);
``dot_allreduce``  shard-local partial products + the cross-segment
                   reduction fused into one SPMD program (paper Table 1:
                   'scalar products of all data' pay exactly one
                   all-reduce).

Scaling behaviour matches paper Fig. 4: ``axpy``/``gemm_batched`` are
segment-local (linear scaling), ``dot``/``norm2`` add one reduction,
``gemm_ksplit`` adds the inter-device reduction of the contracted dim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import comm as _comm
from ..core import compat
from ..core.comm import _axis_arg
from ..core.segmented import Policy, SegmentedArray
from ..kernels import registry as _kreg
from ..kernels.cg_fused import ops as _cg_ops
from .plan import Plan, PlanCache, default_cache, seg_token


def _cache(cache):
    return default_cache() if cache is None else cache


def _binary_plan(op: str, x: SegmentedArray, y: SegmentedArray,
                 builder, cache: PlanCache | None,
                 extra: tuple = ()) -> Plan:
    cache = _cache(cache)
    key = ("blas", op, seg_token(x), seg_token(y), *extra)
    return cache.get_or_build(
        key, lambda: Plan(key=key, fn=builder(), lib="blas", op=op))


# ---------------------------------------------------------------------------
# tree-level math (plain arrays / tracers) — the ONE implementation the
# segmented plans below and nlinv's pytree algebra (operators.uaxpy/udot)
# both route through, so single-device and distributed paths share it.
# ---------------------------------------------------------------------------

def tree_axpy(a, x, y):
    """``a*x + y`` over matching pytrees of plain arrays (jit/shard_map
    safe — the in-program form of :func:`axpy`)."""
    return jax.tree.map(lambda u, v: a * u + v, x, y)


def tree_vdot(x, y):
    """Conjugating inner product summed over all leaves of matching
    pytrees (the in-program form of :func:`dot`; callers inject the
    cross-segment reduction, e.g. ``Communicator.vdot``)."""
    xl, xdef = jax.tree.flatten(x)
    yl, ydef = jax.tree.flatten(y)
    if xdef != ydef:
        raise ValueError(f"tree_vdot operands differ in structure: "
                         f"{xdef} vs {ydef}")
    return sum(jnp.vdot(a, b) for a, b in zip(xl, yl))


# ---------------------------------------------------------------------------
# level-1: axpy / dot / norm2 (+ fused epilogues)
# ---------------------------------------------------------------------------

def axpy(a, x: SegmentedArray, y: SegmentedArray,
         cache: PlanCache | None = None) -> SegmentedArray:
    """a*X + Y, segment-local (the strong-scaling op of paper Fig. 4).
    ``a`` is a runtime scalar — it does not key the plan."""
    plan = _binary_plan("axpy", x, y,
                        lambda: jax.jit(tree_axpy),
                        cache)
    return y.with_data(plan(jnp.asarray(a), x.data, y.data))


def dot(x: SegmentedArray, y: SegmentedArray,
        cache: PlanCache | None = None) -> jax.Array:
    """<x, y> (conjugating) with one reduction across segments."""
    plan = _binary_plan("dot", x, y,
                        lambda: jax.jit(tree_vdot),
                        cache)
    return plan(x.data, y.data)


def norm2(x: SegmentedArray, cache: PlanCache | None = None) -> jax.Array:
    """||x||^2 = Re <x, x>."""
    plan = _binary_plan("norm2", x, x,
                        lambda: jax.jit(
                            lambda xd: jnp.real(jnp.vdot(xd, xd))),
                        cache)
    return plan(x.data)


def axpy_dot(a, x: SegmentedArray, y: SegmentedArray, z: SegmentedArray,
             cache: PlanCache | None = None):
    """Fused epilogue: ``w = a*x + y`` and ``<z, w>`` in one compiled
    program (one pass over ``w`` instead of two).  Returns ``(w, <z, w>)``.

    The CG update pair ``r -= alpha*Ap; rs = <r, r>`` is
    ``axpy_dot(-alpha, Ap, r, z=r_new)`` territory — pass ``z=x`` aliases
    freely, everything is functional.
    """
    def build():
        def fused(a_, xd, yd, zd):
            w = a_ * xd + yd
            return w, jnp.vdot(zd, w)
        return jax.jit(fused)

    plan = _binary_plan("axpy_dot", x, y, build, cache,
                        extra=(seg_token(z),))
    w, d = plan(jnp.asarray(a), x.data, y.data, z.data)
    return y.with_data(w), d


def axpy_norm2(a, x: SegmentedArray, y: SegmentedArray,
               cache: PlanCache | None = None):
    """Fused ``w = a*x + y`` and ``||w||^2`` (the CG residual update)."""
    def build():
        def fused(a_, xd, yd):
            w = a_ * xd + yd
            return w, jnp.real(jnp.vdot(w, w))
        return jax.jit(fused)

    plan = _binary_plan("axpy_norm2", x, y, build, cache)
    w, n = plan(jnp.asarray(a), x.data, y.data)
    return y.with_data(w), n


def _is_seg(leaf):
    return isinstance(leaf, SegmentedArray)


def _seg_leaves(tree, name):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_seg)
    if not leaves or not all(_is_seg(l) for l in leaves):
        raise ValueError(f"{name} operands must be (pytrees of) "
                         f"SegmentedArrays")
    return leaves, treedef


def cg_update(alpha, p, ap, x, r, cache: PlanCache | None = None):
    """Fused single-pass CG update over (pytrees of) containers:
    ``x' = x + alpha*p``, ``r' = r - alpha*Ap`` and the residual
    dot-product epilogue ``rs = sum |r'|^2`` — the three-pass unfused
    body collapsed into one program (``kernels.cg_fused``; the Pallas
    kernels on TPU, the same single-expression fusion via XLA
    elsewhere).  Returns ``(x', r', rs)``.

    The epilogue follows the same reduction contract as
    ``Communicator.vdot``: on the logical container data the global
    contraction already spans all shards, so no explicit collective is
    added and CLONE leaves count once.
    """
    cache = _cache(cache)
    pl_, pdef = _seg_leaves(p, "cg_update")
    apl, _ = _seg_leaves(ap, "cg_update")
    xl, _ = _seg_leaves(x, "cg_update")
    rl, rdef = _seg_leaves(r, "cg_update")
    n = len(xl)
    # resolve (and on TPU, sweep) the row-block choice on the biggest
    # leaf at plan-build time; the winner is part of the plan identity
    big = max(pl_, key=lambda l: l.data.size)
    blocks = _kreg.autotune(
        "cg_fused.cg_update",
        sample=lambda: ((jnp.float32(0.5), big.data, big.data,
                         big.data, big.data), {}),
        token=("blas", seg_token(big)))
    key = ("blas", "cg_update", tuple(seg_token(l) for l in xl),
           tuple(seg_token(l) for l in pl_), blocks)

    def build():
        def fused(a_, *flat):
            ps, aps = flat[:n], flat[n:2 * n]
            xs, rs = flat[2 * n:3 * n], flat[3 * n:]
            outs = [_cg_ops.cg_update(a_, p_, ap_, x_, r_, block=blocks)
                    for p_, ap_, x_, r_ in zip(ps, aps, xs, rs)]
            return ([o[0] for o in outs], [o[1] for o in outs],
                    sum(o[2] for o in outs))
        return Plan(key=key, fn=jax.jit(fused), lib="blas", op="cg_update",
                    meta={"kernel_blocks": {"cg_fused.cg_update": blocks}})

    plan = cache.get_or_build(key, build)
    x2, r2, rs = plan(jnp.asarray(alpha),
                      *[l.data for l in pl_], *[l.data for l in apl],
                      *[l.data for l in xl], *[l.data for l in rl])
    x_out = jax.tree.unflatten(pdef, [s.with_data(d)
                                      for s, d in zip(xl, x2)])
    r_out = jax.tree.unflatten(rdef, [s.with_data(d)
                                      for s, d in zip(rl, r2)])
    return x_out, r_out, rs


def xpby_dot(x, y, beta, cache: PlanCache | None = None):
    """Fused ``w = x + beta*y`` with the ``sum |w|^2`` epilogue over
    (pytrees of) containers — the CG search-direction step
    ``p = r + beta*p`` in one pass.  Returns ``(w, d)``."""
    cache = _cache(cache)
    xl, xdef = _seg_leaves(x, "xpby_dot")
    yl, _ = _seg_leaves(y, "xpby_dot")
    n = len(xl)
    big = max(xl, key=lambda l: l.data.size)
    blocks = _kreg.autotune(
        "cg_fused.xpby_dot",
        sample=lambda: ((big.data, big.data, jnp.float32(0.5)), {}),
        token=("blas", seg_token(big)))
    key = ("blas", "xpby_dot", tuple(seg_token(l) for l in xl),
           tuple(seg_token(l) for l in yl), blocks)

    def build():
        def fused(b_, *flat):
            xs, ys = flat[:n], flat[n:]
            outs = [_cg_ops.xpby_dot(x_, y_, b_, block=blocks)
                    for x_, y_ in zip(xs, ys)]
            return [o[0] for o in outs], sum(o[1] for o in outs)
        return Plan(key=key, fn=jax.jit(fused), lib="blas", op="xpby_dot",
                    meta={"kernel_blocks": {"cg_fused.xpby_dot": blocks}})

    plan = cache.get_or_build(key, build)
    w, d = plan(jnp.asarray(beta),
                *[l.data for l in xl], *[l.data for l in yl])
    w_out = jax.tree.unflatten(xdef, [s.with_data(v)
                                      for s, v in zip(xl, w)])
    return w_out, d


def dot_allreduce(x: SegmentedArray, y: SegmentedArray,
                  cache: PlanCache | None = None) -> jax.Array:
    """<x, y> with the shard-local partial product and the cross-segment
    psum fused into one SPMD program (the paper's 'one inter-device
    reduction' per scalar product, scheduled explicitly rather than left
    to XLA's resharding of the global vdot)."""
    def build():
        # capture only scalars/specs in the kernel closure — capturing
        # the SegmentedArray itself would pin its device buffer inside
        # the long-lived plan cache.
        ax = _axis_arg(x.mesh_axes)
        is_clone = x.policy is Policy.CLONE

        def body(xl, yl):
            part = jnp.vdot(xl, yl)
            return part if is_clone else lax.psum(part, ax)

        sm = compat.shard_map(body, mesh=x.group.mesh,
                              in_specs=(x.pspec, y.pspec), out_specs=P())
        return jax.jit(sm)

    plan = _binary_plan("dot_allreduce", x, y, build, cache)
    return plan(x.data, y.data)


# ---------------------------------------------------------------------------
# level-3: batched / k-split GEMM
# ---------------------------------------------------------------------------

def gemm_batched(a: SegmentedArray, b: SegmentedArray,
                 cache: PlanCache | None = None) -> SegmentedArray:
    """Batched matmul over the segmented batch dim — no communication
    (paper Fig. 4 splits 12 square matrices across GPUs)."""
    plan = _binary_plan(
        "gemm_batched", a, b,
        lambda: jax.jit(lambda ad, bd: jnp.einsum("bij,bjk->bik", ad, bd)),
        cache)
    return a.with_data(plan(a.data, b.data))


def gemm_ksplit_schedule(a: SegmentedArray, b: SegmentedArray) -> str:
    """The reduction schedule ``gemm_ksplit`` picks for these operands:
    ``rs_ag`` (psum_scatter + all_gather, Rabenseifner-style — each
    device reduces 1/n of the product and the replicas are assembled by
    an all-gather, halving the bytes each link carries vs a plain psum)
    above ``comm.REDUCE_RS_AG_MIN_BYTES``, else ``psum``."""
    nseg = a.nseg
    out_rows = a.data.shape[0]
    nbytes = (out_rows * b.data.shape[1]
              * jnp.promote_types(a.dtype, b.dtype).itemsize)
    eligible = nseg > 1 and out_rows % nseg == 0
    if _comm.REDUCE_SCHEDULE is not None:
        return ("rs_ag" if _comm.REDUCE_SCHEDULE == "rs_ag" and eligible
                else "psum")
    if (eligible and not a.group.unified_memory
            and nbytes >= _comm.REDUCE_RS_AG_MIN_BYTES):
        return "rs_ag"
    return "psum"


def gemm_ksplit(a: SegmentedArray, b: SegmentedArray,
                cache: PlanCache | None = None) -> SegmentedArray:
    """A·B with the contraction dim segmented: local partial matmul +
    one inter-device reduction (the paper's non-scaling A·B case; on TPU
    the classic tensor-parallel matmul).  Large products decompose the
    reduction Rabenseifner-style — see :func:`gemm_ksplit_schedule`."""
    schedule = gemm_ksplit_schedule(a, b)

    def build():
        ax = _axis_arg(a.mesh_axes)

        def body(al, bl):
            part = al @ bl
            if schedule == "rs_ag":
                return _comm._psum_rs_ag(part, tuple(a.mesh_axes))
            return lax.psum(part, ax)

        sm = compat.shard_map(body, mesh=a.group.mesh,
                              in_specs=(P(None, ax), P(ax, None)),
                              out_specs=P(), check_vma=False)
        return jax.jit(sm)

    plan = _binary_plan("gemm_ksplit", a, b, build, cache,
                        extra=(schedule,))
    out = plan(a.data, b.data)
    return SegmentedArray(out, a.group, Policy.CLONE, 0, a.mesh_axes)
