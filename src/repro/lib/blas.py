"""libblas port — plan-cached segmented BLAS (paper §4, Fig. 4).

MGPU's libblas consolidates CUBLAS under the segmented-container
interface; the port here adds the plan layer: every operation is a
:class:`repro.lib.plan.Plan` keyed on the operand layout (shape, dtype,
policy, group), compiled once and cached.  On top of the paper's
verb-per-op set it provides the two fused epilogues a CG-style solver
actually wants on the hot path:

``axpy_dot``       w = a*x + y and <z, w> in ONE compiled program (the
                   classic fused AXPY+DOT epilogue — saves a full pass
                   over w);
``dot_allreduce``  shard-local partial products + the cross-segment
                   reduction fused into one SPMD program (paper Table 1:
                   'scalar products of all data' pay exactly one
                   all-reduce).

Scaling behaviour matches paper Fig. 4: ``axpy``/``gemm_batched`` are
segment-local (linear scaling), ``dot``/``norm2`` add one reduction,
``gemm_ksplit`` adds the inter-device reduction of the contracted dim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import compat
from ..core.comm import _axis_arg
from ..core.segmented import Policy, SegmentedArray
from .plan import Plan, PlanCache, default_cache, seg_token


def _cache(cache):
    return default_cache() if cache is None else cache


def _binary_plan(op: str, x: SegmentedArray, y: SegmentedArray,
                 builder, cache: PlanCache | None,
                 extra: tuple = ()) -> Plan:
    cache = _cache(cache)
    key = ("blas", op, seg_token(x), seg_token(y), *extra)
    return cache.get_or_build(
        key, lambda: Plan(key=key, fn=builder(), lib="blas", op=op))


# ---------------------------------------------------------------------------
# level-1: axpy / dot / norm2 (+ fused epilogues)
# ---------------------------------------------------------------------------

def axpy(a, x: SegmentedArray, y: SegmentedArray,
         cache: PlanCache | None = None) -> SegmentedArray:
    """a*X + Y, segment-local (the strong-scaling op of paper Fig. 4).
    ``a`` is a runtime scalar — it does not key the plan."""
    plan = _binary_plan("axpy", x, y,
                        lambda: jax.jit(lambda a_, xd, yd: a_ * xd + yd),
                        cache)
    return y.with_data(plan(jnp.asarray(a), x.data, y.data))


def dot(x: SegmentedArray, y: SegmentedArray,
        cache: PlanCache | None = None) -> jax.Array:
    """<x, y> (conjugating) with one reduction across segments."""
    plan = _binary_plan("dot", x, y,
                        lambda: jax.jit(lambda xd, yd: jnp.vdot(xd, yd)),
                        cache)
    return plan(x.data, y.data)


def norm2(x: SegmentedArray, cache: PlanCache | None = None) -> jax.Array:
    """||x||^2 = Re <x, x>."""
    plan = _binary_plan("norm2", x, x,
                        lambda: jax.jit(
                            lambda xd: jnp.real(jnp.vdot(xd, xd))),
                        cache)
    return plan(x.data)


def axpy_dot(a, x: SegmentedArray, y: SegmentedArray, z: SegmentedArray,
             cache: PlanCache | None = None):
    """Fused epilogue: ``w = a*x + y`` and ``<z, w>`` in one compiled
    program (one pass over ``w`` instead of two).  Returns ``(w, <z, w>)``.

    The CG update pair ``r -= alpha*Ap; rs = <r, r>`` is
    ``axpy_dot(-alpha, Ap, r, z=r_new)`` territory — pass ``z=x`` aliases
    freely, everything is functional.
    """
    def build():
        def fused(a_, xd, yd, zd):
            w = a_ * xd + yd
            return w, jnp.vdot(zd, w)
        return jax.jit(fused)

    plan = _binary_plan("axpy_dot", x, y, build, cache,
                        extra=(seg_token(z),))
    w, d = plan(jnp.asarray(a), x.data, y.data, z.data)
    return y.with_data(w), d


def axpy_norm2(a, x: SegmentedArray, y: SegmentedArray,
               cache: PlanCache | None = None):
    """Fused ``w = a*x + y`` and ``||w||^2`` (the CG residual update)."""
    def build():
        def fused(a_, xd, yd):
            w = a_ * xd + yd
            return w, jnp.real(jnp.vdot(w, w))
        return jax.jit(fused)

    plan = _binary_plan("axpy_norm2", x, y, build, cache)
    w, n = plan(jnp.asarray(a), x.data, y.data)
    return y.with_data(w), n


def dot_allreduce(x: SegmentedArray, y: SegmentedArray,
                  cache: PlanCache | None = None) -> jax.Array:
    """<x, y> with the shard-local partial product and the cross-segment
    psum fused into one SPMD program (the paper's 'one inter-device
    reduction' per scalar product, scheduled explicitly rather than left
    to XLA's resharding of the global vdot)."""
    def build():
        # capture only scalars/specs in the kernel closure — capturing
        # the SegmentedArray itself would pin its device buffer inside
        # the long-lived plan cache.
        ax = _axis_arg(x.mesh_axes)
        is_clone = x.policy is Policy.CLONE

        def body(xl, yl):
            part = jnp.vdot(xl, yl)
            return part if is_clone else lax.psum(part, ax)

        sm = compat.shard_map(body, mesh=x.group.mesh,
                              in_specs=(x.pspec, y.pspec), out_specs=P())
        return jax.jit(sm)

    plan = _binary_plan("dot_allreduce", x, y, build, cache)
    return plan(x.data, y.data)


# ---------------------------------------------------------------------------
# level-3: batched / k-split GEMM
# ---------------------------------------------------------------------------

def gemm_batched(a: SegmentedArray, b: SegmentedArray,
                 cache: PlanCache | None = None) -> SegmentedArray:
    """Batched matmul over the segmented batch dim — no communication
    (paper Fig. 4 splits 12 square matrices across GPUs)."""
    plan = _binary_plan(
        "gemm_batched", a, b,
        lambda: jax.jit(lambda ad, bd: jnp.einsum("bij,bjk->bik", ad, bd)),
        cache)
    return a.with_data(plan(a.data, b.data))


def gemm_ksplit(a: SegmentedArray, b: SegmentedArray,
                cache: PlanCache | None = None) -> SegmentedArray:
    """A·B with the contraction dim segmented: local partial matmul +
    one inter-device reduction (the paper's non-scaling A·B case; on TPU
    the classic tensor-parallel matmul)."""
    def build():
        ax = _axis_arg(a.mesh_axes)

        def body(al, bl):
            return lax.psum(al @ bl, ax)

        sm = compat.shard_map(body, mesh=a.group.mesh,
                              in_specs=(P(None, ax), P(ax, None)),
                              out_specs=P())
        return jax.jit(sm)

    plan = _binary_plan("gemm_ksplit", a, b, build, cache)
    out = plan(a.data, b.data)
    return SegmentedArray(out, a.group, Policy.CLONE, 0, a.mesh_axes)
