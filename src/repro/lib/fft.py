"""libfft port — plan-cached batched 2-D FFT over segmented containers
(paper §4: "MGPU as a framework for porting existing GPU libraries").

MGPU's libfft wraps CUFFT plans: a plan captures the transform geometry
once, execution is repeated per frame.  The port here does the same for
the JAX FFT: ``plan_fft2`` builds a :class:`repro.lib.plan.Plan` keyed
on (shape, dtype, direction, centering, segmentation policy, group) and
the module-level ``fft2``/``fft2_batched`` are the plan-at-call-site
convenience forms — first call builds, every later call with the same
geometry is a cache hit.

Distribution contract (paper §2.4):

* segmented dim outside the transform plane — each shard runs its local
  batched FFT, zero communication (the paper: "individual FFTs can
  currently not be split across devices");
* segmented dim *inside* the transform plane (a row-split NATURAL or
  OVERLAP2D image) — the plan goes beyond the paper with the classic
  transpose algorithm on the verb layer: FFT the locally-contiguous
  axis, ``alltoall`` re-segmentation, FFT the other axis, ``alltoall``
  back.  Centered (fftshift) handling is per-axis, applied while that
  axis is local.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import compat
from ..core.segmented import Policy, SegmentedArray
from .plan import Plan, PlanCache, default_cache, seg_token


def _fft1_local(x: jax.Array, axis: int, inverse: bool,
                centered: bool) -> jax.Array:
    if centered:
        x = jnp.fft.ifftshift(x, axes=axis)
    x = (jnp.fft.ifft(x, axis=axis, norm="ortho") if inverse
         else jnp.fft.fft(x, axis=axis, norm="ortho"))
    if centered:
        x = jnp.fft.fftshift(x, axes=axis)
    return x


def _fft2_local(x: jax.Array, inverse: bool, centered: bool) -> jax.Array:
    axes = (-2, -1)
    if centered:
        x = jnp.fft.ifftshift(x, axes=axes)
    x = (jnp.fft.ifft2(x, axes=axes, norm="ortho") if inverse
         else jnp.fft.fft2(x, axes=axes, norm="ortho"))
    if centered:
        x = jnp.fft.fftshift(x, axes=axes)
    return x


# ---------------------------------------------------------------------------
# plain-array plans (single-device / inside-spmd form)
# ---------------------------------------------------------------------------

def plan_fft2(shape, dtype, *, inverse: bool = False, centered: bool = False,
              cache: PlanCache | None = None) -> Plan:
    """Plan a (batched) 2-D FFT over the trailing two dims of a plain
    array.  The plan's ``fn`` maps ``x -> X`` and is safe to call inside
    jit/shard_map traces (it is itself a jitted program)."""
    cache = default_cache() if cache is None else cache
    key = ("fft", "fft2", tuple(shape), str(jnp.dtype(dtype)),
           bool(inverse), bool(centered))

    def build():
        fn = jax.jit(functools.partial(_fft2_local, inverse=inverse,
                                       centered=centered))
        return Plan(key=key, fn=fn, lib="fft", op="fft2",
                    meta={"shape": tuple(shape), "inverse": inverse,
                          "centered": centered})

    return cache.get_or_build(key, build)


def fft2(x, inverse: bool = False, centered: bool = False,
         cache: PlanCache | None = None) -> jax.Array:
    """Plain (non-segmented) 2-D FFT through the plan cache — the
    single-device path NLINV's operators use.  Works on tracers: the
    plan lookup happens at trace time, so a jitted caller pays it once."""
    plan = plan_fft2(jnp.shape(x), jnp.result_type(x), inverse=inverse,
                     centered=centered, cache=cache)
    return plan(x)


# ---------------------------------------------------------------------------
# segmented-container plans (the library port proper)
# ---------------------------------------------------------------------------

def plan_fft2_batched(seg: SegmentedArray, *, inverse: bool = False,
                      centered: bool = False,
                      cache: PlanCache | None = None) -> Plan:
    """Plan a batched 2-D FFT over a segmented container.

    The plan is keyed on the container's full layout (shape, dtype,
    policy, dim, group) and the transform direction/centering; its
    ``fn`` maps ``SegmentedArray -> SegmentedArray``.
    """
    cache = default_cache() if cache is None else cache
    key = ("fft", "fft2_batched", seg_token(seg),
           bool(inverse), bool(centered))

    def build():
        fn, sched = _build_fft2_batched(seg, inverse, centered)
        return Plan(key=key, fn=fn,
                    lib="fft", op="fft2_batched",
                    meta={"policy": seg.policy.value, "dim": seg.dim,
                          "distributed": _dim_in_plane(seg), **sched})

    return cache.get_or_build(key, build)


def _dim_in_plane(seg: SegmentedArray) -> bool:
    """Is the segmented dim one of the two transform axes?"""
    nd = seg.data.ndim
    return seg.policy is not Policy.CLONE and seg.dim in (nd - 2, nd - 1)


FFT_TRANSPOSE_CHUNKS = 4
"""Chunk count target for the fused distributed transpose: the batch dim
is split into up-to-this-many independent fft -> all_to_all -> fft
chains inside ONE program so the scheduler can run chunk ``i+1``'s local
FFT behind chunk ``i``'s transpose collective (the PR 5 compute-overlap
ring, extended from allreduce to the FFT transpose)."""


def _build_fft2_fused(seg: SegmentedArray, inverse: bool, centered: bool,
                      seg_ax: int, other_ax: int):
    """One jitted shard_map for the in-plane distributed FFT: local FFT of
    the complete axis, tiled all_to_all transpose, FFT of the (now
    complete) formerly-split axis, transpose back — chunked along a batch
    dim so per-chunk compute pipelines behind per-chunk communication."""
    mesh_axes = tuple(seg.mesh_axes)
    ax = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
    nd = seg.data.ndim
    batch_ax = next((i for i in range(nd)
                     if i not in (seg_ax, other_ax) and seg.data.shape[i] > 1),
                    None)
    chunks = (1 if batch_ax is None else
              next(c for c in (FFT_TRANSPOSE_CHUNKS, 2, 1)
                   if seg.data.shape[batch_ax] % c == 0))

    def chain(c):
        c = _fft1_local(c, other_ax, inverse, centered)
        c = lax.all_to_all(c, ax, split_axis=other_ax, concat_axis=seg_ax,
                           tiled=True)
        c = _fft1_local(c, seg_ax, inverse, centered)
        return lax.all_to_all(c, ax, split_axis=seg_ax, concat_axis=other_ax,
                              tiled=True)

    def body(x):
        if chunks == 1:
            return chain(x)
        parts = jnp.split(x, chunks, axis=batch_ax)
        return jnp.concatenate([chain(p) for p in parts], axis=batch_ax)

    spec = [None] * nd
    spec[seg_ax] = ax
    sm = compat.shard_map(body, mesh=seg.group.mesh, in_specs=P(*spec),
                          out_specs=P(*spec), check_vma=False)
    arr_fn = jax.jit(sm)
    return (lambda s: s.with_data(arr_fn(s.data))), chunks


def _build_fft2_batched(seg: SegmentedArray, inverse: bool, centered: bool):
    """Build the executor for one container geometry.  Returns
    ``(fn, meta)`` where meta records the schedule picked."""
    local = functools.partial(_fft2_local, inverse=inverse, centered=centered)
    if not _dim_in_plane(seg):
        # batch segmented (or CLONE): shard-local batched FFT, no comm.
        if seg.policy is Policy.CLONE:
            return (lambda s: s.with_data(local(s.data))), {"schedule": "local"}
        return (lambda s: s.invoke(local)), {"schedule": "local"}

    # transform plane segmented: transpose algorithm.
    nd = seg.data.ndim
    row_ax, col_ax = nd - 2, nd - 1
    seg_ax = seg.dim
    other_ax = col_ax if seg_ax == row_ax else row_ax
    if seg.orig_len is not None and seg.orig_len != seg.data.shape[seg_ax]:
        raise ValueError(
            "distributed in-plane FFT needs the segmented dim unpadded "
            f"(orig_len={seg.orig_len} != {seg.data.shape[seg_ax]}); pick a "
            "length divisible by the group size")

    if seg.data.shape[other_ax] % seg.nseg == 0:
        # both transform axes tile over the group: fuse the whole
        # transpose algorithm (OVERLAP2D included — its stored layout is
        # the NATURAL row split, so the same program applies and the
        # container metadata rides through unchanged).
        fn, chunks = _build_fft2_fused(seg, inverse, centered,
                                       seg_ax, other_ax)
        return fn, {"schedule": "fused_transpose", "chunks": chunks}

    return (_build_fft2_verbs(seg, inverse, centered, seg_ax, other_ax),
            {"schedule": "verbs"})


def _build_fft2_verbs(seg: SegmentedArray, inverse: bool, centered: bool,
                      seg_ax: int, other_ax: int):
    """Eager-verb transpose fallback for geometries whose complete axis
    does not tile over the group (all_to_all pads/slices per round)."""

    def fn(s: SegmentedArray) -> SegmentedArray:
        src_policy, src_halo = s.policy, s.halo
        work = s
        if src_policy is Policy.OVERLAP2D:
            # halos are exchanged dynamically, the stored layout is the
            # NATURAL row split — relabel for alltoall.
            work = s.comm.copy(s, policy=Policy.NATURAL)
        # 1) the non-segmented transform axis is locally complete
        work = work.invoke(lambda xl: _fft1_local(xl, other_ax, inverse,
                                                  centered))
        # 2) re-segment so the formerly-split axis becomes local
        work = work.alltoall(other_ax)
        # 3) transform it
        work = work.invoke(lambda xl: _fft1_local(xl, seg_ax, inverse,
                                                  centered))
        # 4) restore the caller's segmentation
        work = work.alltoall(seg_ax)
        if src_policy is Policy.OVERLAP2D:
            work = work.comm.copy(work, policy=Policy.OVERLAP2D,
                                  halo=src_halo)
        return work

    return fn


def fft2_batched(x: SegmentedArray, inverse: bool = False,
                 centered: bool = False,
                 cache: PlanCache | None = None) -> SegmentedArray:
    """Batched 2-D FFT over a segmented container through the plan cache
    (the MGPU libfft call path: plan once per geometry, execute every
    frame)."""
    plan = plan_fft2_batched(x, inverse=inverse, centered=centered,
                             cache=cache)
    return plan(x)
