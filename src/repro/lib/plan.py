"""Plan / PlanCache — re-export of :mod:`repro.core.plan`.

The Plan/PlanCache substrate moved into ``repro.core`` when the eager
transfer verbs (``core.comm``) started caching their own shard_map
programs as plans; ``repro.lib`` cannot be imported from ``repro.core``
(it would be circular), so the machinery lives below both.  This module
keeps the historical ``repro.lib.plan`` import path: everything —
including the shared default cache instance — is the same object.

>>> cache = PlanCache(maxsize=8)          # a private cache
>>> cache.get_or_build(("demo",),
...                    lambda: Plan(key=("demo",), fn=lambda: 7))()
7
>>> len(cache), cache is default_cache()
(1, False)
"""

from __future__ import annotations

from ..core.plan import (  # noqa: F401
    Plan,
    PlanCache,
    default_cache,
    group_token,
    plan_stats,
    seg_token,
)

__all__ = ["Plan", "PlanCache", "default_cache", "group_token",
           "plan_stats", "seg_token"]
