"""libgridding port — plan-cached non-Cartesian (radial) gridding
(paper §4's third ported library; opens the radial-trajectory NLINV
workload of §3).

A gridding *plan* captures one acquisition geometry: the radial
trajectory, its dense separable interpolation matrices (built once, on
the host — the expensive part), the Ram-Lak density compensation, and
the device group the coil dim is NATURAL-segmented over.  Execution is
then per-frame work only:

  ``plan.degrid(g)``   Cartesian k-space (J, X, Y) -> samples (J, S)
                       (the forward interpolation, paper's DTFT stand-in)
  ``plan.grid(y)``     samples -> Cartesian k-space (exact adjoint)
  ``plan.adjoint_recon(y, fov)``
                       density-compensated adjoint reconstruction with
                       RSS channel combination — the Fig. 10 baseline,
                       distributed over coils via the Communicator verbs.

Both directions accept a plain (J, ...) array (single-device math) or a
coil-NATURAL ``SegmentedArray`` (each shard grids its local coils; the
only communication in the whole pipeline is the RSS channel sum).
"""

from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from ..core.segmented import Policy, SegmentedArray
from ..kernels import registry as _kreg
from ..kernels.gridding import degrid, grid_adjoint, interp_matrices
from . import fft as lfft
from .plan import Plan, PlanCache, default_cache, group_token


def radial_trajectory(grid: int, nspokes: int, frame: int = 0,
                      nsamp: int | None = None) -> np.ndarray:
    """(S, 2) float32 radial trajectory in grid units (DC at grid//2).

    ``nsamp`` samples per spoke (default ``2*grid``: 2x readout
    oversampling), golden-angle rotation per frame — the acquisition of
    the paper's real-time protocol, but at true off-grid coordinates
    rather than the nearest-Cartesian-cell mask approximation.
    """
    if nsamp is None:
        nsamp = 2 * grid
    ga = np.pi * (3 - np.sqrt(5.0))
    c = grid // 2
    r = (np.arange(nsamp) + 0.5) / nsamp * grid - c    # (-c, c)
    pts = []
    for s in range(nspokes):
        th = s * np.pi / nspokes + frame * ga
        pts.append(np.stack([c + r * np.cos(th), c + r * np.sin(th)], 1))
    return np.concatenate(pts).astype(np.float32)


def ramlak_dcf_radial(traj, grid: int) -> np.ndarray:
    """Ram-Lak density compensation |k| per trajectory sample (the
    radial sampling density is 1/|k|; symmetric under k -> -k)."""
    t = np.asarray(traj, np.float64)
    c = grid // 2
    r = np.sqrt(((t - c) ** 2).sum(1))
    return (r / max(r.max(), 1e-9)).astype(np.float32) + 1e-3


@dataclasses.dataclass(frozen=True)
class GriddingPlan:
    """One built gridding geometry (the plan's executable payload)."""

    traj: np.ndarray          # (S, 2) trajectory
    grid_size: int
    ax: jax.Array             # (Sp, X) interp matrix (rows >= S are zero)
    ay: jax.Array             # (Sp, Y)
    dcf: jax.Array            # (Sp,) Ram-Lak weights (zero-padded)
    nsamp: int                # true (pre-padding) sample count S
    blocks: dict = dataclasses.field(default_factory=dict)
    # autotuned sample-block choices {spec name: (bs,)} — part of the
    # plan key, so a re-tuned (or pinned) choice is a different plan

    @property
    def nsamp_padded(self) -> int:
        return self.ax.shape[0]

    def _apply(self, x, fn):
        if isinstance(x, SegmentedArray):
            if x.policy is not Policy.NATURAL or x.dim != 0:
                raise ValueError(
                    "gridding expects the coil dim NATURAL-segmented "
                    f"(dim 0), got {x.policy}/dim={x.dim}")
            return x.comm.invoke_all(fn, x)
        return fn(jnp.asarray(x))

    def degrid(self, g, impl: str = "auto"):
        """Cartesian k-space (J, X, Y) -> trajectory samples (J, Sp).
        Coil-local: a SegmentedArray in means a SegmentedArray out, with
        no communication (each shard samples its own coils)."""
        blk = self.blocks.get("degrid")
        return self._apply(g, lambda gl: degrid(gl, self.ax, self.ay,
                                                impl=impl, block=blk))

    def grid(self, y, impl: str = "auto", density_comp: bool = False):
        """Adjoint: samples (J, Sp) -> Cartesian k-space (J, X, Y).
        ``density_comp`` pre-weights with the Ram-Lak DCF (the adjoint
        reconstruction path)."""
        blk = self.blocks.get("grid_adjoint")

        def fn(yl):
            if density_comp:
                yl = yl * self.dcf[None]
            return grid_adjoint(yl, self.ax, self.ay, impl=impl, block=blk)
        return self._apply(y, fn)

    def adjoint_recon(self, y, fov, impl: str = "auto"):
        """Density-compensated adjoint recon with RSS channel combine
        (paper Fig. 10 baseline): IFFT(grid(dcf * y)), sqrt(sum_j |.|^2).

        ``y`` is (J, Sp) samples — plain array (single device) or a
        coil-NATURAL SegmentedArray (distributed: per-shard gridding +
        one channel-sum all-reduce).  Returns the (X, Y) magnitude image.
        """
        k = self.grid(y, impl=impl, density_comp=True)
        if isinstance(k, SegmentedArray):
            imgs = lfft.fft2_batched(k, inverse=True, centered=True)
            sq = imgs.with_data(jnp.abs(imgs.data) ** 2)
            tot = sq.allreduce_window()          # channel sum -> CLONE
            return jnp.asarray(fov) * jnp.sqrt(tot.data)
        imgs = lfft.fft2(k, inverse=True, centered=True)
        return jnp.asarray(fov) * jnp.sqrt(
            jnp.sum(jnp.abs(imgs) ** 2, axis=0))


def plan_gridding(traj, grid: int, *, comm=None,
                  cache: PlanCache | None = None) -> GriddingPlan:
    """Build (or fetch) the gridding plan for a trajectory + group.

    Keyed on the trajectory bytes, grid size and group identity; the
    interpolation matrices and DCF are computed exactly once per
    geometry.  Returns the executable :class:`GriddingPlan` payload
    (the cache stores it wrapped in a :class:`repro.lib.plan.Plan`).
    """
    cache = default_cache() if cache is None else cache
    t = np.ascontiguousarray(np.asarray(traj, np.float32))
    digest = hashlib.sha1(t.tobytes()).hexdigest()[:16]
    grid = int(grid)
    sp = -(-t.shape[0] // 128) * 128       # interp_matrices' pad_to
    # block-size choices resolve before the key: a re-tuned or pinned
    # choice must be a distinct plan (zeros matrices are cost-equivalent
    # to the real ones for the sweep, and only built if a sweep runs)
    blocks = {
        "degrid": _kreg.autotune(
            "gridding.degrid",
            sample=lambda: ((jnp.zeros((1, grid, grid), jnp.complex64),
                             jnp.zeros((sp, grid), jnp.float32),
                             jnp.zeros((sp, grid), jnp.float32)), {}),
            token=(sp, grid)),
        "grid_adjoint": _kreg.autotune(
            "gridding.grid_adjoint",
            sample=lambda: ((jnp.zeros((1, sp), jnp.complex64),
                             jnp.zeros((sp, grid), jnp.float32),
                             jnp.zeros((sp, grid), jnp.float32)), {}),
            token=(sp, grid)),
    }
    key = ("gridding", "plan", digest, t.shape[0], grid,
           group_token(comm), tuple(sorted(blocks.items())))

    def build():
        ax, ay = interp_matrices(t, grid)
        dcf = np.zeros(ax.shape[0], np.float32)
        dcf[: t.shape[0]] = ramlak_dcf_radial(t, grid)
        ops = GriddingPlan(traj=t, grid_size=grid, ax=jnp.asarray(ax),
                           ay=jnp.asarray(ay), dcf=jnp.asarray(dcf),
                           nsamp=t.shape[0], blocks=dict(blocks))
        return Plan(key=key, fn=ops, lib="gridding", op="plan",
                    meta={"nsamp": t.shape[0],
                          "nsamp_padded": ax.shape[0], "grid": grid,
                          "kernel_blocks": dict(blocks)})

    plan = cache.get_or_build(key, build)
    return plan.fn
