"""repro.lib — ported libraries on the plan/plan-cache substrate
(paper §4: MGPU as a framework for porting existing GPU libraries).

Each port pairs operations with *plans* keyed on problem geometry +
device group, built once and cached (LRU, hit/miss counters):

  ``repro.lib.fft``       plan-cached batched/distributed 2-D FFT
  ``repro.lib.blas``      plan-cached segmented BLAS + fused epilogues
  ``repro.lib.gridding``  plan-cached radial gridding/degridding

``repro.lib.plan`` holds the shared ``Plan``/``PlanCache`` machinery;
``plan_stats()`` reports the default cache (the streaming engine
surfaces it per frame).  The old ``repro.core.fft``/``repro.core.blas``
shims over these ports were removed on schedule (README PR 4); these
modules are the only segmented FFT/BLAS surface.
"""

from . import blas, fft, gridding, plan
from .plan import Plan, PlanCache, default_cache, plan_stats

__all__ = ["blas", "fft", "gridding", "plan",
           "Plan", "PlanCache", "default_cache", "plan_stats"]
