"""granite-moe-3b-a800m [moe] — 40 experts top-8
(assignment config; hf:ibm-granite/granite-3.0 family).

32L d_model=1536 24H (kv=8) moe_d_ff=512 vocab=49155, 40e top-8.
Experts padded 40->48 for EP over the 16-way model axis (router masks
the dummies).  long_500k SKIPPED (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155,
    pattern=("attn",), head_dim=64,
    n_experts=40, top_k=8, moe_d_ff=512,
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
    pattern=("attn",), head_dim=32,
    n_experts=8, top_k=2, moe_d_ff=64,
    capacity_factor=4.0,   # = E/k -> C = N: dropless (exact decode checks)
)
