"""gemma2-27b [dense] — local+global alternating, logit softcaps
(arXiv:2408.00118).

46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000, head_dim=128,
window=4096, attn softcap 50, final softcap 30, sandwich norms, GeGLU.
long_500k SKIPPED: the global layers are full attention.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256000,
    pattern=("local", "attn"), head_dim=128, window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    post_norm=True, embed_scale=True, act="gelu",
)

SMOKE = ModelConfig(
    name="gemma2-27b-smoke", family="dense",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    pattern=("local", "attn"), head_dim=32, window=16,
    attn_softcap=50.0, final_softcap=30.0,
    post_norm=True, embed_scale=True, act="gelu",
)
