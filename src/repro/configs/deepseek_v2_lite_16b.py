"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed
top-6 (arXiv:2405.04434).

27L d_model=2048 16H moe_d_ff=1408 vocab=102400.  First layer is dense
(d_ff=10944).  MLA dims per paper: qk_nope=128, qk_rope=64, v_head=128
(no q compression in the lite model).  long_500k SKIPPED (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400,
    pattern=("mla",),
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense=1, dense_d_ff=10944,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
    pattern=("mla",),
    kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=64,
    first_dense=1, dense_d_ff=128,
    capacity_factor=4.0,   # = E/k -> C = N: dropless (exact decode checks)
)
