"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

24L d_model=1024 4H d_ff=0 vocab=50304.  xLSTM[7:1] ratio: every 8th
block is sLSTM, the rest mLSTM; blocks carry their own up/down
projections (d_ff=0 in the assignment).  Constant-size recurrent state
=> runs the long_500k cell.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    rnn_heads=4, proj_factor=2.0, conv_width=4,
    act="gelu",
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="ssm",
    n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0, vocab=256,
    pattern=("mlstm",) * 7 + ("slstm",),
    rnn_heads=2, proj_factor=2.0, conv_width=4, act="gelu",
)
