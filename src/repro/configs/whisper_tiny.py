"""whisper-tiny [audio] — enc-dec, conv frontend STUB (arXiv:2212.04356).

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865.  The conv/mel
frontend is stubbed: ``input_specs()`` provides precomputed 1500-frame
embeddings (assignment contract).  Decoder layers: self-attn + cross-attn
+ (ungated) GELU MLP.  The assigned 32k/500k shapes exceed Whisper's
native 448-token decoder context; the backbone is shape-polymorphic and
honours them mechanically (noted in DESIGN.md).  long_500k SKIPPED.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865,
    pattern=("attn",), head_dim=64, act="gelu", gated_mlp=False,
    encoder_layers=4, encoder_seq=1500, cross_kind="decoder",
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=256,
    pattern=("attn",), head_dim=32, act="gelu", gated_mlp=False,
    encoder_layers=2, encoder_seq=16, cross_kind="decoder",
)
