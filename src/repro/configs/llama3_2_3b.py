"""llama3.2-3b [dense] — small llama3 (hf:meta-llama/Llama-3.2 family).

28L d_model=3072 24H (kv=8) d_ff=8192 vocab=128256, head_dim=128.
long_500k SKIPPED (pure full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab=128256,
    pattern=("attn",), head_dim=128, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    pattern=("attn",), head_dim=32, rope_theta=500000.0,
)
