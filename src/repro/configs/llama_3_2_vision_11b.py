"""llama-3.2-vision-11b [vlm] — cross-attn image layers
(hf:meta-llama/Llama-3.2-11B-Vision).

40L d_model=4096 32H (kv=8) d_ff=14336 vocab=128256.  Every 5th layer is
a gated cross-attention layer consuming precomputed patch embeddings
(frontend STUB per assignment; 1601 patch tokens).
long_500k SKIPPED (full attention).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    head_dim=128, rope_theta=500000.0,
    cross_kind="interleaved", encoder_seq=1601,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke", family="vlm",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    head_dim=32, cross_kind="interleaved", encoder_seq=16,
)
