"""minicpm3-4b [dense] — MLA (hf:openbmb/MiniCPM3-4B).

62L d_model=2560 40H (kv=40 on latents) d_ff=6400 vocab=73448.
MLA dims from the HF config: q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v_head=64.  Depth-scaled residuals (mup-style).
"""

import numpy as np

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448,
    pattern=("mla",),
    q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
    v_head_dim=64,
    residual_scale=float(1.4 / np.sqrt(62)),
)

SMOKE = ModelConfig(
    name="minicpm3-4b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    pattern=("mla",),
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16,
    residual_scale=float(1.4 / np.sqrt(3)),
)
