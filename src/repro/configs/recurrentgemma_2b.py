"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2
(arXiv:2402.19427, Griffin).

26L d_model=2560 10H (kv=1, MQA) d_ff=7680 vocab=256000, head_dim=256,
lru_width=2560, window=2048.  Pattern (rglru, rglru, local)*8 + 2
trailing rglru layers.  Constant-state + windowed cache => long_500k runs.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000,
    pattern=("rglru", "rglru", "local"), head_dim=256, window=2048,
    rnn_width=2560, conv_width=4,
    embed_scale=True, act="gelu",
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128, vocab=256,
    pattern=("rglru", "rglru", "local"), head_dim=32, window=16,
    rnn_width=64, conv_width=4, embed_scale=True, act="gelu",
)
