"""qwen3-0.6b [dense] — qk_norm, GQA (hf:Qwen/Qwen3-8B family).

28L d_model=1024 16H (kv=8) d_ff=3072 vocab=151936, head_dim=128.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab=151936,
    pattern=("attn",), head_dim=128, qk_norm=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    pattern=("attn",), head_dim=32, qk_norm=True, rope_theta=1e6,
)
