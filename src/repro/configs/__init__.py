"""Architecture registry: ``--arch <id>`` ids -> (full, smoke) configs,
the assigned input-shape set, and per-cell applicability rules."""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

ARCH_IDS = [
    "xlstm-350m", "minicpm3-4b", "qwen3-0.6b", "gemma2-27b", "llama3.2-3b",
    "recurrentgemma-2b", "llama-3.2-vision-11b", "granite-moe-3b-a800m",
    "deepseek-v2-lite-16b", "whisper-tiny",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

# shape id -> (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(arch: str):
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG


def get_smoke(arch: str):
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE


def cell_applicable(cfg, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape == "long_500k" and not cfg.is_subquadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic"
    return True, ""


def input_specs(cfg, shape: str, *, mesh=None):
    """ShapeDtypeStruct stand-ins for every input of the step function
    (the dry-run contract: weak-type-correct, shardable, no allocation)."""
    from ..models import frontends, transformer

    seq, gbatch, kind = SHAPES[shape]
    specs = {}
    if kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)
    elif kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((gbatch, 1), jnp.int32)
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, gbatch, seq, cfg.cdtype))
        specs["cache"] = cache
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    fr = frontends.frontend_struct(cfg, gbatch, cfg.cdtype)
    if fr is not None and kind != "decode":
        specs["enc"] = fr
    return specs
