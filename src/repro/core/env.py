"""First-class environment / communicator API (paper §2.1, §2.3).

An MGPU program begins by instantiating an ``environment`` that detects
the devices in the system, restricts work to a ``dev_group``, and then
calls MPI-like communication *methods bound to that group* (Fig. 3).
This module is that design as the library's stable object surface:

  ``Environment``    device discovery, ICI/DCN topology classification
                     (the PCIe-domain / IOH-boundary analogue) and
                     submesh selection — every ``Communicator`` is
                     minted here;
  ``Communicator``   a group-bound object exposing the full MPI-like
                     verb set as methods — collectives (``bcast`` /
                     ``scatter`` / ``gather`` / ``allgather`` /
                     ``reduce`` / ``allreduce`` / ``allreduce_window`` /
                     ``reduce_scatter`` / ``alltoall`` / ``vdot``),
                     point-to-point (``send_recv`` / ``shift``,
                     ``lax.ppermute`` — the paper's P2P path),
                     synchronization (``barrier`` / ``fence``), the
                     container constructor (``container``, §2.2) and the
                     kernel launchers (``invoke`` / ``invoke_all`` /
                     ``spmd``, §2.5).

Every reduction verb keeps the library's dual calling forms: eagerly on
a :class:`SegmentedArray`, or inside a shard_map body on the local shard
(pass ``axis=comm.axis``; ``axis=None`` degenerates to the local math).
The free functions in ``core.comm`` / ``core.segmented`` /
``core.invoke`` remain only as deprecated shims; algorithm code programs
against these two classes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
from jax.sharding import Mesh

from . import comm as _comm
from . import compat
from . import invoke as _invoke
from . import segmented as _segmented
from . import sync as _sync
from .runtime import DCN_AXES, DeviceGroup
from .segmented import Policy, SegmentedArray

# Fault-injection hook on verb dispatch (``repro.ft.inject`` installs
# it; core itself never imports ft).  Called as ``payload =
# VERB_HOOK(verb_name, payload)`` at the entry of every payload-carrying
# verb: it may return the payload (possibly corrupted), sleep (a
# straggling link) or raise (a transient transfer failure / device
# loss).  ``None`` (the default) costs one attribute read per call.
VERB_HOOK = None


def _fire_verb(name, payload):
    hook = VERB_HOOK
    return payload if hook is None else hook(name, payload)


class Environment:
    """Device discovery + topology classification (MGPU ``environment``).

    Detects the addressable devices (or wraps an explicit subset) and
    mints :class:`Communicator` objects over submeshes of them — the
    paper's ``dev_group`` constructor argument.  Axis names listed in
    ``DCN_AXES`` cross the data-center network (the paper's cross-IOH
    boundary); everything else is ICI.
    """

    def __init__(self, devices: Sequence[jax.Device] | None = None):
        self.devices = tuple(jax.devices() if devices is None else devices)

    @property
    def ndev(self) -> int:
        return len(self.devices)

    @property
    def platform(self) -> str:
        return self.devices[0].platform

    @property
    def dcn_axes(self) -> tuple[str, ...]:
        """Axis names classified as DCN (slow, inter-pod) when used."""
        return tuple(DCN_AXES)

    def __repr__(self) -> str:
        return f"Environment({self.ndev}x {self.platform})"

    # -- communicator constructors (MGPU dev_group selection) -------------
    def group(self, shape: Sequence[int] | int | None = None,
              axes: Sequence[str] = ("data",)) -> "Communicator":
        """Communicator over the first ``prod(shape)`` devices arranged as
        a named-axis mesh (default: all devices on one ``data`` axis)."""
        if shape is None:
            shape = (self.ndev,)
        if isinstance(shape, int):
            shape = (shape,)
        shape = tuple(shape)
        n = math.prod(shape)
        if n > self.ndev:
            raise ValueError(
                f"mesh shape {shape} needs {n} devices, environment has "
                f"{self.ndev} (simulate more with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        mesh = compat.make_mesh(shape, tuple(axes),
                                devices=self.devices[:n])
        return Communicator(DeviceGroup(mesh))

    def subgroup(self, n: int, axes: Sequence[str] = ("data",)) -> "Communicator":
        """Restrict to the first ``n`` devices (MGPU ``dev_group`` ctor)."""
        return self.group((n,), axes)

    @property
    def world(self) -> "Communicator":
        """Communicator over every device (MPI_COMM_WORLD analogue)."""
        return self.group()

    def from_mesh(self, mesh: Mesh) -> "Communicator":
        """Wrap an existing named-axis mesh."""
        return Communicator(DeviceGroup(mesh))

    def survivor(self, comm: "Communicator", lost=()) -> "Communicator":
        """Mint a Communicator over ``comm``'s devices minus the
        unhealthy ones (the elastic-remesh step after a device loss).

        ``lost`` holds group-local device indices (or ``jax.Device``
        objects).  1-D groups only — the survivor of a multi-axis mesh
        has no canonical shape.  Live carries move over with
        ``repro.ft.migrate_carry``.

        >>> from repro.core import Environment
        >>> env = Environment()
        >>> env.survivor(env.subgroup(1)).size     # nobody lost
        1
        """
        if len(comm.mesh_axes) > 1:
            raise ValueError(
                f"survivor() supports 1-D groups; got axes "
                f"{comm.mesh_axes}")
        devs = list(comm.mesh.devices.flat)
        gone = {devs[d] if isinstance(d, int) else d for d in lost}
        keep = [d for d in devs if d not in gone]
        if not keep:
            raise ValueError("no surviving devices in the group")
        mesh = compat.make_mesh((len(keep),), tuple(comm.mesh_axes),
                                devices=keep)
        return Communicator(DeviceGroup(mesh), comm.mesh_axes)


@dataclasses.dataclass(frozen=True)
class Communicator:
    """Group-bound MPI-like verbs (the paper's communication methods).

    ``mesh_axes`` selects which axes of the group the verbs communicate
    over (default: all of them); containers built by this communicator
    are segmented along those axes.
    """

    group: DeviceGroup
    mesh_axes: tuple[str, ...] = ()

    def __post_init__(self):
        if not self.mesh_axes:
            object.__setattr__(self, "mesh_axes",
                               tuple(self.group.axis_names))

    # -- queries ----------------------------------------------------------
    @property
    def mesh(self) -> Mesh:
        return self.group.mesh

    @property
    def size(self) -> int:
        """Number of communicating segments (product of ``mesh_axes``)."""
        return self.group.axis_size(*self.mesh_axes)

    @property
    def ndev(self) -> int:
        return self.group.ndev

    @property
    def axis(self):
        """The in-shard_map reduction-axis argument for this communicator
        (a single axis name, or the tuple for multi-axis groups)."""
        return (self.mesh_axes if len(self.mesh_axes) > 1
                else self.mesh_axes[0])

    @property
    def ici_axes(self) -> tuple[str, ...]:
        return self.group.ici_axes

    @property
    def dcn_axes(self) -> tuple[str, ...]:
        return self.group.dcn_axes

    def __repr__(self) -> str:
        return (f"Communicator(size={self.size}, axes={self.mesh_axes}, "
                f"mesh={dict(self.group.shape)})")

    # -- containers (paper §2.2: the ctor controls the split) -------------
    def container(self, x, *, policy: Policy = Policy.NATURAL, dim: int = 0,
                  block: int | None = None, halo: int = 0) -> SegmentedArray:
        """Build a segmented container on this communicator's group.

        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([[1., 2.], [3., 4.]])
        >>> (seg.policy, seg.dim, seg.global_shape)
        (<Policy.NATURAL: 'natural'>, 0, (2, 2))
        """
        x = _fire_verb("container", x)
        return _segmented.segment(x, self.group, policy=policy, dim=dim,
                                  mesh_axes=self.mesh_axes, block=block,
                                  halo=halo)

    # -- collectives (paper §2.3, Fig. 3) ---------------------------------
    def bcast(self, x) -> SegmentedArray:
        """Replicate a local array on every device (-> CLONE container).

        Large payloads (>= ``comm.BCAST_SCATTER_MIN_BYTES``) take the
        scatter+allgather schedule: the host uploads 1/n to each device
        and a chunked tiled all-gather (ICI submesh first, DCN across)
        assembles the replicas — instead of the host pushing the full
        array to every device.

        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> comm.bcast([1., 2., 3.]).policy
        <Policy.CLONE: 'clone'>
        """
        x = _fire_verb("bcast", x)
        return _comm.broadcast(x, self.group, mesh_axes=self.mesh_axes)

    def scatter(self, x, *, policy: Policy = Policy.NATURAL, dim: int = 0,
                block: int | None = None, halo: int = 0) -> SegmentedArray:
        """Split a local array across the group (Fig. 3 ``scatter`` — the
        container ctor with an explicit policy).

        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> comm.scatter([[1., 2.], [3., 4.]], dim=1).seg_len(0)
        2
        """
        x = _fire_verb("scatter", x)
        return _segmented.segment(x, self.group, policy=policy, dim=dim,
                                  mesh_axes=self.mesh_axes, block=block,
                                  halo=halo)

    def gather(self, seg: SegmentedArray) -> jax.Array:
        """Materialize the logical array of a container (Fig. 3).

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> comm.gather(comm.container([1., 2., 3.])).tolist()
        [1.0, 2.0, 3.0]
        """
        seg = _fire_verb("gather", seg)
        return _segmented.gather(seg)

    def _check_local_axis(self, axis, verb: str):
        """In-shard_map forms on a multi-device communicator must name
        the axis — a silent degenerate (local-math) fallback would drop
        the collective (the sibling free functions keep ``axis=None`` as
        the documented single-device degenerate form)."""
        if axis is None and self.size > 1:
            raise ValueError(
                f"in-shard_map {verb} on a multi-device communicator "
                f"needs axis= (e.g. comm.axis)")

    def allgather(self, x, *, dim: int | None = None, axis=None):
        """MPI_Allgather: the whole logical array on every device.  Eager
        on a container (-> CLONE, along its own segmented dim), or
        in-shard_map on the local shard (gathers along ``dim``).

        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> full = comm.allgather(comm.container([1., 2., 3., 4.]))
        >>> (full.policy, full.data.tolist())
        (<Policy.CLONE: 'clone'>, [1.0, 2.0, 3.0, 4.0])
        """
        if not isinstance(x, SegmentedArray):
            self._check_local_axis(axis, "allgather")
        return _comm.all_gather(x, dim=dim, axis=axis)

    def reduce(self, seg: SegmentedArray, op: str = "sum") -> jax.Array:
        """Merge the segments elementwise into one local array (Fig. 3).

        The segmented dim is reduced away:

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> comm.reduce(comm.container([[1., 2.], [3., 4.]])).tolist()
        [4.0, 6.0]
        """
        return _comm.reduce(seg, op)

    def allreduce(self, x, op: str = "sum", *, hierarchical: bool = False,
                  p2p: bool = False, axis=None):
        """Reduce + replicate (the paper's Σ ρ_g).  Eager on a container,
        or in-shard_map on the local shard with ``axis=self.axis``.

        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> tot = comm.allreduce(comm.container([[1., 2.], [3., 4.]]))
        >>> (tot.policy, tot.data.tolist())
        (<Policy.CLONE: 'clone'>, [4.0, 6.0])
        """
        if isinstance(x, SegmentedArray):
            x = _fire_verb("allreduce", x)
            return _comm.all_reduce(x, op, hierarchical=hierarchical,
                                    p2p=p2p)
        self._check_local_axis(axis, "allreduce")
        return _comm.all_reduce_window(x, None, op=op, axis=axis,
                                       hierarchical=hierarchical, p2p=p2p,
                                       group=self.group,
                                       mesh_axes=self.mesh_axes)

    def allreduce_window(self, x, window=None, *, op: str = "sum",
                         axis=None, reduce_dim: int | None = None,
                         hierarchical: bool = False, window_axes=None,
                         p2p: bool = False):
        """Windowed all-reduce (the paper's ``kern_all_red_p2p_2d`` as a
        primitive); see ``core.comm.all_reduce_window``.  The group and
        mesh axes are bound by this communicator.

        Only the ``window`` section goes on the wire, scattered back
        into zeros (here: the centered 2x2 of a 4x4 after the coil-dim
        reduction):

        >>> import numpy as np
        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container(np.ones((2, 4, 4), np.float32))
        >>> out = comm.allreduce_window(seg, ((1, 3), (1, 3)))
        >>> out.data[:, 1].tolist()
        [0.0, 2.0, 2.0, 0.0]
        """
        if not isinstance(x, SegmentedArray):
            self._check_local_axis(axis, "allreduce_window")
        return _comm.all_reduce_window(x, window, op=op, axis=axis,
                                       reduce_dim=reduce_dim,
                                       hierarchical=hierarchical,
                                       window_axes=window_axes, p2p=p2p,
                                       group=self.group,
                                       mesh_axes=self.mesh_axes)

    def allreduce_overlap(self, x, window=None, *, op: str = "sum",
                          axis=None, reduce_dim: int | None = None,
                          window_axes=None, extras: tuple = (),
                          compute=None, p2p: bool = False,
                          chunks: int = 2, hierarchical: bool = False):
        """Windowed all-reduce fused with piggybacked scalar reductions
        and overlapped caller compute (the fused NLINV DG^H schedule);
        see ``core.comm.all_reduce_overlap``.  In-shard_map /
        single-program form only; returns
        ``(reduced, extras_out, compute_out)``.

        The window section is reduced and scattered back into zeros, the
        extra scalar rides the same collective, and the independent
        compute branch is free to overlap the transfer:

        >>> import jax.numpy as jnp
        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> red, ex, out = comm.allreduce_overlap(
        ...     jnp.ones((4, 4)), ((1, 3), (1, 3)),
        ...     extras=(jnp.float32(2.0),), compute=lambda: jnp.ones(2))
        >>> (red[1].tolist(), float(ex[0]), out.tolist())
        ([0.0, 1.0, 1.0, 0.0], 2.0, [1.0, 1.0])
        """
        if isinstance(x, SegmentedArray):
            # no eager container form: the single-program branch would
            # silently return the container unreduced
            raise TypeError(
                "allreduce_overlap takes a local shard (in-shard_map / "
                "single-program form); for containers use "
                "allreduce_window")
        self._check_local_axis(axis, "allreduce_overlap")
        return _comm.all_reduce_overlap(x, window, op=op, axis=axis,
                                        reduce_dim=reduce_dim,
                                        window_axes=window_axes,
                                        extras=extras, compute=compute,
                                        p2p=p2p, chunks=chunks,
                                        hierarchical=hierarchical,
                                        group=self.group,
                                        mesh_axes=self.mesh_axes)

    def reduce_scatter(self, seg: SegmentedArray,
                       op: str = "sum") -> SegmentedArray:
        """MPI_Reduce_scatter: reduce segments, result left segmented.

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([[1., 2.], [3., 4.]])
        >>> comm.reduce_scatter(seg).gather().tolist()
        [4.0, 6.0]
        """
        return _comm.reduce_scatter(seg, op)

    def alltoall(self, seg: SegmentedArray, new_dim: int) -> SegmentedArray:
        """MPI_Alltoall: re-segment a container onto another dim.

        >>> import numpy as np
        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container(np.zeros((4, 6), np.float32))  # dim 0
        >>> comm.alltoall(seg, 1).dim
        1
        """
        return _comm.all_to_all(seg, new_dim)

    def vdot(self, x, y, *, axis=None, policies=None):
        """Segmented inner product over mixed CLONE/NATURAL pytrees (the
        CG 'scalar products of all data' of paper Table 1).

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> x = comm.container([1., 2.])
        >>> y = comm.container([3., 4.])
        >>> float(comm.vdot(x, y))
        11.0
        """
        leaves = jax.tree.leaves(
            x, is_leaf=lambda l: isinstance(l, SegmentedArray))
        if not all(isinstance(l, SegmentedArray) for l in leaves):
            self._check_local_axis(axis, "vdot")
        return _comm.vdot(x, y, axis=axis, policies=policies)

    def copy(self, seg: SegmentedArray, *, policy: Policy | None = None,
             **kw) -> SegmentedArray:
        """Segmented-to-segmented copy / re-segmentation (Fig. 3).

        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([1., 2., 3., 4.])
        >>> comm.copy(seg, policy=Policy.CLONE).policy
        <Policy.CLONE: 'clone'>
        """
        seg = _fire_verb("copy", seg)
        return _comm.copy(seg, policy=policy, **kw)

    # -- point-to-point (the paper's P2P transfer path) -------------------
    def send_recv(self, x, perm, *, axis=None):
        """Pairwise segment exchange: ship rank ``src``'s segment to rank
        ``dst`` for every ``(src, dst)`` pair (``lax.ppermute``); ranks
        nothing is sent to receive zeros.

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([5., 6.])
        >>> comm.send_recv(seg, [(0, 0)]).gather().tolist()  # identity
        [5.0, 6.0]
        """
        if not isinstance(x, SegmentedArray):
            self._check_local_axis(axis, "send_recv")
        return _comm.send_recv(x, perm, axis=axis)

    def shift(self, x, offset: int = 1, *, wrap: bool = True, axis=None):
        """Ring shift by ``offset`` (``wrap=False``: edges get zeros).
        In-shard_map form: pass ``axis`` (e.g. ``comm.axis``); the ring
        size is that axis's extent.

        On one device the ring has a single rank, so a wrapped shift is
        the identity and an open-boundary shift zero-fills:

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([5., 6.])
        >>> comm.shift(seg, 1).gather().tolist()
        [5.0, 6.0]
        >>> comm.shift(seg, 1, wrap=False).gather().tolist()
        [0.0, 0.0]
        """
        if isinstance(x, SegmentedArray):
            return _comm.shift(x, offset, wrap=wrap)
        if axis is None:
            if self.size > 1:
                raise ValueError(
                    "in-shard_map shift on a multi-device communicator "
                    "needs axis= (e.g. comm.axis)")
            nseg = 1
        else:
            axes = axis if isinstance(axis, tuple) else (axis,)
            nseg = self.group.axis_size(*axes)
        return _comm.shift(x, offset, wrap=wrap, axis=axis, nseg=nseg)

    # -- synchronization (paper §2.5) -------------------------------------
    def barrier(self) -> None:
        """All devices of the group reach this point.

        >>> from repro.core import Environment
        >>> Environment().subgroup(1).barrier()   # returns None
        """
        _sync.barrier(self.group)

    def fence(self, *arrays):
        """Host-block until the given arrays are computed.

        >>> import jax.numpy as jnp
        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> comm.fence(jnp.arange(3.0) * 2).tolist()
        [0.0, 2.0, 4.0]
        """
        return _sync.fence(*arrays)

    def barrier_fence(self, *arrays):
        """Fence, then barrier — the paper's strongest primitive.

        >>> import jax.numpy as jnp
        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> comm.barrier_fence(jnp.ones(2)).tolist()
        [1.0, 1.0]
        """
        return _sync.barrier_fence(*arrays, group=self.group)

    # -- kernel launch (paper §2.5) ---------------------------------------
    def invoke(self, fn: Callable, *args, rank: int, **kw):
        """Launch ``fn`` in the context of one device of the group
        (other ranks' segments are zero-masked).

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([1., 2.])
        >>> comm.invoke(lambda xl: xl * 10, seg, rank=0).gather().tolist()
        [10.0, 20.0]
        """
        kw.setdefault("mesh_axes", self.mesh_axes)
        return _invoke.invoke_kernel(fn, *args, rank=rank, group=self.group,
                                     **kw)

    def invoke_all(self, fn: Callable, *args, **kw):
        """Launch ``fn`` on every device; segmented args arrive as local
        ranges, plain arrays are broadcast.

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> seg = comm.container([1., 2.])
        >>> comm.invoke_all(lambda xl: xl + 1, seg).gather().tolist()
        [2.0, 3.0]
        """
        kw.setdefault("mesh_axes", self.mesh_axes)
        return _invoke.invoke_kernel_all(fn, *args, group=self.group, **kw)

    def spmd(self, fn: Callable, *, in_policies, out_policies,
             check_vma: bool = True, donate_argnums=(), jit: bool = True):
        """Compile an SPMD program from segmentation policies — the one
        launch point algorithms use (no specs, no shard_map).

        >>> import jax.numpy as jnp
        >>> from repro.core import Environment, Policy
        >>> comm = Environment().subgroup(1)
        >>> prog = comm.spmd(lambda xl: 2 * xl,
        ...                  in_policies=(Policy.NATURAL,),
        ...                  out_policies=Policy.NATURAL)
        >>> prog(jnp.arange(2.0)).tolist()
        [0.0, 2.0]
        """
        return _invoke.make_spmd(fn, self.group, in_policies=in_policies,
                                 out_policies=out_policies,
                                 mesh_axes=self.mesh_axes,
                                 check_vma=check_vma,
                                 donate_argnums=donate_argnums, jit=jit)
