"""JAX version binding — the single place repro.core touches the host
JAX API surface that moved between releases.

The library targets everything from jax 0.4.3x (``shard_map`` still in
``jax.experimental``, no ``jax.sharding.AxisType``, ambient mesh only
via thread resources) through 0.6+ (``jax.shard_map`` with ``check_vma``,
``get_abstract_mesh``).  Everything else in repro.core is written against
the thin functions here, so a JAX upgrade is a one-file change.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh

_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename
    papered over."""
    if _HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices=None) -> Mesh:
    """Build a Mesh over the first ``prod(shape)`` devices.

    Unlike ``jax.make_mesh`` this tolerates a mesh smaller than the host
    device count, so the same test/benchmark code runs under any
    ``--xla_force_host_platform_device_count``.  Axis types default to
    Auto on every supported JAX.
    """
    shape = tuple(shape)
    if devices is None:
        devices = jax.devices()[: math.prod(shape)]
    if len(devices) != math.prod(shape):
        raise ValueError(f"mesh shape {shape} needs {math.prod(shape)} "
                         f"devices, got {len(devices)}")
    try:
        # topology-aware ordering (ICI nearest-neighbour rings on TPU)
        from jax.experimental import mesh_utils
        arr = mesh_utils.create_device_mesh(shape, devices=list(devices))
    except Exception:
        arr = np.asarray(devices).reshape(shape)
    return Mesh(arr, tuple(axes))


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across its ``TPUCompilerParams`` rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def ambient_mesh() -> Mesh | None:
    """The innermost ``with mesh:`` context as a concrete Mesh, or None."""
    if _HAS_ABSTRACT_MESH:
        env = jax.sharding.get_abstract_mesh()
        if env is None or env.empty:
            return None
        try:
            return jax.sharding.get_concrete_mesh()
        except Exception:
            return None
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def ambient_axis_names() -> tuple[str, ...]:
    """Axis names of the ambient mesh context (abstract or concrete);
    empty outside any mesh scope.  Safe to call while tracing."""
    if _HAS_ABSTRACT_MESH:
        env = jax.sharding.get_abstract_mesh()
        if env is None or env.empty:
            return ()
        return tuple(env.shape.keys())
    m = ambient_mesh()
    return () if m is None else tuple(m.axis_names)
