"""Plan / PlanCache — the library-port substrate (paper §4).

MGPU ports existing GPU libraries (CUFFT -> libfft, CUBLAS -> libblas)
by pairing every operation with a *plan*: a descriptor object that
captures the problem geometry (shape, dtype, batch, distribution) and
the device group, built once and executed many times.  cudaLibMg's
grid/matrix descriptors and stdgpu's "construct once, use everywhere"
containers follow the same shape.  For a real-time frame loop this is
the difference between per-frame re-setup (trace + lower + compile on
the hot path) and a steady state where every frame is a cache hit.

``Plan``       an executable bound to one immutable key (geometry +
               group); calling it runs the compiled program.
``PlanCache``  an LRU-bounded key -> Plan map with hit/miss/eviction
               counters.  Keys include the communicator group identity
               (device ids + mesh axes), so plans never leak across
               groups.  ``stats()`` is what the streaming engine and
               benchmark reports surface.

Every ported library (``repro.lib.fft`` / ``.blas`` / ``.gridding``)
builds its plans through the shared default cache unless handed a
private one — and so do the eager transfer verbs in ``repro.core.comm``
(their shard_map programs are plans keyed on layout + schedule + size
threshold), which is why the machinery lives in ``repro.core``: the verb
layer must not import ``repro.lib``.  ``repro.lib.plan`` re-exports this
module unchanged for the historical import path.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable


def group_token(group_or_comm) -> tuple:
    """Hashable identity of a device group (or Communicator).

    Two communicators share plans iff they address the same devices
    arranged as the same named-axis mesh — the plan-cache analogue of
    MGPU plans being bound to the ``dev_group`` they were created on.
    """
    if group_or_comm is None:
        return ("nogroup",)
    g = getattr(group_or_comm, "group", group_or_comm)
    mesh = g.mesh
    axes = getattr(group_or_comm, "mesh_axes", None) or tuple(mesh.axis_names)
    return (tuple(int(d.id) for d in mesh.devices.flat),
            tuple(mesh.devices.shape), tuple(mesh.axis_names), tuple(axes))


def seg_token(seg) -> tuple:
    """Hashable layout identity of a SegmentedArray (shape, dtype and the
    full segmentation policy — what an MGPU descriptor records)."""
    return (tuple(seg.data.shape), str(seg.data.dtype), seg.policy.value,
            seg.dim, seg.orig_len, seg.block, seg.halo,
            group_token(seg))


@dataclasses.dataclass
class Plan:
    """One built library plan: an executable bound to an immutable key.

    ``fn`` is the compiled/compilable program (typically a ``jax.jit``
    wrapper or a verb-layer composite); ``meta`` carries whatever the
    builder wants reports to see (interp matrices' nnz, transfer bytes,
    schedule choice, ...).

    >>> p = Plan(key=("square", 3), fn=lambda x: x ** 2,
    ...          lib="libdemo", op="square")
    >>> p(4)                       # calling the plan runs the program
    16
    >>> Plan.value(("blocks",), (8, 8))()   # a cached decision
    (8, 8)
    """

    key: tuple
    fn: Callable
    lib: str = ""
    op: str = ""
    meta: dict = dataclasses.field(default_factory=dict)

    def __call__(self, *args, **kw):
        return self.fn(*args, **kw)

    @classmethod
    def value(cls, key: tuple, payload: Any, lib: str = "", op: str = "",
              meta: dict | None = None) -> "Plan":
        """A plan whose 'program' is a cached decision rather than a
        compiled fn — calling it returns ``payload``.  Used for
        plan-build-time choices that must share the PlanCache counter
        discipline (e.g. the kernel block-size autotuner's winners)."""
        return cls(key=key, fn=lambda: payload, lib=lib, op=op,
                   meta=dict(meta or {}))

    def __repr__(self) -> str:
        return f"Plan({self.lib}.{self.op}, key_hash={hash(self.key):#x})"


class PlanCache:
    """LRU-bounded plan store with hit/miss/eviction counters.

    Keys are full plan keys (op + geometry + group token); a lookup that
    misses runs ``builder()`` once and caches the result.  Counters are
    cumulative; ``snapshot()``/``stats()`` expose them so callers (the
    streaming engine, benchmark rows) can report hit rates and prove the
    steady state builds nothing.

    >>> cache = PlanCache(maxsize=2)
    >>> build = lambda: Plan(key=("square", 3), fn=lambda x: x ** 2)
    >>> cache.get_or_build(("square", 3), build)(4)    # miss: builds
    16
    >>> cache.get_or_build(("square", 3), build)(5)    # hit: cached fn
    25
    >>> s = cache.stats()
    >>> (s["hits"], s["misses"], s["size"])
    (1, 1, 1)
    >>> cache.delta(s)["builds"]     # a steady region builds nothing
    0
    """

    def __init__(self, maxsize: int = 256):
        if maxsize < 1:
            raise ValueError("PlanCache needs maxsize >= 1")
        self.maxsize = maxsize
        self._plans: OrderedDict[tuple, Plan] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key: tuple) -> bool:
        return key in self._plans

    def get_or_build(self, key: tuple, builder: Callable[[], Plan]) -> Plan:
        """Return the cached plan for ``key``, building (and possibly
        evicting the least-recently-used plan) on a miss."""
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
            self.misses += 1
        # build outside the lock: builders may trace/compile for a while
        plan = builder()
        if not isinstance(plan, Plan):
            plan = Plan(key=key, fn=plan)
        with self._lock:
            existing = self._plans.get(key)
            if existing is not None:
                # another thread built the same key meanwhile: keep the
                # first build so every caller shares one plan object.
                self._plans.move_to_end(key)
                return existing
            self._plans[key] = plan
            self._plans.move_to_end(key)
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
                self.evictions += 1
        return plan

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()

    # -- reporting --------------------------------------------------------
    @property
    def builds(self) -> int:
        """Total plans built (== misses: every miss builds exactly once)."""
        return self.misses

    def snapshot(self) -> dict:
        """Point-in-time counters, cheap enough to take per frame."""
        return {"hits": self.hits, "misses": self.misses,
                "builds": self.builds, "evictions": self.evictions,
                "size": len(self._plans)}

    def delta(self, since: dict) -> dict:
        """Counter movement since a ``snapshot()`` — what one measured
        region (a streamed frame, a benchmark's steady state) did to the
        cache.  This is the harness-facing counter surface: the
        streaming engine and ``repro.bench.harness.measure`` both report
        it per region, so 'the steady state builds nothing' is a
        checkable number (``builds == 0``) rather than a belief."""
        now = self.snapshot()
        d = {k: now[k] - since[k]
             for k in ("hits", "misses", "builds", "evictions")}
        total = d["hits"] + d["misses"]
        d["hit_rate"] = round(d["hits"] / total, 4) if total else 0.0
        return d

    def stats(self) -> dict:
        """Counters + derived hit rate, for report artifacts."""
        s = self.snapshot()
        total = s["hits"] + s["misses"]
        s["capacity"] = self.maxsize
        s["hit_rate"] = round(s["hits"] / total, 4) if total else 0.0
        return s

    def __repr__(self) -> str:
        s = self.stats()
        return (f"PlanCache(size={s['size']}/{s['capacity']}, "
                f"hits={s['hits']}, builds={s['builds']}, "
                f"hit_rate={s['hit_rate']})")


_DEFAULT = PlanCache(maxsize=256)


def default_cache() -> PlanCache:
    """The shared cache all ported libraries use unless given their own."""
    return _DEFAULT


def plan_stats() -> dict:
    """Stats of the shared default cache (report-artifact convenience)."""
    return _DEFAULT.stats()
