"""Kernel invocation — the MGPU ``invoke_kernel`` family (paper §2.5).

MGPU forwards segmented containers to user kernels as *device ranges*
referencing only local memory, with a pass-through type when a kernel
needs the entire vector for peer-to-peer access.  The SPMD analogue:
``invoke_kernel_all`` shard_maps the user function so every argument
arrives as its local shard; ``PassThrough`` materializes the full array
(the TPU equivalent of P2P visibility is an all-gather); ``dev_rank``
is ``lax.axis_index``.

Every ``group=`` parameter accepts a ``DeviceGroup`` or an
``env.Communicator`` (whose group is unwrapped); the method forms
``Communicator.invoke``/``invoke_all``/``spmd`` are the stable surface.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from . import compat
from .runtime import DeviceGroup, current_group
from .segmented import Policy, SegmentedArray


@dataclasses.dataclass(frozen=True)
class PassThrough:
    """Forward the *entire* segmented vector to the kernel (paper's
    pass-through type for peer-to-peer access)."""
    seg: SegmentedArray


def dev_rank(axis) -> jax.Array:
    """The calling shard's rank on ``axis`` (usable inside kernels)."""
    return lax.axis_index(axis)


def _unpack(args, group):
    in_specs, vals = [], []
    for a in args:
        if isinstance(a, SegmentedArray):
            in_specs.append(a.pspec)
            vals.append(a.data)
        elif isinstance(a, PassThrough):
            full = jax.device_put(a.seg.data, group.sharding(P()))
            in_specs.append(P())
            vals.append(full)
        else:
            in_specs.append(P())
            vals.append(jnp.asarray(a))
    return tuple(in_specs), tuple(vals)


def invoke_kernel_all(fn: Callable, *args,
                      group: DeviceGroup | None = None,
                      out_specs=None,
                      out_policy: Policy = Policy.NATURAL,
                      out_dim: int = 0,
                      mesh_axes: tuple[str, ...] | None = None,
                      probe_fn: Callable | None = None):
    """Launch ``fn`` on every device of the group (MGPU invoke_kernel_all).

    Segmented arguments are forwarded as local ranges; plain arrays and
    scalars are broadcast.  Returns a SegmentedArray when ``out_specs``
    segments the output, else the replicated array.
    """
    group = current_group(group)
    if mesh_axes is None:
        segs = [a for a in args if isinstance(a, SegmentedArray)]
        mesh_axes = segs[0].mesh_axes if segs else group.axis_names
    in_specs, vals = _unpack(args, group)
    if out_specs is None:
        out = [None] * _out_ndim_probe(probe_fn or fn, vals, in_specs, group)
        out[out_dim] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
        out_specs = P(*out)
    res = compat.shard_map(fn, mesh=group.mesh, in_specs=in_specs,
                           out_specs=out_specs)(*vals)
    if out_specs == P() or all(s is None for s in out_specs):
        return res
    return SegmentedArray(res, group, out_policy, out_dim, tuple(mesh_axes))


def _out_ndim_probe(fn, vals, in_specs, group) -> int:
    """Infer output rank via abstract eval of the shard-local function."""
    local = []
    for v, s in zip(vals, in_specs):
        shape = list(v.shape)
        for d, ax in enumerate(s):
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                shape[d] //= group.axis_size(*axes)
        local.append(jax.ShapeDtypeStruct(tuple(shape), v.dtype))
    with group.mesh:
        out = jax.eval_shape(lambda *a: fn(*a), *local)
    return len(out.shape)


def _is_policy_leaf(p) -> bool:
    # (Policy, dim) pairs only — a tuple of bare Policy members is a
    # container (e.g. the out_policies of a two-output kernel).
    return isinstance(p, Policy) or (
        isinstance(p, tuple) and len(p) == 2
        and isinstance(p[0], Policy) and isinstance(p[1], int))


def policy_pspec(p, axis) -> P:
    """Map a segmentation policy leaf — ``Policy`` or ``(Policy, dim)`` —
    to its PartitionSpec."""
    dim = 0
    if isinstance(p, tuple):
        p, dim = p
    if p is Policy.CLONE:
        return P()
    return P(*([None] * dim + [axis]))


def make_spmd(fn: Callable, group: DeviceGroup | None = None, *,
              in_policies, out_policies,
              mesh_axes: tuple[str, ...] = ("data",),
              check_vma: bool = True, donate_argnums=(), jit: bool = True):
    """Compile an SPMD kernel from segmentation *policies* (paper §2.5's
    ``invoke_kernel_all`` for algorithms, not arrays).

    ``in_policies`` is one pytree per positional argument and
    ``out_policies`` one for the result; leaves are ``Policy`` members or
    ``(Policy, dim)`` pairs (``Policy`` alone segments dim 0).  The body
    sees local shards and may call the verbs' in-shard_map forms
    (``Communicator.allreduce_window`` etc.).  Downstream layers never
    construct a PartitionSpec or touch shard_map: ``Communicator.spmd``
    is the single launch point the container layer exposes (this free
    function is its deprecated-shim engine).

    A 1-device group is the degenerate case — same program, the
    collectives are no-ops — which is how single- and multi-device
    callers share one code path.
    """
    group = current_group(group)
    axis = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
    to_specs = lambda pol: jax.tree.map(lambda p: policy_pspec(p, axis),
                                        pol, is_leaf=_is_policy_leaf)
    sm = compat.shard_map(fn, mesh=group.mesh,
                          in_specs=tuple(to_specs(p) for p in in_policies),
                          out_specs=to_specs(out_policies),
                          check_vma=check_vma)
    if not jit:
        if donate_argnums:
            raise ValueError("donate_argnums requires jit=True")
        return sm
    return jax.jit(sm, donate_argnums=donate_argnums)


def invoke_kernel(fn: Callable, *args, rank: int,
                  group: DeviceGroup | None = None, **kw):
    """Launch ``fn`` only in the context of device ``rank`` (flat index).

    SPMD adaptation: the kernel body executes on every shard (lockstep
    programs cannot diverge) but its effect is masked to ``rank``; other
    shards contribute zeros.  Matches MGPU semantics where only the
    target device's segment is written.
    """
    group = current_group(group)
    sizes = [group.mesh.shape[a] for a in group.axis_names]

    def masked(*local_args):
        idx = 0
        for a in group.axis_names:
            idx = idx * group.mesh.shape[a] + lax.axis_index(a)
        out = fn(*local_args)
        return jnp.where(idx == rank, out, jnp.zeros_like(out))

    return invoke_kernel_all(masked, *args, group=group, probe_fn=fn, **kw)
