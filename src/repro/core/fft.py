"""Segmented batched FFT — the MGPU CUFFT wrapper analogue (paper §2.4).

The paper computes many independent 2-D FFTs in parallel by segmenting
the batch across devices ("individual FFTs can currently not be split
across devices") — the same contract here: the batch dim is segmented,
each shard runs its local batched FFT, zero communication.  ``centered``
applies the fftshift convention needed by the MRI DTFT operator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .segmented import SegmentedArray


def _fft2_local(x: jax.Array, inverse: bool, centered: bool) -> jax.Array:
    axes = (-2, -1)
    if centered:
        x = jnp.fft.ifftshift(x, axes=axes)
    x = jnp.fft.ifft2(x, axes=axes, norm="ortho") if inverse \
        else jnp.fft.fft2(x, axes=axes, norm="ortho")
    if centered:
        x = jnp.fft.fftshift(x, axes=axes)
    return x


def fft2_batched(x: SegmentedArray, inverse: bool = False,
                 centered: bool = False) -> SegmentedArray:
    """Batched 2-D FFT over a batch-segmented container (no comm) —
    launched through the container's ``invoke`` (paper §2.5: segmented
    libraries are kernels over local ranges)."""
    return x.invoke(lambda xl: _fft2_local(xl, inverse, centered))


def fft2(x: jax.Array, inverse: bool = False, centered: bool = False) -> jax.Array:
    """Plain (non-segmented) centered FFT used by single-device paths."""
    return _fft2_local(x, inverse, centered)
