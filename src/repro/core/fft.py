"""Deprecated shim — the segmented FFT moved to ``repro.lib.fft``.

The MGPU CUFFT-wrapper analogue (paper §2.4) is now a *ported library*
on the plan/plan-cache substrate of paper §4: ``repro.lib.fft`` builds a
plan per (shape, dtype, direction, policy, group) and caches it, so the
per-frame hot path never re-sets-up the transform.  These free functions
forward there (through the same cache) and emit ``DeprecationWarning``.
"""

from __future__ import annotations

import functools
import warnings

import jax

from .segmented import SegmentedArray


# warn exactly once per process per shim, whatever the warning filters
# say — a hot loop through a shim must not spam (or pay for) a warning
# per call.  tests clear this set to simulate a fresh process.
_warned: set[str] = set()


def _deprecated(name: str, target):
    @functools.wraps(target)
    def shim(*args, **kw):
        if name not in _warned:
            _warned.add(name)
            warnings.warn(
                f"repro.core.fft.{name} is deprecated; use "
                f"repro.lib.fft.{name}", DeprecationWarning, stacklevel=2)
        return target(*args, **kw)
    shim.__deprecated__ = f"repro.lib.fft.{name}"
    return shim


def _fft2_batched(x: SegmentedArray, inverse: bool = False,
                  centered: bool = False) -> SegmentedArray:
    from ..lib import fft as lfft
    return lfft.fft2_batched(x, inverse=inverse, centered=centered)


def _fft2(x: jax.Array, inverse: bool = False,
          centered: bool = False) -> jax.Array:
    from ..lib import fft as lfft
    return lfft.fft2(x, inverse=inverse, centered=centered)


fft2_batched = _deprecated("fft2_batched", _fft2_batched)
fft2 = _deprecated("fft2", _fft2)
