"""Segmented BLAS — the MGPU CUBLAS wrapper analogue (paper §2.4, Fig. 4).

The paper consolidates CUBLAS under a segmented-container interface:
``a*X + Y`` scales linearly (no communication), scalar products need one
inter-device reduction, and ``A · B`` needs an *additional inter-device
reduction step* when the contracted dimension is split — exactly the
``gemm_ksplit`` + psum path here (on TPU this is the classic tensor-
parallel matmul).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from . import compat
from .runtime import DeviceGroup
from .segmented import Policy, SegmentedArray
from .comm import _axis_arg  # noqa: F401  (gemm_ksplit below)


def axpy(a, x: SegmentedArray, y: SegmentedArray) -> SegmentedArray:
    """a*X + Y, segment-local (strong-scaling op in paper Fig. 4)."""
    return y.with_data(a * x.data + y.data)


def dot(x: SegmentedArray, y: SegmentedArray) -> jax.Array:
    """Scalar product <x, y> (conjugating) with one reduction across
    segments (paper: 'scalar products of all data' in the CG loop) —
    routed through the ``vdot`` comm verb."""
    from .comm import vdot
    return vdot(x, y)


def norm2(x: SegmentedArray) -> jax.Array:
    return jnp.real(dot(x, x))


def gemm_batched(a: SegmentedArray, b: SegmentedArray) -> SegmentedArray:
    """Batched matmul over the segmented batch dim — no communication
    (paper Fig. 4 measures 12 square matrices split across GPUs)."""
    return a.with_data(jnp.einsum("bij,bjk->bik", a.data, b.data))


def gemm_ksplit(a: SegmentedArray, b: SegmentedArray) -> SegmentedArray:
    """A·B with the contraction dim segmented: local partial matmul +
    inter-device reduction (the paper's non-scaling A·B case)."""
    ax = _axis_arg(a.mesh_axes)

    def body(al, bl):
        return lax.psum(al @ bl, ax)

    # A split on dim 1 (k), B split on dim 0 (k)
    out = compat.shard_map(body, mesh=a.group.mesh,
                           in_specs=(P(None, ax), P(ax, None)),
                           out_specs=P())(a.data, b.data)
    return SegmentedArray(out, a.group, Policy.CLONE, 0, a.mesh_axes)
