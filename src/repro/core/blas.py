"""Deprecated shim — the segmented BLAS moved to ``repro.lib.blas``.

The MGPU CUBLAS-wrapper analogue (paper §2.4, Fig. 4) is now a *ported
library* on the plan/plan-cache substrate of paper §4: every operation
in ``repro.lib.blas`` is a cached plan keyed on operand layout + group,
and the port adds the fused ``axpy_dot``/``dot_allreduce`` epilogues the
CG hot path wants.  These free functions forward there (through the same
cache) and emit ``DeprecationWarning``.
"""

from __future__ import annotations

import functools
import warnings


# warn exactly once per process per shim, whatever the warning filters
# say — a hot loop through a shim must not spam (or pay for) a warning
# per call.  tests clear this set to simulate a fresh process.
_warned: set[str] = set()


def _deprecated(name: str):
    def _target(*args, **kw):
        from ..lib import blas as lblas
        return getattr(lblas, name)(*args, **kw)

    @functools.wraps(_target)
    def shim(*args, **kw):
        if name not in _warned:
            _warned.add(name)
            warnings.warn(
                f"repro.core.blas.{name} is deprecated; use "
                f"repro.lib.blas.{name}", DeprecationWarning, stacklevel=2)
        return _target(*args, **kw)

    shim.__name__ = name
    shim.__deprecated__ = f"repro.lib.blas.{name}"
    return shim


axpy = _deprecated("axpy")
dot = _deprecated("dot")
norm2 = _deprecated("norm2")
gemm_batched = _deprecated("gemm_batched")
gemm_ksplit = _deprecated("gemm_ksplit")
