"""repro.core — the paper's contribution (MGPU) as a composable JAX layer.

Segmented containers + MPI-like communication verbs + kernel invocation +
segmented FFT/BLAS, adapted from single-node multi-GPU (PCIe/IOH) to
multi-pod TPU (ICI/DCN).  See DESIGN.md §2 for the adaptation map.

The stable API surface is object-oriented (paper §2.1/§2.3):
``Environment`` discovers devices and mints group-bound
``Communicator`` objects whose *methods* are the MPI-like verbs;
containers built by ``Communicator.container`` carry fluent forms of the
verbs (``x.allreduce()``, ``x.to(Policy.CLONE)``, ...).  The free
functions below (``segment``/``broadcast``/``all_reduce``/...) are the
pre-Communicator surface, kept as thin deprecated shims.
"""

import functools as _functools
import warnings as _warnings

from . import compat
from .runtime import DeviceGroup, HW, DCN_AXES
from .runtime import current_group as _current_group
from .segmented import Policy, SegmentedArray
from .segmented import (segment as _segment, gather as _gather,
                        overlap2d_map as _overlap2d_map)
from . import comm as _comm
from .env import Environment, Communicator
from .invoke import PassThrough, dev_rank
from .invoke import (invoke_kernel as _invoke_kernel,
                     invoke_kernel_all as _invoke_kernel_all,
                     make_spmd as _make_spmd)
from .sync import fence, ordered
from .sync import barrier as _barrier, barrier_fence as _barrier_fence


def _deprecated(fn, name: str, replacement: str):
    """Wrap a free-function verb as a deprecation shim (same signature)."""
    @_functools.wraps(fn)
    def shim(*args, **kw):
        _warnings.warn(
            f"repro.core.{name} is deprecated; use {replacement}",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kw)
    shim.__deprecated__ = replacement
    return shim


# -- deprecated free-function surface (pre-Communicator API) ---------------
current_group = _deprecated(_current_group, "current_group",
                            "an explicit Environment()/Communicator")
segment = _deprecated(_segment, "segment", "Communicator.container")
gather = _deprecated(_gather, "gather",
                     "Communicator.gather / SegmentedArray.gather")
overlap2d_map = _deprecated(_overlap2d_map, "overlap2d_map",
                            "SegmentedArray.halo_exchange")
broadcast = _deprecated(_comm.broadcast, "broadcast", "Communicator.bcast")
scatter = _deprecated(_comm.scatter, "scatter", "Communicator.scatter")
reduce = _deprecated(_comm.reduce, "reduce", "Communicator.reduce")
all_reduce = _deprecated(_comm.all_reduce, "all_reduce",
                         "Communicator.allreduce")
all_reduce_window = _deprecated(_comm.all_reduce_window, "all_reduce_window",
                                "Communicator.allreduce_window")
vdot = _deprecated(_comm.vdot, "vdot", "Communicator.vdot")
copy = _deprecated(_comm.copy, "copy",
                   "Communicator.copy / SegmentedArray.to")
all_to_all = _deprecated(_comm.all_to_all, "all_to_all",
                         "Communicator.alltoall")
reduce_scatter = _deprecated(_comm.reduce_scatter, "reduce_scatter",
                             "Communicator.reduce_scatter")
hierarchical_psum = _comm.hierarchical_psum   # in-shard_map primitive
invoke_kernel = _deprecated(_invoke_kernel, "invoke_kernel",
                            "Communicator.invoke")
invoke_kernel_all = _deprecated(_invoke_kernel_all, "invoke_kernel_all",
                                "Communicator.invoke_all")
make_spmd = _deprecated(_make_spmd, "make_spmd", "Communicator.spmd")
barrier = _deprecated(_barrier, "barrier", "Communicator.barrier")
barrier_fence = _deprecated(_barrier_fence, "barrier_fence",
                            "Communicator.barrier_fence")

__all__ = [
    "compat",
    "Environment", "Communicator",
    "DeviceGroup", "current_group", "HW", "DCN_AXES",
    "Policy", "SegmentedArray", "segment", "gather", "overlap2d_map",
    "broadcast", "scatter", "reduce", "all_reduce", "all_reduce_window",
    "vdot", "copy", "all_to_all", "reduce_scatter", "hierarchical_psum",
    "invoke_kernel", "invoke_kernel_all", "make_spmd", "PassThrough",
    "dev_rank",
    "fence", "barrier", "barrier_fence", "ordered",
]
