"""repro.core — the paper's contribution (MGPU) as a composable JAX layer.

Segmented containers + MPI-like communication verbs + kernel invocation +
segmented FFT/BLAS, adapted from single-node multi-GPU (PCIe/IOH) to
multi-pod TPU (ICI/DCN).  See DESIGN.md §2 for the adaptation map.
"""

from . import compat
from .runtime import DeviceGroup, current_group, HW, DCN_AXES
from .segmented import Policy, SegmentedArray, segment, gather, overlap2d_map
from .comm import (broadcast, scatter, reduce, all_reduce, all_reduce_window,
                   vdot, copy, all_to_all, reduce_scatter, hierarchical_psum)
from .invoke import (invoke_kernel, invoke_kernel_all, make_spmd, PassThrough,
                     dev_rank)
from .sync import fence, barrier, barrier_fence, ordered
from . import blas, fft

__all__ = [
    "compat",
    "DeviceGroup", "current_group", "HW", "DCN_AXES",
    "Policy", "SegmentedArray", "segment", "gather", "overlap2d_map",
    "broadcast", "scatter", "reduce", "all_reduce", "all_reduce_window",
    "vdot", "copy", "all_to_all", "reduce_scatter", "hierarchical_psum",
    "invoke_kernel", "invoke_kernel_all", "make_spmd", "PassThrough",
    "dev_rank",
    "fence", "barrier", "barrier_fence", "ordered",
    "blas", "fft",
]
