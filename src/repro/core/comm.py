"""MPI-like communication verbs over segmented containers (paper §2.3).

The paper implements a subset of the MPI standard routines for segmented
containers: copy, scatter, gather, broadcast, reduce (Fig. 3), with the
transfer path chosen by topology (P2P inside a PCIe domain, host-staged
across IOHs).  Here every verb lowers to ``shard_map`` + ``jax.lax``
collectives, and the topology split becomes the ICI/DCN axis split:
``hierarchical=True`` decomposes an all-reduce into
reduce-scatter(ICI) -> all-reduce(DCN) -> all-gather(ICI), which moves
``1/n_ici`` of the bytes over the slow inter-pod links — the TPU analogue
of the paper's staged cross-IOH reduction.

Dual calling forms
------------------
Every reduction verb works both **eagerly** on a ``SegmentedArray`` (the
verb wraps its own ``shard_map``) and **inside a shard_map body** on the
per-device shard (pass the reduction ``axis`` name; ``axis=None`` means
single-program execution and degenerates to the local math).  This is
what lets whole algorithms — NLINV's Newton/CG loop — be written once
against the verbs and launched either way.

These module-level functions are the verb *implementations*; the stable
public surface is the group-bound method set of ``env.Communicator``
(and the fluent forms on ``SegmentedArray``), for which the re-exports
in ``repro.core`` are deprecated shims.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from . import compat
from .runtime import DeviceGroup, current_group
from .segmented import Policy, SegmentedArray, _pad_to, gather, segment

# re-export container-level scatter/gather as comm verbs (Fig. 3 naming)
scatter = segment
gather = gather

_REDUCERS = {
    "sum": (lax.psum, jnp.sum),
    "max": (lax.pmax, jnp.max),
    "min": (lax.pmin, jnp.min),
}

_ELEMWISE = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


def broadcast(x, group: DeviceGroup | None = None) -> SegmentedArray:
    """Broadcast a local array to every device (-> CLONE container)."""
    return segment(x, group, policy=Policy.CLONE)


def _axis_arg(mesh_axes: Sequence[str]):
    return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]


def reduce(seg: SegmentedArray, op: str = "sum") -> jax.Array:
    """Merge the segments elementwise into one local array (paper Fig. 3/5:
    'reduce merges one matrix per GPU' — the segmented dim is reduced).
    """
    pcoll, jred = _REDUCERS[op]

    def body(x):
        x = jred(x, axis=seg.dim)
        return pcoll(x, _axis_arg(seg.mesh_axes))

    out_spec = P(*[None] * (seg.data.ndim - 1))
    return compat.shard_map(body, mesh=seg.group.mesh,
                            in_specs=seg.pspec, out_specs=out_spec)(seg.data)


def all_reduce(seg: SegmentedArray, op: str = "sum",
               hierarchical: bool = False,
               p2p: bool = False) -> SegmentedArray:
    """Like ``reduce`` but the result is CLONEd on every device
    (the paper's Σ ρ_g block-wise all-reduce).  ``p2p=True`` runs the
    reduction as a ring of ``ppermute`` transfers instead of one psum —
    the paper's explicit P2P schedule."""
    return all_reduce_window(seg, None, op=op, hierarchical=hierarchical,
                             p2p=p2p)


def _window_index(ndim: int, window, axes=None) -> tuple:
    """Slice tuple selecting ``window`` ((lo, hi) pairs) on the trailing
    dims of a rank-``ndim`` array (or on explicit ``axes``)."""
    if axes is None:
        axes = tuple(range(ndim - len(window), ndim))
    idx: list = [slice(None)] * ndim
    for ax, (lo, hi) in zip(axes, window):
        idx[ax] = slice(lo, hi)
    return tuple(idx)


def all_reduce_window(x, window=None, *, op: str = "sum",
                      axis=None, reduce_dim: int | None = None,
                      hierarchical: bool = False, window_axes=None,
                      p2p: bool = False,
                      group: DeviceGroup | None = None,
                      mesh_axes: Sequence[str] | None = None):
    """Windowed all-reduce — generalizes the paper's ``kern_all_red_p2p_2d``.

    The paper's NLINV port observes that after masking with M_Omega only
    a centered 2-D section of Σ_g ρ_g is nonzero, so only that window is
    put on the wire (4x fewer bytes for the FOV quarter).  This verb is
    that trick as a first-class primitive: reduce ``reduce_dim`` locally,
    all-reduce only ``window`` ((lo, hi) per trailing dim, or explicit
    ``window_axes``), and return the result scattered back into zeros.
    ``window=None`` is a plain all-reduce.

    Eager form: ``x`` is a SegmentedArray — returns a CLONE container
    whose ``reduce_dim`` (default: the segmented dim) has been summed
    away globally.

    In-shard_map form: ``x`` is the local shard; ``axis`` names the mesh
    axis to reduce over (``axis=None``: no collective — the single-device
    degenerate case).  ``hierarchical=True`` with ``group``/``mesh_axes``
    stages the window psum over ICI then DCN (paper's cross-IOH path).
    ``p2p=True`` (with ``group``/``mesh_axes``) replaces the psum with a
    ring of ``ppermute`` transfers — the paper's ``kern_all_red_p2p_2d``
    explicit P2P schedule, numerically equivalent up to float summation
    order (each rank accumulates its neighbours in ring order).
    """
    if isinstance(x, SegmentedArray):
        seg = x
        rdim = seg.dim if reduce_dim is None else reduce_dim
        if rdim != seg.dim:
            raise ValueError(
                f"eager all_reduce_window reduces the segmented dim "
                f"({seg.dim}); got reduce_dim={rdim}")
        maxes = tuple(seg.mesh_axes)
        body = partial(_all_reduce_window_local, window=window, op=op,
                       axis=_axis_arg(maxes), reduce_dim=rdim,
                       hierarchical=hierarchical, window_axes=window_axes,
                       p2p=p2p, group=seg.group, mesh_axes=maxes)
        out_spec = P(*[None] * (seg.data.ndim - 1))
        # check_vma=False: the windowed scatter-into-zeros defeats JAX's
        # replication inference even though the result is replicated.
        out = compat.shard_map(body, mesh=seg.group.mesh, in_specs=seg.pspec,
                               out_specs=out_spec,
                               check_vma=False)(seg.data)
        return SegmentedArray(out, seg.group, Policy.CLONE, 0, maxes)
    return _all_reduce_window_local(x, window=window, op=op, axis=axis,
                                    reduce_dim=reduce_dim,
                                    hierarchical=hierarchical,
                                    window_axes=window_axes, p2p=p2p,
                                    group=group, mesh_axes=mesh_axes)


def _all_reduce_window_local(x, *, window, op, axis, reduce_dim,
                             hierarchical, window_axes, group, mesh_axes,
                             p2p=False):
    pcoll, jred = _REDUCERS[op]
    if p2p and hierarchical:
        raise ValueError("p2p and hierarchical are mutually exclusive "
                         "reduction schedules")
    if window is not None and op != "sum":
        # the scatter-back fill is zeros, which is only the identity of +
        raise NotImplementedError(
            f"windowed all-reduce supports op='sum' only, got {op!r}")
    if reduce_dim is not None:
        x = jred(x, axis=reduce_dim)

    def psum_part(v):
        if axis is None:
            return v
        if p2p:
            if group is None or not mesh_axes:
                raise ValueError("p2p=True needs group= and mesh_axes=")
            if len(tuple(mesh_axes)) > 1:
                raise ValueError("p2p ring reduction is single-axis")
            return ring_allreduce(v, _axis_arg(tuple(mesh_axes)),
                                  group.axis_size(*mesh_axes), op=op)
        if hierarchical and op == "sum" and group is not None and mesh_axes:
            return hierarchical_psum(v, group, mesh_axes)
        return pcoll(v, axis)

    if window is None:
        return psum_part(x)
    idx = _window_index(x.ndim, window, window_axes)
    return jnp.zeros_like(x).at[idx].set(psum_part(x[idx]))


def vdot(x, y, *, axis=None, policies=None):
    """Segmented inner product ⟨x, y⟩ over mixed CLONE/NATURAL pytrees
    (the 'scalar products of all data' CG entry of paper Table 1).

    Eager form: ``x``/``y`` are pytrees of SegmentedArrays — the vdot of
    the logical arrays.  No explicit collective: the global contraction
    already spans all shards.

    In-shard_map form: leaves are local shards, ``axis`` names the mesh
    axis, and ``policies`` is a matching pytree of ``Policy`` leaves
    saying which components are CLONE (replicated: counted once, never
    psum'd) versus segmented (partial products: one psum for all of
    them).  ``axis=None`` degenerates to the plain local vdot.
    """
    is_seg = lambda l: isinstance(l, SegmentedArray)
    xl, xdef = jax.tree.flatten(x, is_leaf=is_seg)
    yl, ydef = jax.tree.flatten(y, is_leaf=is_seg)
    if xdef != ydef:
        raise ValueError(f"vdot operands differ in structure: "
                         f"{xdef} vs {ydef}")
    if xl and all(is_seg(l) for l in xl):
        return sum(jnp.vdot(a.data, b.data) for a, b in zip(xl, yl))

    if policies is None:
        pols = [Policy.NATURAL] * len(xl)
    else:
        pols = jax.tree.leaves(
            policies, is_leaf=lambda p: isinstance(p, (Policy, tuple)))
        if len(pols) != len(xl):
            raise ValueError("policies pytree does not match operands")
    clone_part = shard_part = None
    for a, b, p in zip(xl, yl, pols):
        pol = p[0] if isinstance(p, tuple) else p
        v = jnp.vdot(a, b)
        if pol is Policy.CLONE:
            clone_part = v if clone_part is None else clone_part + v
        else:
            shard_part = v if shard_part is None else shard_part + v
    total = None
    if shard_part is not None:
        total = lax.psum(shard_part, axis) if axis is not None else shard_part
    if clone_part is not None:
        total = clone_part if total is None else total + clone_part
    return total


def hierarchical_psum(x: jax.Array, group: DeviceGroup,
                      mesh_axes: Sequence[str]) -> jax.Array:
    """psum decomposed by topology; call INSIDE a shard_map body.

    reduce-scatter over ICI axes, all-reduce over DCN axes, all-gather
    back over ICI — so each slow (DCN) link carries only 1/n_ici of the
    payload.  Falls back to a flat psum when the leading dim does not
    tile.
    """
    ici = [a for a in mesh_axes if a in group.ici_axes]
    dcn = [a for a in mesh_axes if a in group.dcn_axes]
    n_ici = math.prod(group.mesh.shape[a] for a in ici) if ici else 1
    if not dcn or not ici or x.shape[0] % n_ici != 0:
        return lax.psum(x, _axis_arg(tuple(mesh_axes)))
    for a in ici:
        x = lax.psum_scatter(x, a, scatter_dimension=0, tiled=True)
    for a in dcn:
        x = lax.psum(x, a)
    for a in reversed(ici):
        x = lax.all_gather(x, a, axis=0, tiled=True)
    return x


# ---------------------------------------------------------------------------
# point-to-point verbs (the paper's P2P transfer path inside a PCIe domain;
# on TPU: lax.ppermute over ICI neighbour links)
# ---------------------------------------------------------------------------

def ring_perm(nseg: int, offset: int = 1,
              wrap: bool = True) -> list[tuple[int, int]]:
    """(src, dst) pairs shifting every rank by ``offset`` around the ring.
    ``wrap=False`` drops the wrap-around edges (their receivers get the
    collective's zero fill) — the open-boundary form halo exchange uses."""
    if wrap:
        return [(i, (i + offset) % nseg) for i in range(nseg)]
    return [(i, i + offset) for i in range(nseg) if 0 <= i + offset < nseg]


def _p2p_eager(seg: SegmentedArray, perm) -> SegmentedArray:
    bad = [p for p in perm if not all(0 <= r < seg.nseg for r in p)]
    if bad:
        raise ValueError(f"send_recv perm pairs {bad} out of range for a "
                         f"{seg.nseg}-segment group")
    ax = _axis_arg(seg.mesh_axes)
    body = lambda xl: lax.ppermute(xl, ax, perm)
    out = compat.shard_map(body, mesh=seg.group.mesh, in_specs=seg.pspec,
                           out_specs=seg.pspec)(seg.data)
    return seg.with_data(out)


def send_recv(x, perm, *, axis=None):
    """MPI_Sendrecv over segments: for every ``(src, dst)`` pair, rank
    ``src``'s segment is shipped to rank ``dst``; ranks no pair sends to
    receive zeros (``lax.ppermute`` semantics — the paper's P2P copy).

    Eager form: ``x`` is a SegmentedArray — segments move between
    devices, the container metadata is unchanged.  In-shard_map form:
    ``x`` is the local shard and ``axis`` names the mesh axis.
    ``axis=None`` is the single-program degenerate case: identity if
    ``(0, 0)`` is in ``perm``, else zeros.
    """
    perm = [tuple(p) for p in perm]
    if isinstance(x, SegmentedArray):
        return _p2p_eager(x, perm)
    if axis is None:
        return x if (0, 0) in perm else jnp.zeros_like(x)
    return lax.ppermute(x, axis, perm)


def shift(x, offset: int = 1, *, wrap: bool = True, axis=None,
          nseg: int | None = None):
    """Ring shift: rank ``i``'s segment moves to rank ``i + offset``
    (modulo the group size when ``wrap``; otherwise the edge ranks
    receive zeros).  The canonical P2P pattern — halo exchange is two
    ``shift``s with ``wrap=False``.

    Eager form on a SegmentedArray; in-shard_map form needs ``axis`` and
    ``nseg`` (the axis size, static).  ``axis=None``/``nseg=None`` is the
    1-device degenerate case.
    """
    if isinstance(x, SegmentedArray):
        return _p2p_eager(x, ring_perm(x.nseg, offset, wrap))
    if nseg is None:
        if axis is not None:
            raise ValueError("in-shard_map shift needs nseg= (static axis size)")
        nseg = 1
    return send_recv(x, ring_perm(nseg, offset, wrap), axis=axis)


def ring_allreduce(x, axis, nseg: int, op: str = "sum", *,
                   chunks: int = 1, compute: Callable | None = None):
    """All-reduce as ``nseg - 1`` ring ppermutes — the transfer schedule
    of the paper's ``kern_all_red_p2p_2d``, built on the p2p verb layer.
    Call inside a shard_map body.  Equivalent to the psum up to float
    summation order (ranks accumulate neighbours in ring order, so
    replicas may differ in the last ulp).

    ``x`` may be a pytree (every leaf rides the same ring schedule).
    ``chunks > 1`` splits each leaf's leading dim into that many ring
    payloads, so the schedule has independent in-flight transfers the
    compiler can pipeline; the per-element accumulation order is
    unchanged (bitwise identical to the unchunked ring).
    ``compute`` is caller-supplied independent work (the 2017 follow-up's
    communication/computation overlap): it is emitted after the FIRST
    transfer round, so its ops have no data dependence on the remaining
    rounds and the scheduler is free to run them while transfers are in
    flight.  With ``compute`` the return value is ``(reduced, out)``.
    """
    jop = _ELEMWISE[op]
    perm = ring_perm(nseg, 1, wrap=True)
    leaves, treedef = jax.tree.flatten(x)

    def _split(leaf):
        leaf = jnp.asarray(leaf)
        if chunks <= 1 or leaf.ndim == 0 or leaf.shape[0] < chunks:
            return [leaf]
        return jnp.array_split(leaf, chunks, axis=0)

    pieces = [_split(leaf) for leaf in leaves]
    flat = [p for ps in pieces for p in ps]
    out = None
    accs, bufs = list(flat), list(flat)
    for step in range(nseg - 1):
        bufs = [lax.ppermute(b, axis, perm) for b in bufs]
        accs = [jop(a, b) for a, b in zip(accs, bufs)]
        if step == 0 and compute is not None:
            out = compute()
    if compute is not None and out is None:     # nseg == 1 degenerate ring
        out = compute()
    merged, k = [], 0
    for ps in pieces:
        n = len(ps)
        merged.append(accs[k] if n == 1
                      else jnp.concatenate(accs[k:k + n], axis=0))
        k += n
    red = jax.tree.unflatten(treedef, merged)
    return red if compute is None else (red, out)


def all_reduce_overlap(x, window=None, *, op: str = "sum", axis=None,
                       reduce_dim: int | None = None, window_axes=None,
                       extras: tuple = (), compute: Callable | None = None,
                       p2p: bool = False, chunks: int = 2,
                       hierarchical: bool = False,
                       group: DeviceGroup | None = None,
                       mesh_axes: Sequence[str] | None = None):
    """Windowed all-reduce fused with scalar piggybacks and overlapped
    caller compute — the communication half of the fused NLINV hot path.

    Generalizes ``all_reduce_window`` (in-shard_map / single-program
    form) three ways, all motivated by the CG body of the 2017 follow-up:

    * ``extras``: additional (typically scalar) partials reduced IN THE
      SAME collective as the window — one variadic all-reduce instead of
      one per quantity (the CG <p, Ap> scalar rides the Σ_g rho_g wire);
    * ``compute``: independent work emitted between the local partials
      and the collective's consumers, so the scheduler can overlap it
      with the reduction (the ``dchat`` FFT branch of DG^H);
    * ``p2p=True``: the reduction runs as the chunked
      ``kern_all_red_p2p_2d`` ring schedule with ``compute`` interleaved
      after the first transfer round (``chunks`` ring payloads).

    Returns ``(reduced, extras_out, compute_out)``; ``compute_out`` is
    ``None`` when no ``compute`` is given.  ``axis=None`` degenerates to
    the local math (single-program form).
    """
    pcoll, jred = _REDUCERS[op]
    if p2p and hierarchical:
        raise ValueError("p2p and hierarchical are mutually exclusive "
                         "reduction schedules")
    if window is not None and op != "sum":
        raise NotImplementedError(
            f"windowed all-reduce supports op='sum' only, got {op!r}")
    if reduce_dim is not None:
        x = jred(x, axis=reduce_dim)
    extras = tuple(jnp.asarray(e) for e in extras)
    idx = None
    xw = x
    if window is not None:
        idx = _window_index(x.ndim, window, window_axes)
        xw = x[idx]

    if axis is None:
        red, ex = xw, extras
        out = compute() if compute is not None else None
    elif p2p:
        if group is None or not mesh_axes:
            raise ValueError("p2p=True needs group= and mesh_axes=")
        if len(tuple(mesh_axes)) > 1:
            raise ValueError("p2p ring reduction is single-axis")
        ax = _axis_arg(tuple(mesh_axes))
        nseg = group.axis_size(*mesh_axes)
        payload = (xw, *extras)
        if compute is None:
            packed = ring_allreduce(payload, ax, nseg, op=op, chunks=chunks)
            out = None
        else:
            packed, out = ring_allreduce(payload, ax, nseg, op=op,
                                         chunks=chunks, compute=compute)
        red, ex = packed[0], tuple(packed[1:])
    else:
        # emit the independent branch first: everything after has no
        # dependence on it, so it can run while the reduction is on the
        # wire (XLA's latency-hiding scheduler on TPU; harmless on CPU)
        out = compute() if compute is not None else None
        if hierarchical and op == "sum" and group is not None and mesh_axes:
            red = hierarchical_psum(xw, group, mesh_axes)
            ex = pcoll(extras, axis) if extras else ()
        elif extras:
            # pack the scalars INTO the window payload: one collective
            # op, one rendezvous (a tuple psum lowers to one all-reduce
            # per operand — as expensive as separate reductions)
            dt = jnp.result_type(xw.dtype, *[e.dtype for e in extras])
            packed = jnp.concatenate(
                [jnp.ravel(xw).astype(dt)] +
                [jnp.reshape(e, (1,)).astype(dt) for e in extras])
            packed = pcoll(packed, axis)
            n = xw.size
            red = packed[:n].reshape(xw.shape).astype(xw.dtype)
            ex = tuple(packed[n + i] if jnp.iscomplexobj(e)
                       else jnp.real(packed[n + i]).astype(e.dtype)
                       for i, e in enumerate(extras))
        else:
            red = pcoll(xw, axis)
            ex = ()
    if idx is not None:
        red = jnp.zeros_like(x).at[idx].set(red)
    return red, ex, out


def all_gather(x, *, dim: int | None = None, axis=None, tiled: bool = True):
    """MPI_Allgather: every device ends up with the whole logical array.

    Eager form: SegmentedArray -> CLONE container of the logical array
    (gather + bcast collapsed into one resharding collective; padding is
    stripped and block-cyclic order undone like ``gather``).  The gather
    dim is the container's own segmented dim — passing a different
    ``dim`` is an error.
    In-shard_map form: ``lax.all_gather`` of the local shard along
    ``dim`` (default 0); ``axis=None`` degenerates to the identity.
    """
    if isinstance(x, SegmentedArray):
        seg = x
        if dim is not None and dim != seg.dim:
            raise ValueError(f"eager all_gather concatenates the container's "
                             f"segmented dim ({seg.dim}); got dim={dim}")
        full = gather(seg)          # already replicated over the group
        return SegmentedArray(full, seg.group, Policy.CLONE, seg.dim,
                              seg.mesh_axes,
                              orig_len=full.shape[seg.dim] if full.ndim
                              else None)
    if axis is None:
        return x
    return lax.all_gather(x, axis, axis=0 if dim is None else dim,
                          tiled=tiled)


def copy(src: SegmentedArray, *, policy: Policy | None = None,
         dim: int | None = None,
         mesh_axes: tuple[str, ...] | None = None,
         block: int | None = None, halo: int | None = None) -> SegmentedArray:
    """Segmented-to-segmented copy (paper Fig. 3), i.e. re-segmentation.

    Same policy/dim -> pure device-to-device copy; otherwise XLA inserts
    the minimal collective (all-gather / all-to-all / permute) — the
    library's job in the paper of picking the best transfer path.

    Metadata is validated and rebuilt for the destination layout: a
    block-cyclic endpoint, a change of segmented dim, or re-splitting a
    CLONE (whose data was never padded for the new dim) all go through
    the logical array so ``orig_len``/``block``/``halo`` stay truthful.
    """
    policy = src.policy if policy is None else policy
    dim = src.dim if dim is None else dim
    mesh_axes = src.mesh_axes if mesh_axes is None else mesh_axes
    if policy is Policy.BLOCK:
        block = src.block if block is None else block
        if block is None:
            raise ValueError("copy to BLOCK requires block=")
    if halo is not None and policy is not Policy.OVERLAP2D:
        raise ValueError("halo= is only meaningful for OVERLAP2D targets")
    if halo is None and policy is Policy.OVERLAP2D:
        halo = src.halo

    if (Policy.BLOCK in (policy, src.policy) or dim != src.dim
            or tuple(mesh_axes) != tuple(src.mesh_axes)
            or (src.policy is Policy.CLONE and policy is not Policy.CLONE)):
        # element order (block-cyclic) or padding metadata changes:
        # rebuild from the logical array so the ctor re-derives it.
        return segment(gather(src), src.group, policy=policy, dim=dim,
                       mesh_axes=mesh_axes, block=block,
                       halo=0 if halo is None else halo)

    new_halo = halo if policy is Policy.OVERLAP2D else 0
    dst = SegmentedArray(src.data, src.group, policy, dim, mesh_axes,
                         orig_len=src.orig_len, block=None, halo=new_halo)
    return dst.with_data(jax.device_put(src.data, dst.sharding))


def all_to_all(seg: SegmentedArray, new_dim: int) -> SegmentedArray:
    """Re-segment from ``seg.dim`` to ``new_dim`` with an all-to-all
    (MPI_Alltoall — the natural extension of the paper's verb set; used
    for MoE dispatch and FFT transposes).

    The segmentation metadata is rebuilt for the post-transpose layout:
    ``new_dim`` is padded so it tiles across the group and its
    pre-padding length becomes the new ``orig_len``; the old segmented
    dim's padding (now unsegmented) is sliced away so the container stays
    truthful about its logical extent.
    """
    if seg.policy is not Policy.NATURAL:
        raise ValueError(f"all_to_all requires a NATURAL container, "
                         f"got {seg.policy}")
    if new_dim == seg.dim:
        return seg
    ax = _axis_arg(seg.mesh_axes)
    data, new_orig = _pad_to(seg.data, new_dim, seg.nseg)

    def body(x):
        return lax.all_to_all(x, ax, split_axis=new_dim, concat_axis=seg.dim,
                              tiled=True)

    out = [None] * data.ndim
    out[new_dim] = ax
    data = compat.shard_map(body, mesh=seg.group.mesh,
                            in_specs=seg.pspec, out_specs=P(*out))(data)
    if seg.orig_len is not None and seg.orig_len != data.shape[seg.dim]:
        # old-dim padding sits at the global tail; it is local to every
        # shard after the transpose, so the slice needs no communication.
        data = lax.slice_in_dim(data, 0, seg.orig_len, axis=seg.dim)
    import dataclasses
    return dataclasses.replace(seg, data=data, dim=new_dim,
                               orig_len=new_orig)


def reduce_scatter(seg: SegmentedArray, op: str = "sum") -> SegmentedArray:
    """Reduce the segments and leave the result segmented along dim 0 of
    the merged array (MPI_Reduce_scatter)."""
    if op != "sum":
        raise NotImplementedError("reduce_scatter supports sum")
    ax = _axis_arg(seg.mesh_axes)
    nseg = seg.nseg
    merged_len = [d for i, d in enumerate(seg.data.shape) if i != seg.dim][0]
    padded = math.ceil(merged_len / nseg) * nseg

    def body(x):
        x = jnp.sum(x, axis=seg.dim)
        if padded != merged_len:
            pad = [(0, 0)] * x.ndim
            pad[0] = (0, padded - merged_len)
            x = jnp.pad(x, pad)
        return lax.psum_scatter(x, ax, scatter_dimension=0, tiled=True)

    merged_ndim = seg.data.ndim - 1
    out = [None] * merged_ndim
    out[0] = ax
    data = compat.shard_map(body, mesh=seg.group.mesh,
                            in_specs=seg.pspec, out_specs=P(*out))(seg.data)
    return SegmentedArray(data, seg.group, Policy.NATURAL, 0, seg.mesh_axes,
                          orig_len=merged_len)
