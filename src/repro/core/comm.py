"""MPI-like communication verbs over segmented containers (paper §2.3).

The paper implements a subset of the MPI standard routines for segmented
containers: copy, scatter, gather, broadcast, reduce (Fig. 3), with the
transfer path chosen by topology (P2P inside a PCIe domain, host-staged
across IOHs).  Here every verb lowers to ``shard_map`` + ``jax.lax``
collectives, and the topology split becomes the ICI/DCN axis split:
``hierarchical=True`` decomposes an all-reduce into
reduce-scatter(ICI) -> all-reduce(DCN) -> all-gather(ICI), which moves
``1/n_ici`` of the bytes over the slow inter-pod links — the TPU analogue
of the paper's staged cross-IOH reduction.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .runtime import DeviceGroup, current_group
from .segmented import Policy, SegmentedArray, gather, segment

# re-export container-level scatter/gather as comm verbs (Fig. 3 naming)
scatter = segment
gather = gather

_REDUCERS = {
    "sum": (lax.psum, jnp.sum),
    "max": (lax.pmax, jnp.max),
    "min": (lax.pmin, jnp.min),
}


def broadcast(x, group: DeviceGroup | None = None) -> SegmentedArray:
    """Broadcast a local array to every device (-> CLONE container)."""
    return segment(x, group, policy=Policy.CLONE)


def _axis_arg(mesh_axes: Sequence[str]):
    return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]


def reduce(seg: SegmentedArray, op: str = "sum") -> jax.Array:
    """Merge the segments elementwise into one local array (paper Fig. 3/5:
    'reduce merges one matrix per GPU' — the segmented dim is reduced).
    """
    pcoll, jred = _REDUCERS[op]

    def body(x):
        x = jred(x, axis=seg.dim)
        return pcoll(x, _axis_arg(seg.mesh_axes))

    out_spec = P(*[None] * (seg.data.ndim - 1))
    return jax.shard_map(body, mesh=seg.group.mesh,
                         in_specs=seg.pspec, out_specs=out_spec)(seg.data)


def all_reduce(seg: SegmentedArray, op: str = "sum",
               hierarchical: bool = False) -> SegmentedArray:
    """Like ``reduce`` but the result is CLONEd on every device
    (the paper's Σ ρ_g block-wise all-reduce)."""
    pcoll, jred = _REDUCERS[op]
    group = seg.group

    def body(x):
        x = jred(x, axis=seg.dim)
        if hierarchical and op == "sum":
            return hierarchical_psum(x, group, seg.mesh_axes)
        return pcoll(x, _axis_arg(seg.mesh_axes))

    out_spec = P(*[None] * (seg.data.ndim - 1))
    # check_vma=False: after the in-pod all-gather the value IS replicated,
    # but JAX's varying-axes inference cannot prove it.
    out = jax.shard_map(body, mesh=group.mesh, in_specs=seg.pspec,
                        out_specs=out_spec, check_vma=False)(seg.data)
    return SegmentedArray(out, group, Policy.CLONE, 0, seg.mesh_axes)


def hierarchical_psum(x: jax.Array, group: DeviceGroup,
                      mesh_axes: Sequence[str]) -> jax.Array:
    """psum decomposed by topology; call INSIDE a shard_map body.

    reduce-scatter over ICI axes, all-reduce over DCN axes, all-gather
    back over ICI — so each slow (DCN) link carries only 1/n_ici of the
    payload.  Falls back to a flat psum when the leading dim does not
    tile.
    """
    ici = [a for a in mesh_axes if a in group.ici_axes]
    dcn = [a for a in mesh_axes if a in group.dcn_axes]
    n_ici = math.prod(group.mesh.shape[a] for a in ici) if ici else 1
    if not dcn or not ici or x.shape[0] % n_ici != 0:
        return lax.psum(x, _axis_arg(tuple(mesh_axes)))
    for a in ici:
        x = lax.psum_scatter(x, a, scatter_dimension=0, tiled=True)
    for a in dcn:
        x = lax.psum(x, a)
    for a in reversed(ici):
        x = lax.all_gather(x, a, axis=0, tiled=True)
    return x


def copy(src: SegmentedArray, *, policy: Policy | None = None,
         dim: int | None = None,
         mesh_axes: tuple[str, ...] | None = None,
         block: int | None = None) -> SegmentedArray:
    """Segmented-to-segmented copy (paper Fig. 3), i.e. re-segmentation.

    Same policy/dim -> pure device-to-device copy; otherwise XLA inserts
    the minimal collective (all-gather / all-to-all / permute) — the
    library's job in the paper of picking the best transfer path.
    """
    policy = src.policy if policy is None else policy
    dim = src.dim if dim is None else dim
    mesh_axes = src.mesh_axes if mesh_axes is None else mesh_axes
    if Policy.BLOCK in (policy, src.policy):
        # block-cyclic layouts permute element order: go through gather
        return segment(gather(src), src.group, policy=policy, dim=dim,
                       mesh_axes=mesh_axes, block=block or src.block)
    dst = SegmentedArray(src.data, src.group, policy, dim, mesh_axes,
                         orig_len=src.orig_len, halo=src.halo)
    return dst.with_data(jax.device_put(src.data, dst.sharding))


def all_to_all(seg: SegmentedArray, new_dim: int) -> SegmentedArray:
    """Re-segment from ``seg.dim`` to ``new_dim`` with an all-to-all
    (MPI_Alltoall — the natural extension of the paper's verb set; used
    for MoE dispatch and FFT transposes)."""
    ax = _axis_arg(seg.mesh_axes)

    def body(x):
        n = seg.nseg
        return lax.all_to_all(x, ax, split_axis=new_dim, concat_axis=seg.dim,
                              tiled=True)

    in_spec = seg.pspec
    out = list([None] * seg.data.ndim)
    out[new_dim] = ax
    out_spec = P(*out)
    data = jax.shard_map(body, mesh=seg.group.mesh,
                         in_specs=in_spec, out_specs=out_spec)(seg.data)
    import dataclasses
    return dataclasses.replace(seg, data=data, dim=new_dim,
                               orig_len=data.shape[new_dim])


def reduce_scatter(seg: SegmentedArray, op: str = "sum") -> SegmentedArray:
    """Reduce the segments and leave the result segmented along dim 0 of
    the merged array (MPI_Reduce_scatter)."""
    if op != "sum":
        raise NotImplementedError("reduce_scatter supports sum")
    ax = _axis_arg(seg.mesh_axes)
    nseg = seg.nseg
    merged_len = [d for i, d in enumerate(seg.data.shape) if i != seg.dim][0]
    padded = math.ceil(merged_len / nseg) * nseg

    def body(x):
        x = jnp.sum(x, axis=seg.dim)
        if padded != merged_len:
            pad = [(0, 0)] * x.ndim
            pad[0] = (0, padded - merged_len)
            x = jnp.pad(x, pad)
        return lax.psum_scatter(x, ax, scatter_dimension=0, tiled=True)

    merged_ndim = seg.data.ndim - 1
    out = [None] * merged_ndim
    out[0] = ax
    data = jax.shard_map(body, mesh=seg.group.mesh,
                         in_specs=seg.pspec, out_specs=P(*out))(seg.data)
    return SegmentedArray(data, seg.group, Policy.NATURAL, 0, seg.mesh_axes,
                          orig_len=merged_len)
