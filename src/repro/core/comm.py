"""MPI-like communication verbs over segmented containers (paper §2.3).

The paper implements a subset of the MPI standard routines for segmented
containers: copy, scatter, gather, broadcast, reduce (Fig. 3), with the
transfer path chosen by topology (P2P inside a PCIe domain, host-staged
across IOHs).  Here every verb lowers to ``shard_map`` + ``jax.lax``
collectives, and the topology split becomes the ICI/DCN axis split:
``hierarchical=True`` decomposes an all-reduce into
reduce-scatter(ICI) -> all-reduce(DCN) -> all-gather(ICI), which moves
``1/n_ici`` of the bytes over the slow inter-pod links — the TPU analogue
of the paper's staged cross-IOH reduction.

Dual calling forms
------------------
Every reduction verb works both **eagerly** on a ``SegmentedArray`` (the
verb wraps its own ``shard_map``) and **inside a shard_map body** on the
per-device shard (pass the reduction ``axis`` name; ``axis=None`` means
single-program execution and degenerates to the local math).  This is
what lets whole algorithms — NLINV's Newton/CG loop — be written once
against the verbs and launched either way.

Transfer schedules (ISSUE 6)
----------------------------
Every eager verb compiles its shard_map program ONCE per layout through
the shared :class:`repro.core.plan.PlanCache` (key: verb + ``seg_token``
+ the chosen schedule + its size threshold), so the steady state of a
frame loop dispatches a cached executable instead of re-tracing.  On top
of plan caching, the schedules themselves are topology/bandwidth-aware:

* ``broadcast`` above ``BCAST_SCATTER_MIN_BYTES`` uploads 1/n of the
  payload per device and replicates on-fabric with chunked all-gathers,
  minor-to-major (ICI submesh first, DCN across) — instead of shipping
  the full array to every device from the host;
* ``copy`` picks a direct collective per (src, dst) layout pair (see
  ``copy_route``) and only falls back to the gather-then-resegment
  round-trip for genuinely global relayouts;
* ``reduce``/``allreduce`` payloads above ``REDUCE_RS_AG_MIN_BYTES``
  decompose Rabenseifner-style into reduce-scatter + all-gather
  (each link carries ~2·(n-1)/n of one payload instead of n-1 full
  payloads in the naive tree).

The bandwidth-splitting decompositions fire only on discrete-memory
platforms: on the host-simulated CPU mesh (``group.unified_memory``)
every device shares host RAM, so direct ``device_put``/``psum`` already
moves the minimum bytes and the decompositions would only add collective
rounds.  ``BCAST_SCHEDULE``/``REDUCE_SCHEDULE`` force a choice (parity
tests exercise both schedules everywhere).

These module-level functions are the verb *implementations*; the stable
public surface is the group-bound method set of ``env.Communicator``
(and the fluent forms on ``SegmentedArray``), for which the re-exports
in ``repro.core`` are deprecated shims.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from . import compat
from .plan import Plan, PlanCache, default_cache, group_token, seg_token
from .runtime import DeviceGroup, current_group
from .segmented import (Policy, SegmentedArray, _block_cyclic_perm, _pad_to,
                        gather, segment)

# re-export container-level scatter/gather as comm verbs (Fig. 3 naming)
scatter = segment
gather = gather

_REDUCERS = {
    "sum": (lax.psum, jnp.sum),
    "max": (lax.pmax, jnp.max),
    "min": (lax.pmin, jnp.min),
}

_ELEMWISE = {"sum": jnp.add, "max": jnp.maximum, "min": jnp.minimum}

# schedule size thresholds (bytes).  Both are recorded in the PlanCache
# key and the plan meta, so changing them (or monkeypatching in a test)
# builds a distinct plan instead of silently reusing the old schedule.
BCAST_SCATTER_MIN_BYTES = 1 << 16   # below: host device_put replicate
REDUCE_RS_AG_MIN_BYTES = 1 << 16    # below: flat psum
BCAST_CHUNKS = 4                    # independent in-flight fan-out payloads

# Schedule overrides (None = topology-aware auto).  Auto picks the
# decomposed schedules only on discrete-memory platforms
# (``group.unified_memory`` False) AND above the size thresholds; on the
# host-simulated CPU mesh every device shares host RAM, so direct
# ``device_put``/``psum`` is bandwidth-optimal and the decompositions
# only add collective rounds.  Tests and experiments force a schedule by
# setting these module flags:
#   comm.BCAST_SCHEDULE  in {None, "device_put", "scatter_allgather"}
#   comm.REDUCE_SCHEDULE in {None, "psum", "rs_ag"}
BCAST_SCHEDULE: str | None = None
REDUCE_SCHEDULE: str | None = None


def _axis_arg(mesh_axes: Sequence[str]):
    return mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]


def _axspec(mesh_axes: Sequence[str]):
    """The PartitionSpec slot for one dim sharded over ``mesh_axes``."""
    return tuple(mesh_axes) if len(mesh_axes) > 1 else mesh_axes[0]


def _plan(key: tuple, build_fn: Callable, *, op: str, meta: dict | None = None,
          cache: PlanCache | None = None) -> Plan:
    """Look up / build a transfer plan in the (shared) plan cache."""
    cache = default_cache() if cache is None else cache
    md = dict(meta or {})

    def build():
        return Plan(key=key, fn=build_fn(), lib="core", op=op, meta=md)

    return cache.get_or_build(key, build)


def _linear_index(mesh_axes: Sequence[str], group: DeviceGroup):
    """This device's rank linearized over ``mesh_axes`` (major-to-minor,
    matching how a PartitionSpec slot ``(a1, a2)`` splits a dim); call
    inside a shard_map body."""
    i = 0
    for a in mesh_axes:
        i = i * group.mesh.shape[a] + lax.axis_index(a)
    return i


def _psum_rs_ag(x: jax.Array, mesh_axes: Sequence[str]) -> jax.Array:
    """psum decomposed Rabenseifner-style: reduce-scatter then all-gather
    along dim 0 — each link carries ~2·(n-1)/n of one payload instead of
    the naive tree's (n-1) full payloads.  Call inside a shard_map body;
    dim 0 must tile over the product of ``mesh_axes`` (the plan layer
    checks this before choosing the schedule)."""
    for a in mesh_axes:
        x = lax.psum_scatter(x, a, scatter_dimension=0, tiled=True)
    for a in reversed(mesh_axes):
        x = lax.all_gather(x, a, axis=0, tiled=True)
    return x


def bcast_schedule(group: DeviceGroup, mesh_axes: Sequence[str],
                   nbytes: int) -> str:
    """The broadcast schedule for this (group, payload):
    ``scatter_allgather`` on discrete-memory platforms above
    ``BCAST_SCATTER_MIN_BYTES``, else the direct replicated
    ``device_put``.  ``BCAST_SCHEDULE`` forces a choice."""
    if group.axis_size(*mesh_axes) == 1:
        return "device_put"
    if BCAST_SCHEDULE is not None:
        return BCAST_SCHEDULE
    if group.unified_memory or nbytes < BCAST_SCATTER_MIN_BYTES:
        return "device_put"
    return "scatter_allgather"


def _reduce_schedule(seg: SegmentedArray, op: str) -> tuple[str, int]:
    """Pick the reduction schedule for a merged payload: ``rs_ag`` when
    the group has discrete memories, the payload is big enough and its
    leading dim tiles over the group, else a flat ``psum``.
    ``REDUCE_SCHEDULE`` forces a choice (tiling still required).
    Returns (schedule, payload_bytes)."""
    merged = [d for i, d in enumerate(seg.data.shape) if i != seg.dim]
    nbytes = int(math.prod(merged)) * seg.data.dtype.itemsize
    eligible = (op == "sum" and seg.nseg > 1 and bool(merged)
                and merged[0] % seg.nseg == 0)
    if REDUCE_SCHEDULE is not None:
        return (("rs_ag" if REDUCE_SCHEDULE == "rs_ag" and eligible
                 else "psum"), nbytes)
    if (eligible and not seg.group.unified_memory
            and nbytes >= REDUCE_RS_AG_MIN_BYTES):
        return "rs_ag", nbytes
    return "psum", nbytes


# ---------------------------------------------------------------------------
# broadcast (paper Fig. 3/5): host upload + on-fabric replication
# ---------------------------------------------------------------------------

def plan_broadcast(shape, dtype, group: DeviceGroup,
                   mesh_axes: tuple[str, ...],
                   cache: PlanCache | None = None) -> Plan:
    """Plan the scatter+all-gather broadcast: the caller uploads the
    flattened payload sharded 1/n per device; the plan's ``fn``
    replicates it with chunked tiled all-gathers, minor-to-major mesh
    axis — so with the conventional DCN-major mesh the submesh assembles
    over ICI first and only assembled slabs cross the DCN boundary."""
    nseg = group.axis_size(*mesh_axes)
    size = int(math.prod(shape))
    padded = math.ceil(size / nseg) * nseg
    shard = padded // nseg
    chunks = next(c for c in (BCAST_CHUNKS, 2, 1) if shard % c == 0 and c <= shard)
    key = ("transfer", "bcast", tuple(shape), str(jnp.dtype(dtype)),
           group_token(group), tuple(mesh_axes),
           BCAST_SCATTER_MIN_BYTES, chunks)

    def build():
        order = tuple(reversed(mesh_axes))   # minor-to-major: inverts split

        def gather_all(v):
            for a in order:
                v = lax.all_gather(v, a, axis=0, tiled=True)
            return v

        def body(v):
            if chunks == 1:
                return gather_all(v)
            # independent in-flight fan-out rounds the scheduler can
            # pipeline; re-interleave to restore global order.
            gathered = [gather_all(p) for p in jnp.split(v, chunks, axis=0)]
            parts = [g.reshape(nseg, -1) for g in gathered]
            return jnp.concatenate(parts, axis=1).reshape(-1)

        sm = compat.shard_map(body, mesh=group.mesh,
                              in_specs=P(_axspec(mesh_axes)), out_specs=P(),
                              check_vma=False)

        def fn(v):
            return sm(v)[:size].reshape(shape)

        return jax.jit(fn)

    ici = tuple(a for a in mesh_axes if a in group.ici_axes)
    dcn = tuple(a for a in mesh_axes if a in group.dcn_axes)
    return _plan(key, build, op="bcast", cache=cache,
                 meta={"schedule": "scatter_allgather", "chunks": chunks,
                       "threshold_bytes": BCAST_SCATTER_MIN_BYTES,
                       "ici_axes": ici, "dcn_axes": dcn})


def broadcast(x, group: DeviceGroup | None = None, *,
              mesh_axes: tuple[str, ...] = ("data",),
              cache: PlanCache | None = None) -> SegmentedArray:
    """Broadcast a local array to every device (-> CLONE container).

    Small payloads (or 1-device groups) replicate directly from the host
    (``segment(..., CLONE)``: n× the bytes over the host link).  Above
    ``BCAST_SCATTER_MIN_BYTES`` the host uploads only 1/n per device and
    the replication happens on-fabric via ``plan_broadcast``'s chunked
    hierarchical all-gather schedule.
    """
    group = current_group(group)
    mesh_axes = tuple(mesh_axes)
    nseg = group.axis_size(*mesh_axes)
    if isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer):
        xh = x
    elif isinstance(x, jax.core.Tracer):
        return segment(x, group, policy=Policy.CLONE, mesh_axes=mesh_axes)
    else:
        xh = np.asarray(x)
        dt = jax.dtypes.canonicalize_dtype(xh.dtype)
        if xh.dtype != dt:
            xh = xh.astype(dt)
    nbytes = int(math.prod(xh.shape)) * xh.dtype.itemsize
    if (xh.ndim == 0
            or bcast_schedule(group, mesh_axes, nbytes) == "device_put"):
        return segment(xh, group, policy=Policy.CLONE, mesh_axes=mesh_axes)
    plan = plan_broadcast(xh.shape, xh.dtype, group, mesh_axes, cache=cache)
    size = int(math.prod(xh.shape))
    padded = math.ceil(size / nseg) * nseg
    if isinstance(xh, jax.Array):
        flat = jnp.pad(jnp.ravel(xh), (0, padded - size))
    else:
        flat = np.pad(np.ravel(xh), (0, padded - size))
    shards = jax.device_put(flat, group.sharding(P(_axspec(mesh_axes))))
    data = plan(shards)
    return SegmentedArray(data, group, Policy.CLONE, 0, mesh_axes,
                          orig_len=xh.shape[0])


def plan_reduce(seg: SegmentedArray, op: str = "sum",
                cache: PlanCache | None = None) -> Plan:
    """Plan the eager ``reduce``: one jitted shard_map program per
    (layout, op, schedule).  Large sum payloads whose leading merged dim
    tiles over the group go reduce-scatter + all-gather (Rabenseifner);
    everything else is a flat psum/pmax/pmin.  ``meta`` records the
    choice for bench artifacts."""
    schedule, nbytes = _reduce_schedule(seg, op)
    key = ("transfer", "reduce", seg_token(seg), op, schedule,
           REDUCE_RS_AG_MIN_BYTES)

    def build():
        pcoll, jred = _REDUCERS[op]
        maxes = tuple(seg.mesh_axes)
        sdim = seg.dim

        def body(x):
            x = jred(x, axis=sdim)
            if schedule == "rs_ag":
                return _psum_rs_ag(x, maxes)
            return pcoll(x, _axis_arg(maxes))

        out_spec = P(*[None] * (seg.data.ndim - 1))
        sm = compat.shard_map(body, mesh=seg.group.mesh, in_specs=seg.pspec,
                              out_specs=out_spec, check_vma=False)
        return jax.jit(sm)

    return _plan(key, build, op="reduce", cache=cache,
                 meta={"schedule": schedule, "payload_bytes": nbytes,
                       "threshold_bytes": REDUCE_RS_AG_MIN_BYTES})


def reduce(seg: SegmentedArray, op: str = "sum",
           cache: PlanCache | None = None) -> jax.Array:
    """Merge the segments elementwise into one local array (paper Fig. 3/5:
    'reduce merges one matrix per GPU' — the segmented dim is reduced).
    """
    return plan_reduce(seg, op, cache=cache)(seg.data)


def all_reduce(seg: SegmentedArray, op: str = "sum",
               hierarchical: bool = False,
               p2p: bool = False) -> SegmentedArray:
    """Like ``reduce`` but the result is CLONEd on every device
    (the paper's Σ ρ_g block-wise all-reduce).  ``p2p=True`` runs the
    reduction as a ring of ``ppermute`` transfers instead of one psum —
    the paper's explicit P2P schedule."""
    return all_reduce_window(seg, None, op=op, hierarchical=hierarchical,
                             p2p=p2p)


def _window_index(ndim: int, window, axes=None) -> tuple:
    """Slice tuple selecting ``window`` ((lo, hi) pairs) on the trailing
    dims of a rank-``ndim`` array (or on explicit ``axes``)."""
    if axes is None:
        axes = tuple(range(ndim - len(window), ndim))
    idx: list = [slice(None)] * ndim
    for ax, (lo, hi) in zip(axes, window):
        idx[ax] = slice(lo, hi)
    return tuple(idx)


def all_reduce_window(x, window=None, *, op: str = "sum",
                      axis=None, reduce_dim: int | None = None,
                      hierarchical: bool = False, window_axes=None,
                      p2p: bool = False,
                      group: DeviceGroup | None = None,
                      mesh_axes: Sequence[str] | None = None):
    """Windowed all-reduce — generalizes the paper's ``kern_all_red_p2p_2d``.

    The paper's NLINV port observes that after masking with M_Omega only
    a centered 2-D section of Σ_g ρ_g is nonzero, so only that window is
    put on the wire (4x fewer bytes for the FOV quarter).  This verb is
    that trick as a first-class primitive: reduce ``reduce_dim`` locally,
    all-reduce only ``window`` ((lo, hi) per trailing dim, or explicit
    ``window_axes``), and return the result scattered back into zeros.
    ``window=None`` is a plain all-reduce.

    Eager form: ``x`` is a SegmentedArray — returns a CLONE container
    whose ``reduce_dim`` (default: the segmented dim) has been summed
    away globally.

    In-shard_map form: ``x`` is the local shard; ``axis`` names the mesh
    axis to reduce over (``axis=None``: no collective — the single-device
    degenerate case).  ``hierarchical=True`` with ``group``/``mesh_axes``
    stages the window psum over ICI then DCN (paper's cross-IOH path).
    ``p2p=True`` (with ``group``/``mesh_axes``) replaces the psum with a
    ring of ``ppermute`` transfers — the paper's ``kern_all_red_p2p_2d``
    explicit P2P schedule, numerically equivalent up to float summation
    order (each rank accumulates its neighbours in ring order).
    """
    if isinstance(x, SegmentedArray):
        seg = x
        rdim = seg.dim if reduce_dim is None else reduce_dim
        if rdim != seg.dim:
            raise ValueError(
                f"eager all_reduce_window reduces the segmented dim "
                f"({seg.dim}); got reduce_dim={rdim}")
        maxes = tuple(seg.mesh_axes)
        plain = window is None and not p2p and not hierarchical
        schedule, nbytes = (_reduce_schedule(seg, op) if plain
                            else ("psum", None))
        wkey = (None if window is None
                else tuple(tuple(w) for w in window))
        wxkey = None if window_axes is None else tuple(window_axes)
        key = ("transfer", "allreduce", seg_token(seg), wkey, wxkey, op,
               rdim, bool(hierarchical), bool(p2p), schedule,
               REDUCE_RS_AG_MIN_BYTES)

        def build():
            body = partial(_all_reduce_window_local, window=window, op=op,
                           axis=_axis_arg(maxes), reduce_dim=rdim,
                           hierarchical=hierarchical, window_axes=window_axes,
                           p2p=p2p, group=seg.group, mesh_axes=maxes,
                           rs_ag=(schedule == "rs_ag"))
            out_spec = P(*[None] * (seg.data.ndim - 1))
            # check_vma=False: the windowed scatter-into-zeros defeats
            # JAX's replication inference though the result is replicated.
            sm = compat.shard_map(body, mesh=seg.group.mesh,
                                  in_specs=seg.pspec, out_specs=out_spec,
                                  check_vma=False)
            return jax.jit(sm)

        plan = _plan(key, build, op="allreduce",
                     meta={"schedule": schedule, "payload_bytes": nbytes,
                           "threshold_bytes": REDUCE_RS_AG_MIN_BYTES,
                           "window": wkey, "p2p": p2p,
                           "hierarchical": hierarchical})
        out = plan(seg.data)
        return SegmentedArray(out, seg.group, Policy.CLONE, 0, maxes)
    return _all_reduce_window_local(x, window=window, op=op, axis=axis,
                                    reduce_dim=reduce_dim,
                                    hierarchical=hierarchical,
                                    window_axes=window_axes, p2p=p2p,
                                    group=group, mesh_axes=mesh_axes)


def _all_reduce_window_local(x, *, window, op, axis, reduce_dim,
                             hierarchical, window_axes, group, mesh_axes,
                             p2p=False, rs_ag=False):
    pcoll, jred = _REDUCERS[op]
    if p2p and hierarchical:
        raise ValueError("p2p and hierarchical are mutually exclusive "
                         "reduction schedules")
    if window is not None and op != "sum":
        # the scatter-back fill is zeros, which is only the identity of +
        raise NotImplementedError(
            f"windowed all-reduce supports op='sum' only, got {op!r}")
    if reduce_dim is not None:
        x = jred(x, axis=reduce_dim)

    def psum_part(v):
        if axis is None:
            return v
        if p2p:
            if group is None or not mesh_axes:
                raise ValueError("p2p=True needs group= and mesh_axes=")
            if len(tuple(mesh_axes)) > 1:
                raise ValueError("p2p ring reduction is single-axis")
            return ring_allreduce(v, _axis_arg(tuple(mesh_axes)),
                                  group.axis_size(*mesh_axes), op=op)
        if hierarchical and op == "sum" and group is not None and mesh_axes:
            return hierarchical_psum(v, group, mesh_axes)
        if rs_ag and op == "sum" and mesh_axes:
            # plan layer already checked dim-0 tiles over the group
            return _psum_rs_ag(v, tuple(mesh_axes))
        return pcoll(v, axis)

    if window is None:
        return psum_part(x)
    idx = _window_index(x.ndim, window, window_axes)
    return jnp.zeros_like(x).at[idx].set(psum_part(x[idx]))


def vdot(x, y, *, axis=None, policies=None):
    """Segmented inner product ⟨x, y⟩ over mixed CLONE/NATURAL pytrees
    (the 'scalar products of all data' CG entry of paper Table 1).

    Eager form: ``x``/``y`` are pytrees of SegmentedArrays — the vdot of
    the logical arrays.  No explicit collective: the global contraction
    already spans all shards.

    In-shard_map form: leaves are local shards, ``axis`` names the mesh
    axis, and ``policies`` is a matching pytree of ``Policy`` leaves
    saying which components are CLONE (replicated: counted once, never
    psum'd) versus segmented (partial products: one psum for all of
    them).  ``axis=None`` degenerates to the plain local vdot.
    """
    is_seg = lambda l: isinstance(l, SegmentedArray)
    xl, xdef = jax.tree.flatten(x, is_leaf=is_seg)
    yl, ydef = jax.tree.flatten(y, is_leaf=is_seg)
    if xdef != ydef:
        raise ValueError(f"vdot operands differ in structure: "
                         f"{xdef} vs {ydef}")
    if xl and all(is_seg(l) for l in xl):
        return sum(jnp.vdot(a.data, b.data) for a, b in zip(xl, yl))

    if policies is None:
        pols = [Policy.NATURAL] * len(xl)
    else:
        pols = jax.tree.leaves(
            policies, is_leaf=lambda p: isinstance(p, (Policy, tuple)))
        if len(pols) != len(xl):
            raise ValueError("policies pytree does not match operands")
    clone_part = shard_part = None
    for a, b, p in zip(xl, yl, pols):
        pol = p[0] if isinstance(p, tuple) else p
        v = jnp.vdot(a, b)
        if pol is Policy.CLONE:
            clone_part = v if clone_part is None else clone_part + v
        else:
            shard_part = v if shard_part is None else shard_part + v
    total = None
    if shard_part is not None:
        total = lax.psum(shard_part, axis) if axis is not None else shard_part
    if clone_part is not None:
        total = clone_part if total is None else total + clone_part
    return total


def hierarchical_psum(x: jax.Array, group: DeviceGroup,
                      mesh_axes: Sequence[str]) -> jax.Array:
    """psum decomposed by topology; call INSIDE a shard_map body.

    reduce-scatter over ICI axes, all-reduce over DCN axes, all-gather
    back over ICI — so each slow (DCN) link carries only 1/n_ici of the
    payload.  Falls back to a flat psum when the leading dim does not
    tile.
    """
    ici = [a for a in mesh_axes if a in group.ici_axes]
    dcn = [a for a in mesh_axes if a in group.dcn_axes]
    n_ici = math.prod(group.mesh.shape[a] for a in ici) if ici else 1
    if not dcn or not ici or x.shape[0] % n_ici != 0:
        return lax.psum(x, _axis_arg(tuple(mesh_axes)))
    for a in ici:
        x = lax.psum_scatter(x, a, scatter_dimension=0, tiled=True)
    for a in dcn:
        x = lax.psum(x, a)
    for a in reversed(ici):
        x = lax.all_gather(x, a, axis=0, tiled=True)
    return x


# ---------------------------------------------------------------------------
# point-to-point verbs (the paper's P2P transfer path inside a PCIe domain;
# on TPU: lax.ppermute over ICI neighbour links)
# ---------------------------------------------------------------------------

def ring_perm(nseg: int, offset: int = 1,
              wrap: bool = True) -> list[tuple[int, int]]:
    """(src, dst) pairs shifting every rank by ``offset`` around the ring.
    ``wrap=False`` drops the wrap-around edges (their receivers get the
    collective's zero fill) — the open-boundary form halo exchange uses."""
    if wrap:
        return [(i, (i + offset) % nseg) for i in range(nseg)]
    return [(i, i + offset) for i in range(nseg) if 0 <= i + offset < nseg]


def _p2p_eager(seg: SegmentedArray, perm) -> SegmentedArray:
    bad = [p for p in perm if not all(0 <= r < seg.nseg for r in p)]
    if bad:
        raise ValueError(f"send_recv perm pairs {bad} out of range for a "
                         f"{seg.nseg}-segment group")
    ax = _axis_arg(seg.mesh_axes)
    body = lambda xl: lax.ppermute(xl, ax, perm)
    out = compat.shard_map(body, mesh=seg.group.mesh, in_specs=seg.pspec,
                           out_specs=seg.pspec)(seg.data)
    return seg.with_data(out)


def send_recv(x, perm, *, axis=None):
    """MPI_Sendrecv over segments: for every ``(src, dst)`` pair, rank
    ``src``'s segment is shipped to rank ``dst``; ranks no pair sends to
    receive zeros (``lax.ppermute`` semantics — the paper's P2P copy).

    Eager form: ``x`` is a SegmentedArray — segments move between
    devices, the container metadata is unchanged.  In-shard_map form:
    ``x`` is the local shard and ``axis`` names the mesh axis.
    ``axis=None`` is the single-program degenerate case: identity if
    ``(0, 0)`` is in ``perm``, else zeros.
    """
    perm = [tuple(p) for p in perm]
    if isinstance(x, SegmentedArray):
        return _p2p_eager(x, perm)
    if axis is None:
        return x if (0, 0) in perm else jnp.zeros_like(x)
    return lax.ppermute(x, axis, perm)


def shift(x, offset: int = 1, *, wrap: bool = True, axis=None,
          nseg: int | None = None):
    """Ring shift: rank ``i``'s segment moves to rank ``i + offset``
    (modulo the group size when ``wrap``; otherwise the edge ranks
    receive zeros).  The canonical P2P pattern — halo exchange is two
    ``shift``s with ``wrap=False``.

    Eager form on a SegmentedArray; in-shard_map form needs ``axis`` and
    ``nseg`` (the axis size, static).  ``axis=None``/``nseg=None`` is the
    1-device degenerate case.
    """
    if isinstance(x, SegmentedArray):
        return _p2p_eager(x, ring_perm(x.nseg, offset, wrap))
    if nseg is None:
        if axis is not None:
            raise ValueError("in-shard_map shift needs nseg= (static axis size)")
        nseg = 1
    return send_recv(x, ring_perm(nseg, offset, wrap), axis=axis)


def ring_allreduce(x, axis, nseg: int, op: str = "sum", *,
                   chunks: int = 1, compute: Callable | None = None):
    """All-reduce as ``nseg - 1`` ring ppermutes — the transfer schedule
    of the paper's ``kern_all_red_p2p_2d``, built on the p2p verb layer.
    Call inside a shard_map body.  Equivalent to the psum up to float
    summation order (ranks accumulate neighbours in ring order, so
    replicas may differ in the last ulp).

    ``x`` may be a pytree (every leaf rides the same ring schedule).
    ``chunks > 1`` splits each leaf's leading dim into that many ring
    payloads, so the schedule has independent in-flight transfers the
    compiler can pipeline; the per-element accumulation order is
    unchanged (bitwise identical to the unchunked ring).
    ``compute`` is caller-supplied independent work (the 2017 follow-up's
    communication/computation overlap): it is emitted after the FIRST
    transfer round, so its ops have no data dependence on the remaining
    rounds and the scheduler is free to run them while transfers are in
    flight.  With ``compute`` the return value is ``(reduced, out)``.
    """
    jop = _ELEMWISE[op]
    perm = ring_perm(nseg, 1, wrap=True)
    leaves, treedef = jax.tree.flatten(x)

    def _split(leaf):
        leaf = jnp.asarray(leaf)
        if chunks <= 1 or leaf.ndim == 0 or leaf.shape[0] < chunks:
            return [leaf]
        return jnp.array_split(leaf, chunks, axis=0)

    pieces = [_split(leaf) for leaf in leaves]
    flat = [p for ps in pieces for p in ps]
    out = None
    accs, bufs = list(flat), list(flat)
    for step in range(nseg - 1):
        bufs = [lax.ppermute(b, axis, perm) for b in bufs]
        accs = [jop(a, b) for a, b in zip(accs, bufs)]
        if step == 0 and compute is not None:
            out = compute()
    if compute is not None and out is None:     # nseg == 1 degenerate ring
        out = compute()
    merged, k = [], 0
    for ps in pieces:
        n = len(ps)
        merged.append(accs[k] if n == 1
                      else jnp.concatenate(accs[k:k + n], axis=0))
        k += n
    red = jax.tree.unflatten(treedef, merged)
    return red if compute is None else (red, out)


def all_reduce_overlap(x, window=None, *, op: str = "sum", axis=None,
                       reduce_dim: int | None = None, window_axes=None,
                       extras: tuple = (), compute: Callable | None = None,
                       p2p: bool = False, chunks: int = 2,
                       hierarchical: bool = False,
                       group: DeviceGroup | None = None,
                       mesh_axes: Sequence[str] | None = None):
    """Windowed all-reduce fused with scalar piggybacks and overlapped
    caller compute — the communication half of the fused NLINV hot path.

    Generalizes ``all_reduce_window`` (in-shard_map / single-program
    form) three ways, all motivated by the CG body of the 2017 follow-up:

    * ``extras``: additional (typically scalar) partials reduced IN THE
      SAME collective as the window — one variadic all-reduce instead of
      one per quantity (the CG <p, Ap> scalar rides the Σ_g rho_g wire);
    * ``compute``: independent work emitted between the local partials
      and the collective's consumers, so the scheduler can overlap it
      with the reduction (the ``dchat`` FFT branch of DG^H);
    * ``p2p=True``: the reduction runs as the chunked
      ``kern_all_red_p2p_2d`` ring schedule with ``compute`` interleaved
      after the first transfer round (``chunks`` ring payloads).

    Returns ``(reduced, extras_out, compute_out)``; ``compute_out`` is
    ``None`` when no ``compute`` is given.  ``axis=None`` degenerates to
    the local math (single-program form).
    """
    pcoll, jred = _REDUCERS[op]
    if p2p and hierarchical:
        raise ValueError("p2p and hierarchical are mutually exclusive "
                         "reduction schedules")
    if window is not None and op != "sum":
        raise NotImplementedError(
            f"windowed all-reduce supports op='sum' only, got {op!r}")
    if reduce_dim is not None:
        x = jred(x, axis=reduce_dim)
    extras = tuple(jnp.asarray(e) for e in extras)
    idx = None
    xw = x
    if window is not None:
        idx = _window_index(x.ndim, window, window_axes)
        xw = x[idx]

    if axis is None:
        red, ex = xw, extras
        out = compute() if compute is not None else None
    elif p2p:
        if group is None or not mesh_axes:
            raise ValueError("p2p=True needs group= and mesh_axes=")
        if len(tuple(mesh_axes)) > 1:
            raise ValueError("p2p ring reduction is single-axis")
        ax = _axis_arg(tuple(mesh_axes))
        nseg = group.axis_size(*mesh_axes)
        payload = (xw, *extras)
        if compute is None:
            packed = ring_allreduce(payload, ax, nseg, op=op, chunks=chunks)
            out = None
        else:
            packed, out = ring_allreduce(payload, ax, nseg, op=op,
                                         chunks=chunks, compute=compute)
        red, ex = packed[0], tuple(packed[1:])
    else:
        # emit the independent branch first: everything after has no
        # dependence on it, so it can run while the reduction is on the
        # wire (XLA's latency-hiding scheduler on TPU; harmless on CPU)
        out = compute() if compute is not None else None
        if hierarchical and op == "sum" and group is not None and mesh_axes:
            red = hierarchical_psum(xw, group, mesh_axes)
            ex = pcoll(extras, axis) if extras else ()
        elif extras:
            # pack the scalars INTO the window payload: one collective
            # op, one rendezvous (a tuple psum lowers to one all-reduce
            # per operand — as expensive as separate reductions)
            dt = jnp.result_type(xw.dtype, *[e.dtype for e in extras])
            packed = jnp.concatenate(
                [jnp.ravel(xw).astype(dt)] +
                [jnp.reshape(e, (1,)).astype(dt) for e in extras])
            packed = pcoll(packed, axis)
            n = xw.size
            red = packed[:n].reshape(xw.shape).astype(xw.dtype)
            ex = tuple(packed[n + i] if jnp.iscomplexobj(e)
                       else jnp.real(packed[n + i]).astype(e.dtype)
                       for i, e in enumerate(extras))
        else:
            red = pcoll(xw, axis)
            ex = ()
    if idx is not None:
        red = jnp.zeros_like(x).at[idx].set(red)
    return red, ex, out


def all_gather(x, *, dim: int | None = None, axis=None, tiled: bool = True):
    """MPI_Allgather: every device ends up with the whole logical array.

    Eager form: SegmentedArray -> CLONE container of the logical array
    (gather + bcast collapsed into one resharding collective; padding is
    stripped and block-cyclic order undone like ``gather``).  The gather
    dim is the container's own segmented dim — passing a different
    ``dim`` is an error.
    In-shard_map form: ``lax.all_gather`` of the local shard along
    ``dim`` (default 0); ``axis=None`` degenerates to the identity.
    """
    if isinstance(x, SegmentedArray):
        seg = x
        if dim is not None and dim != seg.dim:
            raise ValueError(f"eager all_gather concatenates the container's "
                             f"segmented dim ({seg.dim}); got dim={dim}")
        full = gather(seg)          # already replicated over the group
        return SegmentedArray(full, seg.group, Policy.CLONE, seg.dim,
                              seg.mesh_axes,
                              orig_len=full.shape[seg.dim] if full.ndim
                              else None)
    if axis is None:
        return x
    return lax.all_gather(x, axis, axis=0 if dim is None else dim,
                          tiled=tiled)


# ---------------------------------------------------------------------------
# copy (paper Fig. 3): re-segmentation via direct per-layout collectives
# ---------------------------------------------------------------------------

_SPLIT = (Policy.NATURAL, Policy.OVERLAP2D)


def _copy_resolve(src, policy, dim, mesh_axes, block, halo):
    """Fill defaults from ``src`` and validate the destination layout."""
    policy = src.policy if policy is None else policy
    dim = src.dim if dim is None else dim
    mesh_axes = tuple(src.mesh_axes if mesh_axes is None else mesh_axes)
    if policy is Policy.BLOCK:
        block = src.block if block is None else block
        if block is None:
            raise ValueError("copy to BLOCK requires block=")
    else:
        block = None
    if halo is not None and policy is not Policy.OVERLAP2D:
        raise ValueError("halo= is only meaningful for OVERLAP2D targets")
    if halo is None and policy is Policy.OVERLAP2D:
        halo = src.halo
    halo = halo if policy is Policy.OVERLAP2D else 0
    return policy, dim, mesh_axes, block, halo


def _block_aligned(total: int, nseg: int, block: int) -> bool:
    """Can NATURAL<->BLOCK re-segmentation run as one uniform tiled
    all_to_all?  Needs the padded length to tile into ``nseg*block``
    (both layouts then share the same physical length) and the
    blocks-per-rank count to tile into ``nseg`` (uniform send counts)."""
    if total % (nseg * block) != 0:
        return False
    return (total // (nseg * block)) % nseg == 0


def _copy_route(src: SegmentedArray, policy, dim, mesh_axes, block,
                halo) -> str:
    sp = src.policy
    if mesh_axes != tuple(src.mesh_axes):
        return "rebuild"                      # group re-layout: global
    unpadded = (src.orig_len is None
                or src.orig_len == src.data.shape[src.dim])
    if sp is Policy.CLONE:
        if policy is Policy.CLONE:
            if dim == src.dim:
                return "alias"
            return "meta" if unpadded else "rebuild"
        return "clone_split"                  # local slice, no collective
    if policy is Policy.CLONE:
        return "replicate" if sp in _SPLIT and dim == src.dim else "rebuild"
    if sp in _SPLIT and policy in _SPLIT:
        return "meta" if dim == src.dim else "alltoall"
    if dim != src.dim:
        return "rebuild"                      # BLOCK endpoint + dim change
    if sp in _SPLIT and policy is Policy.BLOCK:
        return ("block_pack"
                if _block_aligned(src.data.shape[dim], src.nseg, block)
                else "rebuild")
    if sp is Policy.BLOCK and policy in _SPLIT:
        return ("block_unpack"
                if _block_aligned(src.data.shape[dim], src.nseg, src.block)
                else "rebuild")
    if sp is Policy.BLOCK and policy is Policy.BLOCK:
        return "alias" if block == src.block else "rebuild"
    return "rebuild"


def copy_route(src: SegmentedArray, *, policy: Policy | None = None,
               dim: int | None = None,
               mesh_axes: tuple[str, ...] | None = None,
               block: int | None = None, halo: int | None = None) -> str:
    """The transfer schedule ``copy`` would pick for this re-segmentation
    (introspection for tests and bench reports):

    ``alias``         same layout — metadata only, zero bytes moved
    ``meta``          layout-compatible relabel (NATURAL<->OVERLAP2D,
                      halo-only change, CLONE dim change) — zero bytes
    ``clone_split``   CLONE -> split: every replica slices its own
                      segment locally, no collective
    ``replicate``     split -> CLONE: tiled all-gathers, minor-to-major
    ``alltoall``      segmented-dim change: one tiled all_to_all
    ``block_pack``    NATURAL -> BLOCK aligned: one uniform all_to_all
    ``block_unpack``  BLOCK -> NATURAL aligned: one uniform all_to_all
    ``rebuild``       fallback through the logical array (gather +
                      re-segment) for genuinely global relayouts
    """
    policy, dim, mesh_axes, block, halo = _copy_resolve(
        src, policy, dim, mesh_axes, block, halo)
    return _copy_route(src, policy, dim, mesh_axes, block, halo)


def _plan_clone_split(src, policy, dim, mesh_axes, block, halo, cache):
    """CLONE -> split: the data is already replicated, so every device
    pads/permutes locally and slices out its own segment — communication
    free (the old path gathered and re-uploaded the full logical array).
    """
    key = ("transfer", "copy", "clone_split", seg_token(src), policy.value,
           dim, mesh_axes, block)
    group, nseg = src.group, src.nseg
    shape = src.data.shape
    sdim, sorig = src.dim, src.orig_len

    def build():
        def fn(x):
            if sorig is not None and sorig != shape[sdim]:
                x = lax.slice_in_dim(x, 0, sorig, axis=sdim)
            if policy is Policy.BLOCK:
                x, _ = _pad_to(x, dim, nseg * block)
                perm = _block_cyclic_perm(x.shape[dim], nseg, block)
                x = jnp.take(x, jnp.asarray(perm), axis=dim)
            else:
                x, _ = _pad_to(x, dim, nseg)
            per = x.shape[dim] // nseg

            def body(v):
                i = _linear_index(mesh_axes, group)
                return lax.dynamic_slice_in_dim(v, i * per, per, axis=dim)

            spec = [None] * x.ndim
            spec[dim] = _axspec(mesh_axes)
            sm = compat.shard_map(body, mesh=group.mesh, in_specs=P(),
                                  out_specs=P(*spec), check_vma=False)
            return sm(x)

        return jax.jit(fn)

    return _plan(key, build, op="copy", cache=cache,
                 meta={"schedule": "clone_split"})


def _plan_replicate(src, cache):
    """split -> CLONE: tiled all-gathers minor-to-major (ICI submesh
    assembly first, DCN across) instead of a host-staged resharding."""
    key = ("transfer", "copy", "replicate", seg_token(src))
    mesh_axes = tuple(src.mesh_axes)
    sdim = src.dim

    def build():
        def body(v):
            for a in reversed(mesh_axes):
                v = lax.all_gather(v, a, axis=sdim, tiled=True)
            return v

        sm = compat.shard_map(body, mesh=src.group.mesh, in_specs=src.pspec,
                              out_specs=P(), check_vma=False)
        return jax.jit(sm)

    return _plan(key, build, op="copy", cache=cache,
                 meta={"schedule": "replicate"})


def _plan_block_exchange(src, block: int, pack: bool, cache):
    """Aligned NATURAL<->BLOCK re-segmentation as ONE uniform tiled
    all_to_all (the direct block-cyclic exchange; the ppermute pattern
    batched into a single collective).

    With ``m`` blocks per rank (``m % nseg == 0``), the target rank of a
    NATURAL rank's local block ``j`` is ``j % nseg`` and its landing
    position is source-major — both rank-independent, so send/receive
    sides are static reshapes around one collective.  The inverse
    (unpack) sends contiguous ``m/nseg``-block chunks and interleaves
    the received slabs back into natural order.
    """
    key = ("transfer", "copy", "block_pack" if pack else "block_unpack",
           seg_token(src), block)
    mesh_axes = tuple(src.mesh_axes)
    ax = _axis_arg(mesh_axes)
    nseg = src.nseg
    dim = src.dim
    m = src.data.shape[dim] // (nseg * block)   # blocks per rank

    def build():
        def body(xl):
            xm = jnp.moveaxis(xl, dim, 0)        # (m*block, ...)
            rest = xm.shape[1:]
            if pack:
                t = xm.reshape(m // nseg, nseg, block, *rest)
                t = jnp.moveaxis(t, 1, 0).reshape(m * block, *rest)
                r = lax.all_to_all(t, ax, split_axis=0, concat_axis=0,
                                   tiled=True)
            else:
                r = lax.all_to_all(xm, ax, split_axis=0, concat_axis=0,
                                   tiled=True)
                r = r.reshape(nseg, m // nseg, block, *rest)
                r = jnp.moveaxis(r, 0, 1).reshape(m * block, *rest)
            return jnp.moveaxis(r, 0, dim)

        sm = compat.shard_map(body, mesh=src.group.mesh, in_specs=src.pspec,
                              out_specs=src.pspec, check_vma=False)
        return jax.jit(sm)

    return _plan(key, build, op="copy", cache=cache,
                 meta={"schedule": "block_pack" if pack else "block_unpack",
                       "block": block, "blocks_per_rank": m})


def copy(src: SegmentedArray, *, policy: Policy | None = None,
         dim: int | None = None,
         mesh_axes: tuple[str, ...] | None = None,
         block: int | None = None, halo: int | None = None,
         cache: PlanCache | None = None) -> SegmentedArray:
    """Segmented-to-segmented copy (paper Fig. 3), i.e. re-segmentation.

    The schedule is picked per (src, dst) layout pair — see
    ``copy_route`` for the full table.  Layout-compatible relabels
    (halo-only OVERLAP2D changes, NATURAL<->OVERLAP2D on the same dim)
    move zero bytes; CLONE re-splits slice locally; dim changes run one
    ``all_to_all``; aligned BLOCK endpoints run one uniform exchange.
    Only genuinely global relayouts (mesh-axes change, unaligned
    block-cyclic, padded CLONE re-dim) still round-trip the logical
    array.  Direct schedules preserve the source's physical padding
    (``orig_len`` metadata stays truthful, but the padded extent may
    exceed the canonical minimum the ctor would pick).
    """
    policy, dim, mesh_axes, block, halo = _copy_resolve(
        src, policy, dim, mesh_axes, block, halo)
    route = _copy_route(src, policy, dim, mesh_axes, block, halo)

    if route == "rebuild":
        return segment(gather(src), src.group, policy=policy, dim=dim,
                       mesh_axes=mesh_axes, block=block, halo=halo)
    if route == "alias":
        return dataclasses.replace(src, policy=policy, dim=dim,
                                   mesh_axes=mesh_axes, block=block,
                                   halo=halo)
    if route == "meta":
        if src.policy is Policy.CLONE:      # CLONE dim change (unpadded)
            return dataclasses.replace(src, dim=dim,
                                       orig_len=src.data.shape[dim])
        return dataclasses.replace(src, policy=policy, halo=halo)
    if route == "clone_split":
        plan = _plan_clone_split(src, policy, dim, mesh_axes, block, halo,
                                 cache)
        new_orig = (src.orig_len if dim == src.dim and src.orig_len is not None
                    else src.data.shape[dim])
        return SegmentedArray(plan(src.data), src.group, policy, dim,
                              mesh_axes, orig_len=new_orig, block=block,
                              halo=halo)
    if route == "replicate":
        plan = _plan_replicate(src, cache)
        return SegmentedArray(plan(src.data), src.group, Policy.CLONE, dim,
                              mesh_axes, orig_len=src.orig_len)
    if route == "alltoall":
        work = src if src.policy is Policy.NATURAL else dataclasses.replace(
            src, policy=Policy.NATURAL, halo=0)
        res = all_to_all(work, dim, cache=cache)
        return dataclasses.replace(res, policy=policy, halo=halo)
    if route in ("block_pack", "block_unpack"):
        pack = route == "block_pack"
        plan = _plan_block_exchange(src, block if pack else src.block,
                                    pack, cache)
        orig = (src.orig_len if src.orig_len is not None
                else src.data.shape[dim])
        return SegmentedArray(plan(src.data), src.group, policy, dim,
                              mesh_axes, orig_len=orig, block=block,
                              halo=halo)
    raise AssertionError(f"unknown copy route {route!r}")


def plan_all_to_all(seg: SegmentedArray, new_dim: int,
                    cache: PlanCache | None = None) -> Plan:
    """Plan the all_to_all re-segmentation (pad + one tiled collective +
    old-dim padding slice, jitted as one program)."""
    key = ("transfer", "all_to_all", seg_token(seg), int(new_dim))
    mesh_axes = tuple(seg.mesh_axes)
    ax = _axis_arg(mesh_axes)
    nseg = seg.nseg
    sdim, sorig = seg.dim, seg.orig_len
    shape = seg.data.shape

    def build():
        def body(x):
            return lax.all_to_all(x, ax, split_axis=new_dim,
                                  concat_axis=sdim, tiled=True)

        def fn(x):
            x, _ = _pad_to(x, new_dim, nseg)
            out = [None] * x.ndim
            out[new_dim] = _axspec(mesh_axes)
            sm = compat.shard_map(body, mesh=seg.group.mesh,
                                  in_specs=seg.pspec, out_specs=P(*out),
                                  check_vma=False)
            y = sm(x)
            if sorig is not None and sorig != shape[sdim]:
                # old-dim padding sits at the global tail; it is local to
                # every shard after the transpose — no communication.
                y = lax.slice_in_dim(y, 0, sorig, axis=sdim)
            return y

        return jax.jit(fn)

    return _plan(key, build, op="all_to_all", cache=cache,
                 meta={"schedule": "all_to_all"})


def all_to_all(seg: SegmentedArray, new_dim: int,
               cache: PlanCache | None = None) -> SegmentedArray:
    """Re-segment from ``seg.dim`` to ``new_dim`` with an all-to-all
    (MPI_Alltoall — the natural extension of the paper's verb set; used
    for MoE dispatch and FFT transposes).

    The segmentation metadata is rebuilt for the post-transpose layout:
    ``new_dim`` is padded so it tiles across the group and its
    pre-padding length becomes the new ``orig_len``; the old segmented
    dim's padding (now unsegmented) is sliced away so the container stays
    truthful about its logical extent.
    """
    if seg.policy is not Policy.NATURAL:
        raise ValueError(f"all_to_all requires a NATURAL container, "
                         f"got {seg.policy}")
    if new_dim == seg.dim:
        return seg
    data = plan_all_to_all(seg, new_dim, cache=cache)(seg.data)
    return dataclasses.replace(seg, data=data, dim=new_dim,
                               orig_len=seg.data.shape[new_dim])


_REDUCE_SCATTER_OPS = ("sum", "max", "min")


def plan_reduce_scatter(seg: SegmentedArray, op: str = "sum",
                        cache: PlanCache | None = None) -> Plan:
    """Plan the reduce_scatter: ``sum`` lowers to ``lax.psum_scatter``;
    ``max``/``min`` run the same schedule explicitly (one tiled
    all_to_all of the locally-reduced payload + a local elementwise
    merge — identical bytes on the wire)."""
    if op not in _REDUCE_SCATTER_OPS:
        raise ValueError(f"reduce_scatter supports {_REDUCE_SCATTER_OPS}, "
                         f"got {op!r}")
    key = ("transfer", "reduce_scatter", seg_token(seg), op)
    mesh_axes = tuple(seg.mesh_axes)
    ax = _axis_arg(mesh_axes)
    nseg = seg.nseg
    sdim = seg.dim
    merged_len = [d for i, d in enumerate(seg.data.shape) if i != sdim][0]
    padded = math.ceil(merged_len / nseg) * nseg

    def build():
        jred = _REDUCERS[op][1]

        def body(x):
            x = jred(x, axis=sdim)
            if padded != merged_len:
                pad = [(0, 0)] * x.ndim
                pad[0] = (0, padded - merged_len)
                x = jnp.pad(x, pad)
            if op == "sum":
                return lax.psum_scatter(x, ax, scatter_dimension=0,
                                        tiled=True)
            t = lax.all_to_all(x, ax, split_axis=0, concat_axis=0,
                               tiled=True)
            t = t.reshape(nseg, padded // nseg, *x.shape[1:])
            return jred(t, axis=0)

        merged_ndim = seg.data.ndim - 1
        out = [None] * merged_ndim
        out[0] = _axspec(mesh_axes)
        sm = compat.shard_map(body, mesh=seg.group.mesh, in_specs=seg.pspec,
                              out_specs=P(*out), check_vma=False)
        return jax.jit(sm)

    return _plan(key, build, op="reduce_scatter", cache=cache,
                 meta={"schedule": ("psum_scatter" if op == "sum"
                                    else f"alltoall_{op}")})


def reduce_scatter(seg: SegmentedArray, op: str = "sum",
                   cache: PlanCache | None = None) -> SegmentedArray:
    """Reduce the segments and leave the result segmented along dim 0 of
    the merged array (MPI_Reduce_scatter).  ``op`` may be ``sum``,
    ``max`` or ``min``."""
    merged_len = [d for i, d in enumerate(seg.data.shape)
                  if i != seg.dim][0]
    data = plan_reduce_scatter(seg, op, cache=cache)(seg.data)
    return SegmentedArray(data, seg.group, Policy.NATURAL, 0, seg.mesh_axes,
                          orig_len=merged_len)
