"""Runtime environment — the MGPU ``environment`` / ``dev_group`` analogue.

MGPU instantiates an ``environment`` that detects the devices in the node
and lets the user restrict computation to a ``dev_group``.  On TPU the
equivalent object is a named-axis mesh: the environment builds a
``jax.Mesh`` from the available devices, classifies each axis as ICI
(intra-pod, fast) or DCN (inter-pod, slow) — the direct analogue of the
paper's PCIe-domain / IOH-boundary distinction — and supports submesh
selection (the ``dev_group`` constructor argument).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from . import compat

# Axis names that cross the data-center network rather than ICI.  The
# paper's topology split (P2P inside an IOH vs. host-staged across IOHs)
# maps onto this boundary.
DCN_AXES = ("pod",)

# TPU v5e hardware model used for all analytic/roofline derivations.
HW = dict(
    peak_flops_bf16=197e12,  # FLOP/s per chip
    hbm_bw=819e9,            # bytes/s per chip
    ici_bw=50e9,             # bytes/s per link (intra-pod)
    dcn_bw=25e9,             # bytes/s per chip (inter-pod, conservative)
    vmem_bytes=128 * 2**20,  # VMEM per chip
    hbm_bytes=16 * 2**30,    # HBM per chip
)


@dataclasses.dataclass(frozen=True)
class DeviceGroup:
    """A named-axis device group (MGPU ``dev_group``)."""

    mesh: Mesh

    # -- constructors -----------------------------------------------------
    @classmethod
    def all_devices(cls, shape: Sequence[int] | None = None,
                    axes: Sequence[str] = ("data",)) -> "DeviceGroup":
        """Build a group over every addressable device (MGPU default ctor)."""
        ndev = len(jax.devices())
        if shape is None:
            shape = (ndev,)
        if math.prod(shape) != ndev:
            raise ValueError(f"mesh shape {shape} != device count {ndev}")
        return cls(compat.make_mesh(tuple(shape), tuple(axes)))

    @classmethod
    def subset(cls, n: int, axes: Sequence[str] = ("data",)) -> "DeviceGroup":
        """Restrict to the first ``n`` devices (MGPU ``dev_group`` ctor)."""
        avail = jax.devices()
        if n > len(avail):
            raise ValueError(
                f"requested {n} devices, host has {len(avail)} (simulate "
                f"more with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        devs = np.asarray(avail[:n]).reshape((n,))
        return cls(Mesh(devs, tuple(axes)))

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "DeviceGroup":
        return cls(mesh)

    # -- queries ----------------------------------------------------------
    @property
    def ndev(self) -> int:
        return self.mesh.size

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def shape(self) -> Mapping[str, int]:
        return dict(self.mesh.shape)

    @property
    def ici_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axis_names if a not in DCN_AXES)

    @property
    def dcn_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in DCN_AXES)

    @property
    def platform(self) -> str:
        return self.mesh.devices.flat[0].platform

    @property
    def unified_memory(self) -> bool:
        """True when the group's devices share one memory domain (the
        host-simulated CPU mesh): a host->device upload or replicated
        ``device_put`` is then a local copy, so bandwidth-splitting
        schedules (scatter+allgather broadcast, psum_scatter+all_gather
        reduce) only add collective rounds.  The transfer layer picks
        direct schedules here and the decomposed ones on discrete-memory
        accelerator platforms."""
        return self.platform == "cpu"

    def axis_size(self, *axes: str) -> int:
        return math.prod(self.mesh.shape[a] for a in axes)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def __enter__(self):
        self._ctx = self.mesh
        self._ctx.__enter__()
        return self

    def __exit__(self, *exc):
        return self._ctx.__exit__(*exc)


def current_group(group=None) -> DeviceGroup:
    """Default-group resolution: explicit arg > ambient mesh > all devices.

    .. deprecated:: PR 2
        The implicit-global-group idiom is deprecated.  Hold an
        ``env.Communicator`` (whose group is always explicit) instead.
        This resolver remains as the engine of the free-function shims.

    ``group`` may be a ``DeviceGroup`` or anything carrying one under a
    ``.group`` attribute (an ``env.Communicator``).
    """
    if group is not None:
        return getattr(group, "group", group)
    mesh = compat.ambient_mesh()  # inside a `with mesh:` scope
    if mesh is not None:
        return DeviceGroup(mesh)
    return DeviceGroup.all_devices()
