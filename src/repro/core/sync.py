"""Synchronization — the MGPU barrier/fence family (paper §2.5).

MGPU is asynchronous by default and offers ``barrier``/``fence``
functions built on condition variables + driver sync.  JAX is likewise
async by default (dispatch returns futures); the adaptation is:

  fence(x...)        host-blocks until the given arrays are computed
                     (driver-sync analogue, ``cudaStreamSynchronize``),
  barrier(group)     a collective no-op all devices must reach,
  barrier_fence()    both — the paper's strongest primitive,
  ordered(x, dep)    in-graph ordering: make ``x`` depend on ``dep``
                     without numerical effect (optimization_barrier), the
                     jit-compatible fence used to sequence collectives.

``group=`` accepts a ``DeviceGroup`` or an ``env.Communicator``; the
bound forms ``Communicator.barrier``/``fence``/``barrier_fence`` are the
stable surface (``barrier``/``barrier_fence`` here are their shims).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from . import compat
from .runtime import DeviceGroup, current_group


def fence(*arrays):
    """Block the host until all pending ops producing ``arrays`` finish."""
    jax.block_until_ready(arrays)
    return arrays[0] if len(arrays) == 1 else arrays


def barrier(group: DeviceGroup | None = None) -> None:
    """All devices of the group reach this point (tiny psum round-trip)."""
    group = current_group(group)
    token = jnp.zeros((), jnp.int32)
    out = compat.shard_map(
        lambda t: lax.psum(t, group.axis_names
                           if len(group.axis_names) > 1 else group.axis_names[0]),
        mesh=group.mesh, in_specs=P(), out_specs=P())(token)
    jax.block_until_ready(out)


def barrier_fence(*arrays, group: DeviceGroup | None = None):
    """MGPU ``barrier_fence()``: wait for pending ops, then barrier."""
    if arrays:
        fence(*arrays)
    barrier(group)
    return arrays[0] if len(arrays) == 1 else (arrays or None)


def ordered(x, dep):
    """Make ``x`` data-depend on ``dep`` inside jit (sequencing fence)."""
    x, _ = lax.optimization_barrier((x, dep))
    return x
