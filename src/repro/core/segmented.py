"""Segmented containers — the core MGPU abstraction, on JAX arrays.

An MGPU ``seg_dev_vector`` is one logical vector physically split across
device memories, carrying its own location metadata (a vector of
(pointer, size) tuples, Fig. 1 of the paper).  The JAX analogue keeps the
*global* ``jax.Array`` — whose shards already live on distinct devices —
and attaches the segmentation *policy* so that algorithms (comm verbs,
segmented FFT/BLAS, invoke_kernel) can reason about locality exactly the
way MGPU's hierarchical algorithms do.

Split policies (paper §2.2):
  NATURAL   contiguous even split along one dim,
  BLOCK     block-cyclic split (fixed block size, round-robin),
  CLONE     replicated on every device,
  OVERLAP2D contiguous row split with a halo of ``h`` rows exchanged
            with neighbours (for stencil-style kernels).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from . import compat
from .runtime import DeviceGroup, current_group


class Policy(enum.Enum):
    NATURAL = "natural"
    BLOCK = "block"
    CLONE = "clone"
    OVERLAP2D = "overlap2d"


@dataclasses.dataclass(frozen=True)
class SegmentedArray:
    """A logically-global array with explicit segmentation metadata."""

    data: jax.Array
    group: DeviceGroup
    policy: Policy
    dim: int = 0                      # logical dim that is segmented
    mesh_axes: tuple[str, ...] = ("data",)
    orig_len: int | None = None       # pre-padding length along `dim`
    block: int | None = None          # BLOCK policy block size
    halo: int = 0                     # OVERLAP2D halo rows

    # -- basic queries ----------------------------------------------------
    @property
    def nseg(self) -> int:
        return self.group.axis_size(*self.mesh_axes)

    @property
    def global_shape(self) -> tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def pspec(self) -> P:
        if self.policy is Policy.CLONE:
            return P()
        spec: list[Any] = [None] * self.data.ndim
        spec[self.dim] = self.mesh_axes if len(self.mesh_axes) > 1 else self.mesh_axes[0]
        return P(*spec)

    @property
    def sharding(self) -> NamedSharding:
        return self.group.sharding(self.pspec)

    def seg_len(self) -> int:
        """Per-segment length along the segmented dim."""
        return self.data.shape[self.dim] // self.nseg

    def segments(self) -> list[tuple[int, ...]]:
        """MGPU's (pointer, size) tuple vector — here, per-segment shapes."""
        if self.policy is Policy.CLONE:
            return [self.global_shape] * self.group.ndev
        s = list(self.global_shape)
        s[self.dim] = self.seg_len()
        return [tuple(s)] * self.nseg

    # -- rewrap helpers ---------------------------------------------------
    def with_data(self, data: jax.Array) -> "SegmentedArray":
        return dataclasses.replace(self, data=data)

    # Elementwise arithmetic keeps segmentation (MGPU containers interoperate
    # with algorithms through iterators; here through jnp ops on .data).
    def _binop(self, other, op):
        o = other.data if isinstance(other, SegmentedArray) else other
        return self.with_data(op(self.data, o))

    def __add__(self, o): return self._binop(o, jnp.add)
    def __sub__(self, o): return self._binop(o, jnp.subtract)
    def __mul__(self, o): return self._binop(o, jnp.multiply)
    def __truediv__(self, o): return self._binop(o, jnp.divide)

    def astype(self, dt) -> "SegmentedArray":
        return self.with_data(self.data.astype(dt))


jax.tree_util.register_pytree_node(
    SegmentedArray,
    lambda s: ((s.data,), (s.group, s.policy, s.dim, s.mesh_axes,
                           s.orig_len, s.block, s.halo)),
    lambda aux, ch: SegmentedArray(ch[0], *aux))


# ---------------------------------------------------------------------------
# construction (MGPU: container ctor + implicit scatter)
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, dim: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[dim]
    target = math.ceil(n / mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[dim] = (0, target - n)
    return jnp.pad(x, pad), n


def _block_cyclic_perm(n: int, nseg: int, block: int) -> np.ndarray:
    """Permutation mapping logical index -> segment-major block-cyclic order."""
    nblocks = n // block
    ids = np.arange(n).reshape(nblocks, block)
    order = []
    for s in range(nseg):
        order.append(ids[s::nseg].reshape(-1))
    return np.concatenate(order)


def segment(x, group: DeviceGroup | None = None, *,
            policy: Policy = Policy.NATURAL, dim: int = 0,
            mesh_axes: tuple[str, ...] = ("data",), block: int | None = None,
            halo: int = 0) -> SegmentedArray:
    """Create a segmented container from a host/global array (MGPU ctor).

    The way data is split across devices is controlled here, exactly as in
    the paper's container constructor.
    """
    group = current_group(group)
    x = jnp.asarray(x)
    nseg = group.axis_size(*mesh_axes)

    if policy is Policy.CLONE:
        data = jax.device_put(x, group.sharding(P()))
        return SegmentedArray(data, group, policy, dim, mesh_axes,
                              orig_len=x.shape[dim] if x.ndim else None)

    if policy is Policy.BLOCK:
        if block is None:
            raise ValueError("BLOCK policy requires block=")
        x, orig = _pad_to(x, dim, nseg * block)
        perm = _block_cyclic_perm(x.shape[dim], nseg, block)
        x = jnp.take(x, jnp.asarray(perm), axis=dim)
        seg = SegmentedArray(x, group, policy, dim, mesh_axes,
                             orig_len=orig, block=block)
    elif policy in (Policy.NATURAL, Policy.OVERLAP2D):
        x, orig = _pad_to(x, dim, nseg)
        seg = SegmentedArray(x, group, policy, dim, mesh_axes,
                             orig_len=orig, halo=halo)
    else:
        raise ValueError(policy)

    data = jax.device_put(seg.data, seg.sharding)
    return seg.with_data(data)


def gather(seg: SegmentedArray) -> jax.Array:
    """Materialize the logical array (inverse of ``segment``)."""
    x = seg.data
    if seg.policy is Policy.BLOCK:
        perm = _block_cyclic_perm(x.shape[seg.dim], seg.nseg, seg.block)
        inv = np.argsort(perm)
        x = jnp.take(jax.device_put(x, seg.group.sharding(P())),
                     jnp.asarray(inv), axis=seg.dim)
    if seg.orig_len is not None and seg.orig_len != x.shape[seg.dim]:
        x = jax.lax.slice_in_dim(x, 0, seg.orig_len, axis=seg.dim)
    return jax.device_put(x, seg.group.sharding(P()))


# ---------------------------------------------------------------------------
# OVERLAP2D halo exchange (paper: "2D overlapped splitting")
# ---------------------------------------------------------------------------

def overlap2d_map(seg: SegmentedArray,
                  fn: Callable[[jax.Array], jax.Array]) -> SegmentedArray:
    """Apply ``fn`` to each local row-block extended by ``halo`` rows from
    its neighbours (zero-padded at the edges).  ``fn`` must map shape
    ``(rows + 2h, ...)`` -> ``(rows, ...)``.
    """
    if seg.policy is not Policy.OVERLAP2D:
        raise ValueError("overlap2d_map requires an OVERLAP2D container")
    h = seg.halo
    axis = seg.mesh_axes[0]
    mesh = seg.group.mesh
    n = seg.nseg

    def body(x):
        # x: local block, segmented dim first for simplicity of slicing
        xm = jnp.moveaxis(x, seg.dim, 0)
        lo = xm[:h]          # rows this shard sends downward
        hi = xm[-h:]         # rows this shard sends upward
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
        from_prev = jax.lax.ppermute(hi, axis, fwd)   # prev shard's top rows
        from_next = jax.lax.ppermute(lo, axis, bwd)   # next shard's bottom rows
        idx = jax.lax.axis_index(axis)
        from_prev = jnp.where(idx == 0, jnp.zeros_like(from_prev), from_prev)
        from_next = jnp.where(idx == n - 1, jnp.zeros_like(from_next), from_next)
        ext = jnp.concatenate([from_prev, xm, from_next], axis=0)
        out = fn(jnp.moveaxis(ext, 0, seg.dim))
        return out

    spec = seg.pspec
    out = compat.shard_map(body, mesh=mesh, in_specs=spec,
                           out_specs=spec)(seg.data)
    return seg.with_data(out)
