"""Segmented containers — the core MGPU abstraction, on JAX arrays.

An MGPU ``seg_dev_vector`` is one logical vector physically split across
device memories, carrying its own location metadata (a vector of
(pointer, size) tuples, Fig. 1 of the paper).  The JAX analogue keeps the
*global* ``jax.Array`` — whose shards already live on distinct devices —
and attaches the segmentation *policy* so that algorithms (comm verbs,
segmented FFT/BLAS, invoke_kernel) can reason about locality exactly the
way MGPU's hierarchical algorithms do.

Split policies (paper §2.2):
  NATURAL   contiguous even split along one dim,
  BLOCK     block-cyclic split (fixed block size, round-robin),
  CLONE     replicated on every device,
  OVERLAP2D contiguous row split with a halo of ``h`` rows exchanged
            with neighbours (for stencil-style kernels).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from . import compat
from .runtime import DeviceGroup, current_group


class Policy(enum.Enum):
    NATURAL = "natural"
    BLOCK = "block"
    CLONE = "clone"
    OVERLAP2D = "overlap2d"


@dataclasses.dataclass(frozen=True)
class SegmentedArray:
    """A logically-global array with explicit segmentation metadata."""

    data: jax.Array
    group: DeviceGroup
    policy: Policy
    dim: int = 0                      # logical dim that is segmented
    mesh_axes: tuple[str, ...] = ("data",)
    orig_len: int | None = None       # pre-padding length along `dim`
    block: int | None = None          # BLOCK policy block size
    halo: int = 0                     # OVERLAP2D halo rows

    # -- basic queries ----------------------------------------------------
    @property
    def nseg(self) -> int:
        return self.group.axis_size(*self.mesh_axes)

    @property
    def global_shape(self) -> tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def pspec(self) -> P:
        if self.policy is Policy.CLONE:
            return P()
        spec: list[Any] = [None] * self.data.ndim
        spec[self.dim] = self.mesh_axes if len(self.mesh_axes) > 1 else self.mesh_axes[0]
        return P(*spec)

    @property
    def sharding(self) -> NamedSharding:
        return self.group.sharding(self.pspec)

    def seg_len(self, rank: int | None = None) -> int:
        """Per-segment length along the segmented dim.

        Without ``rank``: the uniform *physical* shard length (padding
        included).  With ``rank``: the *logical* length of that segment —
        block-cyclic remainders (BLOCK) and halo rows (OVERLAP2D)
        included, matching what MGPU's (pointer, size) metadata reports.
        """
        if rank is not None:
            return self._seg_sizes()[rank]
        if self.policy is Policy.CLONE:
            return self.data.shape[self.dim]
        return self.data.shape[self.dim] // self.nseg

    def _seg_sizes(self) -> list[int]:
        """Logical per-segment lengths along the segmented dim."""
        n = self.nseg
        total = self.data.shape[self.dim]
        orig = total if self.orig_len is None else self.orig_len
        if self.policy is Policy.CLONE:
            return [orig] * n
        if self.policy is Policy.BLOCK:
            # rank r owns blocks r, r+n, r+2n, ... of the padded sequence;
            # count only the elements below the pre-padding length.
            nblocks = total // self.block
            return [sum(max(0, min(orig - b * self.block, self.block))
                        for b in range(r, nblocks, n)) for r in range(n)]
        per = total // n                      # padded contiguous rows
        sizes = [max(0, min(orig - r * per, per)) for r in range(n)]
        if self.policy is Policy.OVERLAP2D and self.halo:
            # each segment additionally holds ``halo`` rows per existing
            # neighbour (edge segments have only one neighbour).
            h = self.halo
            sizes = [s + (h if r > 0 else 0) + (h if r < n - 1 else 0)
                     for r, s in enumerate(sizes)]
        return sizes

    def segments(self) -> list[tuple[int, ...]]:
        """MGPU's (pointer, size) tuple vector — here, per-segment shapes.

        Shapes are *logical*: BLOCK reports the block-cyclic remainder
        split and OVERLAP2D includes the halo rows exchanged with each
        existing neighbour.  One entry per segment (``nseg``) for every
        policy, CLONE included.
        """
        if self.policy is Policy.CLONE:
            return [self.global_shape] * self.nseg
        out = []
        for sz in self._seg_sizes():
            s = list(self.global_shape)
            s[self.dim] = sz
            out.append(tuple(s))
        return out

    # -- rewrap helpers ---------------------------------------------------
    def with_data(self, data: jax.Array) -> "SegmentedArray":
        return dataclasses.replace(self, data=data)

    # Elementwise arithmetic keeps segmentation (MGPU containers interoperate
    # with algorithms through iterators; here through jnp ops on .data).
    def _binop(self, other, op):
        o = other.data if isinstance(other, SegmentedArray) else other
        return self.with_data(op(self.data, o))

    def __add__(self, o): return self._binop(o, jnp.add)
    def __sub__(self, o): return self._binop(o, jnp.subtract)
    def __mul__(self, o): return self._binop(o, jnp.multiply)
    def __truediv__(self, o): return self._binop(o, jnp.divide)

    def astype(self, dt) -> "SegmentedArray":
        return self.with_data(self.data.astype(dt))

    # -- fluent verb surface (delegates to the owning communicator) -------
    # MGPU containers are arguments *to* communication methods bound to a
    # dev_group (paper Fig. 3); the fluent forms here resolve the owning
    # Communicator from the container's own group so algorithm code never
    # re-derives it.  Imports are deferred: comm/env import this module.
    @property
    def comm(self):
        """The owning :class:`repro.core.env.Communicator`."""
        from .env import Communicator
        return Communicator(self.group, self.mesh_axes)

    def to(self, policy: "Policy | None" = None, **kw) -> "SegmentedArray":
        """Re-segment under a new policy/dim (``comm.copy``).

        >>> from repro.core import Environment, Policy
        >>> seg = Environment().subgroup(1).container([1., 2.])
        >>> seg.to(Policy.CLONE).policy
        <Policy.CLONE: 'clone'>
        """
        from .comm import copy
        return copy(self, policy=policy, **kw)

    def gather(self) -> jax.Array:
        """Materialize the logical array (inverse of construction).

        >>> from repro.core import Environment
        >>> Environment().subgroup(1).container([1., 2.]).gather().tolist()
        [1.0, 2.0]
        """
        return gather(self)

    def reduce(self, op: str = "sum") -> jax.Array:
        """Merge the segments: the segmented dim is reduced away.

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([[1., 2.], [3., 4.]])
        >>> seg.reduce().tolist()
        [4.0, 6.0]
        """
        from .comm import reduce
        return reduce(self, op)

    def allreduce(self, op: str = "sum", *, hierarchical: bool = False,
                  p2p: bool = False) -> "SegmentedArray":
        """Reduce + replicate (-> CLONE container).

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([[1., 2.], [3., 4.]])
        >>> seg.allreduce().data.tolist()
        [4.0, 6.0]
        """
        from .comm import all_reduce
        return all_reduce(self, op, hierarchical=hierarchical, p2p=p2p)

    def allreduce_window(self, window=None, **kw) -> "SegmentedArray":
        """Windowed all-reduce: only ``window`` goes on the wire,
        scattered back into zeros (paper ``kern_all_red_p2p_2d``).

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([[1., 2., 3., 4.]])
        >>> seg.allreduce_window(((1, 3),)).data.tolist()
        [0.0, 2.0, 3.0, 0.0]
        """
        from .comm import all_reduce_window
        return all_reduce_window(self, window, **kw)

    def allgather(self) -> "SegmentedArray":
        """MPI_Allgather: the whole logical array, CLONEd.

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([1., 2., 3.])
        >>> seg.allgather().policy
        <Policy.CLONE: 'clone'>
        """
        from .comm import all_gather
        return all_gather(self)

    def reduce_scatter(self, op: str = "sum") -> "SegmentedArray":
        """Reduce the segments, leave the result segmented.

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([[1., 2.], [3., 4.]])
        >>> seg.reduce_scatter().gather().tolist()
        [4.0, 6.0]
        """
        from .comm import reduce_scatter
        return reduce_scatter(self, op)

    def alltoall(self, new_dim: int) -> "SegmentedArray":
        """Re-segment onto ``new_dim`` with an all-to-all.

        >>> import numpy as np
        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container(
        ...     np.zeros((2, 4), np.float32))
        >>> seg.alltoall(1).dim
        1
        """
        from .comm import all_to_all
        return all_to_all(self, new_dim)

    def vdot(self, other):
        """Inner product of the logical arrays (one reduction).

        >>> from repro.core import Environment
        >>> comm = Environment().subgroup(1)
        >>> float(comm.container([1., 2.]).vdot(comm.container([3., 4.])))
        11.0
        """
        from .comm import vdot
        return vdot(self, other)

    def shift(self, offset: int = 1, *, wrap: bool = True) -> "SegmentedArray":
        """Ring-shift segments by ``offset`` (p2p path); on a 1-segment
        ring the wrapped shift is the identity.

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([5., 6.])
        >>> seg.shift(1).gather().tolist()
        [5.0, 6.0]
        """
        from .comm import shift
        return shift(self, offset, wrap=wrap)

    def send_recv(self, perm) -> "SegmentedArray":
        """Pairwise segment exchange over ``(src, dst)`` pairs.

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([5., 6.])
        >>> seg.send_recv([(0, 0)]).gather().tolist()
        [5.0, 6.0]
        """
        from .comm import send_recv
        return send_recv(self, perm)

    def halo_exchange(self, fn: "Callable | None" = None) -> "SegmentedArray":
        """OVERLAP2D halo exchange over the p2p path.  With ``fn``: apply
        it to every halo-extended block (``(rows + 2h, ...) -> (rows,
        ...)``).  Without: return the halo-extended container itself
        (each segment physically carries its neighbours' rows, the
        paper's overlapped splitting of Fig. 1).

        A single segment has no neighbours, so its halo rows zero-fill:

        >>> from repro.core import Environment, Policy
        >>> seg = Environment().subgroup(1).container(
        ...     [[1., 1.], [2., 2.]], policy=Policy.OVERLAP2D, halo=1)
        >>> seg.halo_exchange().gather().tolist()
        [[0.0, 0.0], [1.0, 1.0], [2.0, 2.0], [0.0, 0.0]]
        """
        return overlap2d_map(self, fn)

    def invoke(self, fn: Callable, *args) -> "SegmentedArray":
        """Launch a shape-preserving kernel over this container's group
        with the local segment as first argument (``invoke_kernel_all``);
        the result inherits this container's segmentation.

        >>> from repro.core import Environment
        >>> seg = Environment().subgroup(1).container([1., 2.])
        >>> seg.invoke(lambda xl: xl * 10).gather().tolist()
        [10.0, 20.0]
        """
        from .invoke import invoke_kernel_all
        res = invoke_kernel_all(fn, self, *args, group=self.group,
                                out_specs=self.pspec,
                                mesh_axes=self.mesh_axes)
        return self.with_data(res.data if isinstance(res, SegmentedArray)
                              else res)


jax.tree_util.register_pytree_node(
    SegmentedArray,
    lambda s: ((s.data,), (s.group, s.policy, s.dim, s.mesh_axes,
                           s.orig_len, s.block, s.halo)),
    lambda aux, ch: SegmentedArray(ch[0], *aux))


# ---------------------------------------------------------------------------
# construction (MGPU: container ctor + implicit scatter)
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, dim: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[dim]
    target = math.ceil(n / mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[dim] = (0, target - n)
    return jnp.pad(x, pad), n


def _pad_to_np(x: np.ndarray, dim: int, mult: int) -> tuple[np.ndarray, int]:
    """numpy twin of ``_pad_to`` for the host-side segment() prologue."""
    n = x.shape[dim]
    target = math.ceil(n / mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[dim] = (0, target - n)
    return np.pad(x, pad), n


def _block_cyclic_perm(n: int, nseg: int, block: int) -> np.ndarray:
    """Permutation mapping logical index -> segment-major block-cyclic order."""
    nblocks = n // block
    ids = np.arange(n).reshape(nblocks, block)
    order = []
    for s in range(nseg):
        order.append(ids[s::nseg].reshape(-1))
    return np.concatenate(order)


def segment(x, group: DeviceGroup | None = None, *,
            policy: Policy = Policy.NATURAL, dim: int = 0,
            mesh_axes: tuple[str, ...] = ("data",), block: int | None = None,
            halo: int = 0) -> SegmentedArray:
    """Create a segmented container from a host/global array (MGPU ctor).

    The way data is split across devices is controlled here, exactly as in
    the paper's container constructor.
    """
    group = current_group(group)
    nseg = group.axis_size(*mesh_axes)
    # Host inputs (lists, numpy arrays) stay in numpy through the
    # pad/permute prologue so the single ``device_put`` at the end
    # uploads each shard straight to its owner — no staging hop through
    # device 0 of a committed full-array copy.  jax arrays and tracers
    # keep the jnp path (they may already live on-device or be abstract).
    on_host = not isinstance(x, (jax.Array, jax.core.Tracer))
    if on_host:
        x = np.asarray(x)
        x = x.astype(jax.dtypes.canonicalize_dtype(x.dtype), copy=False)
        xp, pad_to = np, _pad_to_np
    else:
        x = jnp.asarray(x)
        xp, pad_to = jnp, _pad_to

    if policy is Policy.CLONE:
        data = jax.device_put(x, group.sharding(P()))
        return SegmentedArray(data, group, policy, dim, mesh_axes,
                              orig_len=x.shape[dim] if x.ndim else None)

    if policy is Policy.BLOCK:
        if block is None:
            raise ValueError("BLOCK policy requires block=")
        x, orig = pad_to(x, dim, nseg * block)
        perm = _block_cyclic_perm(x.shape[dim], nseg, block)
        x = xp.take(x, perm if on_host else jnp.asarray(perm), axis=dim)
        seg = SegmentedArray(x, group, policy, dim, mesh_axes,
                             orig_len=orig, block=block)
    elif policy in (Policy.NATURAL, Policy.OVERLAP2D):
        x, orig = pad_to(x, dim, nseg)
        seg = SegmentedArray(x, group, policy, dim, mesh_axes,
                             orig_len=orig, halo=halo)
    else:
        raise ValueError(policy)

    data = jax.device_put(seg.data, seg.sharding)
    return seg.with_data(data)


def gather(seg: SegmentedArray) -> jax.Array:
    """Materialize the logical array (inverse of ``segment``)."""
    x = seg.data
    if seg.policy is Policy.BLOCK:
        perm = _block_cyclic_perm(x.shape[seg.dim], seg.nseg, seg.block)
        inv = np.argsort(perm)
        x = jnp.take(jax.device_put(x, seg.group.sharding(P())),
                     jnp.asarray(inv), axis=seg.dim)
    if seg.orig_len is not None and seg.orig_len != x.shape[seg.dim]:
        x = jax.lax.slice_in_dim(x, 0, seg.orig_len, axis=seg.dim)
    return jax.device_put(x, seg.group.sharding(P()))


# ---------------------------------------------------------------------------
# OVERLAP2D halo exchange (paper: "2D overlapped splitting")
# ---------------------------------------------------------------------------

def overlap2d_map(seg: SegmentedArray,
                  fn: Callable[[jax.Array], jax.Array] | None) -> SegmentedArray:
    """Halo exchange + map over an OVERLAP2D container.

    Each local row-block is extended by ``halo`` rows from its
    neighbours through the p2p path (two open-boundary ring ``shift``s —
    ``lax.ppermute``, the paper's P2P transfer; edge shards see zeros)
    and ``fn`` is applied to the extended block (``(rows + 2h, ...) ->
    (rows, ...)``).  ``fn=None`` returns the halo-extended container
    itself: a NATURAL container whose segments are the ``rows + 2h``
    blocks (MGPU's physically overlapped segments, Fig. 1).
    """
    if seg.policy is not Policy.OVERLAP2D:
        raise ValueError("overlap2d_map requires an OVERLAP2D container")
    from .comm import shift  # deferred: comm imports this module
    h = seg.halo
    axis = seg.mesh_axes[0]
    mesh = seg.group.mesh
    n = seg.nseg

    def body(x):
        # x: local block, segmented dim first for simplicity of slicing
        xm = jnp.moveaxis(x, seg.dim, 0)
        if h:
            # halo exchange == two open-boundary ring shifts: the top
            # rows travel up (+1), the bottom rows travel down (-1);
            # wrap=False zero-fills the edge shards.
            from_prev = shift(xm[-h:], +1, wrap=False, axis=axis, nseg=n)
            from_next = shift(xm[:h], -1, wrap=False, axis=axis, nseg=n)
            xm = jnp.concatenate([from_prev, xm, from_next], axis=0)
        ext = jnp.moveaxis(xm, 0, seg.dim)
        return ext if fn is None else fn(ext)

    spec = seg.pspec
    out = compat.shard_map(body, mesh=mesh, in_specs=spec,
                           out_specs=spec)(seg.data)
    if fn is None:
        return SegmentedArray(out, seg.group, Policy.NATURAL, seg.dim,
                              seg.mesh_axes, orig_len=out.shape[seg.dim])
    return seg.with_data(out)
