"""Deterministic synthetic token pipeline (host-sharded, resumable).

Sequences follow a fixed random Markov chain over the vocab so that a
language model has real structure to learn (train-loss decrease is a
meaningful signal in examples/tests).  Every batch is a pure function of
(seed, step, host_id) — the data order is reproducible across restarts
and across different host counts, which is what checkpoint-resume
correctness requires at scale.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int                      # per-host batch
    seq: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    branching: int = 8              # markov out-degree
    step: int = 0                   # resumable cursor

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._next = rng.integers(0, self.vocab,
                                  size=(self.vocab, self.branching))

    def batch_at(self, step: int):
        """(tokens, labels) for a global step (host-sharded slice)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        starts = rng.integers(0, self.vocab, size=self.batch)
        choices = rng.integers(0, self.branching,
                               size=(self.batch, self.seq))
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = starts
        for t in range(self.seq):
            toks[:, t + 1] = self._next[toks[:, t], choices[:, t]]
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self):
        return self

    def __next__(self):
        out = self.batch_at(self.step)
        self.step += 1
        return out

    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])
