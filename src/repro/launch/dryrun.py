import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init, and the production meshes below need 512 placeholder devices.
# inner-scan unrolling is toggled per compile by launch.costing: ON for
# the shallow costing compiles (truthful FLOP counts), OFF for the
# full-depth compile (memory_analysis + compile proof, 1-core budget).
os.environ.setdefault("REPRO_UNROLL_SCANS", "0")
# bigger blocks -> fewer unrolled inner-scan steps -> tractable compile
# times at 512 devices (same math; block size is a costing knob only)
os.environ.setdefault("REPRO_BLOCK_K", "1024")
os.environ.setdefault("REPRO_MLSTM_CHUNK", "1024")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, print memory/cost analysis, record roofline inputs.

  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --out results/dryrun

Proves, without hardware: the sharding config is coherent (no mismatched
collectives), every cell fits per-chip HBM, and yields the per-device
FLOP/byte/collective numbers EXPERIMENTS.md §Roofline reads.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from ..configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from .cells import build_cell, model_flops
from .costing import cell_cost
from .mesh import make_production_mesh
from .roofline import collective_summary, parse_collectives, roofline_terms


def run_cell(arch, shape, mesh, mesh_name, *, act_sp=True,
             policy="fsdp_tp"):
    t0 = time.time()
    multi = "pod" in mesh.shape
    # full-depth compile: the lower/compile proof + memory_analysis
    # (scans kept, inner scans not unrolled -> tractable on one core)
    lowered, meta = build_cell(arch, shape, mesh, act_sp=act_sp,
                               policy=policy)
    if lowered is None:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "skipped": meta}
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    mem = {"argument_bytes": ma.argument_size_in_bytes,
           "output_bytes": ma.output_size_in_bytes,
           "temp_bytes": ma.temp_size_in_bytes,
           "alias_bytes": ma.alias_size_in_bytes}
    print(f"  memory_analysis: arg={mem['argument_bytes']/2**30:.2f}GiB "
          f"temp={mem['temp_bytes']/2**30:.2f}GiB "
          f"out={mem['output_bytes']/2**30:.2f}GiB "
          f"alias={mem['alias_bytes']/2**30:.2f}GiB")
    rec = {**meta, "mesh_name": mesh_name, "memory": mem}

    if multi:
        # the multi-pod pass proves the pod axis shards; §Roofline is
        # single-pod, so report raw (count-while-once) collectives only
        cs = collective_summary(parse_collectives(compiled.as_text()),
                                pod_group=2)
        rec["collectives_counted_once"] = cs
        print(f"  multi-pod compile OK; dcn_wire(once)="
              f"{cs['dcn_wire_bytes']:.3e}B")
    else:
        cost = cell_cost(arch, shape, mesh, compiled, act_sp=act_sp,
                         policy=policy)
        mf = model_flops(arch, shape)
        terms = roofline_terms(cost, cost["colls"], multi_pod=False)
        rec.update({
            "flops": cost["flops"], "bytes": cost["bytes"],
            "hlo_bytes_raw": cost["hlo_bytes"],
            "slstm_analytic_flops": cost["slstm_analytic_flops"],
            "hbm_model": cost["hbm_model"],
            "depth_correction": cost["depth_correction"],
            "collectives": collective_summary(cost["colls"]),
            "roofline": {k: v for k, v in terms.items()
                         if k != "collectives"},
            **mf,
            "model_vs_hlo": (mf["model_flops"] / mesh.size) /
                            max(cost["flops"], 1.0),
        })
        print(f"  cost_analysis: flops={cost['flops']:.3e} "
              f"bytes={cost['bytes']:.3e} "
              f"coll_ici={rec['collectives']['ici_wire_bytes']:.3e}B")
        print(f"  roofline: compute={terms['t_compute_s']*1e3:.2f}ms "
              f"memory={terms['t_memory_s']*1e3:.2f}ms "
              f"collective={terms['t_collective_s']*1e3:.2f}ms "
              f"dominant={terms['dominant']}")
    rec["compile_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-act-sp", action="store_true",
                    help="disable sequence-parallel activation sharding")
    ap.add_argument("--policy", default="fsdp_tp",
                    choices=["fsdp_tp", "pure_fsdp"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    failures = []
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                tag = f"_{args.tag}" if args.tag else ""
                fn = out / f"{arch}__{shape}__{mesh_name}{tag}.json"
                if fn.exists() and not args.force:
                    print(f"[skip existing] {fn.name}")
                    continue
                print(f"[{mesh_name}] {arch} x {shape}")
                try:
                    rec = run_cell(arch, shape, mesh, mesh_name,
                                   act_sp=not args.no_act_sp,
                                   policy=args.policy)
                    fn.write_text(json.dumps(rec, indent=1))
                    if "skipped" in rec:
                        print(f"  SKIPPED: {rec['skipped']}")
                except Exception as e:  # noqa: BLE001
                    failures.append((mesh_name, arch, shape, repr(e)))
                    print("  FAILED:", repr(e))
                    traceback.print_exc(limit=3)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested cells OK")


if __name__ == "__main__":
    main()
