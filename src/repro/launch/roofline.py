"""Roofline term derivation from a compiled dry-run cell.

  compute term    = HLO_FLOPs(per-device SPMD program) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = wire bytes per device (ring model) / ICI link bw

cost_analysis() describes the per-device SPMD program (verified in the
512-device spike: global/512), so no chip division is needed.
collective bytes are parsed from the compiled HLO text; each op's wire
traffic uses the standard ring model on its replica-group size n:

  all-reduce      2 B (n-1)/n        all-gather      B (n-1)/n
  reduce-scatter  B_out (n-1)        all-to-all      B (n-1)/n
  collective-permute  B

DCN (pod-axis) collectives are separated by group-size-2 heuristic on
the (2,16,16) mesh and costed at dcn_bw.
"""

from __future__ import annotations

import re

from ..core.runtime import HW

_DT_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=(?:\[(\d+),(\d+)\]|\{\{([\d,]+)\}?)")


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> list[dict]:
    """One record per collective op in the per-device program."""
    out = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_s, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_s)
        g = _GROUPS_RE.search(line)
        if g:
            if g.group(2) is not None:          # iota [G,S]<=...
                n = int(g.group(2))
            else:                               # explicit {{0,1,..},..}
                n = len(g.group(3).split(","))
        else:
            n = 1
        if n <= 1:
            continue
        wire = {
            "all-reduce": 2 * nbytes * (n - 1) / n,
            "all-gather": nbytes * (n - 1) / n,
            "reduce-scatter": nbytes * (n - 1),
            "all-to-all": nbytes * (n - 1) / n,
            "collective-permute": float(nbytes),
        }[kind]
        out.append({"kind": kind, "bytes": nbytes, "group": n,
                    "wire_bytes": wire})
    return out


def collective_summary(colls: list[dict], pod_group: int | None = None) -> dict:
    """pod_group: replica-group size that indicates a DCN (pod-axis)
    collective — only meaningful on the multi-pod mesh (size-2 pod axis);
    pass None on single-pod meshes (all traffic is ICI)."""
    s = {"ici_wire_bytes": 0.0, "dcn_wire_bytes": 0.0, "by_kind": {}}
    for c in colls:
        tgt = "dcn_wire_bytes" if (pod_group and c["group"] == pod_group) \
            else "ici_wire_bytes"
        s[tgt] += c["wire_bytes"]
        k = s["by_kind"].setdefault(c["kind"], {"count": 0, "wire": 0.0})
        k["count"] += 1
        k["wire"] += c["wire_bytes"]
    return s


def roofline_terms(cost: dict, colls: list[dict], *, multi_pod=False) -> dict:
    flops = float(cost.get("flops", cost.get("flops", 0.0)))
    bytes_acc = float(cost.get("bytes", cost.get("bytes accessed", 0.0)))
    cs = collective_summary(colls, pod_group=2 if multi_pod else None)
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_coll = cs["ici_wire_bytes"] / HW["ici_bw"]
    if multi_pod:
        t_coll += cs["dcn_wire_bytes"] / HW["dcn_bw"]
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll, "collectives": cs}
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    terms["dominant"] = dom[0]
    terms["step_time_bound_s"] = dom[1]
    return terms
