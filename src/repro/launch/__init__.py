from . import mesh, roofline
