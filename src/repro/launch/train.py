"""Production training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \\
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a real pod each host runs this under the cluster launcher (see
launch_pod.sh); jax.distributed wires the hosts together.  On CPU it
trains reduced configs end-to-end (examples/train_lm.py uses it).
Fault tolerance: resume-from-latest, periodic + preemption-flush
checkpoints, straggler logging, restart envelope.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..ckpt import latest_step, restore_sharded, save
from ..configs import ARCH_IDS, get_config, get_smoke
from ..data import TokenPipeline
from ..ft import PreemptionGuard, RestartPolicy, StragglerWatchdog, \
    run_with_restarts
from ..models import frontends
from ..train import make_train_state, make_train_step, state_shardings


def build(args):
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.dtype:
        cfg = dataclasses.replace(cfg, compute_dtype=args.dtype)
    ndev = len(jax.devices())
    mesh_shape = tuple(int(x) for x in args.mesh.split("x")) \
        if args.mesh else (ndev,)
    axes = ("data", "model")[: len(mesh_shape)]
    from repro.core import compat
    mesh = compat.make_mesh(mesh_shape, axes)
    return cfg, mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, mesh = build(args)
    fsdp = tuple(a for a in ("data",) if a in mesh.shape)
    step_fn, _ = make_train_step(
        cfg, mesh, base_lr=args.lr, warmup=min(20, args.steps // 10 + 1),
        total=args.steps, microbatches=args.microbatches,
        remat=False, fsdp=fsdp, donate=False)
    jstep = jax.jit(step_fn)

    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         seed=args.seed,
                         n_hosts=jax.process_count(),
                         host_id=jax.process_index())
    guard = PreemptionGuard()
    watchdog = StragglerWatchdog()

    def train_loop(_start):
        with mesh:
            state = make_train_state(cfg, jax.random.PRNGKey(args.seed))
            start = 0
            if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
                sh = jax.tree.map(
                    lambda x: NamedSharding(mesh, P()), state)
                state, start = restore_sharded(args.ckpt_dir, state, sh)
                print(f"resumed from step {start}")
            losses = []
            for step in range(start, args.steps):
                t0 = time.time()
                tok, lab = pipe.batch_at(step)
                state, metrics = jstep(state, jnp.asarray(tok),
                                       jnp.asarray(lab), None)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                if watchdog.record(dt):
                    print(f"[straggler] step {step}: {dt:.2f}s "
                          f"(median {watchdog.median:.2f}s)")
                if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                    save(args.ckpt_dir, step + 1, state, blocking=False)
                if args.ckpt_dir and guard.maybe_flush(
                        args.ckpt_dir, step + 1, state):
                    print("preempted: checkpoint flushed")
                    return step + 1
                if step % args.log_every == 0 or step == args.steps - 1:
                    tput = args.batch * args.seq / dt
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['gnorm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"{tput:,.0f} tok/s")
            if args.ckpt_dir:
                save(args.ckpt_dir, args.steps, state, blocking=True)
            print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
            return args.steps

    run_with_restarts(train_loop, policy=RestartPolicy(max_restarts=3))


if __name__ == "__main__":
    main()
