"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets the host
device count before first jax init.
"""

from __future__ import annotations

import jax

from ..core import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e); 2 pods over DCN when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def mesh_axes(mesh):
    names = tuple(mesh.axis_names)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    tp = "model" if "model" in names else None
    return fsdp, tp
