"""Serving driver: batched greedy decoding with the slot engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \\
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke
from ..models import transformer
from ..serve import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import dataclasses
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, batch=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for r in range(args.requests):
        plen = int(rng.integers(4, 17))
        eng.submit(rng.integers(0, cfg.vocab, plen).tolist(),
                   max_new=args.max_new)
    done = eng.run()
    dt = time.time() - t0
    ntok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {ntok} tokens "
          f"in {dt:.2f}s ({ntok / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4]} out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
