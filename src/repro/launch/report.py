"""Render EXPERIMENTS.md tables from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import pathlib

from ..configs import ARCH_IDS, SHAPES


def load(dir_):
    recs = {}
    for fn in sorted(pathlib.Path(dir_).glob("*.json")):
        d = json.loads(fn.read_text())
        mesh = d.get("mesh_name") or d.get("mesh", "?")
        mesh = mesh if isinstance(mesh, str) else "?"
        recs[(d["arch"], d["shape"], mesh)] = d
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(recs, mesh="pod16x16"):
    lines = [
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
        "HLO GFLOP/dev | model/HLO | HBM GiB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | MISSING "
                             "| | | | |")
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | - | - | - | "
                             f"SKIP ({r['skipped'][:40]}) | | | | |")
                continue
            t = r["roofline"]
            mem = r["memory"]
            per_dev = (mem["argument_bytes"] + mem["temp_bytes"] +
                       mem["output_bytes"] - mem["alias_bytes"])
            fits = "Y" if per_dev < 16 * 2**30 else "N"
            lines.append(
                f"| {arch} | {shape} | {t['t_compute_s']*1e3:.2f} | "
                f"{t['t_memory_s']*1e3:.2f} | {t['t_collective_s']*1e3:.2f} | "
                f"{t['dominant']} | {r['flops']/1e9:.1f} | "
                f"{r['model_vs_hlo']:.2f} | {per_dev/2**30:.2f} | {fits} |")
    return "\n".join(lines)


def multipod_table(recs, mesh="pod2x16x16"):
    lines = [
        "| arch | shape | compile | arg GiB | temp GiB | dcn wire (once) |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | |")
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | SKIP | | | |")
                continue
            mem = r["memory"]
            dcn = r.get("collectives_counted_once", {}).get(
                "dcn_wire_bytes", 0.0)
            lines.append(
                f"| {arch} | {shape} | OK ({r['compile_s']}s) | "
                f"{fmt_bytes(mem['argument_bytes'])} | "
                f"{fmt_bytes(mem['temp_bytes'])} | {dcn:.2e} B |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Single-pod roofline (16x16)\n")
    print(roofline_table(recs, args.mesh))
    print("\n## Multi-pod compile pass (2x16x16)\n")
    print(multipod_table(recs))


if __name__ == "__main__":
    main()
