"""Build + lower one (architecture x input-shape x mesh) dry-run cell.

Shared by dryrun.py (compile + record) and the perf loop.  Everything is
ShapeDtypeStruct-based — no parameter/cache allocation ever happens.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, cell_applicable, get_config
from ..models import frontends, transformer
from ..train.trainer import make_train_step, make_train_state, \
    state_shardings
from .mesh import mesh_axes


def _bspec(axes):
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _sh(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _to_named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def expert_pad_for(cfg, mesh):
    tpn = mesh.shape.get("model", 1)
    return tpn if (cfg.n_experts and cfg.n_experts % tpn) else 1


def build_cell(arch: str, shape: str, mesh, *, remat=True,
               act_sp=True, overrides=None, policy="fsdp_tp"):
    """Returns (lowered, meta) or (None, skip_reason).

    ``policy``: "fsdp_tp" (2-D: FSDP over data/pod + TP over model) or
    "pure_fsdp" (every axis is a data/FSDP axis; no tensor parallelism —
    the right split for small-d models where TP is all overhead).  The
    MGPU lesson: the segmentation policy is a per-workload choice.
    """
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return None, reason

    seq, gbatch, kind = SHAPES[shape]
    fsdp, tp = mesh_axes(mesh)
    if policy == "pure_fsdp":
        fsdp = tuple(mesh.axis_names)
        tp = None
    tpn = mesh.shape.get("model", 1)
    epad = expert_pad_for(cfg, mesh)
    bt = _bspec(fsdp)
    nbatch = int(np.prod([mesh.shape[a] for a in fsdp]))
    batch_ok = gbatch % nbatch == 0
    bspec = bt if batch_ok else None         # batch=1 cells: replicate

    meta = dict(arch=arch, shape=shape, kind=kind, seq=seq, gbatch=gbatch,
                mesh=dict(mesh.shape), expert_pad=epad,
                batch_sharded=batch_ok, policy=policy)

    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(
        lambda: transformer.init_params(cfg, key, expert_pad=epad))
    pspec = transformer.param_pspecs(cfg, params_sds, dict(mesh.shape),
                                     tp=tp, fsdp=fsdp)
    param_sh = _to_named(mesh, pspec)
    rep = _sh(mesh)
    tok_sh = _sh(mesh, bspec, None)
    enc_sds = frontends.frontend_struct(cfg, gbatch, cfg.cdtype)
    enc_sh = _sh(mesh, bspec, None, None) if enc_sds is not None else None
    if act_sp and tp and seq % tpn == 0 and kind != "decode":
        act = _sh(mesh, bspec, "model", None)   # Megatron SP
    elif kind != "decode":
        act = _sh(mesh, bspec, None, None)      # batch-sharded residual
    else:
        act = None

    if kind == "train":
        state_sds = jax.eval_shape(
            lambda: make_train_state(cfg, key, expert_pad=epad))
        st_sh = state_shardings(cfg, state_sds, mesh, fsdp=fsdp, tp=tp)
        step_fn, _ = make_train_step(cfg, mesh, remat=remat, fsdp=fsdp,
                                     tp=tp, batch_axes=fsdp,
                                     act_sharding=act)
        tok = jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)
        with mesh:
            if enc_sds is None:
                fn = lambda st, t, l: step_fn(st, t, l, None)
                jitted = jax.jit(fn, in_shardings=(st_sh, tok_sh, tok_sh),
                                 out_shardings=(st_sh, rep),
                                 donate_argnums=(0,))
                lowered = jitted.lower(state_sds, tok, tok)
            else:
                fn = lambda st, t, l, e: step_fn(st, t, l, e)
                jitted = jax.jit(fn,
                                 in_shardings=(st_sh, tok_sh, tok_sh, enc_sh),
                                 out_shardings=(st_sh, rep),
                                 donate_argnums=(0,))
                lowered = jitted.lower(state_sds, tok, tok, enc_sds)
        return lowered, meta

    cache_sds = jax.eval_shape(
        lambda: transformer.init_cache(cfg, gbatch, seq, cfg.cdtype))
    cspec = transformer.cache_pspecs(cfg, cache_sds, dict(mesh.shape),
                                     tp=tp, batch=fsdp if batch_ok else ())
    cache_sh = _to_named(mesh, cspec)

    if kind == "prefill":
        def prefill_step(params, tokens, enc=None):
            cache = transformer.init_cache(cfg, gbatch, seq, cfg.cdtype)
            logits, cache, _ = transformer.apply(
                cfg, params, tokens, enc=enc, mode="prefill", pos=0,
                cache=cache, act_sharding=act, logits_window=1)
            return logits[:, -1], cache

        tok = jax.ShapeDtypeStruct((gbatch, seq), jnp.int32)
        with mesh:
            if enc_sds is None:
                jitted = jax.jit(lambda p, t: prefill_step(p, t),
                                 in_shardings=(param_sh, tok_sh),
                                 out_shardings=(rep, cache_sh))
                lowered = jitted.lower(params_sds, tok)
            else:
                jitted = jax.jit(prefill_step,
                                 in_shardings=(param_sh, tok_sh, enc_sh),
                                 out_shardings=(rep, cache_sh))
                lowered = jitted.lower(params_sds, tok, enc_sds)
        return lowered, meta

    if kind == "decode":
        def decode_step(params, cache, tokens, pos):
            logits, cache, _ = transformer.apply(
                cfg, params, tokens, enc=None, mode="decode", pos=pos,
                cache=cache)
            return logits[:, -1], cache

        tok = jax.ShapeDtypeStruct((gbatch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            jitted = jax.jit(decode_step,
                             in_shardings=(param_sh, cache_sh, tok_sh, rep),
                             out_shardings=(rep, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok, pos)
        return lowered, meta

    raise ValueError(kind)


def model_flops(arch: str, shape: str) -> dict:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (N = active
    params; D = tokens processed per step)."""
    cfg = get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    n_active = transformer.param_count(cfg, active_only=True)
    n_total = transformer.param_count(cfg)
    tokens = gbatch * (seq if kind in ("train", "prefill") else 1)
    mult = 6 if kind == "train" else 2
    return {"n_total": n_total, "n_active": n_active,
            "tokens_per_step": tokens,
            "model_flops": mult * n_active * tokens}
