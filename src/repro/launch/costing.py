"""Truthful cost extraction from compiled dry-run cells — 1-core budget.

XLA's HloCostAnalysis counts a while-loop body ONCE, so a scanned-layers
model under-reports FLOPs/bytes/collectives by ~n_layers, and unrolling
everything at 512 devices is too slow to compile on one core.  Scheme:

  stem      = compile with n_layers = first_dense        (embed/loss/opt
              + any leading dense layers, inner scans unrolled)
  reduced   = compile with n_layers = first_dense + k*len(pattern) + rem
              (k<=2 pattern units, unrolled inner scans)
  corrected = stem + (reduced - stem) * (n_layers - first_dense)
                                       / (reduced_layers - first_dense)

Exact for homogeneous stacks (9/10 archs); <=5% mix error for
recurrentgemma's 1:2 hybrid remainder (noted in EXPERIMENTS.md).
The full-depth cell is compiled separately (scans kept, no unroll) for
memory_analysis and the lower/compile proof — its cost numbers are not
used.  The sequential sLSTM keeps a time-step while loop even unrolled;
its per-token work is added analytically.

The memory term uses an itemized HBM-traffic model (weights, optimizer,
remat stashes, KV cache, logits): HLO 'bytes accessed' counts every
pre-fusion intermediate and is orders of magnitude above real traffic.
"""

from __future__ import annotations

import os

import jax
import numpy as np

from ..configs import SHAPES, get_config
from ..models import transformer
from .cells import build_cell
from .roofline import parse_collectives


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    return {"flops": float(ca.get("flops", 0.0)),
            "hlo_bytes": float(ca.get("bytes accessed", 0.0)),
            "colls": parse_collectives(compiled.as_text())}


def _compile_cost(arch, shape, mesh, *, n_layers, act_sp, unroll,
                  policy="fsdp_tp"):
    prev = os.environ.get("REPRO_UNROLL_SCANS", "0")
    os.environ["REPRO_UNROLL_SCANS"] = "1" if unroll else "0"
    try:
        lowered, meta = build_cell(arch, shape, mesh, act_sp=act_sp,
                                   overrides={"n_layers": n_layers},
                                   policy=policy)
        compiled = lowered.compile()
        return _cost_dict(compiled)
    finally:
        os.environ["REPRO_UNROLL_SCANS"] = prev


def reduced_depths(cfg) -> tuple[int, int]:
    """(stem_layers, reduced_layers) preserving the group structure."""
    u = max(len(cfg.pattern), 1)
    fd = cfg.first_dense
    body = cfg.n_layers - fd
    k = 2 if (u <= 3 and body >= 2 * u) else 1
    rem = body % u
    red = fd + min(k * u + rem, body)
    return fd, max(red, fd + 1)


def _scale_costs(stem, red, factor):
    out = {
        "flops": stem["flops"] + (red["flops"] - stem["flops"]) * factor,
        "hlo_bytes": stem["hlo_bytes"] +
        (red["hlo_bytes"] - stem["hlo_bytes"]) * factor,
    }
    # collectives: stem ops once + (reduced - stem share) scaled.  Rather
    # than diff op lists, scale every reduced-compile collective by
    # factor and add stem's unscaled ones with weight (1 - factor/1)
    # folded in: stem ops also appear in reduced; net = stem*(1) +
    # (red - stem)*factor  ==  red_colls*factor + stem_colls*(1-factor).
    colls = []
    for c in red["colls"]:
        colls.append({**c, "wire_bytes": c["wire_bytes"] * factor})
    for c in stem["colls"]:
        colls.append({**c, "wire_bytes": c["wire_bytes"] * (1.0 - factor)})
    out["colls"] = colls
    return out


def analytic_hbm_bytes(cfg, kind, gbatch, seq, mesh, n_total,
                       cache_bytes=0) -> dict:
    """Per-chip HBM traffic model (bytes) for the memory roofline term."""
    chips = mesh.size
    d = cfg.d_model
    wt_bf16 = n_total * 2 / chips
    items = {}
    if kind == "train":
        items["weights_rw"] = 3 * wt_bf16
        items["grads_rw"] = n_total * 4 * 2 / chips
        items["optimizer_rw"] = n_total * 4 * 6 / chips
        items["act_stash_rw"] = (gbatch * seq * d * 2 / chips
                                 * cfg.n_layers * 3)
        items["logits_rw"] = gbatch * seq * cfg.vocab * 4 / chips * 2
    elif kind == "prefill":
        items["weights_r"] = wt_bf16
        items["activations_rw"] = gbatch * seq * d * 2 / chips \
            * cfg.n_layers * 2
        items["cache_w"] = cache_bytes / chips
    else:
        items["weights_r"] = wt_bf16
        items["cache_rw"] = cache_bytes / chips * 2
        items["activations_rw"] = gbatch * 1 * d * 2 / chips \
            * cfg.n_layers * 2
    items["total"] = float(sum(items.values()))
    return items


def slstm_analytic(cfg, kind, gbatch, seq) -> float:
    kinds = cfg.layer_kinds()
    n_sl = sum(1 for k in kinds if k == "slstm")
    if not n_sl:
        return 0.0
    d = cfg.d_model
    hd = d // max(cfg.rnn_heads, 1)
    per_tok = 2 * 4 * d * hd + 20 * d
    toks = gbatch * (seq if kind != "decode" else 1)
    mult = 3 if kind == "train" else 1
    return float(n_sl * per_tok * toks * mult)


def cell_cost(arch, shape, mesh, compiled_full, *, act_sp=True,
              policy="fsdp_tp") -> dict:
    """Corrected per-device cost for one cell (single-pod roofline)."""
    cfg = get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    fd, red = reduced_depths(cfg)

    # (act spec mirrors cells.build_cell via the same policy/act_sp args)
    stem = _compile_cost(arch, shape, mesh, n_layers=fd,
                         act_sp=act_sp, unroll=True, policy=policy)
    redc = _compile_cost(arch, shape, mesh, n_layers=red,
                         act_sp=act_sp, unroll=True, policy=policy)
    factor = (cfg.n_layers - fd) / max(red - fd, 1)
    total = _scale_costs(stem, redc, factor)

    # sLSTM sequential while: add per-token analytic work (per device:
    # batch is sharded over the non-model mesh axes)
    data_shards = max(mesh.size // mesh.shape.get("model", 1), 1)
    total["slstm_analytic_flops"] = \
        slstm_analytic(cfg, kind, gbatch, seq) / data_shards
    total["flops"] += total["slstm_analytic_flops"]

    ma = compiled_full.memory_analysis()
    total["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
    }

    n_total = transformer.param_count(cfg)
    cache_bytes = 0
    if kind != "train":
        cache_sds = jax.eval_shape(
            lambda: transformer.init_cache(cfg, gbatch, seq, cfg.cdtype))
        cache_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                          for l in jax.tree.leaves(cache_sds))
    total["hbm_model"] = analytic_hbm_bytes(cfg, kind, gbatch, seq, mesh,
                                            n_total, cache_bytes)
    total["bytes"] = total["hbm_model"]["total"]
    total["depth_correction"] = {"stem_layers": fd, "reduced_layers": red,
                                 "factor": factor}
    return total
