"""Composable decoder stack covering all 10 assigned architectures.

Layers are grouped into maximal runs of a repeating *unit* (the config's
``pattern``) and executed with ``jax.lax.scan`` over stacked unit params —
this keeps the HLO size independent of depth (46-layer gemma2 compiles as
one unit body), which is what makes the 512-device dry-run tractable.

Pure functional API:
  init_params(cfg, key)                     -> params pytree
  apply(cfg, params, tokens, ...)           -> (logits, new_cache, aux)
  init_cache(cfg, batch, max_len, dtype)    -> cache pytree
  param_pspecs(cfg, params, mesh_axes)      -> matching PartitionSpec tree
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import attention, moe, recurrent
from .layers import dense_init, mlp, mlp_params, rms_norm, softcap, \
    sinusoidal_positions

ATTN_KINDS = ("attn", "local", "mla", "cross")
RNN_KINDS = ("mlstm", "slstm", "rglru")


# ---------------------------------------------------------------------------
# layer grouping
# ---------------------------------------------------------------------------

def layer_sigs(cfg) -> list[tuple[str, str]]:
    return [(k, cfg.ffn_kind(i)) for i, k in enumerate(cfg.layer_kinds())]


def layer_groups(cfg) -> list[tuple[list[tuple[str, str]], int]]:
    """[(unit_signature, n_repeats)] covering all layers in order."""
    sigs = layer_sigs(cfg)
    n = len(sigs)
    u = max(len(cfg.pattern), 1)
    groups = []
    i = 0
    while i < n:
        for ulen in (u, 1):
            unit = sigs[i:i + ulen]
            if len(unit) < ulen:
                continue
            reps = 1
            while sigs[i + reps * ulen: i + (reps + 1) * ulen] == unit:
                reps += 1
            if reps > 1 or ulen == 1:
                groups.append((unit, reps))
                i += ulen * reps
                break
        else:  # pragma: no cover
            groups.append((sigs[i:i + 1], 1))
            i += 1
    return groups


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(cfg, key, sig, moe_pad):
    kind, ffn = sig
    ks = iter(jax.random.split(key, 8))
    p: dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.post_norm:
        p["norm1_post"] = jnp.ones((cfg.d_model,), jnp.float32)
    if kind in ATTN_KINDS:
        p["attn"] = attention.init(cfg, next(ks), kind)
    elif kind == "mlstm":
        p["rnn"] = recurrent.mlstm_init(cfg, next(ks))
    elif kind == "slstm":
        p["rnn"] = recurrent.slstm_init(cfg, next(ks))
    elif kind == "rglru":
        p["rnn"] = recurrent.rglru_init(cfg, next(ks))
    else:
        raise ValueError(kind)
    if cfg.cross_kind == "decoder":
        p["xnorm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["xattn"] = attention.init(cfg, next(ks), "cross")
    if ffn != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        if cfg.post_norm:
            p["norm2_post"] = jnp.ones((cfg.d_model,), jnp.float32)
    if ffn == "mlp":
        dff = cfg.dense_d_ff if (cfg.n_experts and cfg.dense_d_ff) else cfg.d_ff
        p["mlp"] = mlp_params(next(ks), cfg.d_model, dff, gated=cfg.gated_mlp)
    elif ffn == "moe":
        p["moe"] = moe.init(cfg, next(ks), pad_to=moe_pad)
    return p


def _layer_cache(cfg, sig, batch, max_len, dtype):
    kind, _ = sig
    c: dict[str, Any] = {}
    if kind in ATTN_KINDS:
        c["attn"] = attention.init_cache(cfg, kind, batch, max_len, dtype)
    elif kind == "mlstm":
        c["rnn"] = recurrent.mlstm_state(cfg, batch, dtype)
    elif kind == "slstm":
        c["rnn"] = recurrent.slstm_state(cfg, batch, dtype)
    elif kind == "rglru":
        c["rnn"] = recurrent.rglru_state(cfg, batch, dtype)
    if cfg.cross_kind == "decoder":
        c["xattn"] = attention.init_cache(cfg, "cross", batch, max_len, dtype)
    return c


def _layer_apply(cfg, sig, p, x, mode, *, pos, cache, enc, constrain=None):
    kind, ffn = sig
    rs = cfg.residual_scale
    cst = constrain or (lambda v: v)
    new_cache: dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)

    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ATTN_KINDS:
        h, nc = attention.apply(cfg, p["attn"], h, kind, mode, pos=pos,
                                cache=None if cache is None else cache.get("attn"),
                                enc=enc if kind == "cross" else None)
        if nc is not None:
            new_cache["attn"] = nc
    else:
        fn = {"mlstm": recurrent.mlstm_apply, "slstm": recurrent.slstm_apply,
              "rglru": recurrent.rglru_apply}[kind]
        h, nc = fn(cfg, p["rnn"], h, mode,
                   state=None if cache is None else cache.get("rnn"), pos=pos)
        if nc is not None:
            new_cache["rnn"] = nc
    if cfg.post_norm:
        h = rms_norm(h, p["norm1_post"], cfg.norm_eps)
    # constrain at every residual junction: turns the TP psum into a
    # reduce-scatter onto the sequence-sharded residual (Megatron SP)
    x = cst(x + rs * h)

    if cfg.cross_kind == "decoder":
        h = rms_norm(x, p["xnorm"], cfg.norm_eps)
        h, ncx = attention.apply(cfg, p["xattn"], h, "cross", mode, pos=pos,
                                 cache=None if cache is None else cache.get("xattn"),
                                 enc=enc)
        if ncx is not None:
            new_cache["xattn"] = ncx
        x = cst(x + rs * h)

    if ffn != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if ffn == "mlp":
            h = mlp(p["mlp"], h, cfg.act)
        else:
            h, moe_aux = moe.apply(cfg, p["moe"], h)
            aux = aux + moe_aux["lb_loss"]
        if cfg.post_norm:
            h = rms_norm(h, p["norm2_post"], cfg.norm_eps)
        x = cst(x + rs * h)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# whisper-style bidirectional encoder
# ---------------------------------------------------------------------------

def _encoder_init(cfg, key):
    ks = jax.random.split(key, cfg.encoder_layers + 1)
    layers = []
    for i in range(cfg.encoder_layers):
        kk = iter(jax.random.split(ks[i], 3))
        layers.append({
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": attention.init(cfg, next(kk), "attn"),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": mlp_params(next(kk), cfg.d_model, cfg.d_ff, gated=False),
        })
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {"layers": stack,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}


def _encoder_apply(cfg, p, frames):
    """frames: (B, T, d) precomputed frontend embeddings (stub)."""
    from ..kernels.flash_attention import chunked_attention
    x = frames + sinusoidal_positions(frames.shape[1],
                                      cfg.d_model).astype(frames.dtype)

    def body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        B, S, d = h.shape
        H = cfg.n_heads
        dt = h.dtype
        q = attention._split_heads(h @ lp["attn"]["wq"].astype(dt), H)
        k = attention._split_heads(h @ lp["attn"]["wk"].astype(dt),
                                   cfg.n_kv_heads)
        v = attention._split_heads(h @ lp["attn"]["wv"].astype(dt),
                                   cfg.n_kv_heads)
        o = chunked_attention(q, k, v, causal=False)
        x = x + attention._merge_heads(o) @ lp["attn"]["wo"].astype(dt)
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, "gelu")
        return x, None

    # unroll: the encoder is shallow and HloCostAnalysis counts while
    # bodies once — unrolling keeps the dry-run FLOP numbers truthful.
    x, _ = jax.lax.scan(body, x, p["layers"], unroll=cfg.encoder_layers)
    return rms_norm(x, p["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init_params(cfg, key, expert_pad: int = 1):
    """``expert_pad``: pad the expert count to a multiple of the TP axis
    size so the (E, d, f) stacks shard (launch passes the mesh's model
    size; dummy experts are masked in the router)."""
    groups = layer_groups(cfg)
    ks = jax.random.split(key, len(groups) + 3)
    params: dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), 0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab))
    if cfg.encoder_layers:
        params["encoder"] = _encoder_init(cfg, ks[2])
    gp = []
    mpad = expert_pad if cfg.n_experts else 1
    for gi, (unit, reps) in enumerate(groups):
        rep_keys = jax.random.split(ks[3 + gi], reps)
        units = []
        for r in range(reps):
            lk = jax.random.split(rep_keys[r], len(unit))
            units.append({f"l{j}": _layer_init(cfg, lk[j], sig, mpad)
                          for j, sig in enumerate(unit)})
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *units) \
            if reps > 1 else units[0]
        gp.append(stacked)
    params["groups"] = gp
    return params


def init_cache(cfg, batch, max_len, dtype):
    caches = []
    for unit, reps in layer_groups(cfg):
        one = {f"l{j}": _layer_cache(cfg, sig, batch, max_len, dtype)
               for j, sig in enumerate(unit)}
        if reps > 1:
            one = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), one)
        caches.append(one)
    return caches


def apply(cfg, params, tokens, *, enc=None, mode="train", pos=0,
          cache=None, remat=False, act_sharding=None, logits_window=None):
    """tokens: (B, S) int32.  Returns (logits, new_cache, aux).

    ``act_sharding``: optional NamedSharding constraint applied to the
    residual stream at every unit boundary — with the sequence dim on the
    TP axis this is Megatron-style sequence parallelism, and (because the
    scan carry is what remat stashes) it divides the activation-
    checkpoint footprint by the TP degree.
    ``logits_window``: compute logits only for the last N positions
    (prefill needs just the final token — skips the (B,S,V) tensor).
    """
    dt = cfg.cdtype
    constrain = (lambda v: jax.lax.with_sharding_constraint(v, act_sharding)) \
        if act_sharding is not None else (lambda v: v)
    x = params["embed"][tokens].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    x = constrain(x)
    if cfg.encoder_layers and enc is not None:
        enc = _encoder_apply(cfg, params["encoder"], enc.astype(dt))
    elif enc is not None:
        enc = enc.astype(dt)

    groups = layer_groups(cfg)
    new_cache = [] if cache is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    for gi, (unit, reps) in enumerate(groups):
        gp = params["groups"][gi]
        gc = cache[gi] if cache is not None else None

        def unit_apply(x, up, uc):
            return unit_forward(cfg, unit, up, x, uc, enc=enc, mode=mode,
                                pos=pos, constrain=constrain)

        if reps == 1:
            x, ncs, aux = unit_apply(x, gp, gc)
            aux_total = aux_total + aux
            if new_cache is not None:
                new_cache.append(ncs)
        else:
            def body(carry, xs):
                x, aux_acc = carry
                up, uc = xs
                x, ncs, aux = unit_apply(x, up, uc)
                return (x, aux_acc + aux), ncs

            body_fn = jax.checkpoint(body) if (remat and mode == "train") \
                else body
            uc_stack = gc if gc is not None else _none_stack(gp)
            (x, aux_total), ncs = jax.lax.scan(
                body_fn, (x, aux_total), (gp, uc_stack))
            if new_cache is not None:
                new_cache.append(ncs)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_window is not None:
        x = x[:, -logits_window:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(dt)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_cache, aux_total


def _none_stack(gp):
    """Scan xs placeholder when there is no cache (train mode)."""
    reps = jax.tree.leaves(gp)[0].shape[0]
    return jnp.zeros((reps,), jnp.float32)


def unit_forward(cfg, unit, up, x, uc=None, *, enc=None, mode="train",
                 pos=0, constrain=None):
    """Apply one pattern unit (the scan body).  Public so the dry-run
    costing can compile a unit standalone and correct for XLA's
    count-while-body-once FLOP accounting."""
    constrain = constrain or (lambda v: v)
    uc = uc if isinstance(uc, dict) else None
    ncs, aux = {}, jnp.zeros((), jnp.float32)
    for j, sig in enumerate(unit):
        x, nc, a = _layer_apply(
            cfg, sig, up[f"l{j}"], x, mode, pos=pos,
            cache=None if uc is None else uc[f"l{j}"], enc=enc,
            constrain=constrain)
        ncs[f"l{j}"] = nc
        aux = aux + a
    return constrain(x), ncs, aux


# ---------------------------------------------------------------------------
# parameter/cache partition specs (FSDP over data(+pod), TP over model)
# ---------------------------------------------------------------------------

def _divides(n, axes, mesh_shape):
    size = int(np.prod([mesh_shape[a] for a in axes]))
    return n % size == 0


def _matrix_spec(shape, mesh_shape, tp, fsdp):
    """Shard one dim over TP (prefer last), another over FSDP."""
    nd = len(shape)
    spec = [None] * nd
    tp_dim = None
    if tp is not None:
        for d in reversed(range(nd)):
            if _divides(shape[d], (tp,), mesh_shape) and shape[d] >= 8:
                tp_dim = d
                spec[d] = tp
                break
    for d in reversed(range(nd)):
        if d != tp_dim and fsdp and _divides(shape[d], fsdp, mesh_shape) \
                and shape[d] >= 8:
            spec[d] = fsdp if len(fsdp) > 1 else fsdp[0]
            break
    return P(*spec)


def param_pspecs(cfg, params, mesh_shape, *, tp="model", fsdp=("data",)):
    """PartitionSpec pytree matching ``params`` (works on SDS trees too)."""
    fsdp = tuple(a for a in fsdp if a in mesh_shape)
    tp_ok = tp in mesh_shape

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) <= 1:
            return P()
        names = [str(getattr(k, "key", getattr(k, "name", "")))
                 for k in path]
        # strip any leading stacked-unit dim awareness: specs are by shape.
        if "experts" in names:  # (E, din, dout): EP over model, FSDP inside
            if tp_ok and _divides(shape[-3], (tp,), mesh_shape):
                spec = [None] * len(shape)
                spec[-3] = tp
                if _divides(shape[-2], fsdp, mesh_shape):
                    spec[-2] = fsdp if len(fsdp) > 1 else fsdp[0]
                return P(*spec)
        if names and names[-1] in ("embed", "lm_head"):
            # vocab over TP only (sharded logits).  Deliberately NOT
            # FSDP-sharding d_model: a gather from a (vocab@tp, d@fsdp)
            # table forces GSPMD to materialize a batch-UNsharded
            # (B_global, S, d/fsdp) intermediate before resharding.
            vdim = 0 if names[-1] == "embed" else 1
            spec = [None, None]
            if tp_ok and _divides(shape[vdim], (tp,), mesh_shape):
                spec[vdim] = tp
            elif _divides(shape[vdim], fsdp, mesh_shape):
                spec[vdim] = fsdp if len(fsdp) > 1 else fsdp[0]
            return P(*spec)
        if names and names[-1] in ("wo", "down", "ff_down", "wuv", "wuk"):
            # reduction-side matrices: TP on the contracted (first) dim
            spec = [None] * len(shape)
            if tp_ok and _divides(shape[-2], (tp,), mesh_shape) \
                    and shape[-2] >= 8:
                spec[-2] = tp
            if _divides(shape[-1], fsdp, mesh_shape) and shape[-1] >= 8:
                spec[-1] = fsdp if len(fsdp) > 1 else fsdp[0]
            return P(*spec)
        sp = _matrix_spec(shape[-2:], mesh_shape, tp if tp_ok else None, fsdp)
        return P(*([None] * (len(shape) - 2)), *sp)

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_pspecs(cfg, cache, mesh_shape, *, tp="model", batch=("data",),
                 kv_shard="seq"):
    """KV caches: batch over data axes; TP axis placement per ``kv_shard``:

      "seq"    shard the time dim (flash-decode style: scores/softmax
               decompose into per-shard partials + tiny psums — avoids
               the cache replication GSPMD falls back to when q is
               head-sharded but the cache is head_dim-sharded),
      "heads"  shard kv heads (falls back to trailing dims when heads
               don't divide the axis).

    Built structurally group-by-group so the leading `reps` dim of
    scanned groups is never mistaken for batch."""
    batch = tuple(a for a in batch if a in mesh_shape)
    bspec = batch if len(batch) > 1 else (batch[0] if batch else None)
    tp_ok = tp in mesh_shape

    def leaf_spec(leaf, reps, name):
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        b_dim = 1 if reps > 1 else 0
        if nd <= b_dim:
            return P(*spec)
        if bspec is not None and _divides(shape[b_dim], batch, mesh_shape):
            spec[b_dim] = bspec
        if tp_ok:
            # time dim: attn k/v are (B,Hkv,T,hd) -> dim 2 (+reps);
            # MLA latents (B,T,r) -> dim 1 (+reps)
            t_dim = None
            if kv_shard == "seq":
                if name in ("k", "v") and nd - b_dim == 4:
                    t_dim = b_dim + 2
                elif name in ("ckv", "kr") and nd - b_dim == 3:
                    t_dim = b_dim + 1
            if t_dim is not None and \
                    _divides(shape[t_dim], (tp,), mesh_shape):
                spec[t_dim] = tp
                return P(*spec)
            for d in reversed(range(b_dim + 1, nd)):
                if _divides(shape[d], (tp,), mesh_shape) and shape[d] >= 8:
                    spec[d] = tp
                    break
        return P(*spec)

    out = []
    for (unit, reps), gc in zip(layer_groups(cfg), cache):
        out.append(jax.tree_util.tree_map_with_path(
            lambda p, x: leaf_spec(
                x, reps, str(getattr(p[-1], "key",
                                     getattr(p[-1], "name", "")))), gc))
    return out


# ---------------------------------------------------------------------------
# counts
# ---------------------------------------------------------------------------

def param_count(cfg, active_only=False) -> int:
    tree = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        n = int(np.prod(leaf.shape))
        names = [str(getattr(k, "key", getattr(k, "name", "")))
                 for k in path]
        if active_only and "experts" in names:
            # routed experts: only top_k of n_experts are touched per token
            n = int(n * cfg.top_k / max(cfg.n_experts, 1))
        total += n
    return total
