"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM
(xLSTM).  Same init/apply contract as attention.py; "cache" is the
recurrent state (constant memory — this is what makes long_500k decode
feasible for these archs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.mlstm import init_state as mlstm_init_state
from ..kernels.mlstm import mlstm_scan, mlstm_step
from ..kernels.rg_lru import rg_lru_scan, rg_lru_step
from .layers import ACTS, dense_init, rms_norm

C_RGLRU = 8.0  # Griffin's gate sharpness constant


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: 2 branches, conv, gated LRU)
# ---------------------------------------------------------------------------

def rglru_init(cfg, key):
    d, w = cfg.d_model, cfg.rnn_width
    ks = iter(jax.random.split(key, 8))
    lam = jax.random.uniform(next(ks), (w,), jnp.float32, 0.9, 0.999)
    return {
        "wx": dense_init(next(ks), (d, w)),
        "wy": dense_init(next(ks), (d, w)),
        "conv": dense_init(next(ks), (cfg.conv_width, w), 0.1),
        "wa": dense_init(next(ks), (w, w)),
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": dense_init(next(ks), (w, w)),
        "bi": jnp.zeros((w,), jnp.float32),
        # Λ parametrized so a = sigmoid(lambda_p) starts near 0.9..0.999
        "lam": jnp.log(lam / (1 - lam)),
        "wo": dense_init(next(ks), (w, d)),
    }


def rglru_state(cfg, batch, dtype):
    return {"h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width),
                              dtype)}


def _causal_conv(x, w, tail):
    """Depthwise causal conv.  x: (B,S,W), w: (K,W), tail: (B,K-1,W)."""
    K = w.shape[0]
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    new_tail = xp[:, -(K - 1):] if K > 1 else tail
    return out, new_tail


def rglru_apply(cfg, p, x, mode, *, state=None, pos=0):
    B, S, d = x.shape
    dt = x.dtype
    if state is None:
        state = rglru_state(cfg, B, dt)
    bx = x @ p["wx"].astype(dt)
    by = ACTS["gelu"](x @ p["wy"].astype(dt))
    bx, conv_tail = _causal_conv(bx, p["conv"], state["conv"])

    bxf = bx.astype(jnp.float32)
    r = jax.nn.sigmoid(bxf @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(bxf @ p["wi"] + p["bi"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r       # (B,S,W)
    gated = i * bxf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated

    if mode == "decode":
        h = rg_lru_step(log_a[:, 0], b[:, 0], state["h"])
        hs = h[:, None]
        new_state = {"h": h.astype(jnp.float32), "conv": conv_tail}
    else:
        hs, h_last = rg_lru_scan(log_a, b, state["h"])
        new_state = {"h": h_last.astype(jnp.float32), "conv": conv_tail}

    y = (hs.astype(dt) * by) @ p["wo"].astype(dt)
    return y, new_state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): up-proj, conv, matrix-memory cell, gated down-proj
# ---------------------------------------------------------------------------

def mlstm_init(cfg, key):
    d = cfg.d_model
    di = int(d * cfg.proj_factor)
    H = cfg.rnn_heads
    ks = iter(jax.random.split(key, 9))
    return {
        "up": dense_init(next(ks), (d, di)),
        "gate": dense_init(next(ks), (d, di)),
        "conv": dense_init(next(ks), (cfg.conv_width, di), 0.1),
        "wq": dense_init(next(ks), (di, di)),
        "wk": dense_init(next(ks), (di, di)),
        "wv": dense_init(next(ks), (di, di)),
        "wif": dense_init(next(ks), (di, 2 * H), 0.1),
        "bif": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "down": dense_init(next(ks), (di, d)),
    }


def mlstm_state(cfg, batch, dtype):
    di = int(cfg.d_model * cfg.proj_factor)
    H = cfg.rnn_heads
    hd = di // H
    C, n, m = mlstm_init_state(batch, H, hd, hd)
    return {"C": C, "n": n, "m": m,
            "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype)}


def _heads(x, H):
    B, S, di = x.shape
    return x.reshape(B, S, H, di // H).transpose(0, 2, 1, 3)


def mlstm_apply(cfg, p, x, mode, *, state=None, pos=0):
    B, S, d = x.shape
    dt = x.dtype
    H = cfg.rnn_heads
    if state is None:
        state = mlstm_state(cfg, B, dt)
    u = x @ p["up"].astype(dt)
    z = x @ p["gate"].astype(dt)
    c, conv_tail = _causal_conv(u, p["conv"], state["conv"])
    c_act = ACTS["silu"](c)
    q = _heads(c_act @ p["wq"].astype(dt), H)
    k = _heads(c_act @ p["wk"].astype(dt), H)
    v = _heads(u @ p["wv"].astype(dt), H)
    gates = c_act.astype(jnp.float32) @ p["wif"] + p["bif"]  # (B,S,2H)
    log_i = gates[..., :H].transpose(0, 2, 1)                # (B,H,S)
    log_f = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)

    st = (state["C"], state["n"], state["m"])
    if mode == "decode":
        h, st = mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                           log_i[:, :, 0], log_f[:, :, 0], st)
        h = h[:, :, None]
    else:
        h, st = mlstm_scan(q, k, v, log_i, log_f, st)
    hm = h.transpose(0, 2, 1, 3).reshape(B, S, -1)           # merge heads
    y = (hm.astype(dt) * ACTS["silu"](z)) @ p["down"].astype(dt)
    new_state = {"C": st[0], "n": st[1], "m": st[2], "conv": conv_tail}
    return y, new_state


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, exp gating, block-diag recurrence
# ---------------------------------------------------------------------------

def slstm_init(cfg, key):
    d = cfg.d_model
    H = cfg.rnn_heads
    hd = d // H
    ks = iter(jax.random.split(key, 12))
    p = {f"w{g}": dense_init(next(ks), (d, d)) for g in "ifzo"}
    p.update({f"r{g}": dense_init(next(ks), (H, hd, hd)) for g in "ifzo"})
    p["b"] = jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))])
    dff = int(d * 4 / 3)
    p["ff_up"] = dense_init(next(ks), (d, dff))
    p["ff_gate"] = dense_init(next(ks), (d, dff))
    p["ff_down"] = dense_init(next(ks), (dff, d))
    return p


def slstm_state(cfg, batch, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def _slstm_cell(cfg, p, xt, st):
    """One step.  xt: (B,d) f32 pre-projections applied outside."""
    H = cfg.rnn_heads
    d = cfg.d_model
    hd = d // H
    h = st["h"].reshape(-1, H, hd)
    rec = {g: jnp.einsum("bhk,hkj->bhj", h, p[f"r{g}"]).reshape(-1, d)
           for g in "ifzo"}
    xi, xf, xz, xo = jnp.split(xt + jnp.concatenate(
        [rec["i"], rec["f"], rec["z"], rec["o"]], axis=-1) + p["b"], 4, -1)
    log_i = xi
    log_f = jax.nn.log_sigmoid(xf)
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i = jnp.exp(log_i - m_new)
    f = jnp.exp(log_f + st["m"] - m_new)
    z = jnp.tanh(xz)
    o = jax.nn.sigmoid(xo)
    c = f * st["c"] + i * z
    n = f * st["n"] + i
    h_new = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h_new}


def slstm_apply(cfg, p, x, mode, *, state=None, pos=0):
    B, S, d = x.shape
    dt = x.dtype
    if state is None:
        state = slstm_state(cfg, B, dt)
    xg = jnp.concatenate([x @ p[f"w{g}"].astype(dt) for g in "ifzo"],
                         axis=-1).astype(jnp.float32)       # (B,S,4d)

    if mode == "decode":
        st = _slstm_cell(cfg, p, xg[:, 0], state)
        hs = st["h"][:, None]
        new_state = st
    else:
        def step(st, xt):
            st = _slstm_cell(cfg, p, xt, st)
            return st, st["h"]
        new_state, hs = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)

    hs = hs.astype(dt)
    ff = (ACTS["silu"](hs @ p["ff_gate"].astype(dt)) *
          (hs @ p["ff_up"].astype(dt))) @ p["ff_down"].astype(dt)
    return ff, new_state
