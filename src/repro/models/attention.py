"""Attention variants: GQA (global/local window), MLA, cross-attention.

Pure functions: ``init(cfg, key, kind)`` -> params pytree;
``apply(cfg, p, x, kind, mode, ...)`` -> (y, new_cache).

Modes:
  train    full sequence, no cache returned
  prefill  full sequence, returns a cache sized ``max_len``
  decode   single token at position ``pos`` (uniform over batch), reads
           and updates the cache

Cache layouts (per layer):
  attn   {"k","v": (B, Hkv, T, hd)}            T = max_len
  local  {"k","v": (B, Hkv, W, hd)}            rolling, slot = t % W
  mla    {"ckv": (B, T, r), "kr": (B, T, rope_dim)}   latent cache
  cross  {"k","v": (B, Hkv, T_enc, hd)}        static after prefill
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.flash_attention import (chunked_attention, decode_attention,
                                       flash_attention)
from .layers import dense_init, hint, rms_norm, rope, wuse


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init(cfg, key, kind):
    d, hd, H, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = iter(jax.random.split(key, 12))
    if kind == "mla":
        r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
        nope, ropd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        p = {
            "wdkv": dense_init(next(ks), (d, r)),
            "kv_norm": jnp.ones((r,), jnp.float32),
            "wkr": dense_init(next(ks), (d, ropd)),
            "wuk": dense_init(next(ks), (r, H * nope)),
            "wuv": dense_init(next(ks), (r, H * vd)),
            "wo": dense_init(next(ks), (H * vd, d)),
        }
        if qr:
            p["wdq"] = dense_init(next(ks), (d, qr))
            p["q_norm"] = jnp.ones((qr,), jnp.float32)
            p["wuq"] = dense_init(next(ks), (qr, H * (nope + ropd)))
        else:
            p["wq"] = dense_init(next(ks), (d, H * (nope + ropd)))
        return p
    p = {
        "wq": dense_init(next(ks), (d, H * hd)),
        "wk": dense_init(next(ks), (d, Hkv * hd)),
        "wv": dense_init(next(ks), (d, Hkv * hd)),
        "wo": dense_init(next(ks), (H * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    if kind == "cross":
        p["gate"] = jnp.zeros((), jnp.float32)   # gated cross-attn (vlm)
    return p


def init_cache(cfg, kind, batch, max_len, dtype):
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    if kind == "local":
        W = min(cfg.window, max_len)
        return {"k": jnp.zeros((batch, Hkv, W, hd), dtype),
                "v": jnp.zeros((batch, Hkv, W, hd), dtype)}
    if kind == "mla":
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
    if kind == "cross":
        T = cfg.encoder_seq
        return {"k": jnp.zeros((batch, Hkv, T, hd), dtype),
                "v": jnp.zeros((batch, Hkv, T, hd), dtype)}
    return {"k": jnp.zeros((batch, Hkv, max_len, hd), dtype),
            "v": jnp.zeros((batch, Hkv, max_len, hd), dtype)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _split_heads(x, n):
    B, S, _ = x.shape
    return x.reshape(B, S, n, -1).transpose(0, 2, 1, 3)


def _merge_heads(x):
    B, H, S, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * hd)


def _maybe_qk_norm(cfg, p, q, k):
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k


def apply(cfg, p, x, kind, mode, *, pos=0, cache=None, enc=None):
    """x: (B, S, d).  Returns (y, new_cache)."""
    if kind == "mla":
        return _apply_mla(cfg, p, x, mode, pos=pos, cache=cache)
    if kind == "cross":
        return _apply_cross(cfg, p, x, mode, cache=cache, enc=enc)

    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    window = cfg.window if kind == "local" else None
    dt = x.dtype

    q = _split_heads(x @ wuse(p["wq"], dt), H)
    k = _split_heads(x @ wuse(p["wk"], dt), Hkv)
    v = _split_heads(x @ wuse(p["wv"], dt), Hkv)
    q, k = _maybe_qk_norm(cfg, p, q, k)

    if mode == "decode":
        positions = jnp.full((B, 1), pos, jnp.int32)
    else:
        positions = (pos + jnp.arange(S, dtype=jnp.int32))[None]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if mode == "decode":
        T = cache["k"].shape[2]
        if kind == "local":
            slot = pos % T
        else:
            slot = pos
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
        new_cache = {"k": ck, "v": cv}
        if kind == "local":
            idx = jnp.arange(T)
            k_positions = pos - ((pos - idx) % T)        # slot -> abs pos
            k_positions = jnp.broadcast_to(k_positions[None], (B, T))
        else:
            k_positions = None
        # flash-decode: q is tiny — replicate it over TP so GSPMD keeps
        # the cache sequence-sharded (partial softmax + small psums)
        # rather than gathering the (B,Hkv,T,hd) cache.
        q = hint(q, None, None, None, None)
        o = decode_attention(q, ck.astype(dt), cv.astype(dt),
                             kv_len=jnp.full((B,), pos + 1, jnp.int32),
                             window=window, softcap=cfg.attn_softcap,
                             k_positions=k_positions)
    else:
        o = flash_attention(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_softcap, q_offset=pos)
        if mode == "prefill":
            new_cache = _write_prefill_cache(cfg, kind, cache, k, v, pos, S)

    y = _merge_heads(o) @ wuse(p["wo"], dt)
    return y, new_cache


def _write_prefill_cache(cfg, kind, cache, k, v, pos, S):
    """Write prefilled k/v (positions pos..pos+S) into the cache."""
    T = cache["k"].shape[2]
    if kind == "local" and S >= T:
        # rolling cache: keep the last T positions, slot = t % T
        tail_k, tail_v = k[:, :, -T:], v[:, :, -T:]
        start = pos + S - T
        idx = (start + jnp.arange(T)) % T
        ck = cache["k"].at[:, :, idx].set(tail_k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, :, idx].set(tail_v.astype(cache["v"].dtype))
        return {"k": ck, "v": cv}
    slot = pos % T if kind == "local" else pos
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention; deepseek-v2 / minicpm3)
# ---------------------------------------------------------------------------

def _apply_mla(cfg, p, x, mode, *, pos=0, cache=None):
    B, S, d = x.shape
    H = cfg.n_heads
    r = cfg.kv_lora_rank
    nope, ropd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    scale = 1.0 / np.sqrt(nope + ropd)

    # -- queries
    if cfg.q_lora_rank:
        cq = rms_norm(x @ wuse(p["wdq"], dt), p["q_norm"], cfg.norm_eps)
        q = cq @ wuse(p["wuq"], dt)
    else:
        q = x @ wuse(p["wq"], dt)
    q = _split_heads(q, H)                                  # (B,H,S,nope+ropd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    # -- latent kv + shared rope key
    ckv = rms_norm(x @ wuse(p["wdkv"], dt), p["kv_norm"], cfg.norm_eps)
    kr = (x @ wuse(p["wkr"], dt))[:, None]                 # (B,1,S,ropd)

    if mode == "decode":
        positions = jnp.full((B, 1), pos, jnp.int32)
    else:
        positions = (pos + jnp.arange(S, dtype=jnp.int32))[None]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kr = rope(kr, positions, cfg.rope_theta)
    kr = kr[:, 0]                                           # (B,S,ropd)

    new_cache = cache
    if mode == "decode":
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr.astype(cache["kr"].dtype), pos, axis=1)
        new_cache = {"ckv": ckv_all, "kr": kr_all}
        ckv_ctx, kr_ctx = ckv_all.astype(dt), kr_all.astype(dt)
        kv_len = pos + 1
    else:
        ckv_ctx, kr_ctx = ckv, kr
        kv_len = None
        if mode == "prefill":
            ckv_all = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
            kr_all = jax.lax.dynamic_update_slice_in_dim(
                cache["kr"], kr.astype(cache["kr"].dtype), pos, axis=1)
            new_cache = {"ckv": ckv_all, "kr": kr_all}

    # up-project context latents to per-head keys/values
    T = ckv_ctx.shape[1]
    k_nope = _split_heads(ckv_ctx @ wuse(p["wuk"], dt), H)   # (B,H,T,nope)
    vv = _split_heads(ckv_ctx @ wuse(p["wuv"], dt), H)       # (B,H,T,vd)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_ctx[:, None], (B, H, T, ropd))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    if mode == "decode":
        q_full = hint(q_full, None, None, None, None)   # flash-decode
        o = decode_attention(q_full, k_full, vv,
                             kv_len=jnp.full((B,), kv_len, jnp.int32),
                             scale=scale)
    else:
        o = flash_attention(q_full, k_full, vv, causal=True, q_offset=pos,
                            scale=scale)
    y = _merge_heads(o) @ wuse(p["wo"], dt)
    return y, new_cache


# ---------------------------------------------------------------------------
# cross attention (vlm interleaved / whisper decoder)
# ---------------------------------------------------------------------------

def _apply_cross(cfg, p, x, mode, *, cache=None, enc=None):
    """enc: (B, T_enc, d) encoder/frontend states (None in decode: use cache)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype

    q = _split_heads(x @ wuse(p["wq"], dt), H)
    if enc is not None:
        k = _split_heads(enc.astype(dt) @ wuse(p["wk"], dt), Hkv)
        v = _split_heads(enc.astype(dt) @ wuse(p["wv"], dt), Hkv)
        if mode in ("prefill", "decode") and cache is not None:
            cache = {"k": k.astype(cache["k"].dtype),
                     "v": v.astype(cache["v"].dtype)}
    else:
        k, v = cache["k"].astype(dt), cache["v"].astype(dt)
    q, k = _maybe_qk_norm(cfg, p, q, k)

    o = chunked_attention(q, k, v, causal=False)
    y = _merge_heads(o) @ wuse(p["wo"], dt)
    if "gate" in p:
        y = jnp.tanh(p["gate"]).astype(dt) * y
    return y, cache
