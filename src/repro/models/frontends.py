"""Modality frontend STUBS (per assignment: [audio]/[vlm] entries specify
the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers produce deterministic synthetic embeddings for smoke tests
and ShapeDtypeStructs for the dry-run."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def frontend_shape(cfg, batch):
    """(B, T_frontend, d_model) for archs with a frontend; else None."""
    if cfg.encoder_seq:
        return (batch, cfg.encoder_seq, cfg.d_model)
    return None


def frontend_struct(cfg, batch, dtype=jnp.bfloat16):
    shp = frontend_shape(cfg, batch)
    return None if shp is None else jax.ShapeDtypeStruct(shp, dtype)


def synthetic_frontend(cfg, batch, key=None, dtype=jnp.float32):
    shp = frontend_shape(cfg, batch)
    if shp is None:
        return None
    key = key if key is not None else jax.random.PRNGKey(7)
    return 0.02 * jax.random.normal(key, shp, dtype)
