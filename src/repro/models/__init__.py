from .config import ModelConfig
from . import attention, frontends, layers, moe, recurrent, transformer
from .transformer import (apply, init_cache, init_params, layer_groups,
                          param_count, param_pspecs, cache_pspecs)

__all__ = ["ModelConfig", "apply", "init_cache", "init_params",
           "layer_groups", "param_count", "param_pspecs", "cache_pspecs",
           "attention", "frontends", "layers", "moe", "recurrent",
           "transformer"]
