"""Shared model building blocks (pure JAX, functional params-in/out)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def wuse(w, dt):
    """Weight as used by compute.  Under REPRO_ZERO3=1 (pure-FSDP /
    ZeRO-3 policy) the sharded *storage* copy is gathered to a
    replicated *compute* copy right before the matmul, keeping
    activation math local — GSPMD then reduce-scatters the grads back
    to the storage sharding."""
    w = w.astype(dt)
    if os.environ.get("REPRO_ZERO3") == "1":
        w = hint(w, *([None] * w.ndim))
    return w


def dense_init(key, shape, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32))


def rms_norm(x, w, eps=1e-6, offset=0.0):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (offset + w.astype(jnp.float32))
    return y.astype(dt)


def rope(x, positions, theta=10000.0):
    """Rotary embedding. x: (B, H, S, D even), positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None]
    ang = positions[:, None, :, None].astype(jnp.float32) * freq  # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(S, D, offset=0):
    pos = np.arange(offset, offset + S)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / (10000 ** (2 * i / D))
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_params(key, d_model, d_ff, gated=True):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], (d_model, d_ff)),
         "down": dense_init(ks[1], (d_ff, d_model))}
    if gated:
        p["gate"] = dense_init(ks[2], (d_model, d_ff))
    return p


def mlp(p, x, act="silu"):
    a = ACTS[act]
    h = x @ wuse(p["up"], x.dtype)
    if "gate" in p:
        h = a(x @ wuse(p["gate"], x.dtype)) * h
    else:
        h = a(h)
    return h @ wuse(p["down"], x.dtype)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def hint(x, *spec):
    """Best-effort sharding constraint using the ambient mesh's axis
    names; a no-op outside a mesh context (smoke tests, single device).
    Lets model code steer GSPMD at known decision points (e.g. keep the
    decode KV cache sequence-sharded instead of gathering it)."""
    try:
        from ..core.compat import ambient_axis_names
        names = set(ambient_axis_names())
        if not names:
            return x
        for a in spec:
            for ax in (a if isinstance(a, tuple) else (a,)):
                if isinstance(ax, str) and ax not in names:
                    return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
