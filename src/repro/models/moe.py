"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Top-k routing -> stable sort by expert -> scatter into a per-expert
capacity buffer (E, C, d) -> batched expert matmuls -> gather back and
combine.  FLOPs scale with top_k (not n_experts), matching real MoE
runtimes; overflow tokens beyond capacity are dropped (GShard policy).

Distribution: the (E, C, d) buffer is sharded on E over the `model` axis
(expert parallelism); GSPMD lowers the scatter/gather to the MGPU-verb
``all_to_all`` (DESIGN.md §2).  When E doesn't divide the axis, experts
are padded with never-routed dummies (router logits masked to -inf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import ACTS, dense_init, hint, mlp, mlp_params


def init(cfg, key, pad_to: int = 1):
    d, dff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    Ep = -(-E // pad_to) * pad_to
    ks = iter(jax.random.split(key, 5 + cfg.n_shared_experts))
    p = {
        "router": dense_init(next(ks), (d, Ep)),
        "experts": {
            "gate": dense_init(next(ks), (Ep, d, dff)),
            "up": dense_init(next(ks), (Ep, d, dff)),
            "down": dense_init(next(ks), (Ep, dff, d)),
        },
    }
    for i in range(cfg.n_shared_experts):
        p[f"shared{i}"] = mlp_params(next(ks), d, dff)
    return p


def apply(cfg, p, x, *, capacity_factor=None):
    """x: (B, S, d) -> (B, S, d), aux metrics dict."""
    B, S, d = x.shape
    dt = x.dtype
    E = p["router"].shape[1]                    # padded expert count
    k = cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    N = B * S
    # capacity from the REAL expert count (dummies receive no tokens)
    C = int(np.ceil(N * k / cfg.n_experts * cf))
    C = max(C, 1)

    xf = x.reshape(N, d)
    logits = (xf.astype(jnp.float32) @ p["router"])
    emask = jnp.arange(E) < cfg.n_experts   # padded dummies never routed
    logits = jnp.where(emask[None], logits, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(gates, k)        # (N,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # sort token-assignments by expert -> position within expert group
    flat_e = tope.reshape(-1)                   # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos_in_e = jnp.arange(N * k) - seg_start[sorted_e]
    slot = jnp.where(pos_in_e < C, sorted_e * C + pos_in_e, E * C)

    tok_idx = order // k                        # originating token
    # dispatch as a GATHER, not a scatter: slot (e, c) pulls sorted
    # assignment seg_start[e]+c.  GSPMD partitions gathers along the
    # output (expert) dim locally, where a scatter into the capacity
    # buffer is replicated + all-reduced (TBs of wire per MoE layer).
    j = jnp.arange(E * C)
    e_of = j // C
    c_of = j % C
    idx_sorted = seg_start[e_of] + c_of
    seg_end = jnp.concatenate([seg_start[1:], jnp.array([N * k])])
    valid = idx_sorted < seg_end[e_of]
    assign = order[jnp.minimum(idx_sorted, N * k - 1)]
    buf = jnp.where(valid[:, None], xf[assign // k], 0).reshape(E, C, d)
    buf = hint(buf, "model", None, None)

    a = ACTS[cfg.act]
    eg = p["experts"]
    h = a(jnp.einsum("ecd,edf->ecf", buf, eg["gate"].astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", buf, eg["up"].astype(dt))
    out_buf = jnp.einsum("ecf,efd->ecd", h, eg["down"].astype(dt))
    out_buf = hint(out_buf, "model", None, None)

    routed = out_buf.reshape(E * C, d)
    padded = jnp.concatenate([routed, jnp.zeros((1, d), dt)], axis=0)
    out_sorted = padded[jnp.minimum(slot, E * C)]
    out_flat = jnp.zeros((N * k, d), dt).at[order].set(out_sorted)
    out = (out_flat.reshape(N, k, d) *
           topw[..., None].astype(dt)).sum(1)

    for i in range(cfg.n_shared_experts):
        out = out + mlp(p[f"shared{i}"], xf, cfg.act)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(tope[:, 0], E), axis=0)
    mean_gate = jnp.mean(gates, axis=0)
    aux = {"lb_loss": E * jnp.sum(density * mean_gate),
           "dropped": jnp.sum(pos_in_e >= C) / (N * k)}
    return out.reshape(B, S, d), aux
