"""Model configuration — one dataclass covers all 10 assigned families."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # layer pattern: tuple of layer-kind strings, tiled over n_layers.
    # kinds: attn, local, mla, cross, mlstm, slstm, rglru  (+ffn flavour
    # is chosen by `ffn(layer_idx)`).
    pattern: tuple[str, ...] = ("attn",)

    head_dim: int | None = None
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None          # sliding window for `local` layers
    rope_theta: float = 10000.0
    act: str = "silu"
    gated_mlp: bool = True
    norm_eps: float = 1e-6
    post_norm: bool = False            # gemma2 sandwich norms
    embed_scale: bool = False          # gemma: scale embeddings by sqrt(d)
    residual_scale: float = 1.0        # minicpm depth-scaled residuals
    tie_embeddings: bool = True

    # MLA
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0                # d_ff of leading dense layers
    first_dense: int = 0               # deepseek: first k layers use dense FFN
    capacity_factor: float = 1.25

    # recurrent
    rnn_width: int = 0                 # RG-LRU lru_width / xLSTM inner dim
    rnn_heads: int = 0
    conv_width: int = 4
    proj_factor: float = 2.0           # mLSTM up-projection factor

    # encoder / multimodal
    encoder_layers: int = 0            # whisper encoder depth
    encoder_seq: int = 0               # frames (whisper) / patches (vlm)
    cross_kind: str = "none"           # none | interleaved (vlm) | decoder (whisper)

    compute_dtype: str = "bfloat16"

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_kinds(self) -> tuple[str, ...]:
        """Kind of each of the n_layers decoder layers."""
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    def ffn_kind(self, layer_idx: int) -> str:
        """none | mlp | moe for each layer."""
        k = self.layer_kinds()[layer_idx]
        if k in ("mlstm", "slstm"):
            return "none"              # xLSTM blocks carry their own proj
        if self.n_experts and layer_idx >= self.first_dense:
            return "moe"
        return "mlp"

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer does unbounded full attention (long_500k gate)."""
        kinds = set(self.layer_kinds())
        return not (("attn" in kinds) or ("mla" in kinds)
                    or ("cross" in kinds))

    @property
    def has_decoder(self) -> bool:
        return True                    # all assigned archs have a decode path

    def total_params(self) -> int:
        """Exact parameter count, derived from the real init pytree."""
        from . import transformer
        return transformer.param_count(self)

    def active_params(self) -> int:
        """Params touched per token (MoE: top_k of n_experts routed)."""
        from . import transformer
        return transformer.param_count(self, active_only=True)
