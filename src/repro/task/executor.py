"""Executing task graphs: async dispatch in dependency order, fences
only at the sinks, and a rolling frame pipeline.

The concurrency model is the library's own (and the paper's: CUDA
streams become XLA async dispatch).  JAX dispatch is asynchronous — a
dispatched program runs on the devices while the host keeps going — so
the executor gets overlap not by threads but by *issue order*: it
dispatches every task of a graph in topological order **without
fencing**, and blocks only where the caller needs a materialized value.
Independent tasks — the gridding of frame ``f+2``, the FFT of ``f+1``,
the Newton/CG solve of ``f``, the crop of ``f-1`` — are all in flight
on the device queue at once; the per-frame host fence of the old
two-stage engine (the pipeline bubble) is gone.

``Executor``  runs one graph: validate, toposort, dispatch each task,
              record per-task host (dispatch) time in ``trace``.
``Pipeline``  the rolling form for streams: ``push`` one graph per
              frame/tick; at most ``inflight`` pushed steps stay
              unfenced — pushing past that retires (fences) the oldest,
              bounding device-buffer liveness while keeping the next
              frames' work behind the current one.

>>> g = TaskGraph()
>>> _ = g.add("double", lambda x: 2 * x, inputs=("x",), outputs=("d",))
>>> _ = g.add("inc", lambda d: d + 1, inputs=("d",), outputs=("out",))
>>> Executor().run(g, feeds={"x": 20})
{'d': 40, 'out': 41}
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Mapping, Sequence

import jax

from .graph import TaskGraph

# Fault-injection hook on task dispatch (``repro.ft.inject`` installs
# it; this module never imports ft).  Called as ``args = TASK_HOOK(task,
# args)`` immediately before ``task.fn(*args)``: it may corrupt the
# args, sleep, or raise.  ``None`` (default) costs one attribute read.
TASK_HOOK = None


@dataclasses.dataclass(frozen=True)
class TaskRun:
    """One dispatched task: host-side cost, not device completion (the
    executor never fences per task — that is the point)."""

    name: str
    kind: str
    host_ms: float
    retries: int = 0    # re-dispatches this run needed (retry policy)


class Executor:
    """Dispatch a :class:`TaskGraph` in dependency order.

    ``run`` returns the produced values.  With ``fence=True`` (default)
    the returned values are materialized (``jax.block_until_ready``);
    ``fence=False`` leaves them in flight — the :class:`Pipeline` uses
    that to keep several frames on the device queue at once.

    ``retry`` takes a ``repro.ft.RestartPolicy``: a task raising a
    *transient* failure (``exc.transient`` truthy — e.g.
    ``ft.TransientFault`` — or an instance of ``retryable``) is
    re-dispatched up to ``max_restarts`` times with exponential backoff.
    Dispatch is topo-ordered and host-side, so retrying the failed task
    before anything downstream has been issued re-dispatches its whole
    downstream subgraph against the retried value; non-transient errors
    (including ``ft.DeviceLossFault``) propagate to the caller.

    >>> g = TaskGraph()
    >>> _ = g.add("one", lambda: 1, outputs=("a",))
    >>> ex = Executor()
    >>> ex.run(g)
    {'a': 1}
    >>> [r.name for r in ex.trace]
    ['one']
    """

    def __init__(self, *, retry=None, retryable=()):
        self.trace: list[TaskRun] = []
        self.retry = retry
        self.retryable = tuple(retryable)
        self.retried = 0    # successful re-dispatches, lifetime

    def _dispatch(self, t, args):
        """One task through the injection hook + retry envelope."""
        tries = 0
        backoff = getattr(self.retry, "backoff_s", 0.0)
        while True:
            try:
                hook = TASK_HOOK
                a = args if hook is None else hook(t, args)
                return t.fn(*a), tries
            except Exception as e:  # noqa: BLE001 — policy decides
                transient = getattr(e, "transient", False) \
                    or isinstance(e, self.retryable)
                if self.retry is None or not transient \
                        or tries >= self.retry.max_restarts:
                    raise
                tries += 1
                self.retried += 1
                if backoff > 0:
                    time.sleep(backoff)
                    backoff *= getattr(self.retry, "backoff_mult", 1.0)

    def run(self, graph: TaskGraph, feeds: Mapping[str, Any] | None = None,
            *, outputs: Sequence[str] | None = None,
            fence: bool = True) -> dict:
        """Execute ``graph`` with ``feeds`` bound to the unproduced
        value names.  Returns every produced value, or only ``outputs``
        when given.  Raises the graph's validation errors
        (cycle / missing feed / cross-group race) before any task runs.
        """
        feeds = dict(feeds or {})
        order = graph.toposort(feeds=feeds.keys())
        values = feeds
        for t in order:
            args = [values[v] for v in t.inputs]
            t0 = time.perf_counter()
            res, tries = self._dispatch(t, args)
            self.trace.append(TaskRun(
                t.name, t.kind, (time.perf_counter() - t0) * 1e3,
                retries=tries))
            if len(t.outputs) == 1:
                values[t.outputs[0]] = res
            elif t.outputs:
                if not isinstance(res, (tuple, list)) \
                        or len(res) != len(t.outputs):
                    raise TypeError(
                        f"task {t.name!r} declares {len(t.outputs)} "
                        f"outputs but returned "
                        f"{type(res).__name__}")
                values.update(zip(t.outputs, res))
        produced = {v: values[v] for v in graph.values()}
        out = (produced if outputs is None
               else {v: values[v] for v in outputs})
        return jax.block_until_ready(out) if fence else out


class Pipeline:
    """Rolling execution of a stream of graphs (one per frame/tick).

    ``push`` dispatches a graph unfenced and returns ``(values,
    retired)``: the step's in-flight values (feed them into the next
    frame's graph — JAX tracks the data dependency) plus any older
    steps that just left the ``inflight`` window, now fenced.  ``flush``
    retires everything left.  The window is the pipeline depth: 1
    degenerates to the fence-every-frame loop, 2 is the classic
    double-buffered overlap, 3+ keeps deeper stages of older frames
    concurrent with younger ones.

    >>> pipe = Pipeline(inflight=2)
    >>> g = TaskGraph()
    >>> _ = g.add("inc", lambda x: x + 1, inputs=("x",), outputs=("y",))
    >>> vals, done = pipe.push(g, {"x": 0}, tag="f0")
    >>> vals["y"], done                    # still inside the window
    (1, [])
    >>> for f in range(1, 3):
    ...     vals, done = pipe.push(g, {"x": vals["y"]}, tag=f"f{f}")
    >>> done                               # f0 was forced out and fenced
    [('f0', {'y': 1})]
    >>> [tag for tag, _ in pipe.flush()]
    ['f1', 'f2']

    With ``drop_failed=True`` a step whose dispatch raises is DROPPED —
    recorded in ``dropped`` and ``push`` returns ``(None, [])`` — so a
    stream keeps draining past a poisoned frame instead of deadlocking
    the window; the caller decides what stands in for the lost step.
    """

    def __init__(self, executor: Executor | None = None, *,
                 inflight: int = 2, drop_failed: bool = False):
        if inflight < 1:
            raise ValueError("Pipeline needs inflight >= 1")
        self.executor = executor or Executor()
        self.inflight = inflight
        self.drop_failed = drop_failed
        self.dropped: list[tuple] = []    # (tag, exception) per drop
        self._window: deque = deque()

    def __len__(self) -> int:
        return len(self._window)

    def push(self, graph: TaskGraph,
             feeds: Mapping[str, Any] | None = None, *,
             tag: Any = None,
             outputs: Sequence[str] | None = None) -> tuple[dict, list]:
        try:
            vals = self.executor.run(graph, feeds, outputs=outputs,
                                     fence=False)
        except Exception as e:  # noqa: BLE001 — opted in via drop_failed
            if not self.drop_failed:
                raise
            self.dropped.append((tag, e))
            return None, []
        self._window.append((tag, vals))
        retired = []
        while len(self._window) > self.inflight:
            retired.append(self._retire())
        return vals, retired

    def _retire(self) -> tuple:
        tag, vals = self._window.popleft()
        return tag, jax.block_until_ready(vals)

    def flush(self) -> list:
        """Fence and return every step still in the window, oldest
        first."""
        out = []
        while self._window:
            out.append(self._retire())
        return out
