"""Dependency-driven task graphs over ``Environment``/``Communicator``.

The streaming engine of PR 1 was a rigid two-stage overlap: upload frame
``f+1`` behind the solve of frame ``f``, fence, repeat.  The 2017
follow-up (Schaetz et al., arXiv:1701.08361 §3) runs the reconstruction
as a multi-stage *pipeline* — gridding, FFT, Newton/CG and cropping of
**different frames** execute concurrently — and Parla-style task
runtimes show the right abstraction for that: tasks that declare their
data dependencies and a placement hint, with a scheduler deciding the
issue order.  ``repro.task`` is that abstraction for this library:

``Task``       one unit of device (or host) work: a callable plus the
               *names* of the values it consumes and produces, a
               placement hint (the ``Communicator``/group it runs on)
               and a kind (``compute`` or ``copy`` — the explicit
               transfer edges).
``TaskGraph``  the dependency graph.  Construction validates producer
               uniqueness; ``toposort`` orders ready tasks and raises
               :class:`CycleError` on cycles; ``validate`` raises
               :class:`CrossGroupError` when a value produced on one
               device group is consumed on a *different* group without
               an explicit ``copy``/verb edge in between (a cross-group
               data race — the bytes would never actually move).

Graphs are cheap, pure-Python descriptions — build one per frame (or
per tick) and hand it to :class:`repro.task.Executor`; the executor
supplies the concurrency (JAX async dispatch, fences only at sinks).
See ``docs/task_graph.md`` for the programming guide.

>>> g = TaskGraph()
>>> t = g.add("scale", lambda x: [2 * v for v in x],
...           inputs=("raw",), outputs=("scaled",))
>>> g.add("total", sum, inputs=("scaled",), outputs=("out",))
Task('total', inputs=('scaled',), outputs=('out',))
>>> [t.name for t in g.toposort(feeds=("raw",))]
['scale', 'total']
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Sequence

from ..core.plan import group_token


class TaskError(RuntimeError):
    """Base class for task-graph construction/validation errors."""


class CycleError(TaskError):
    """The graph has a dependency cycle (named in the message)."""


class CrossGroupError(TaskError):
    """A value produced on one device group is consumed on another
    without an explicit ``copy`` edge — a cross-group data race."""


def placement_token(group) -> tuple | None:
    """Hashable placement identity of ``group`` (a Communicator,
    DeviceGroup or None).  Two hints collide iff they address the same
    devices as the same named-axis mesh — the same identity plans key
    on (:func:`repro.core.plan.group_token`)."""
    return None if group is None else group_token(group)


@dataclasses.dataclass(frozen=True)
class Task:
    """One node: ``fn`` consuming ``inputs`` and producing ``outputs``.

    ``group`` is the placement hint (where the work runs); ``kind`` is
    ``"compute"`` for ordinary work and ``"copy"`` for explicit
    transfer edges (verb calls / host↔device staging) — the only tasks
    allowed to bridge device groups.
    """

    name: str
    fn: Callable
    inputs: tuple[str, ...] = ()
    outputs: tuple[str, ...] = ()
    group: Any = None
    kind: str = "compute"

    def __post_init__(self):
        if self.kind not in ("compute", "copy"):
            raise TaskError(f"task {self.name!r}: kind must be "
                            f"compute|copy, got {self.kind!r}")

    @property
    def placement(self) -> tuple | None:
        return placement_token(self.group)

    def __repr__(self) -> str:
        return (f"Task({self.name!r}, inputs={self.inputs}, "
                f"outputs={self.outputs})")


class TaskGraph:
    """A dependency graph of named tasks over named values.

    Tasks communicate through *value names*: a task runs once every
    input name is produced (or supplied as a feed at execution time).
    Each value has exactly one producer; adding a second raises.

    >>> g = TaskGraph()
    >>> g.add("a", lambda: 1, outputs=("x",))
    Task('a', inputs=(), outputs=('x',))
    >>> g.add("b", lambda x: x + 1, inputs=("x",), outputs=("y",))
    Task('b', inputs=('x',), outputs=('y',))
    >>> g.add("again", lambda: 2, outputs=("x",))
    Traceback (most recent call last):
        ...
    repro.task.graph.TaskError: value 'x' already produced by task 'a'
    """

    def __init__(self):
        self._tasks: dict[str, Task] = {}
        self._producer: dict[str, str] = {}   # value name -> task name

    # -- construction -----------------------------------------------------
    def add(self, name: str, fn: Callable, *, inputs: Sequence[str] = (),
            outputs: Sequence[str] = (), group: Any = None,
            kind: str = "compute") -> Task:
        """Add one task.  ``fn`` is called as ``fn(*input_values)`` and
        must return one value per output name (a tuple when there are
        several).  ``group`` is the placement hint."""
        if name in self._tasks:
            raise TaskError(f"duplicate task name {name!r}")
        t = Task(name=name, fn=fn, inputs=tuple(inputs),
                 outputs=tuple(outputs), group=group, kind=kind)
        for v in t.outputs:
            if v in self._producer:
                raise TaskError(f"value {v!r} already produced by task "
                                f"{self._producer[v]!r}")
        # commit only after full validation so a failed add is a no-op
        self._tasks[name] = t
        for v in t.outputs:
            self._producer[v] = name
        return t

    def copy(self, name: str, fn: Callable, *, inputs: Sequence[str] = (),
             outputs: Sequence[str] = (), group: Any = None) -> Task:
        """Add an explicit transfer edge (``kind="copy"``): a verb call
        or host↔device staging step.  Copy tasks are the only ones
        allowed to consume values placed on a different group."""
        return self.add(name, fn, inputs=inputs, outputs=outputs,
                        group=group, kind="copy")

    # -- queries ----------------------------------------------------------
    @property
    def tasks(self) -> tuple[Task, ...]:
        return tuple(self._tasks.values())

    def __len__(self) -> int:
        return len(self._tasks)

    def __contains__(self, name: str) -> bool:
        return name in self._tasks

    def task(self, name: str) -> Task:
        return self._tasks[name]

    def producer(self, value: str) -> Task | None:
        """The task producing ``value`` (None: it must be a feed)."""
        name = self._producer.get(value)
        return None if name is None else self._tasks[name]

    def values(self) -> tuple[str, ...]:
        """Every value name produced by some task."""
        return tuple(self._producer)

    def __repr__(self) -> str:
        return (f"TaskGraph({len(self._tasks)} tasks, "
                f"{len(self._producer)} values)")

    # -- validation -------------------------------------------------------
    def validate(self, feeds: Iterable[str] = ()) -> None:
        """Raise loudly on the graph's failure modes:

        * an input neither produced nor fed (:class:`TaskError`),
        * a dependency cycle (:class:`CycleError`),
        * a cross-group consume without a ``copy`` edge
          (:class:`CrossGroupError`).
        """
        feeds = set(feeds)
        for t in self._tasks.values():
            for v in t.inputs:
                if v not in self._producer and v not in feeds:
                    raise TaskError(
                        f"task {t.name!r} consumes {v!r}, which no task "
                        f"produces and no feed supplies")
        self._check_cross_group()
        self.toposort(feeds=feeds, _validate=False)

    def _check_cross_group(self) -> None:
        for t in self._tasks.values():
            if t.kind == "copy" or t.placement is None:
                continue
            for v in t.inputs:
                p = self.producer(v)
                if p is None or p.kind == "copy" or p.placement is None:
                    continue
                if p.placement != t.placement:
                    raise CrossGroupError(
                        f"value {v!r} is produced by task {p.name!r} on "
                        f"one device group but consumed by task "
                        f"{t.name!r} on a different one: route it "
                        f"through an explicit copy/verb edge "
                        f"(TaskGraph.copy)")

    def toposort(self, feeds: Iterable[str] = (), *,
                 _validate: bool = True) -> tuple[Task, ...]:
        """Dependency order (Kahn's algorithm).  Ties break by insertion
        order, so independent tasks of *older* pipeline stages issue
        first.  Raises :class:`CycleError` naming the cycle.

        >>> g = TaskGraph()
        >>> _ = g.add("a", lambda x: x, inputs=("b_out",), outputs=("a_out",))
        >>> _ = g.add("b", lambda x: x, inputs=("a_out",), outputs=("b_out",))
        >>> g.toposort()
        Traceback (most recent call last):
            ...
        repro.task.graph.CycleError: dependency cycle: a -> b -> a
        """
        if _validate:
            self.validate(feeds)
            return self.toposort(feeds, _validate=False)
        feeds = set(feeds)
        # in-degree = number of inputs produced by a not-yet-run task
        deps = {t.name: {self._producer[v] for v in t.inputs
                         if v in self._producer}
                for t in self._tasks.values()}
        order, ready = [], [n for n, d in deps.items() if not d]
        done: set[str] = set()
        while ready:
            name = ready.pop(0)
            done.add(name)
            order.append(self._tasks[name])
            ready += [n for n, d in deps.items()
                      if n not in done and n not in ready
                      and d <= done]
        if len(order) != len(self._tasks):
            raise CycleError("dependency cycle: "
                             + " -> ".join(self._find_cycle(deps, done)))
        return tuple(order)

    def _find_cycle(self, deps: dict, done: set) -> list[str]:
        """Walk producer edges from any unordered task until a repeat."""
        start = next(n for n in self._tasks if n not in done)
        seen, path = {}, []
        node = start
        while node not in seen:
            seen[node] = len(path)
            path.append(node)
            node = next(iter(n for n in sorted(deps[node])
                             if n not in done))
        return path[seen[node]:] + [node]
