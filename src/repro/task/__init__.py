"""``repro.task`` — dependency-driven task graphs for frame pipelining.

Declare device work as :class:`Task` nodes (inputs/outputs by name,
placement hint, explicit ``copy`` transfer edges) in a
:class:`TaskGraph`; run it with :class:`Executor` (topological async
dispatch, fences only at sinks) or stream per-frame graphs through a
:class:`Pipeline` with a bounded in-flight window.  The programming
guide is ``docs/task_graph.md``; the NLINV frame program rides it in
``repro.nlinv.stream.FramePipeline``.
"""

from .executor import Executor, Pipeline, TaskRun
from .graph import (CrossGroupError, CycleError, Task, TaskError,
                    TaskGraph, placement_token)

__all__ = [
    "Task", "TaskGraph", "TaskError", "CycleError", "CrossGroupError",
    "placement_token",
    "Executor", "Pipeline", "TaskRun",
]
