"""Training step builder: loss, remat, microbatch accumulation, pjit
shardings (FSDP over data/pod + TP over model), metrics.

``make_train_step`` returns (step_fn, state_shardings); step_fn is
jit-compiled with explicit in/out shardings — this is the function the
multi-pod dry-run lowers for every architecture.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import transformer
from .optimizer import adamw_init, adamw_update, warmup_cosine


def lm_loss(cfg, params, tokens, labels, enc=None, *, remat=True,
            aux_weight=0.01, act_sharding=None):
    logits, _, aux = transformer.apply(cfg, params, tokens, enc=enc,
                                       mode="train", remat=remat,
                                       act_sharding=act_sharding)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)
    loss = jnp.mean(nll)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


def make_train_state(cfg, key, *, expert_pad=1):
    params = transformer.init_params(cfg, key, expert_pad=expert_pad)
    return {"params": params, "opt": adamw_init(params)}


def state_shardings(cfg, state, mesh, *, fsdp=("data",), tp="model"):
    pspecs = transformer.param_pspecs(cfg, state["params"], dict(mesh.shape),
                                      tp=tp, fsdp=fsdp)
    to_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    return {"params": to_sh(pspecs),
            "opt": {"m": to_sh(pspecs), "v": to_sh(pspecs),
                    "step": NamedSharding(mesh, P())}}


def make_train_step(cfg, mesh, *, base_lr=3e-4, warmup=100, total=10000,
                    microbatches=1, remat=True, fsdp=("data",), tp="model",
                    batch_axes=("data",), donate=True, act_sharding=None):
    lr_fn = warmup_cosine(base_lr, warmup, total)
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def step(state, tokens, labels, enc=None):
        def grads_of(tok, lab):
            (loss, met), g = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, tok, lab, enc, remat=remat,
                                  act_sharding=act_sharding),
                has_aux=True)(state["params"])
            return loss, met, g

        if microbatches > 1:
            B = tokens.shape[0]
            mb = B // microbatches
            tok_mb = tokens.reshape(microbatches, mb, -1)
            lab_mb = labels.reshape(microbatches, mb, -1)

            def acc_fn(carry, xs):
                gsum, lsum = carry
                loss, _, g = grads_of(xs[0], xs[1])
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (zero_g, 0.0),
                                           (tok_mb, lab_mb))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            met = {"nll": loss, "aux": jnp.zeros(())}
        else:
            loss, met, grads = grads_of(tokens, labels)

        lr = lr_fn(state["opt"]["step"])
        params, opt, gnorm = adamw_update(state["params"], grads,
                                          state["opt"], lr)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr, **met}
        return {"params": params, "opt": opt}, metrics

    def build(state_sh):
        data_sh = NamedSharding(mesh, P(bspec, None))
        enc_sh = NamedSharding(mesh, P(bspec, None, None))
        return jax.jit(
            step,
            in_shardings=(state_sh, data_sh, data_sh, enc_sh),
            out_shardings=(state_sh, NamedSharding(mesh, P())),
            donate_argnums=(0,) if donate else (),
        )

    return step, build
