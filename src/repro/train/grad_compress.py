"""Gradient compression for the slow (DCN / pod) axis: int8 block
quantization with error feedback — call inside shard_map.

Shared-scale scheme so the reduction stays linear:
  s   = pmax(local absmax) / 127          (one scalar per block)
  q_i = round(g_i / s)  in int8           (per device)
  g~  = s * psum(q_i)                     (int32 accumulation)

Error feedback carries the quantization residual into the next step,
which restores convergence to the uncompressed path (1-bit-Adam lineage).
8x fewer bytes over DCN per gradient element (int8 vs f32 wire, plus no
fp32 upcast on the slow hop).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def compressed_psum(g, axis, err=None, block: int = 4096):
    """Returns (reduced grad f32, new error-feedback state)."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    flat = gf.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = lax.pmax(absmax, axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    new_err = (blocks - deq_local).reshape(-1)[:n].reshape(g.shape)
    total = lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) * scale
    out = total.reshape(-1)[:n].reshape(g.shape)
    return out, new_err


def tree_compressed_psum(grads, axis, err_state=None):
    leaves, tdef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_state) if err_state is not None \
        else [None] * len(leaves)
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        o, ne = compressed_psum(g, axis, e)
        outs.append(o)
        new_errs.append(ne)
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, new_errs)


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
