from . import grad_compress, optimizer, trainer
from .optimizer import adamw_init, adamw_update, warmup_cosine
from .trainer import lm_loss, make_train_state, make_train_step, \
    state_shardings

__all__ = ["grad_compress", "optimizer", "trainer", "adamw_init",
           "adamw_update", "warmup_cosine", "lm_loss", "make_train_state",
           "make_train_step", "state_shardings"]
