"""AdamW + schedules, pure JAX (no external deps).

State layout mirrors params (ZeRO-3: m/v inherit the param sharding)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def warmup_cosine(base_lr, warmup, total, min_frac=0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(np.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip=1.0):
    if clip:
        grads, gnorm = clip_by_global_norm(grads, clip)
    else:
        gnorm = jnp.zeros(())
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps)
        if weight_decay and p.ndim >= 2:      # no decay on norms/biases
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
