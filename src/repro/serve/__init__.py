from .engine import Engine, Request, make_serve_steps

__all__ = ["Engine", "Request", "make_serve_steps"]
