"""The serving layer: one scheduler for every real-time workload.

``StreamScheduler`` (admission, per-client backpressure, bucketed batch
formation, latency/SLO accounting) drives both production workloads —
``NlinvStreamWorkload`` (N concurrent MRI streams batched into one SPMD
launch) and ``LMDecodeWorkload`` (slot-based greedy decode).  ``Engine``
is the LM front door kept API-compatible with the pre-scheduler engine.
"""

from .engine import Engine, Request, make_serve_steps
from .scheduler import (AdmissionError, Rejected, ServeConfig, Session,
                        StreamScheduler, Workload)
from .workloads import (LMDecodeWorkload, NlinvStreamWorkload, SlotPool,
                        stack_carries, unstack_carry)

__all__ = [
    "Engine", "Request", "make_serve_steps",
    "AdmissionError", "Rejected", "ServeConfig", "Session",
    "StreamScheduler", "Workload",
    "LMDecodeWorkload", "NlinvStreamWorkload", "SlotPool",
    "stack_carries", "unstack_carry",
]
