"""One scheduler for N concurrent real-time streams (the serving layer).

The paper's production setting (and the 2017 follow-up's, Schaetz et
al., arXiv:1701.08361) is a continuously running reconstruction service
fed by the scanner.  This module is that service's control plane,
workload-agnostic: a :class:`StreamScheduler` owns admission, per-client
queueing/backpressure, batch formation and latency/SLO accounting, and a
:class:`Workload` implementation owns the actual device work — NLINV
Newton solves batched into one SPMD launch, or LM token decode over KV
slots (``repro.serve.workloads``).  Both production workloads run
through this one loop; there is no per-workload driver.

The lifecycle of one client:

  open()    admission control: admitted up to ``max_concurrency``
            (workload ``open_session`` runs: carry init / prefill),
            queued up to ``max_queue`` beyond that, rejected past it.
  submit()  per-session backpressure: at most ``queue_depth`` staged
            work items; a real-time client past the bound has its frame
            REJECTED (shed) rather than silently growing latency.
            The workload's ``enqueue`` hook stages host→device uploads
            here, so transfers overlap the in-flight tick.
  tick()    batch formation: everything ready this instant, rounded up
            to a bucketed batch width (``buckets``) so the compiled-
            program set stays small; one ``Workload.step`` per tick.
  close()   session teardown (workload ``close_session``: slot free /
            carry drop) + admission of the next queued client.

``report()`` emits per-client latency statistics via the same
``latency_stats`` every latency number in the repo uses, plus the
fraction of frames inside the real-time budget (``budget_ms``).

Fault tolerance (see ``docs/fault_tolerance.md``): a *transient* step
failure requeues the popped items and retries next tick; a workload may
refuse individual frames with :class:`Rejected` (client quarantine);
and ``deadline_ms`` arms a degradation ladder — sustained breaches
lower the workload operating point, then the batch-width cap, stepping
back up when headroom returns, every transition logged in
``report()['aggregate']['ft']``.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

from ..nlinv.stream import latency_stats

# Fault-injection hook on the tick boundary (``repro.ft.inject``
# installs it; this module never imports ft).  Called as ``batch =
# STEP_HOOK(workload, batch)`` right before ``Workload.step``: it may
# corrupt per-client items, sleep, or raise a transient failure (the
# tick requeues and retries).  ``None`` (default) is one attribute read.
STEP_HOOK = None


class AdmissionError(RuntimeError):
    """open() past ``max_concurrency`` + ``max_queue``: the service is
    full and the client must back off (the hard admission bound)."""


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Client-visible error status standing in for a frame the service
    refused to deliver (poisoned output, quarantined client).  Appears
    in ``session.results`` so the stream stays frame-aligned; the
    per-client ``poisoned`` counter in ``report()`` tallies them."""

    reason: str


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Scheduler policy knobs (one instance per scheduler)."""

    max_concurrency: int = 8        # admitted sessions at once
    max_queue: int = 16             # waiting sessions beyond that
    queue_depth: int = 4            # staged work items per session
    budget_ms: Optional[float] = None   # real-time SLO target per item
    buckets: tuple = (1, 2, 4, 8)   # allowed batch widths (sorted)
    # -- deadline enforcement + graceful degradation ----------------------
    # per-tick wall-clock budget: ``breach_ticks`` consecutive breaches
    # step DOWN the degradation ladder (workload operating points first,
    # then smaller batch-width caps); ``recover_ticks`` consecutive
    # ticks under ``headroom * deadline_ms`` step back UP.  None (the
    # default) disables enforcement entirely.
    deadline_ms: Optional[float] = None
    breach_ticks: int = 3
    recover_ticks: int = 6
    headroom: float = 0.7

    def __post_init__(self):
        if self.max_concurrency < 1:
            raise ValueError("max_concurrency must be >= 1")
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if not self.buckets or list(self.buckets) != sorted(self.buckets):
            raise ValueError(f"buckets must be sorted+nonempty: "
                             f"{self.buckets}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be positive (None = off)")
        if self.breach_ticks < 1 or self.recover_ticks < 1:
            raise ValueError("breach_ticks/recover_ticks must be >= 1")
        if not 0.0 < self.headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1]: {self.headroom}")

    def bucket(self, n: int) -> int:
        """Smallest allowed batch width >= n (n capped at the largest)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]


@dataclasses.dataclass
class Session:
    """One client's stream through the scheduler."""

    sid: int
    client: str
    meta: dict = dataclasses.field(default_factory=dict)
    state: Any = None               # workload-owned (carry / KV slot)
    pending: deque = dataclasses.field(default_factory=deque)
    results: list = dataclasses.field(default_factory=list)
    latency_ms: list = dataclasses.field(default_factory=list)
    rejected: int = 0               # frames shed by backpressure
    poisoned: int = 0               # frames rejected by health checks
    admitted: bool = False
    done: bool = False


class Workload:
    """What the scheduler schedules.  Implementations own all device
    state; the scheduler never touches arrays."""

    # degraded operating points below nominal (0 = none: the default
    # workload cannot trade accuracy for latency, so the deadline ladder
    # falls straight through to smaller batch buckets)
    levels: int = 0

    def open_session(self, session: Session) -> Any:
        """Admission-time setup (carry init / prefill).  The return
        value becomes ``session.state``."""
        raise NotImplementedError

    def set_level(self, level: int) -> None:
        """Switch to degraded operating point ``level`` (0 = nominal;
        called by the scheduler's deadline ladder, only with
        ``level <= self.levels``)."""
        if level != 0:
            raise ValueError(
                f"{type(self).__name__} declares no degraded operating "
                f"points (levels={self.levels})")

    def counters(self) -> dict:
        """Workload-side fault counters merged into
        ``StreamScheduler.report()['aggregate']['ft']`` (retried tasks,
        quarantined clients, ...)."""
        return {}

    def enqueue(self, session: Session, item):
        """Stage one submitted work item (hook for upload-at-enqueue;
        the default stages nothing)."""
        return item

    def step(self, batch: list, width: int) -> list:
        """Run one tick over ``batch`` = [(session, item), ...] with
        ``len(batch) <= width`` (the bucketed launch width).  Returns
        [(result, done), ...] aligned with ``batch``; results must be
        materialized (the scheduler stamps completion time on return).
        """
        raise NotImplementedError

    def close_session(self, session: Session) -> None:
        """Teardown (slot free / carry drop)."""


class StreamScheduler:
    """Continuous batching of N client streams over one Workload."""

    def __init__(self, workload: Workload,
                 config: ServeConfig | None = None):
        self.workload = workload
        self.config = config or ServeConfig()
        self.sessions: dict[int, Session] = {}   # admitted, by sid
        self.waiting: deque[Session] = deque()
        self.closed: list[Session] = []
        self.ticks = 0
        self.tick_ms: list[float] = []
        self._sids = itertools.count()
        # -- fault accounting + degradation-ladder state ------------------
        self.step_faults = 0            # transient tick failures (requeued)
        # ladder rung 0..levels+len(buckets)-1: workload operating points
        # shed accuracy first, then the batch-width cap sheds throughput
        self.rung = 0
        self.events: list[dict] = []    # every ladder transition
        self._breach = self._ok = 0     # consecutive-tick counters

    # -- admission --------------------------------------------------------
    def open(self, client: str = "client", **meta) -> Session:
        """Admit (or queue) one new client stream; raises
        :class:`AdmissionError` when the service is full.

        >>> class Echo(Workload):
        ...     def open_session(self, session): return {}
        ...     def step(self, batch, width):
        ...         return [(item, False) for _, item in batch]
        >>> sched = StreamScheduler(Echo(), ServeConfig(max_concurrency=1,
        ...                                             max_queue=1))
        >>> sched.open("scanner-a").admitted
        True
        >>> sched.open("scanner-b").admitted    # queued behind the first
        False
        >>> sched.open("scanner-c")
        Traceback (most recent call last):
            ...
        repro.serve.scheduler.AdmissionError: service full: 1 admitted, \
1 waiting (max_queue=1)
        """
        if (len(self.sessions) >= self.config.max_concurrency
                and len(self.waiting) >= self.config.max_queue):
            raise AdmissionError(
                f"service full: {len(self.sessions)} admitted, "
                f"{len(self.waiting)} waiting (max_queue="
                f"{self.config.max_queue})")
        s = Session(sid=next(self._sids), client=client, meta=dict(meta))
        if len(self.sessions) < self.config.max_concurrency:
            self._admit(s)
        else:
            self.waiting.append(s)
        return s

    def _admit(self, s: Session) -> None:
        s.state = self.workload.open_session(s)
        s.admitted = True
        self.sessions[s.sid] = s

    def _refill(self) -> None:
        while self.waiting and \
                len(self.sessions) < self.config.max_concurrency:
            self._admit(self.waiting.popleft())

    # -- per-session queueing (backpressure) ------------------------------
    def submit(self, session: Session, item) -> bool:
        """Enqueue one work item (a frame / a decode step).  Returns
        False — the item was SHED — once ``queue_depth`` items are
        already staged: a real-time client must drop frames, not let
        its latency grow without bound.

        >>> class Echo(Workload):
        ...     def open_session(self, session): return {}
        ...     def step(self, batch, width):
        ...         return [(item, False) for _, item in batch]
        >>> sched = StreamScheduler(Echo(), ServeConfig(queue_depth=1))
        >>> s = sched.open("scanner")
        >>> sched.submit(s, "frame0")
        True
        >>> sched.submit(s, "frame1")   # past queue_depth: shed
        False
        >>> s.rejected
        1
        """
        if session.done:
            raise RuntimeError(f"submit on closed session {session.sid}")
        if len(session.pending) >= self.config.queue_depth:
            session.rejected += 1
            return False
        staged = self.workload.enqueue(session, item)
        session.pending.append((staged, time.perf_counter()))
        return True

    # -- the tick ---------------------------------------------------------
    def tick(self) -> int:
        """Admit what fits, batch everything ready, run one Workload
        step.  Returns the number of items completed.

        >>> class Echo(Workload):
        ...     def open_session(self, session): return {}
        ...     def step(self, batch, width):
        ...         return [(item, False) for _, item in batch]
        >>> sched = StreamScheduler(Echo())
        >>> a, b = sched.open("a"), sched.open("b")
        >>> _ = sched.submit(a, 1); _ = sched.submit(b, 2)
        >>> sched.tick()                # one batched step over both
        2
        >>> (a.results, b.results)
        ([1], [2])
        >>> sched.tick()                # nothing ready
        0
        """
        self._refill()
        ready = [s for _, s in sorted(self.sessions.items()) if s.pending]
        if not ready:
            return 0
        cap = self._bucket_cap()
        if len(ready) > cap:
            # overcommitted: rotate the start so no client is starved
            r = self.ticks % len(ready)
            ready = (ready[r:] + ready[:r])[:cap]
        width = self.config.bucket(len(ready))
        batch = [(s, s.pending.popleft()) for s in ready]
        t0 = time.perf_counter()
        try:
            items = [(s, item) for s, (item, _) in batch]
            hook = STEP_HOOK
            if hook is not None:
                items = hook(self.workload, items)
            out = self.workload.step(items, width)
        except Exception as e:
            if not getattr(e, "transient", False):
                raise
            # transient tick failure: nothing was delivered — return
            # every popped item to the FRONT of its queue (submit order
            # and timestamps intact) and let the next tick retry
            for s, staged in batch:
                s.pending.appendleft(staged)
            self.step_faults += 1
            return 0
        t1 = time.perf_counter()
        self.ticks += 1
        self.tick_ms.append((t1 - t0) * 1e3)
        if len(out) != len(batch):
            raise RuntimeError(
                f"{type(self.workload).__name__}.step returned {len(out)} "
                f"results for a batch of {len(batch)}")
        for (s, (_, t_submit)), (result, done) in zip(batch, out):
            s.results.append(result)
            if isinstance(result, Rejected):
                # a refused frame is an error outcome, not a latency
                # sample: it must not pollute the SLO percentiles
                s.poisoned += 1
            else:
                s.latency_ms.append((t1 - t_submit) * 1e3)
            if done:
                self.close(s)
        if self.config.deadline_ms is not None:
            self._deadline((t1 - t0) * 1e3)
        return len(batch)

    # -- deadline enforcement / degradation ladder ------------------------
    def _bucket_cap(self) -> int:
        """Largest allowed batch width at the current ladder rung."""
        shed = max(self.rung - self.workload.levels, 0)
        return self.config.buckets[
            max(len(self.config.buckets) - 1 - shed, 0)]

    def _max_rung(self) -> int:
        return self.workload.levels + len(self.config.buckets) - 1

    def _deadline(self, ms: float) -> None:
        """Track one tick against the budget; shift the ladder on
        sustained breaches (down) or sustained headroom (up)."""
        cfg = self.config
        if ms > cfg.deadline_ms:
            self._breach += 1
            self._ok = 0
            if self._breach >= cfg.breach_ticks \
                    and self.rung < self._max_rung():
                self._breach = 0
                self._shift(+1, ms)
        else:
            self._breach = 0
            if ms <= cfg.headroom * cfg.deadline_ms:
                self._ok += 1
                if self._ok >= cfg.recover_ticks and self.rung > 0:
                    self._ok = 0
                    self._shift(-1, ms)
            else:
                self._ok = 0

    def _shift(self, direction: int, ms: float) -> None:
        """Move one rung down (+1) or up (-1): workload operating
        points shed accuracy before the bucket cap sheds throughput, so
        recovery restores throughput before accuracy."""
        self.rung += direction
        level = min(self.rung, self.workload.levels)
        if self.workload.levels:
            self.workload.set_level(level)
        self.events.append({
            "tick": self.ticks, "dir": "down" if direction > 0 else "up",
            "rung": self.rung, "op_level": level,
            "bucket_cap": self._bucket_cap(),
            "tick_ms": round(ms, 3)})

    def close(self, session: Session) -> None:
        """End one stream: workload teardown, then admit from the
        waiting queue."""
        if session.done:
            return
        self.workload.close_session(session)
        session.done = True
        session.pending.clear()
        self.sessions.pop(session.sid, None)
        if session in self.waiting:
            self.waiting.remove(session)
        self.closed.append(session)
        self._refill()

    def drain(self) -> int:
        """Tick until no admitted session has work and the waiting
        queue cannot make progress.  Returns items completed."""
        total = 0
        while True:
            n = self.tick()
            total += n
            if n == 0 and not any(s.pending for s in self.sessions.values()):
                self._refill()
                if not any(s.pending for s in self.sessions.values()):
                    return total

    # -- accounting -------------------------------------------------------
    def report(self) -> dict:
        """Per-client latency/SLO table + aggregate throughput, on the
        repo-wide ``latency_stats``."""
        budget = self.config.budget_ms
        clients: dict[str, dict] = {}
        for s in itertools.chain(self.closed, self.waiting,
                                 self.sessions.values()):
            row = {"sid": s.sid, "frames": len(s.latency_ms),
                   "rejected": s.rejected, "poisoned": s.poisoned,
                   **latency_stats(s.latency_ms)}
            if budget is not None:
                inside = sum(1 for t in s.latency_ms if t <= budget)
                row["slo"] = {
                    "budget_ms": budget,
                    "met": round(inside / max(len(s.latency_ms), 1), 3)}
            clients[s.client] = row
        frames = sum(len(s.latency_ms)
                     for s in itertools.chain(self.closed, self.waiting,
                                              self.sessions.values()))
        wall = sum(self.tick_ms)
        # error accounting: "slow" (latency columns) vs "failing" (these)
        ft = {
            "step_faults": self.step_faults,
            "rejected_poisoned": sum(c["poisoned"]
                                     for c in clients.values()),
            "degradation_events": len(self.events),
            "events": list(self.events),
            "rung": self.rung,
            "bucket_cap": self._bucket_cap(),
            **self.workload.counters(),
        }
        return {
            "clients": clients,
            "aggregate": {
                "frames": frames,
                "ticks": self.ticks,
                "tick": latency_stats(self.tick_ms),
                "fps": round(frames / max(wall, 1e-9) * 1e3, 2),
                "rejected": sum(c["rejected"] for c in clients.values()),
                "ft": ft,
            },
        }
