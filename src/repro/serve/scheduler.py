"""One scheduler for N concurrent real-time streams (the serving layer).

The paper's production setting (and the 2017 follow-up's, Schaetz et
al., arXiv:1701.08361) is a continuously running reconstruction service
fed by the scanner.  This module is that service's control plane,
workload-agnostic: a :class:`StreamScheduler` owns admission, per-client
queueing/backpressure, batch formation and latency/SLO accounting, and a
:class:`Workload` implementation owns the actual device work — NLINV
Newton solves batched into one SPMD launch, or LM token decode over KV
slots (``repro.serve.workloads``).  Both production workloads run
through this one loop; there is no per-workload driver.

The lifecycle of one client:

  open()    admission control: admitted up to ``max_concurrency``
            (workload ``open_session`` runs: carry init / prefill),
            queued up to ``max_queue`` beyond that, rejected past it.
  submit()  per-session backpressure: at most ``queue_depth`` staged
            work items; a real-time client past the bound has its frame
            REJECTED (shed) rather than silently growing latency.
            The workload's ``enqueue`` hook stages host→device uploads
            here, so transfers overlap the in-flight tick.
  tick()    batch formation: everything ready this instant, rounded up
            to a bucketed batch width (``buckets``) so the compiled-
            program set stays small; one ``Workload.step`` per tick.
  close()   session teardown (workload ``close_session``: slot free /
            carry drop) + admission of the next queued client.

``report()`` emits per-client latency statistics via the same
``latency_stats`` every latency number in the repo uses, plus the
fraction of frames inside the real-time budget (``budget_ms``).
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Optional

from ..nlinv.stream import latency_stats


class AdmissionError(RuntimeError):
    """open() past ``max_concurrency`` + ``max_queue``: the service is
    full and the client must back off (the hard admission bound)."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Scheduler policy knobs (one instance per scheduler)."""

    max_concurrency: int = 8        # admitted sessions at once
    max_queue: int = 16             # waiting sessions beyond that
    queue_depth: int = 4            # staged work items per session
    budget_ms: Optional[float] = None   # real-time SLO target per item
    buckets: tuple = (1, 2, 4, 8)   # allowed batch widths (sorted)

    def __post_init__(self):
        if self.max_concurrency < 1:
            raise ValueError("max_concurrency must be >= 1")
        if self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if not self.buckets or list(self.buckets) != sorted(self.buckets):
            raise ValueError(f"buckets must be sorted+nonempty: "
                             f"{self.buckets}")

    def bucket(self, n: int) -> int:
        """Smallest allowed batch width >= n (n capped at the largest)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]


@dataclasses.dataclass
class Session:
    """One client's stream through the scheduler."""

    sid: int
    client: str
    meta: dict = dataclasses.field(default_factory=dict)
    state: Any = None               # workload-owned (carry / KV slot)
    pending: deque = dataclasses.field(default_factory=deque)
    results: list = dataclasses.field(default_factory=list)
    latency_ms: list = dataclasses.field(default_factory=list)
    rejected: int = 0               # frames shed by backpressure
    admitted: bool = False
    done: bool = False


class Workload:
    """What the scheduler schedules.  Implementations own all device
    state; the scheduler never touches arrays."""

    def open_session(self, session: Session) -> Any:
        """Admission-time setup (carry init / prefill).  The return
        value becomes ``session.state``."""
        raise NotImplementedError

    def enqueue(self, session: Session, item):
        """Stage one submitted work item (hook for upload-at-enqueue;
        the default stages nothing)."""
        return item

    def step(self, batch: list, width: int) -> list:
        """Run one tick over ``batch`` = [(session, item), ...] with
        ``len(batch) <= width`` (the bucketed launch width).  Returns
        [(result, done), ...] aligned with ``batch``; results must be
        materialized (the scheduler stamps completion time on return).
        """
        raise NotImplementedError

    def close_session(self, session: Session) -> None:
        """Teardown (slot free / carry drop)."""


class StreamScheduler:
    """Continuous batching of N client streams over one Workload."""

    def __init__(self, workload: Workload,
                 config: ServeConfig | None = None):
        self.workload = workload
        self.config = config or ServeConfig()
        self.sessions: dict[int, Session] = {}   # admitted, by sid
        self.waiting: deque[Session] = deque()
        self.closed: list[Session] = []
        self.ticks = 0
        self.tick_ms: list[float] = []
        self._sids = itertools.count()

    # -- admission --------------------------------------------------------
    def open(self, client: str = "client", **meta) -> Session:
        """Admit (or queue) one new client stream; raises
        :class:`AdmissionError` when the service is full.

        >>> class Echo(Workload):
        ...     def open_session(self, session): return {}
        ...     def step(self, batch, width):
        ...         return [(item, False) for _, item in batch]
        >>> sched = StreamScheduler(Echo(), ServeConfig(max_concurrency=1,
        ...                                             max_queue=1))
        >>> sched.open("scanner-a").admitted
        True
        >>> sched.open("scanner-b").admitted    # queued behind the first
        False
        >>> sched.open("scanner-c")
        Traceback (most recent call last):
            ...
        repro.serve.scheduler.AdmissionError: service full: 1 admitted, \
1 waiting (max_queue=1)
        """
        if (len(self.sessions) >= self.config.max_concurrency
                and len(self.waiting) >= self.config.max_queue):
            raise AdmissionError(
                f"service full: {len(self.sessions)} admitted, "
                f"{len(self.waiting)} waiting (max_queue="
                f"{self.config.max_queue})")
        s = Session(sid=next(self._sids), client=client, meta=dict(meta))
        if len(self.sessions) < self.config.max_concurrency:
            self._admit(s)
        else:
            self.waiting.append(s)
        return s

    def _admit(self, s: Session) -> None:
        s.state = self.workload.open_session(s)
        s.admitted = True
        self.sessions[s.sid] = s

    def _refill(self) -> None:
        while self.waiting and \
                len(self.sessions) < self.config.max_concurrency:
            self._admit(self.waiting.popleft())

    # -- per-session queueing (backpressure) ------------------------------
    def submit(self, session: Session, item) -> bool:
        """Enqueue one work item (a frame / a decode step).  Returns
        False — the item was SHED — once ``queue_depth`` items are
        already staged: a real-time client must drop frames, not let
        its latency grow without bound.

        >>> class Echo(Workload):
        ...     def open_session(self, session): return {}
        ...     def step(self, batch, width):
        ...         return [(item, False) for _, item in batch]
        >>> sched = StreamScheduler(Echo(), ServeConfig(queue_depth=1))
        >>> s = sched.open("scanner")
        >>> sched.submit(s, "frame0")
        True
        >>> sched.submit(s, "frame1")   # past queue_depth: shed
        False
        >>> s.rejected
        1
        """
        if session.done:
            raise RuntimeError(f"submit on closed session {session.sid}")
        if len(session.pending) >= self.config.queue_depth:
            session.rejected += 1
            return False
        staged = self.workload.enqueue(session, item)
        session.pending.append((staged, time.perf_counter()))
        return True

    # -- the tick ---------------------------------------------------------
    def tick(self) -> int:
        """Admit what fits, batch everything ready, run one Workload
        step.  Returns the number of items completed.

        >>> class Echo(Workload):
        ...     def open_session(self, session): return {}
        ...     def step(self, batch, width):
        ...         return [(item, False) for _, item in batch]
        >>> sched = StreamScheduler(Echo())
        >>> a, b = sched.open("a"), sched.open("b")
        >>> _ = sched.submit(a, 1); _ = sched.submit(b, 2)
        >>> sched.tick()                # one batched step over both
        2
        >>> (a.results, b.results)
        ([1], [2])
        >>> sched.tick()                # nothing ready
        0
        """
        self._refill()
        ready = [s for _, s in sorted(self.sessions.items()) if s.pending]
        if not ready:
            return 0
        cap = self.config.buckets[-1]
        if len(ready) > cap:
            # overcommitted: rotate the start so no client is starved
            r = self.ticks % len(ready)
            ready = (ready[r:] + ready[:r])[:cap]
        width = self.config.bucket(len(ready))
        batch = [(s, s.pending.popleft()) for s in ready]
        t0 = time.perf_counter()
        out = self.workload.step([(s, item) for s, (item, _) in batch],
                                 width)
        t1 = time.perf_counter()
        self.ticks += 1
        self.tick_ms.append((t1 - t0) * 1e3)
        if len(out) != len(batch):
            raise RuntimeError(
                f"{type(self.workload).__name__}.step returned {len(out)} "
                f"results for a batch of {len(batch)}")
        for (s, (_, t_submit)), (result, done) in zip(batch, out):
            s.results.append(result)
            s.latency_ms.append((t1 - t_submit) * 1e3)
            if done:
                self.close(s)
        return len(batch)

    def close(self, session: Session) -> None:
        """End one stream: workload teardown, then admit from the
        waiting queue."""
        if session.done:
            return
        self.workload.close_session(session)
        session.done = True
        session.pending.clear()
        self.sessions.pop(session.sid, None)
        if session in self.waiting:
            self.waiting.remove(session)
        self.closed.append(session)
        self._refill()

    def drain(self) -> int:
        """Tick until no admitted session has work and the waiting
        queue cannot make progress.  Returns items completed."""
        total = 0
        while True:
            n = self.tick()
            total += n
            if n == 0 and not any(s.pending for s in self.sessions.values()):
                self._refill()
                if not any(s.pending for s in self.sessions.values()):
                    return total

    # -- accounting -------------------------------------------------------
    def report(self) -> dict:
        """Per-client latency/SLO table + aggregate throughput, on the
        repo-wide ``latency_stats``."""
        budget = self.config.budget_ms
        clients: dict[str, dict] = {}
        for s in itertools.chain(self.closed, self.waiting,
                                 self.sessions.values()):
            row = {"sid": s.sid, "frames": len(s.latency_ms),
                   "rejected": s.rejected,
                   **latency_stats(s.latency_ms)}
            if budget is not None:
                inside = sum(1 for t in s.latency_ms if t <= budget)
                row["slo"] = {
                    "budget_ms": budget,
                    "met": round(inside / max(len(s.latency_ms), 1), 3)}
            clients[s.client] = row
        frames = sum(len(s.latency_ms)
                     for s in itertools.chain(self.closed, self.waiting,
                                              self.sessions.values()))
        wall = sum(self.tick_ms)
        return {
            "clients": clients,
            "aggregate": {
                "frames": frames,
                "ticks": self.ticks,
                "tick": latency_stats(self.tick_ms),
                "fps": round(frames / max(wall, 1e-9) * 1e3, 2),
                "rejected": sum(c["rejected"] for c in clients.values()),
            },
        }
