"""The two production workloads behind ``StreamScheduler``.

:class:`NlinvStreamWorkload` — N concurrent real-time NLINV streams.
Independent clients' Newton solves are stacked on a leading batch dim of
the ``(rho, chat)`` carry pytree and solved in ONE SPMD launch
(``Reconstructor.fn_batched``): the per-iteration collectives of B
solves coalesce into one rendezvous each, which is where the batching
win comes from.  Two invariants keep the tick cheap:

  * the stacked carry is PERSISTENT — while the ready set is stable
    (the steady state of K clients streaming) the carry never leaves
    the device or gets restacked; it is sliced back into per-session
    state only when the membership changes (client joins/leaves/skips
    a tick: the "mixed frame phases" case);
  * uploads happen at submit() time through the same
    ``upload_frame`` helper the single-stream ``FrameStream`` uses, so
    every client's next acquisition lands behind the in-flight tick.

:class:`LMDecodeWorkload` — greedy continuous-batching LM decode, the
old bespoke ``Engine`` loop re-expressed as a Workload: admission =
prefill into a KV slot from the explicit :class:`SlotPool`, one tick =
one decode step per active request, close = slot free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..ft.remesh import migrate_carry, pad_rows
from ..nlinv.operators import sobolev_weight
from ..nlinv.recon import Reconstructor, pad_channels
from ..nlinv.stream import upload_frame
from ..task import Executor, TaskGraph
from .scheduler import Rejected, Session, Workload


def stack_carries(carries: list) -> dict:
    """Stack per-session ``(rho, chat)`` carries on a new leading batch
    dim (one jnp.stack per leaf)."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *carries)


def unstack_carry(stacked, i: int):
    """Slice session ``i``'s carry back out of the stacked pytree."""
    return jax.tree.map(lambda a: a[i], stacked)


class NlinvStreamWorkload(Workload):
    """B NLINV frame solves per tick, one batched SPMD launch.

    Work item (per ``submit``): a ``(y, mask)`` acquisition with ``y``
    of shape (J, X, Y) (channel-padded here) and ``mask`` (X, Y).
    Result: the reconstructed (X, Y) image (device array, ready) — or a
    :class:`~repro.serve.Rejected` status when the health check finds a
    non-finite output (the client is quarantined: its carry row is
    re-initialized in place, every other row is untouched).
    Geometry (grid, coil count) is fixed per workload — one scanner
    protocol per scheduler; the first session pins it.

    ``retry`` (a ``repro.ft.RestartPolicy``) arms the tick executor's
    transient-task retry; ``operating_points`` is the degradation
    ladder — ``((newton, cg_iters), ...)`` below nominal, coarsest
    last (default: one derived point at roughly half the CG work).
    Newton/CG depth is part of every batched plan key, so each point
    compiles its own program and switching is just a cache lookup after
    the first visit.
    """

    def __init__(self, rec: Reconstructor, *, damping: float = 0.9,
                 retry=None, operating_points=None):
        self.rec = rec
        self.damping = damping
        self._exec = Executor(retry=retry)
        self._damp = jax.jit(
            lambda u: jax.tree.map(lambda a: damping * a, u))
        self._geom = None            # (J_padded, grid), pinned by 1st open
        self._fov_d = self._w_d = None
        # persistent stacked carry: (sids tuple, u_stack, x_ref_stack),
        # plus the Session objects whose carries live in that stack
        self._stack = None
        self._by_sid: dict = {}
        # -- fault tolerance ----------------------------------------------
        if operating_points is None:
            n0, c0 = rec.newton, rec.cg_iters
            pt = (max(n0 - 1, 1), max(c0 // 2, 2))
            operating_points = () if pt == (n0, c0) else (pt,)
        self._points = ((rec.newton, rec.cg_iters),) \
            + tuple(operating_points)
        self._level = 0
        self._health_jit = None
        self.quarantined = 0         # total quarantine events
        self.remeshes = 0            # survivor-group migrations

    # -- degradation ladder (scheduler deadline enforcement) --------------
    @property
    def levels(self) -> int:
        return len(self._points) - 1

    def set_level(self, level: int) -> None:
        """Switch the Newton/CG operating point (0 = nominal).  The
        carry shapes are level-independent, so the persistent stack
        stays put; only the plan key changes."""
        if not 0 <= level <= self.levels:
            raise ValueError(f"level {level} outside 0..{self.levels}")
        if level == self._level:
            return
        self._level = level
        self.rec.newton, self.rec.cg_iters = self._points[level]

    def counters(self) -> dict:
        return {"retried_tasks": self._exec.retried,
                "quarantined": self.quarantined,
                "remeshes": self.remeshes}

    # -- session lifecycle ------------------------------------------------
    def open_session(self, session: Session):
        g = int(session.meta["grid"])
        J = pad_channels(np.zeros((int(session.meta["ncoils"]), 1, 1),
                                  np.complex64),
                         self.rec.comm.size).shape[0]
        if self._geom is None:
            self._geom = (J, g)
            self._fov_d = self.rec.put_const(
                np.asarray(session.meta["fov"]))
            self._w_d = self.rec.put_const(
                np.asarray(session.meta.get("weight",
                                            sobolev_weight(g))))
        elif self._geom != (J, g):
            raise ValueError(
                f"session geometry (J={J}, grid={g}) does not match the "
                f"workload's {self._geom}: one protocol per scheduler")
        u = self.rec.init_carry(J, g)
        # x_ref starts equal to u but must be a distinct buffer
        return {"u": u, "x_ref": jax.tree.map(lambda a: a + 0, u)}

    def enqueue(self, session: Session, item):
        """Upload at submit time: the scatter/bcast of this frame lands
        while the current tick's solve is still in flight (the serving
        analogue of FrameStream's double buffer)."""
        y, mask = item
        y = pad_channels(np.asarray(y), self.rec.comm.size)
        if self._geom is not None and y.shape[0] < self._geom[0]:
            # after an elastic remesh the pinned coil dim can exceed the
            # raw padding (J was padded for the OLD group size); zero
            # channels are exact NLINV no-ops, so top up
            y = pad_rows(y, self._geom[0])
        return upload_frame(self.rec, y, mask)

    def close_session(self, session: Session) -> None:
        self._spill(keep=lambda sid: sid != session.sid)

    # -- the batched tick -------------------------------------------------
    def _spill(self, keep=lambda sid: True) -> None:
        """Write the stacked carry back into per-session state (dropping
        sessions ``keep`` rejects) and forget the stack."""
        if self._stack is None:
            return
        sids, ub, xb = self._stack
        self._stack = None
        for i, sid in enumerate(sids):
            s = self._by_sid.get(sid)
            if s is None or not keep(sid):
                continue
            s.state["u"] = unstack_carry(ub, i)
            s.state["x_ref"] = unstack_carry(xb, i)

    def step(self, batch: list, width: int) -> list:
        sessions = [s for s, _ in batch]
        sids = tuple(s.sid for s in sessions)
        B = len(batch)
        if self._stack is not None and self._stack[0][:B] == sids \
                and len(self._stack[0]) == width:
            # steady state: same members, same width — reuse in place
            _, ub, xb = self._stack
        else:
            # membership or width changed: write everyone's carry back
            # to their session BEFORE the new map is installed
            self._spill()
            # pad the launch to the bucket width by replicating the
            # last session's row (vmap rows are independent; padded
            # rows are computed and discarded)
            rows = sessions + [sessions[-1]] * (width - B)
            ub = stack_carries([s.state["u"] for s in rows])
            xb = stack_carries([s.state["x_ref"] for s in rows])
        pads = [item for _, item in batch]
        pads += [pads[-1]] * (width - B)
        # One tick is one task graph: the stack of the already-uploaded
        # acquisitions is an explicit copy edge into the batched solve,
        # and the fence happens once, at the executor's sinks, instead
        # of an ad-hoc block on the image batch.
        g = TaskGraph()
        g.copy("stack",
               lambda: (jnp.stack([yd for yd, _ in pads]),
                        jnp.stack([md for _, md in pads])),
               outputs=("yb", "mb"))
        # the stacked carry is replaced every tick, so its two largest
        # buffers are donated to the launch (as in FrameStream)
        g.add("solve", self.rec.fn_batched(width, donate=True),
              inputs=("yb", "mb", "fov", "weight", "u_prev", "xref_prev"),
              outputs=("u", "img"), group=self.rec.comm)
        g.add("damp", self._damp, inputs=("u",), outputs=("xref",),
              group=self.rec.comm)
        vals = self._exec.run(
            g, feeds={"fov": self._fov_d, "weight": self._w_d,
                      "u_prev": ub, "xref_prev": xb},
            outputs=("u", "xref", "img", "yb"))
        ub, xb, imgb = vals["u"], vals["xref"], vals["img"]
        # fused health check: one jitted all-finite reduction over the
        # carry + image + acquisition rows, one (width,) bool vector to
        # the host.  The INPUT rows matter: a NaN acquisition makes the
        # CG residual norm NaN, its `rs > thresh` guard False — the
        # solve degenerates to du = 0 and would silently deliver a
        # stale image; the only honest outcome is a Rejected frame.
        ok = np.asarray(self._health(ub, imgb, vals["yb"]))
        out = []
        for i in range(width):
            if bool(ok[i]):
                if i < B:
                    out.append((imgb[i], False))
                continue
            # quarantine row i: re-initialize its carry slice in place
            # (rows are vmap-independent — every other client's result
            # is bitwise what it would have been without the poison).
            # Padded rows (i >= B) replicate the last session and must
            # be reset too, or the spill would hand it a poisoned carry.
            ub, xb = self._reset_row(ub, xb, i)
            if i < B:
                self.quarantined += 1
                out.append((Rejected("non-finite frame output; client "
                                     "quarantined, carry re-initialized"),
                            False))
        self._stack = (sids + (sids[-1],) * (width - B), ub, xb)
        self._by_sid = {s.sid: s for s in sessions}
        # NLINV streams are long-lived: never done from inside a tick
        return out

    def _health(self, ub, imgb, yb):
        """All-finite per batch row (carry, image, acquisition), fused
        into one jitted program."""
        if self._health_jit is None:
            def fn(u, img, y):
                ok = None
                for a in jax.tree.leaves(u) + [img, y]:
                    r = jnp.isfinite(a).all(
                        axis=tuple(range(1, a.ndim)))
                    ok = r if ok is None else ok & r
                return ok
            self._health_jit = jax.jit(fn)
        return self._health_jit(ub, imgb, yb)

    def _reset_row(self, ub, xb, i: int):
        """Fresh carry into batch row ``i`` of the stacked pytrees."""
        J, g = self._geom
        fresh = self.rec.init_carry(J, g)
        ub = jax.tree.map(lambda st, fr: st.at[i].set(fr), ub, fresh)
        xb = jax.tree.map(lambda st, fr: st.at[i].set(fr), xb, fresh)
        return ub, xb

    # -- elastic remesh ---------------------------------------------------
    def remesh(self, comm, sessions=()) -> None:
        """Continue every live stream on a survivor communicator (after
        ``Environment.survivor`` minted one for a device loss).

        The persistent stack is spilled, a new :class:`Reconstructor`
        is built on ``comm`` (plan keys carry the group token, so the
        survivor programs compile fresh), the pinned constants and every
        session carry in ``sessions`` migrate via
        ``repro.ft.migrate_carry`` — coil rows zero-padded to the new
        group size, which is exact for all NLINV sums — and subsequent
        ticks run at the survivor width.
        """
        self._spill()
        old = self.rec
        self.rec = Reconstructor(comm, newton=old.newton,
                                 cg_iters=old.cg_iters,
                                 channel_sum=old.channel_sum,
                                 hierarchical=old.hierarchical,
                                 fused=old.fused, overlap=old.overlap)
        self.remeshes += 1
        self._health_jit = None
        if self._geom is None:
            return
        J, g = self._geom
        size = self.rec.comm.size
        Jp = -(-J // size) * size
        self._geom = (Jp, g)
        self._fov_d = self.rec.put_const(np.asarray(self._fov_d))
        self._w_d = self.rec.put_const(np.asarray(self._w_d))
        for s in sessions:
            if s.done or not isinstance(s.state, dict):
                continue
            s.state["u"] = migrate_carry(self.rec, s.state["u"],
                                         pad_to=Jp)
            s.state["x_ref"] = migrate_carry(self.rec, s.state["x_ref"],
                                             pad_to=Jp)
            # staged uploads live on the LOST group: drop them (the
            # client resubmits; a dropped frame beats a dead stream)
            s.pending.clear()


class SlotPool:
    """Explicit KV-slot bookkeeping for continuous batching: ``assign``
    takes the lowest free slot, ``free`` returns it.  Every transition
    is checked — a double free or an over-assign is a bug in the caller,
    never silent state corruption."""

    def __init__(self, n: int):
        if n < 1:
            raise ValueError("SlotPool needs at least one slot")
        self.n = n
        self._free = list(range(n))
        self._used: set[int] = set()

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> tuple:
        return tuple(sorted(self._used))

    def assign(self) -> int:
        if not self._free:
            raise RuntimeError(f"SlotPool exhausted ({self.n} slots in use)")
        slot = self._free.pop(0)
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise RuntimeError(f"SlotPool.free({slot}): slot not assigned")
        self._used.remove(slot)
        self._free.append(slot)
        self._free.sort()


class LMDecodeWorkload(Workload):
    """Greedy LM decode as a Workload: one KV slot per admitted request,
    one decode step per work item.  Work items carry no payload (the
    token fed back is the previous output); results are token ids."""

    def __init__(self, cfg, params, *, batch: int = 4, max_len: int = 512):
        from ..models import transformer
        from .engine import make_serve_steps
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        pf, dec, _ = make_serve_steps(cfg, None, max_len=max_len, batch=1)
        self._prefill, self._decode = pf, dec
        self._mk_cache = lambda: transformer.init_cache(cfg, 1, max_len,
                                                        cfg.cdtype)
        self.slots = SlotPool(batch)

    def open_session(self, session: Session):
        from ..models import frontends
        prompt = list(session.meta["prompt"])
        slot = self.slots.assign()
        enc = frontends.synthetic_frontend(self.cfg, 1)
        cache = self._mk_cache()
        toks = jnp.asarray([prompt], jnp.int32)
        logits, cache = self._prefill(self.params, toks, cache, enc=enc)
        # the prefill emits the first output token at admission
        session.results.append(int(jnp.argmax(logits[0])))
        return {"slot": slot, "cache": cache, "pos": len(prompt)}

    def step(self, batch: list, width: int) -> list:
        out = []
        for session, _ in batch:
            st = session.state
            tok = jnp.asarray([[session.results[-1]]], jnp.int32)
            logits, st["cache"] = self._decode(self.params, tok,
                                               st["cache"], st["pos"])
            st["pos"] += 1
            nxt = int(jnp.argmax(logits[0]))
            produced = len(session.results) + 1   # incl. this token
            done = (produced >= int(session.meta["max_new"])
                    or st["pos"] >= self.max_len - 1)
            out.append((nxt, done))
        return out

    def close_session(self, session: Session) -> None:
        self.slots.free(session.state["slot"])
