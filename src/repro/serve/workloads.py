"""The two production workloads behind ``StreamScheduler``.

:class:`NlinvStreamWorkload` — N concurrent real-time NLINV streams.
Independent clients' Newton solves are stacked on a leading batch dim of
the ``(rho, chat)`` carry pytree and solved in ONE SPMD launch
(``Reconstructor.fn_batched``): the per-iteration collectives of B
solves coalesce into one rendezvous each, which is where the batching
win comes from.  Two invariants keep the tick cheap:

  * the stacked carry is PERSISTENT — while the ready set is stable
    (the steady state of K clients streaming) the carry never leaves
    the device or gets restacked; it is sliced back into per-session
    state only when the membership changes (client joins/leaves/skips
    a tick: the "mixed frame phases" case);
  * uploads happen at submit() time through the same
    ``upload_frame`` helper the single-stream ``FrameStream`` uses, so
    every client's next acquisition lands behind the in-flight tick.

:class:`LMDecodeWorkload` — greedy continuous-batching LM decode, the
old bespoke ``Engine`` loop re-expressed as a Workload: admission =
prefill into a KV slot from the explicit :class:`SlotPool`, one tick =
one decode step per active request, close = slot free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..nlinv.operators import sobolev_weight
from ..nlinv.recon import Reconstructor, pad_channels
from ..nlinv.stream import upload_frame
from ..task import Executor, TaskGraph
from .scheduler import Session, Workload


def stack_carries(carries: list) -> dict:
    """Stack per-session ``(rho, chat)`` carries on a new leading batch
    dim (one jnp.stack per leaf)."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *carries)


def unstack_carry(stacked, i: int):
    """Slice session ``i``'s carry back out of the stacked pytree."""
    return jax.tree.map(lambda a: a[i], stacked)


class NlinvStreamWorkload(Workload):
    """B NLINV frame solves per tick, one batched SPMD launch.

    Work item (per ``submit``): a ``(y, mask)`` acquisition with ``y``
    of shape (J, X, Y) (channel-padded here) and ``mask`` (X, Y).
    Result: the reconstructed (X, Y) image (device array, ready).
    Geometry (grid, coil count) is fixed per workload — one scanner
    protocol per scheduler; the first session pins it.
    """

    def __init__(self, rec: Reconstructor, *, damping: float = 0.9):
        self.rec = rec
        self.damping = damping
        self._exec = Executor()
        self._damp = jax.jit(
            lambda u: jax.tree.map(lambda a: damping * a, u))
        self._geom = None            # (J_padded, grid), pinned by 1st open
        self._fov_d = self._w_d = None
        # persistent stacked carry: (sids tuple, u_stack, x_ref_stack),
        # plus the Session objects whose carries live in that stack
        self._stack = None
        self._by_sid: dict = {}

    # -- session lifecycle ------------------------------------------------
    def open_session(self, session: Session):
        g = int(session.meta["grid"])
        J = pad_channels(np.zeros((int(session.meta["ncoils"]), 1, 1),
                                  np.complex64),
                         self.rec.comm.size).shape[0]
        if self._geom is None:
            self._geom = (J, g)
            self._fov_d = self.rec.put_const(
                np.asarray(session.meta["fov"]))
            self._w_d = self.rec.put_const(
                np.asarray(session.meta.get("weight",
                                            sobolev_weight(g))))
        elif self._geom != (J, g):
            raise ValueError(
                f"session geometry (J={J}, grid={g}) does not match the "
                f"workload's {self._geom}: one protocol per scheduler")
        u = self.rec.init_carry(J, g)
        # x_ref starts equal to u but must be a distinct buffer
        return {"u": u, "x_ref": jax.tree.map(lambda a: a + 0, u)}

    def enqueue(self, session: Session, item):
        """Upload at submit time: the scatter/bcast of this frame lands
        while the current tick's solve is still in flight (the serving
        analogue of FrameStream's double buffer)."""
        y, mask = item
        y = pad_channels(np.asarray(y), self.rec.comm.size)
        return upload_frame(self.rec, y, mask)

    def close_session(self, session: Session) -> None:
        self._spill(keep=lambda sid: sid != session.sid)

    # -- the batched tick -------------------------------------------------
    def _spill(self, keep=lambda sid: True) -> None:
        """Write the stacked carry back into per-session state (dropping
        sessions ``keep`` rejects) and forget the stack."""
        if self._stack is None:
            return
        sids, ub, xb = self._stack
        self._stack = None
        for i, sid in enumerate(sids):
            s = self._by_sid.get(sid)
            if s is None or not keep(sid):
                continue
            s.state["u"] = unstack_carry(ub, i)
            s.state["x_ref"] = unstack_carry(xb, i)

    def step(self, batch: list, width: int) -> list:
        sessions = [s for s, _ in batch]
        sids = tuple(s.sid for s in sessions)
        B = len(batch)
        if self._stack is not None and self._stack[0][:B] == sids \
                and len(self._stack[0]) == width:
            # steady state: same members, same width — reuse in place
            _, ub, xb = self._stack
        else:
            # membership or width changed: write everyone's carry back
            # to their session BEFORE the new map is installed
            self._spill()
            # pad the launch to the bucket width by replicating the
            # last session's row (vmap rows are independent; padded
            # rows are computed and discarded)
            rows = sessions + [sessions[-1]] * (width - B)
            ub = stack_carries([s.state["u"] for s in rows])
            xb = stack_carries([s.state["x_ref"] for s in rows])
        pads = [item for _, item in batch]
        pads += [pads[-1]] * (width - B)
        # One tick is one task graph: the stack of the already-uploaded
        # acquisitions is an explicit copy edge into the batched solve,
        # and the fence happens once, at the executor's sinks, instead
        # of an ad-hoc block on the image batch.
        g = TaskGraph()
        g.copy("stack",
               lambda: (jnp.stack([yd for yd, _ in pads]),
                        jnp.stack([md for _, md in pads])),
               outputs=("yb", "mb"))
        # the stacked carry is replaced every tick, so its two largest
        # buffers are donated to the launch (as in FrameStream)
        g.add("solve", self.rec.fn_batched(width, donate=True),
              inputs=("yb", "mb", "fov", "weight", "u_prev", "xref_prev"),
              outputs=("u", "img"), group=self.rec.comm)
        g.add("damp", self._damp, inputs=("u",), outputs=("xref",),
              group=self.rec.comm)
        vals = self._exec.run(
            g, feeds={"fov": self._fov_d, "weight": self._w_d,
                      "u_prev": ub, "xref_prev": xb},
            outputs=("u", "xref", "img"))
        ub, xb, imgb = vals["u"], vals["xref"], vals["img"]
        self._stack = (sids + (sids[-1],) * (width - B), ub, xb)
        self._by_sid = {s.sid: s for s in sessions}
        # NLINV streams are long-lived: never done from inside a tick
        return [(imgb[i], False) for i in range(B)]


class SlotPool:
    """Explicit KV-slot bookkeeping for continuous batching: ``assign``
    takes the lowest free slot, ``free`` returns it.  Every transition
    is checked — a double free or an over-assign is a bug in the caller,
    never silent state corruption."""

    def __init__(self, n: int):
        if n < 1:
            raise ValueError("SlotPool needs at least one slot")
        self.n = n
        self._free = list(range(n))
        self._used: set[int] = set()

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> tuple:
        return tuple(sorted(self._used))

    def assign(self) -> int:
        if not self._free:
            raise RuntimeError(f"SlotPool exhausted ({self.n} slots in use)")
        slot = self._free.pop(0)
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise RuntimeError(f"SlotPool.free({slot}): slot not assigned")
        self._used.remove(slot)
        self._free.append(slot)
        self._free.sort()


class LMDecodeWorkload(Workload):
    """Greedy LM decode as a Workload: one KV slot per admitted request,
    one decode step per work item.  Work items carry no payload (the
    token fed back is the previous output); results are token ids."""

    def __init__(self, cfg, params, *, batch: int = 4, max_len: int = 512):
        from ..models import transformer
        from .engine import make_serve_steps
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        pf, dec, _ = make_serve_steps(cfg, None, max_len=max_len, batch=1)
        self._prefill, self._decode = pf, dec
        self._mk_cache = lambda: transformer.init_cache(cfg, 1, max_len,
                                                        cfg.cdtype)
        self.slots = SlotPool(batch)

    def open_session(self, session: Session):
        from ..models import frontends
        prompt = list(session.meta["prompt"])
        slot = self.slots.assign()
        enc = frontends.synthetic_frontend(self.cfg, 1)
        cache = self._mk_cache()
        toks = jnp.asarray([prompt], jnp.int32)
        logits, cache = self._prefill(self.params, toks, cache, enc=enc)
        # the prefill emits the first output token at admission
        session.results.append(int(jnp.argmax(logits[0])))
        return {"slot": slot, "cache": cache, "pos": len(prompt)}

    def step(self, batch: list, width: int) -> list:
        out = []
        for session, _ in batch:
            st = session.state
            tok = jnp.asarray([[session.results[-1]]], jnp.int32)
            logits, st["cache"] = self._decode(self.params, tok,
                                               st["cache"], st["pos"])
            st["pos"] += 1
            nxt = int(jnp.argmax(logits[0]))
            produced = len(session.results) + 1   # incl. this token
            done = (produced >= int(session.meta["max_new"])
                    or st["pos"] >= self.max_len - 1)
            out.append((nxt, done))
        return out

    def close_session(self, session: Session) -> None:
        self.slots.free(session.state["slot"])
