"""LM serving entry point: prefill/decode step functions plus the
``Engine`` front door.  Since the serve subsystem landed, ``Engine`` is
a thin request-tracking wrapper over the shared
:class:`~repro.serve.scheduler.StreamScheduler` driving
:class:`~repro.serve.workloads.LMDecodeWorkload` — the same scheduler
that batches concurrent NLINV streams; there is no bespoke decode loop
here anymore."""

from __future__ import annotations

import dataclasses
import itertools

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import transformer


def make_serve_steps(cfg, mesh=None, *, max_len=2048, batch=8,
                     tp="model", batch_axes=("data",)):
    """Returns (prefill_fn, decode_fn, init_cache_fn), jit'd (+sharded
    when a mesh is given)."""

    def prefill(params, tokens, cache, enc=None, pos=0):
        logits, cache, _ = transformer.apply(
            cfg, params, tokens, enc=enc, mode="prefill", pos=pos,
            cache=cache, logits_window=1)
        return logits[:, -1], cache

    def decode(params, tokens, cache, pos):
        logits, cache, _ = transformer.apply(
            cfg, params, tokens, enc=None, mode="decode", pos=pos,
            cache=cache)
        return logits[:, -1], cache

    def init_cache():
        return transformer.init_cache(cfg, batch, max_len, cfg.cdtype)

    if mesh is None:
        return jax.jit(prefill), jax.jit(decode), init_cache

    cache_shape = jax.eval_shape(init_cache)
    cspec = transformer.cache_pspecs(cfg, cache_shape, dict(mesh.shape),
                                     tp=tp, batch=batch_axes)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                            is_leaf=lambda x: isinstance(x, P))
    pspecs = transformer.param_pspecs(cfg, jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))),
        dict(mesh.shape), tp=tp, fsdp=batch_axes)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    tok_sh = NamedSharding(mesh, P(bspec, None))
    rep = NamedSharding(mesh, P())

    prefill_j = jax.jit(prefill, in_shardings=(param_sh, tok_sh, cache_sh),
                        out_shardings=(None, cache_sh),
                        static_argnames=("pos",))
    decode_j = jax.jit(decode, in_shardings=(param_sh, tok_sh, cache_sh, rep),
                       out_shardings=(None, cache_sh),
                       donate_argnums=(2,))
    return prefill_j, decode_j, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Greedy continuous-batching LM server over ``batch`` KV slots.

    Front door only: admission, slot assignment, batching, ticking and
    reclamation all live in the shared ``StreamScheduler`` +
    ``LMDecodeWorkload`` (prefill at admission, one decode per tick,
    slot freed through the explicit ``SlotPool`` on completion).
    Request ids come from a monotonic counter — submitting after a
    drain can never reuse a live rid.  Deterministic: greedy argmax.
    """

    def __init__(self, cfg, params, *, batch=4, max_len=512):
        from .scheduler import ServeConfig, StreamScheduler
        from .workloads import LMDecodeWorkload
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.workload = LMDecodeWorkload(cfg, params, batch=batch,
                                         max_len=max_len)
        # decode items are enqueued all at submit time, so the per-
        # session depth bound must admit the longest request; admission
        # (slot) pressure is the real LM bound.
        self.scheduler = StreamScheduler(self.workload, ServeConfig(
            max_concurrency=batch, max_queue=2 ** 30,
            queue_depth=max(max_len, 1), buckets=(batch,)))
        self._rids = itertools.count()
        self._requests: dict[int, tuple[Request, object]] = {}

    def submit(self, prompt, max_new=32) -> int:
        rid = next(self._rids)
        req = Request(rid, list(prompt), max_new)
        sess = self.scheduler.open(client=f"req{rid}", prompt=req.prompt,
                                   max_new=max_new)
        # prefill (at admission) emits token 1; each decode tick emits one
        for _ in range(max(max_new - 1, 0)):
            self.scheduler.submit(sess, None)
        self._requests[rid] = (req, sess)
        return rid

    def _collect(self) -> list[Request]:
        finished = []
        for rid, (req, sess) in list(self._requests.items()):
            if (sess.admitted and not sess.done and not sess.pending
                    and len(sess.results) >= req.max_new):
                # prefill-only request (max_new <= 1): complete at
                # admission, no decode tick ever fires for it
                self.scheduler.close(sess)
            if sess.done and not req.done:
                req.out = list(sess.results)
                req.done = True
                finished.append(req)
        return finished

    def step(self) -> list[Request]:
        """One scheduler tick; returns the requests it completed."""
        self.scheduler.tick()
        return self._collect()

    def run(self) -> list[Request]:
        """Drain every submitted request; returns them in rid order."""
        while True:
            n = self.scheduler.drain()
            # a drain that moved nothing and completed nothing cannot
            # make progress on the next pass either
            if not self._collect() and n == 0:
                break
            if all(req.done for req, _ in self._requests.values()):
                break
        done = [req for rid, (req, _) in sorted(self._requests.items())
                if req.done]
        for req in done:                 # returned once; engine stays usable
            self._requests.pop(req.rid)
        return done
