"""Serving engine: prefill/decode step functions + a slot-based
continuous-batching driver (the LM analogue of the paper's real-time
reconstruction server: fixed problem size, bounded latency per step).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import frontends, transformer


def make_serve_steps(cfg, mesh=None, *, max_len=2048, batch=8,
                     tp="model", batch_axes=("data",)):
    """Returns (prefill_fn, decode_fn, init_cache_fn), jit'd (+sharded
    when a mesh is given)."""

    def prefill(params, tokens, cache, enc=None, pos=0):
        logits, cache, _ = transformer.apply(
            cfg, params, tokens, enc=enc, mode="prefill", pos=pos,
            cache=cache, logits_window=1)
        return logits[:, -1], cache

    def decode(params, tokens, cache, pos):
        logits, cache, _ = transformer.apply(
            cfg, params, tokens, enc=None, mode="decode", pos=pos,
            cache=cache)
        return logits[:, -1], cache

    def init_cache():
        return transformer.init_cache(cfg, batch, max_len, cfg.cdtype)

    if mesh is None:
        return jax.jit(prefill), jax.jit(decode), init_cache

    cache_shape = jax.eval_shape(init_cache)
    cspec = transformer.cache_pspecs(cfg, cache_shape, dict(mesh.shape),
                                     tp=tp, batch=batch_axes)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                            is_leaf=lambda x: isinstance(x, P))
    pspecs = transformer.param_pspecs(cfg, jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0))),
        dict(mesh.shape), tp=tp, fsdp=batch_axes)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    tok_sh = NamedSharding(mesh, P(bspec, None))
    rep = NamedSharding(mesh, P())

    prefill_j = jax.jit(prefill, in_shardings=(param_sh, tok_sh, cache_sh),
                        out_shardings=(None, cache_sh),
                        static_argnames=("pos",))
    decode_j = jax.jit(decode, in_shardings=(param_sh, tok_sh, cache_sh, rep),
                       out_shardings=(None, cache_sh),
                       donate_argnums=(2,))
    return prefill_j, decode_j, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Greedy continuous-batching server over ``batch`` slots.

    Simplification vs production: slots decode in lockstep at a shared
    position (per-slot kv_len masking handles ragged prompts by left-
    aligning each new request at position 0 of its own slot-batch run);
    one prefill per admission.  Deterministic: greedy argmax.
    """

    def __init__(self, cfg, params, *, batch=4, max_len=512):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        pf, dec, init_cache = make_serve_steps(cfg, None, max_len=max_len,
                                               batch=1)
        self._prefill, self._decode = pf, dec
        self._mk_cache = lambda: transformer.init_cache(cfg, 1, max_len,
                                                        cfg.cdtype)
        self.queue: list[Request] = []
        self.active: dict[int, dict[str, Any]] = {}

    def submit(self, prompt, max_new=32) -> int:
        rid = len(self.queue)
        self.queue.append(Request(rid, list(prompt), max_new))
        return rid

    def _admit(self):
        while self.queue and len(self.active) < self.batch:
            req = self.queue.pop(0)
            enc = frontends.synthetic_frontend(self.cfg, 1)
            cache = self._mk_cache()
            toks = jnp.asarray([req.prompt], jnp.int32)
            logits, cache = self._prefill(self.params, toks, cache, enc=enc)
            nxt = int(jnp.argmax(logits[0]))
            req.out.append(nxt)
            self.active[req.rid] = {"req": req, "cache": cache,
                                    "pos": len(req.prompt)}

    def step(self):
        """One decode step for every active request."""
        self._admit()
        finished = []
        for rid, st in list(self.active.items()):
            req = st["req"]
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, st["cache"] = self._decode(self.params, tok,
                                               st["cache"], st["pos"])
            st["pos"] += 1
            req.out.append(int(jnp.argmax(logits[0])))
            if len(req.out) >= req.max_new or st["pos"] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                del self.active[rid]
        return finished

    def run(self):
        done = []
        while self.queue or self.active:
            done.extend(self.step())
        return done
