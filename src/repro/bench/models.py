"""Calibrated analytic performance models for the ``derived`` columns.

Wall-clock on this container measures the CPU backend; multi-device
scaling columns are DERIVED from the roofline/alpha-beta model with the
TPU v5e constants, or from the paper's own 2013 testbed constants to
validate its claims (DESIGN.md §7's three-layer validation: semantics
are tested, counts are asserted, scaling comes from the model).
"""

from __future__ import annotations

import numpy as np

from ..core.runtime import HW

# The paper's 2013 testbed (Tyan FT72-B7015, 8x GTX 580): used to
# validate the paper's OWN speedup claims (1.7x @ 2 GPUs, 2.1x @ 4);
# the TPU-v5e columns show how the adaptation behaves on modern HW.
PAPER_HW = dict(
    peak_flops=0.79e12,      # GTX 580 fp32, ~50% achievable
    mem_bw=150e9,            # GDDR5 effective
    p2p_bw=6e9,              # PCIe 2.0 peer-to-peer (same IOH)
    host_bw=5e9,             # staged through host (cross IOH)
    latency=10e-6,
)

PCIE_BW = 16e9          # host->device, per path (the paper's 8-GPU box
                        # has multiple independent PCIe pathways)


def allreduce_time(nbytes: int, ndev: int, bw: float | None = None,
                   latency: float = 1e-6) -> float:
    """Ring all-reduce seconds for one device's payload."""
    if ndev <= 1:
        return 0.0
    bw = bw or HW["ici_bw"]
    return 2 * nbytes * (ndev - 1) / ndev / bw + 2 * (ndev - 1) * latency


def copy_time(nbytes: int, bw: float, latency: float = 5e-6) -> float:
    return nbytes / bw + latency


def speedup_model(grid: int, J: int, newton=7, cg_iters=6, hw="paper",
                  crop=True) -> dict:
    """Modeled NLINV speedup for G devices, calibrated on op counts.

    hw="paper": GTX-580/PCIe constants -> validates the paper's claims.
    hw="v5e":   TPU constants -> our adaptation's scaling.
    Per CG iteration: DF + DF^H = 6 FFT batches over the J local
    channels + ~9 pointwise passes + 1 all-reduce of rho (cropped FOV
    quarter when ``crop``); ~7% non-scaling CG overhead (scalar products
    + host sync, per the paper's CG row of Table 1)."""
    if hw == "paper":
        peak, bw, p2p, lat = (PAPER_HW["peak_flops"], PAPER_HW["mem_bw"],
                              PAPER_HW["p2p_bw"], PAPER_HW["latency"])
    else:
        peak, bw, p2p, lat = (HW["peak_flops_bf16"], HW["hbm_bw"],
                              HW["ici_bw"], 1e-6)
    flop_fft = 2 * 5 * grid * grid * np.log2(grid * grid)   # per channel
    bytes_img = grid * grid * 8                             # complex64
    t_fft = 3 * J * flop_fft / peak
    t_pw = 9 * J * bytes_img / bw
    t_serial = 0.07 * (t_fft + t_pw)
    ar_bytes = bytes_img // 4 if crop else bytes_img
    out = {}
    t1 = t_fft + t_pw + t_serial
    for G in (1, 2, 3, 4, 8):
        t_comp = (t_fft + t_pw) / G
        t_ar = allreduce_time(ar_bytes, G, bw=p2p, latency=lat) \
            if G > 1 else 0.0
        if hw == "paper":
            if G >= 4:
                t_ar *= G / 2.0     # shared PCIe switches: ring contention
                                    # (paper Fig.9: DF^H slows at 4 GPUs)
            if G > 4:
                t_ar *= 3.0         # cross-IOH: host-staged, no P2P
        out[G] = t1 / (t_comp + t_ar + t_serial)
    return out
