"""Timing harness: compile/plan-build cost separated from steady state.

The follow-up MRI paper (Schaetz et al. 2017) makes the point that
speed-up claims are only reproducible when one-time setup (trace, lower,
compile, plan builds) is measured apart from the steady-state per-call
cost.  ``measure`` enforces that discipline for every scenario:

  * the FIRST call is timed alone and fenced with
    ``jax.block_until_ready`` — that is ``compile_ms`` (it includes any
    plan-cache builds the call triggers);
  * ``warmup - 1`` further unfenced-timed calls settle caches/allocators;
  * ``iters`` fenced calls form the steady-state sample, summarized with
    the same percentile machinery as the streaming engine's
    ``LatencyReport`` (``repro.nlinv.stream.latency_stats``);
    ``steady_ms`` is the BEST (minimum) sample — the robust CPU-micro-
    benchmark estimator: scheduler interference only inflates samples,
    so the floor tracks the true cost, while a genuine slowdown shifts
    the floor itself (p50/p95/jitter still describe the distribution);
  * the plan-cache counter deltas for the setup and steady regions are
    recorded (``PlanCache.delta``) — a healthy steady state has
    ``steady.builds == 0``.
"""

from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Any, Callable

import jax

from ..lib.plan import PlanCache, default_cache
from ..nlinv.stream import latency_stats

# steady-state sampling defaults per problem size
SIZE_DEFAULTS = {"tiny": dict(warmup=1, iters=5),
                 "paper": dict(warmup=2, iters=7)}


@dataclasses.dataclass
class Timing:
    """One measured scenario: setup cost + steady-state distribution."""

    wall_ms: float       # total wall clock of the measurement
    compile_ms: float    # first call: trace + lower + compile + plan builds
    steady_ms: float     # steady-state per-call BEST (minimum) sample
    p50_ms: float
    p95_ms: float
    jitter_ms: float     # std dev of the steady samples
    iters: int
    warmup: int
    plan_cache: dict     # {"setup": delta, "steady": delta} counter deltas

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def measure(fn: Callable, *args, warmup: int = 1, iters: int = 5,
            cache: PlanCache | None = None, **kw) -> Timing:
    """Measure ``fn(*args, **kw)`` with warmup discipline and
    ``block_until_ready`` fencing; see the module docstring."""
    if warmup < 1 or iters < 1:
        raise ValueError("measure needs warmup >= 1 and iters >= 1")
    cache = default_cache() if cache is None else cache
    t_all = time.perf_counter()

    s0 = cache.snapshot()
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    compile_ms = (time.perf_counter() - t0) * 1e3
    setup = cache.delta(s0)

    for _ in range(warmup - 1):
        jax.block_until_ready(fn(*args, **kw))

    s1 = cache.snapshot()
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        samples.append((time.perf_counter() - t0) * 1e3)
    steady = cache.delta(s1)

    stats = latency_stats(samples)
    return Timing(
        wall_ms=round((time.perf_counter() - t_all) * 1e3, 3),
        compile_ms=round(compile_ms, 3),
        steady_ms=round(min(samples), 3),
        p50_ms=stats["p50_ms"],
        p95_ms=stats["p95_ms"],
        jitter_ms=stats["jitter_ms"],
        iters=iters, warmup=warmup,
        plan_cache={"setup": setup, "steady": steady})


def calibrate(iters: int = 5) -> float:
    """Machine-speed reference (ms): best-of-N over a fixed numpy
    FFT+matmul workload.

    Stamped into every artifact so ``repro.bench.compare`` can normalize
    steady states by relative machine speed: on shared/cgroup-limited
    hosts, neighbor contention slows a whole sweep by 2-5x invisibly —
    it moves this reference and the scenarios together, while a genuine
    code regression moves only the scenario.
    """
    import numpy as np
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 256)).astype(np.float32)
    c = (a + 1j * a).astype(np.complex64)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(4):
            np.fft.fft2(c)
            a @ a
        best = min(best, time.perf_counter() - t0)
    return round(best * 1e3, 3)


@dataclasses.dataclass
class BenchContext:
    """Everything a scenario needs: the sweep point + a bound harness.

    ``comm`` is a Communicator over ``devices`` devices (the runner
    builds it as ``Environment().subgroup(devices)`` in a process whose
    visible device count equals ``devices``); ``out_dir`` is where
    scenarios may drop side artifacts (e.g. the streaming latency
    report).
    """

    size: str
    devices: int
    comm: Any
    out_dir: pathlib.Path
    warmup: int = 1
    iters: int = 3

    def measure(self, fn: Callable, *args, warmup: int | None = None,
                iters: int | None = None, **kw) -> Timing:
        return measure(fn, *args,
                       warmup=self.warmup if warmup is None else warmup,
                       iters=self.iters if iters is None else iters, **kw)
