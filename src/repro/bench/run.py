"""Benchmark sweep driver.

  PYTHONPATH=src python -m repro.bench.run [--size tiny|paper]
      [--devices 1,4] [--only fig4,stream,...] [--out BENCH_paper.json]
      [--sweep SIZE:FIG,FIG ...] [--iters N] [--warmup N] [--list]

``--sweep SIZE:FIGURES`` (repeatable) runs several (size, figure-set)
combinations in ONE artifact — e.g. ``--sweep tiny:fig4,fig5 --sweep
paper:fig5`` gives the cheap tiny coverage everywhere plus paper-size
columns for the transfer figures.  When present it replaces
``--size``/``--only``.

XLA locks the host device count at first JAX init, so the parent
process never runs a scenario itself: it spawns one child per requested
device count with ``--xla_force_host_platform_device_count=N`` (the
same simulated-device mechanism as ``tests/helpers.py``), collects the
children's partial results, computes per-scenario speed-ups vs the
1-device runs, and writes one schema-versioned artifact
(``repro.bench.artifact``).  ``--out -`` prints the table only.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import re
import subprocess
import sys
import tempfile
import traceback

REPO = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_OUT_DIR = REPO / "benchmarks" / "out"
# lm (per-architecture LM steps) is opt-in: it is paper-size only and far
# heavier than the paper-figure scenarios the CI trajectory tracks.
DEFAULT_FIGURES = ("fig4", "fig5", "fig6", "fig89", "gridding", "serve",
                   "stream", "table1")


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="repro.bench.run",
        description="run registered benchmark scenarios, emit an artifact")
    ap.add_argument("--size", choices=("tiny", "paper"), default="tiny")
    ap.add_argument("--quick", action="store_true",
                    help="alias for --size tiny (old benchmarks.run flag)")
    ap.add_argument("--devices", default="1,4",
                    help="comma-separated device counts (default 1,4)")
    ap.add_argument("--only", default=",".join(DEFAULT_FIGURES),
                    help="comma-separated figure names; 'all' = every "
                         "registered figure (default: paper figures, no lm)")
    ap.add_argument("--out", default="-",
                    help="artifact path (CI uses the BENCH_paper.json "
                         "baseline at the repo root); '-' = print only "
                         "(default — a partial sweep must never clobber "
                         "the committed baseline by accident)")
    ap.add_argument("--out-dir", default=str(DEFAULT_OUT_DIR),
                    help="directory for side artifacts (latency reports)")
    ap.add_argument("--sweep", action="append", default=[],
                    metavar="SIZE:FIGURES",
                    help="repeatable SIZE:FIG,FIG spec; when given, "
                         "replaces --size/--only and every spec runs at "
                         "every --devices count into one artifact")
    ap.add_argument("--iters", type=int, default=None,
                    help="steady-state samples per scenario (default by size)")
    ap.add_argument("--warmup", type=int, default=None,
                    help="warmup calls incl. the compile call (default by size)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--emit", default="", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.quick:
        args.size = "tiny"
    return args


def _figures(args):
    if args.only.strip().lower() == "all":
        return None
    return tuple(f.strip() for f in args.only.split(",") if f.strip())


def _jobs(args) -> list[tuple[str, str]]:
    """The (size, only) combinations this sweep runs — one child per
    (job, device count).  Default: the single --size/--only pair."""
    if not args.sweep:
        return [(args.size, args.only)]
    jobs = []
    for spec in args.sweep:
        size, sep, figs = spec.partition(":")
        size = size.strip()
        if not sep or size not in ("tiny", "paper") or not figs.strip():
            raise SystemExit(f"repro.bench: bad --sweep spec {spec!r} "
                             "(want SIZE:FIG,FIG with SIZE tiny|paper)")
        jobs.append((size, figs.strip()))
    return jobs


def _sampling(args):
    from .harness import SIZE_DEFAULTS
    s = dict(SIZE_DEFAULTS[args.size])
    if args.iters is not None:
        s["iters"] = args.iters
    if args.warmup is not None:
        s["warmup"] = args.warmup
    return s


# ---------------------------------------------------------------------------
# child: one device count, real measurements
# ---------------------------------------------------------------------------

def _child_main(args) -> int:
    import jax

    from repro.core import Environment

    from .harness import BenchContext
    from .registry import scenarios

    want = int(args.devices)
    got = jax.device_count()
    if got != want:
        print(f"repro.bench: need {want} devices, jax sees {got} "
              f"(parent sets --xla_force_host_platform_device_count)",
              file=sys.stderr)
        return 2

    out_dir = pathlib.Path(args.out_dir)
    sampling = _sampling(args)
    ctx = BenchContext(size=args.size, devices=want,
                       comm=Environment().subgroup(want),
                       out_dir=out_dir, **sampling)

    runs, failures = [], []
    for key, sc in scenarios(figures=_figures(args)).items():
        if args.size not in sc.sizes or want not in sc.devices:
            continue
        print(f"  [{want}d/{args.size}] {key} ...", file=sys.stderr, flush=True)
        try:
            res = dict(sc.fn(ctx))
        except Exception:
            # one broken scenario must not void the rest of the sweep;
            # the parent fails the run but still reports what measured.
            traceback.print_exc()
            failures.append(f"{key}@d{want}@{args.size}")
            continue
        runs.append({"scenario": key, "figure": sc.figure,
                     "devices": want, "size": args.size, **res})

    from .harness import calibrate
    payload = {
        "host": {"platform": jax.devices()[0].platform,
                 "device_count": got, "jax": jax.__version__,
                 "python": sys.version.split()[0]},
        "calibration_ms": calibrate(),
        "runs": runs,
        "failures": failures,
    }
    emit = pathlib.Path(args.emit) if args.emit else None
    if emit is None:
        json.dump(payload, sys.stdout)
    else:
        emit.write_text(json.dumps(payload))
    return 0


# ---------------------------------------------------------------------------
# parent: sweep device counts in subprocesses, merge, write artifact
# ---------------------------------------------------------------------------

def _spawn(args, ndev: int, size: str, only: str,
           emit: pathlib.Path) -> bool:
    cmd = [sys.executable, "-m", "repro.bench.run", "--child",
           "--devices", str(ndev), "--size", size,
           "--only", only, "--out-dir", args.out_dir,
           "--emit", str(emit)]
    if args.iters is not None:
        cmd += ["--iters", str(args.iters)]
    if args.warmup is not None:
        cmd += ["--warmup", str(args.warmup)]
    env = os.environ.copy()
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (f"{flags} " if flags else "") + \
        f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(cmd, env=env, cwd=str(REPO))
    if r.returncode != 0:
        print(f"repro.bench: {ndev}-device child failed "
              f"(exit {r.returncode})", file=sys.stderr)
        return False
    return True


def _format_table(art: dict) -> str:
    head = f"{'scenario':<38} {'dev':>3} {'size':>5} {'compile_ms':>11} " \
           f"{'steady_ms':>10} {'speedup':>8}"
    lines = [head, "-" * len(head)]
    for key in sorted(art["scenarios"]):
        r = art["scenarios"][key]
        sp = r.get("speedup_vs_1dev")
        lines.append(
            f"{r['scenario']:<38} {r['devices']:>3} {r['size']:>5} "
            f"{r['compile_ms']:>11.3f} {r['steady_ms']:>10.3f} "
            f"{sp if sp is not None else '-':>8}")
    return "\n".join(lines)


def main(argv=None) -> int:
    args = _parse_args(argv)

    if args.list:
        from .registry import scenarios
        for key, sc in scenarios(figures=_figures(args)).items():
            print(f"{key:<30} sizes={','.join(sc.sizes)} "
                  f"devices={','.join(map(str, sc.devices))}  {sc.doc}")
        return 0

    if args.child:
        return _child_main(args)

    from .artifact import make_artifact, write_artifact
    from .registry import figure_names

    jobs = _jobs(args)
    for _, only in jobs:
        if only.strip().lower() == "all":
            continue
        figs = tuple(f.strip() for f in only.split(",") if f.strip())
        unknown = set(figs) - set(figure_names())
        if unknown:
            raise SystemExit(f"repro.bench: unknown figure(s) "
                             f"{sorted(unknown)}; registered: "
                             f"{list(figure_names())}")

    counts = [int(d) for d in args.devices.split(",") if d.strip()]
    if not counts:
        raise SystemExit("repro.bench: --devices must name at least one count")
    partials, failures = [], []
    for ndev in counts:
        for size, only in jobs:
            with tempfile.NamedTemporaryFile(suffix=".json",
                                             delete=False) as f:
                emit = pathlib.Path(f.name)
            try:
                # a failed child must not void the others' results
                if _spawn(args, ndev, size, only, emit):
                    p = json.loads(emit.read_text())
                    partials.append(p)
                    failures += p.get("failures", [])
                else:
                    failures.append(f"<{ndev}-device {size} child>")
            finally:
                emit.unlink(missing_ok=True)

    runs = [r for p in partials for r in p["runs"]]
    if not runs:
        raise SystemExit("repro.bench: the sweep produced no runs "
                         "(every scenario failed or none matched the "
                         f"requested sizes / --devices {args.devices})")
    sizes = list(dict.fromkeys(size for size, _ in jobs))
    host = dict(partials[0]["host"], size=",".join(sizes),
                device_counts=counts)
    # best (fastest) reference across children = the machine's speed
    # with the least neighbor interference during this sweep
    cal = min(p["calibration_ms"] for p in partials)
    art = make_artifact(runs, host=host, calibration_ms=cal)
    print(_format_table(art))
    if failures:
        # never persist a partial sweep: a baseline missing the failed
        # rows would silently drop them from the regression gate
        print(f"FAILED scenarios: {failures}", file=sys.stderr)
        if args.out != "-":
            print(f"not writing {args.out} (incomplete sweep)",
                  file=sys.stderr)
        return 1
    if args.out != "-":
        path = write_artifact(args.out, art)
        print(f"wrote {path} ({len(runs)} runs)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
