"""Per-architecture LM step benchmarks (reduced configs, CPU): one
train step and one decode step for every assigned arch.

These are paper-size only and opt-in (``--only lm`` or ``--only all``):
they compile a full transformer per architecture and are far heavier
than the paper-figure scenarios the CI trajectory tracks.  The derived
column carries the single-pod roofline bound from the dry-run artifacts
when present.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

from ..registry import scenario

RESULTS = pathlib.Path(__file__).resolve().parents[4] / "results" / "dryrun"


def _derived(arch: str, shape: str) -> str:
    fn = RESULTS / f"{arch}__{shape}__pod16x16.json"
    if not fn.exists():
        return "dryrun=pending"
    d = json.loads(fn.read_text())
    if "skipped" in d:
        return "skipped"
    r = d["roofline"]
    return (f"bound={r['dominant']};step_bound_ms="
            f"{r['step_time_bound_s'] * 1e3:.1f}")


def _steps(ctx, mode: str) -> dict:
    # heavy imports stay inside the scenario: registering "lm" must not
    # pull the model zoo into every bench child
    import jax
    import numpy as np

    from ...configs import ARCH_IDS, get_smoke
    from ...core import compat
    from ...models import frontends, transformer
    from ...train import make_train_state, make_train_step

    per_arch, steady = {}, []
    for arch in ARCH_IDS:
        cfg = dataclasses.replace(get_smoke(arch), compute_dtype="float32")
        mesh = compat.make_mesh((1,), ("data",))
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
        enc = frontends.synthetic_frontend(cfg, 2)
        if mode == "train":
            step_fn, _ = make_train_step(cfg, mesh, remat=False, donate=False)
            with mesh:
                t = ctx.measure(jax.jit(step_fn), state, tok, tok, enc)
            shape = "train_4k"
        else:
            params = state["params"]
            cache = transformer.init_cache(cfg, 2, 64, cfg.cdtype)
            _, cache, _ = transformer.apply(cfg, params, tok[:, :16], enc=enc,
                                            mode="prefill", pos=0, cache=cache)

            @jax.jit
            def dec(p, c, t, pos):
                lg, c2, _ = transformer.apply(cfg, p, t, mode="decode",
                                              pos=pos, cache=c)
                return lg, c2

            t = ctx.measure(dec, params, cache, tok[:, :1], 16)
            shape = "decode_32k"
        per_arch[arch] = {"steady_ms": t.steady_ms,
                          "compile_ms": t.compile_ms,
                          "derived": _derived(arch, shape)}
        steady.append(t.steady_ms)
    return {"wall_ms": round(float(sum(steady)), 3),
            "compile_ms": round(max(a["compile_ms"] for a in
                                    per_arch.values()), 3),
            "steady_ms": round(float(np.median(steady)), 3),
            "extra": {"mode": mode, "per_arch": per_arch}}


@scenario("lm", "train_step", sizes=("paper",), devices=(1,))
def train_step(ctx):
    """One train step per assigned architecture (median steady state)."""
    return _steps(ctx, "train")


@scenario("lm", "decode_step", sizes=("paper",), devices=(1,))
def decode_step(ctx):
    """One decode step per assigned architecture (median steady state)."""
    return _steps(ctx, "decode")
