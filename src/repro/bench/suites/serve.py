"""Serving-layer scenarios: the multi-stream reconstruction service.

``serve.multi_stream`` is the SLO evidence: K concurrent clients
streaming through one ``StreamScheduler``, per-tick latency plus the
worst per-client p95 (``extra.client_p95_ms`` — the column
``repro.bench.compare`` gates for serve scenarios).

``serve.batched_vs_sequential`` is the acceptance A/B: aggregate
steady-state frames/sec of the batched scheduler vs the same K streams
solved one-at-a-time (``FrameStream`` per client), same machine, same
run — plus the max relative error between the two answers (must be
bitwise-comparable; the batched program is the vmapped same math).
"""

from __future__ import annotations

import json
import os

import numpy as np

from ...ft import FaultInjector, FaultSpec, RestartPolicy
from ...lib.plan import default_cache
from ...nlinv import phantom
from ...nlinv.recon import Reconstructor
from ...nlinv.stream import FrameStream, latency_stats
from ...serve import NlinvStreamWorkload, ServeConfig, StreamScheduler
from ..registry import scenario

# newton/cg deep enough to be collective-bound: the batched win is the
# amortized per-iteration rendezvous, so a too-shallow solve understates
# it and makes the A/B flaky
PARAMS = {"tiny": dict(n=16, J=4, newton=3, cg=8, frames=5, clients=4),
          "paper": dict(n=32, J=8, newton=4, cg=10, frames=6, clients=4)}


def _datasets(p):
    return [phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=7,
                                 frames=p["frames"], seed=s)
            for s in range(p["clients"])]


def _run_scheduler(ctx, p, datas):
    """K clients in lockstep through the scheduler; returns (scheduler,
    sessions, plan builds on tick 0, plan builds after)."""
    rec = Reconstructor(ctx.comm, newton=p["newton"], cg_iters=p["cg"],
                        channel_sum="crop")
    sched = StreamScheduler(
        NlinvStreamWorkload(rec, damping=0.9),
        ServeConfig(max_concurrency=2 * p["clients"], buckets=(1, 2, 4, 8)))
    sessions = [sched.open(client=f"client{k}", grid=d["grid"],
                           ncoils=p["J"], fov=d["fov"])
                for k, d in enumerate(datas)]
    cache = default_cache()
    start = cache.builds
    setup_builds = steady_builds = 0
    for f in range(p["frames"]):
        for k, d in enumerate(datas):
            sched.submit(sessions[k], (d["y"][f], d["masks"][f]))
        sched.tick()
        if f == 0:
            setup_builds = cache.builds - start
    steady_builds = cache.builds - start - setup_builds
    return sched, sessions, setup_builds, steady_builds


@scenario("serve", "multi_stream")
def multi_stream(ctx):
    """K concurrent NLINV streams through one scheduler: per-tick
    latency and worst per-client p95 (the serving SLO columns)."""
    p = PARAMS[ctx.size]
    datas = _datasets(p)
    sched, _, setup_builds, steady_builds = _run_scheduler(ctx, p, datas)
    rep = sched.report()
    ticks = sched.tick_ms
    steady = ticks[1:] if len(ticks) > 1 else ticks
    stats = latency_stats(steady)
    client_p95 = max(c["p95_ms"] for c in rep["clients"].values())
    agg = rep["aggregate"]
    name = f"serve_multi_stream_d{ctx.devices}_{ctx.size}.json"
    (ctx.out_dir / name).parent.mkdir(parents=True, exist_ok=True)
    (ctx.out_dir / name).write_text(json.dumps(rep, indent=2) + "\n")
    return {
        "wall_ms": round(float(sum(ticks)), 3),
        "compile_ms": round(ticks[0], 3),
        "steady_ms": round(min(steady), 3),
        "p50_ms": stats["p50_ms"],
        "p95_ms": stats["p95_ms"],
        "jitter_ms": stats["jitter_ms"],
        "plan_cache": {"setup": {"builds": setup_builds},
                       "steady": {"builds": steady_builds}},
        "extra": {"clients": p["clients"], "frames": agg["frames"],
                  "ticks": agg["ticks"], "agg_fps": agg["fps"],
                  "client_p95_ms": client_p95, "artifact": name},
    }


@scenario("serve", "chaos")
def chaos(ctx):
    """Serving under seed-scheduled fault injection (ADVISORY — not
    regression-gated, ``extra.advisory`` tells the comparator so): K
    clients stream while the injector fires a transient solve failure
    (absorbed by task retry), poisons one client's tick items (absorbed
    by quarantine), and straggles the step (feeds the deadline ladder).
    Evidence columns: recovery latency of the faulted ticks and the
    aggregate frames/sec the degraded service still delivers."""
    p = PARAMS[ctx.size]
    datas = _datasets(p)
    seed = int(os.environ.get("REPRO_FAULT_SEED", "1234"))
    rec = Reconstructor(ctx.comm, newton=p["newton"], cg_iters=p["cg"],
                        channel_sum="crop")
    wl = NlinvStreamWorkload(rec, damping=0.9,
                             retry=RestartPolicy(max_restarts=2,
                                                 backoff_s=0.0))
    sched = StreamScheduler(wl, ServeConfig(
        max_concurrency=2 * p["clients"], buckets=(1, 2, 4, 8),
        deadline_ms=10_000.0, breach_ticks=2, recover_ticks=2))
    sessions = [sched.open(client=f"client{k}", grid=d["grid"],
                           ncoils=p["J"], fov=d["fov"])
                for k, d in enumerate(datas)]
    specs = [
        FaultSpec(site="task", kind="transient", match="solve", at=(1,),
                  max_fires=1),
        FaultSpec(site="step", kind="corrupt", at=(2,), pick=1,
                  max_fires=1),
        FaultSpec(site="step", kind="straggle", at=(3,), delay_ms=2.0),
    ]
    with FaultInjector(specs, seed=seed) as inj:
        for f in range(p["frames"]):
            for k, d in enumerate(datas):
                sched.submit(sessions[k], (d["y"][f], d["masks"][f]))
            while sched.tick() == 0 and \
                    any(s.pending for s in sched.sessions.values()):
                pass    # transient tick: retry until the batch lands
    rep = sched.report()
    ft = rep["aggregate"]["ft"]
    ticks = sched.tick_ms
    steady = ticks[1:] if len(ticks) > 1 else ticks
    # recovery latency: the faulted ticks' cost over the clean floor
    floor = min(steady)
    faulted = [round(t - floor, 3) for t in steady if t > floor]
    name = f"serve_chaos_d{ctx.devices}_{ctx.size}.json"
    (ctx.out_dir / name).parent.mkdir(parents=True, exist_ok=True)
    (ctx.out_dir / name).write_text(json.dumps(rep, indent=2) + "\n")
    return {
        "wall_ms": round(float(sum(ticks)), 3),
        "compile_ms": round(ticks[0], 3),
        "steady_ms": round(floor, 3),
        "extra": {
            "advisory": True,
            "seed": seed,
            "fired": [list(f) for f in inj.fired],
            "step_faults": ft["step_faults"],
            "retried_tasks": ft["retried_tasks"],
            "quarantined": ft["quarantined"],
            "rejected_poisoned": ft["rejected_poisoned"],
            "degradation_events": ft["degradation_events"],
            "recovery_ms_max": max(faulted, default=0.0),
            "degraded_fps": rep["aggregate"]["fps"],
            "artifact": name,
        },
    }


@scenario("serve", "batched_vs_sequential")
def batched_vs_sequential(ctx):
    """A/B: batched-scheduler aggregate frames/sec vs K one-at-a-time
    streams, plus parity of the two answers (the acceptance gate)."""
    p = PARAMS[ctx.size]
    datas = _datasets(p)
    K, F = p["clients"], p["frames"]
    sched, sessions, setup_builds, steady_builds = \
        _run_scheduler(ctx, p, datas)
    ticks = sched.tick_ms
    steady = ticks[1:] if len(ticks) > 1 else ticks
    batched_wall = float(sum(steady))
    batched_fps = K * len(steady) / max(batched_wall, 1e-9) * 1e3

    # sequential baseline: the same K streams, one FrameStream each
    seq_wall, seq_frames, errs = 0.0, 0, []
    for k, d in enumerate(datas):
        rec = Reconstructor(ctx.comm, newton=p["newton"],
                            cg_iters=p["cg"], channel_sum="crop")
        imgs, rep = FrameStream(rec, damping=0.9).run(
            d["y"], d["masks"], d["fov"])
        fms = rep.frame_ms[1:] if len(rep.frame_ms) > 1 else rep.frame_ms
        seq_wall += float(sum(fms))
        seq_frames += len(fms)
        for f in range(F):
            a = np.asarray(sessions[k].results[f])
            b = np.asarray(imgs[f])
            errs.append(float(np.abs(a - b).max() /
                              max(np.abs(b).max(), 1e-30)))
    seq_fps = seq_frames / max(seq_wall, 1e-9) * 1e3
    return {
        "wall_ms": round(float(sum(ticks)) + seq_wall, 3),
        "compile_ms": round(ticks[0], 3),
        "steady_ms": round(min(steady), 3),
        "plan_cache": {"setup": {"builds": setup_builds},
                       "steady": {"builds": steady_builds}},
        "extra": {"clients": K, "frames_per_client": F,
                  "batched_fps": round(batched_fps, 2),
                  "sequential_fps": round(seq_fps, 2),
                  "batched_speedup": round(batched_fps /
                                           max(seq_fps, 1e-9), 3),
                  "max_rel_err": max(errs)},
    }
