"""Paper Fig. 5: transfer primitives — strong copy, weak copy,
broadcast, reduce.

Measured: wall time of the verb on this host at the scenario's device
count.  Derived: modeled v5e times (host->HBM over PCIe for scatter;
ICI ring for reduce) at 1/2/4/8 devices, showing the paper's effects:
strong copy gets FASTER with more devices (parallel PCIe paths), reduce
efficiency decays with P2P hops.
"""

from __future__ import annotations

import numpy as np

from ...core import comm as _comm
from ...core.runtime import HW
from .. import models
from ..registry import scenario

PARAMS = {"tiny": dict(n=128, batch=4), "paper": dict(n=512, batch=8)}


def _payload(ctx, seed=2):
    p = PARAMS[ctx.size]
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((p["batch"], p["n"], p["n"]))
         + 1j * rng.standard_normal((p["batch"], p["n"], p["n"])))
    return p, x.astype(np.complex64)


def _model_times(fn_bytes_to_s) -> dict:
    return {f"model_t{G}_us": round(fn_bytes_to_s(G) * 1e6, 1)
            for G in (1, 2, 4, 8)}


@scenario("fig5", "strong_copy")
def strong_copy(ctx):
    """Fixed total payload scattered over the group (strong scaling)."""
    _, x = _payload(ctx)
    t = ctx.measure(lambda: ctx.comm.container(x).data)
    extra = {"nbytes": x.nbytes, "schedule": "host_shard_upload",
             **_model_times(
                 lambda G: models.copy_time(x.nbytes / G, models.PCIE_BW))}
    return {**t.as_dict(), "extra": extra}


@scenario("fig5", "weak_copy")
def weak_copy(ctx):
    """Per-device-constant payload (weak scaling: one slab regardless)."""
    p, x = _payload(ctx)
    one = x[:1]
    t = ctx.measure(lambda: ctx.comm.container(one).data)
    extra = {"nbytes": one.nbytes, "schedule": "host_shard_upload",
             **_model_times(
                 lambda G: models.copy_time(x.nbytes / p["batch"],
                                            models.PCIE_BW))}
    return {**t.as_dict(), "extra": extra}


@scenario("fig5", "broadcast")
def broadcast(ctx):
    """CLONE one matrix to every device (host upload + ICI fan-out)."""
    _, x = _payload(ctx)
    one = x[0]
    t = ctx.measure(lambda: ctx.comm.bcast(one).data)
    sched = _comm.bcast_schedule(ctx.comm.group, ctx.comm.mesh_axes,
                                 one.nbytes)
    extra = {"nbytes": one.nbytes, "schedule": sched,
             "threshold_bytes": _comm.BCAST_SCATTER_MIN_BYTES,
             **_model_times(
                 lambda G: models.copy_time(one.nbytes, models.PCIE_BW)
                 + (G - 1) * one.nbytes / HW["ici_bw"])}
    return {**t.as_dict(), "extra": extra}


@scenario("fig5", "reduce")
def reduce(ctx):
    """Sum a segmented container to rank 0 (ring reduce + download)."""
    _, x = _payload(ctx)
    sm = ctx.comm.container(x)
    one = x[0].nbytes
    t = ctx.measure(lambda: ctx.comm.reduce(sm))
    sched, nbytes = _comm._reduce_schedule(sm, "sum")
    extra = {"nbytes": one, "schedule": sched,
             "payload_bytes": nbytes,
             "threshold_bytes": _comm.REDUCE_RS_AG_MIN_BYTES,
             **_model_times(
                 lambda G: models.allreduce_time(one, G) / 2
                 + models.copy_time(one, models.PCIE_BW))}
    return {**t.as_dict(), "extra": extra}
