"""Gridding plan: geometry setup cost vs a plan-cache hit.

The radial gridding plan precomputes the separable interpolation
matrices + Ram-Lak DCF once per trajectory; per-frame re-planning would
put that on the real-time latency budget.  ``compile_ms`` is the cold
build, ``steady_ms`` the LRU-hit lookup — their ratio is the
library-port win for the frame loop.
"""

from __future__ import annotations

from ...kernels import registry as kreg
from ...lib.gridding import plan_gridding, radial_trajectory
from ...lib.plan import PlanCache
from ..registry import scenario

PARAMS = {"tiny": dict(grid=64, nspokes=11), "paper": dict(grid=256, nspokes=65)}


@scenario("gridding", "plan_cold_vs_hit")
def plan_cold_vs_hit(ctx):
    """Cold gridding-plan build vs an LRU cache hit."""
    p = PARAMS[ctx.size]
    traj = radial_trajectory(p["grid"], p["nspokes"])
    cache = PlanCache()         # private: the first call is surely cold
    t = ctx.measure(lambda: plan_gridding(traj, p["grid"], cache=cache),
                    cache=cache)
    return {**t.as_dict(),
            "extra": {"grid": p["grid"], "nspokes": p["nspokes"],
                      "cold_ms": t.compile_ms, "hit_ms": t.steady_ms,
                      "speedup_cold_vs_hit": round(
                          t.compile_ms / max(t.steady_ms, 1e-6), 1),
                      # the (bs,) sample-block choices baked into the
                      # plan key by the registry autotuner
                      "kernel_blocks": kreg.choices("gridding")}}
