"""Paper Fig. 4: FFT / aX+Y / A.B over a batch of complex square
matrices.

Measured: steady-state per-call cost of the plan-cached ``repro.lib``
implementations on the scenario's device count.  Derived: modeled
parallel efficiency at 2/4/8 devices — FFT and aXPY are embarrassingly
batch-parallel (efficiency ~1); A.B with the contracted dim split pays
one inter-device reduction (the paper's finding that A.B does not
strong-scale).
"""

from __future__ import annotations

import jax
import numpy as np

from ...core.runtime import HW
from ...lib import blas as lblas
from ...lib import fft as lfft
from .. import models
from ..registry import scenario

# paper: 12 complex square matrices, 128..512
PARAMS = {"tiny": dict(n=64, batch=4), "paper": dict(n=512, batch=12)}


def _cbatch(ctx, seed=0):
    p = PARAMS[ctx.size]
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((p["batch"], p["n"], p["n"]))
         + 1j * rng.standard_normal((p["batch"], p["n"], p["n"])))
    return p, x.astype(np.complex64)


@scenario("fig4", "fft_fwdinv")
def fft_fwdinv(ctx):
    """Forward+inverse batched 2-D FFT (batch-parallel, zero comm)."""
    p, x = _cbatch(ctx)
    sx = ctx.comm.container(x)
    f = jax.jit(lambda a: lfft.fft2_batched(
        lfft.fft2_batched(a), inverse=True).data)
    t = ctx.measure(f, sx)
    return {**t.as_dict(),
            "extra": {"n": p["n"], "batch": p["batch"],
                      "model_eff2": 1.0, "model_eff4": 1.0,
                      "model_eff8": 1.0}}


@scenario("fig4", "axpy")
def axpy(ctx):
    """aX+Y over the segmented batch (batch-parallel, zero comm)."""
    p, x = _cbatch(ctx)
    sx = ctx.comm.container(x)
    sy = ctx.comm.container(x[..., ::-1].copy())
    f = jax.jit(lambda u, v: lblas.axpy(2.0 + 1j, u, v).data)
    t = ctx.measure(f, sx, sy)
    return {**t.as_dict(),
            "extra": {"n": p["n"], "batch": p["batch"],
                      "model_eff2": 1.0, "model_eff4": 1.0,
                      "model_eff8": 1.0}}


@scenario("fig4", "gemm_ksplit")
def gemm_ksplit(ctx):
    """A.B with the contracted dim split: local matmul + one psum."""
    n = PARAMS[ctx.size]["n"]
    rng = np.random.default_rng(1)
    A = rng.standard_normal((n, n)).astype(np.float32)
    B = rng.standard_normal((n, n)).astype(np.float32)
    sA = ctx.comm.container(A, dim=1)
    sB = ctx.comm.container(B, dim=0)
    f = jax.jit(lambda u, v: lblas.gemm_ksplit(u, v).data)
    t = ctx.measure(f, sA, sB)
    # modeled: local matmul scales 1/G, then psum of the full (n, n)
    t1 = 2 * n ** 3 / HW["peak_flops_bf16"]
    extra = {"n": n, "schedule": lblas.gemm_ksplit_schedule(sA, sB)}
    for G in (2, 4, 8):
        tG = t1 / G + models.allreduce_time(n * n * 4, G)
        extra[f"model_eff{G}"] = round(t1 / (G * tG), 3)
    return {**t.as_dict(), "extra": extra}
