"""Streaming real-time engine: steady-state per-frame latency + jitter.

Frame f+1's upload overlaps frame f's solve (double buffering through
the verbs), the Newton carry is donated, and the per-frame latency
report artifact is the recon-service SLO evidence.  ``compile_ms`` is
the first frame (it pays every trace/compile/plan build), ``steady_ms``
the best steady-state frame (the harness's robust metric; mean/p50/p95/
jitter ride along) — and the plan-cache columns prove the steady state
builds nothing.
"""

from __future__ import annotations

from ...nlinv import phantom
from ...nlinv.recon import Reconstructor
from ...nlinv.stream import FrameStream
from ..registry import scenario

PARAMS = {"tiny": dict(n=24, J=4, newton=3, cg=6, frames=4),
          "paper": dict(n=48, J=8, newton=6, cg=10, frames=8)}


@scenario("stream", "nlinv_latency")
def nlinv_latency(ctx):
    """Per-frame latency/jitter of the double-buffered frame loop."""
    p = PARAMS[ctx.size]
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11,
                             frames=p["frames"])
    rec = Reconstructor(ctx.comm, newton=p["newton"], cg_iters=p["cg"],
                        channel_sum="crop")
    # one report per sweep point — the 4-device child must not clobber
    # the 1-device child's SLO evidence in benchmarks/out/
    name = f"nlinv_stream_latency_d{ctx.devices}_{ctx.size}.json"
    path = ctx.out_dir / name
    _, rep = FrameStream(rec, damping=0.9).run(
        d["y"], d["masks"], d["fov"], report_path=path)
    s = rep.summary()
    pc = s.get("plan_cache", {})
    return {
        "wall_ms": round(float(sum(s["frame_ms"])), 3),
        "compile_ms": s["first_frame_ms"],
        # best steady frame, like every harness-measured scenario: the
        # compare gate sees one consistently-defined robust metric
        "steady_ms": round(min(s["frame_ms"][1:] or s["frame_ms"]), 3),
        "p50_ms": s["p50_ms"],
        "p95_ms": s["p95_ms"],
        "jitter_ms": s["jitter_ms"],
        "plan_cache": {
            "setup": {"builds": (pc.get("frame_builds") or [0])[0]},
            "steady": {"builds": pc.get("steady_builds", 0),
                       "hit_rate": pc.get("hit_rate", 0.0)},
        },
        "extra": {"fps": s["fps"], "frames": s["frames"],
                  "mean_ms": s["mean_ms"], "grid": s["grid"],
                  "ncoils": s["ncoils"], "artifact": name},
    }
