"""Paper Fig. 6 (+Fig. 7): NLINV frame rate vs (#devices, #channels)
and the paper-claims validation at the paper's own problem size.

Measured: per-frame solve cost of the ``Reconstructor`` frame program
on the scenario's device count (coils NATURAL-split over the group).
Derived: the calibrated speedup model at 1-4 devices (paper §3.2 —
FFT+pointwise scale 1/G, the Sum rho_g all-reduce grows with G;
validated against the paper's claims: ~1.7x @ 2 GPUs, ~2.1x @ 4).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels import registry as kreg
from ...nlinv import phantom
from ...nlinv.operators import sobolev_weight
from ...nlinv.recon import Reconstructor, pad_channels
from ...nlinv.stream import FramePipeline, FrameStream, latency_stats
from .. import models
from ..registry import scenario

PARAMS = {"tiny": dict(n=24, J=4, newton=3, cg=6),
          "paper": dict(n=64, J=8, newton=6, cg=10)}


@scenario("fig6", "nlinv_frame")
def nlinv_frame(ctx):
    """One NLINV frame solve (IRGNM + CG) at the sweep's device count."""
    p = PARAMS[ctx.size]
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11, frames=1)
    g, J = d["grid"], d["ncoils"]
    rec = Reconstructor(ctx.comm, newton=p["newton"], cg_iters=p["cg"],
                        channel_sum="crop")
    y = rec.put_frame(pad_channels(np.asarray(d["y"][0]), rec.comm.size))
    mask = rec.put_const(np.asarray(d["masks"][0]))
    fov = rec.put_const(np.asarray(d["fov"]))
    w = rec.put_const(np.asarray(sobolev_weight(g)))
    u0 = rec.init_carry(pad_channels(np.asarray(d["y"][0]),
                                     rec.comm.size).shape[0], g)
    x_ref = jax.tree.map(lambda a: a + 0, u0)

    t = ctx.measure(lambda: rec.fn(y, mask, fov, w, u0, x_ref)[1])
    sp = models.speedup_model(g, J)
    sv = models.speedup_model(g, J, hw="v5e")
    extra = {"grid": g, "ncoils": J, "newton": p["newton"], "cg": p["cg"],
             "fps": round(1e3 / max(t.steady_ms, 1e-9), 2),
             "model_paper_s2": round(sp[2], 2),
             "model_paper_s4": round(sp[4], 2),
             "model_v5e_s4": round(sv[4], 2)}
    return {**t.as_dict(), "extra": extra}


@scenario("fig6", "cg_fused")
def cg_fused(ctx):
    """A/B: fused CG hot path (default) vs the unfused escape hatch.

    ``steady_ms`` is the fused frame (what the regression gate tracks);
    ``extra`` carries the back-to-back unfused measurement and the
    resulting same-machine speedup, which is the evidence the ISSUE-5
    fusion/overlap work actually wins on this host.
    """
    p = PARAMS[ctx.size]
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11, frames=1)
    g = d["grid"]

    def setup(fused):
        rec = Reconstructor(ctx.comm, newton=p["newton"], cg_iters=p["cg"],
                            channel_sum="crop", fused=fused)
        y = rec.put_frame(pad_channels(np.asarray(d["y"][0]),
                                       rec.comm.size))
        mask = rec.put_const(np.asarray(d["masks"][0]))
        fov = rec.put_const(np.asarray(d["fov"]))
        w = rec.put_const(np.asarray(sobolev_weight(g)))
        u0 = rec.init_carry(y.shape[0], g)
        x_ref = jax.tree.map(lambda a: a + 0, u0)
        return rec, (y, mask, fov, w, u0, x_ref)

    rec_f, args_f = setup(True)
    rec_u, args_u = setup(False)
    # interleave the A/B rounds so slow machine episodes (shared-host
    # neighbors, thermal) hit both arms instead of biasing whichever
    # ran second; per arm the best (minimum) sample is kept.
    t_f = ctx.measure(lambda: rec_f.fn(*args_f)[1])
    t_u = ctx.measure(lambda: rec_u.fn(*args_u)[1])
    t_f2 = ctx.measure(lambda: rec_f.fn(*args_f)[1])
    t_u2 = ctx.measure(lambda: rec_u.fn(*args_u)[1])
    fused_ms = min(t_f.steady_ms, t_f2.steady_ms)
    unfused_ms = min(t_u.steady_ms, t_u2.steady_ms)
    speedup = round(unfused_ms / max(fused_ms, 1e-9), 3)
    extra = {"grid": g, "ncoils": d["ncoils"],
             "unfused_steady_ms": unfused_ms,
             "fused_speedup": speedup,
             # the block choices the fused frame traced with (tuned on
             # TPU, default/pinned elsewhere) — the autotuner's output
             # is part of the artifact, per plan
             "kernel_blocks": kreg.choices("cg_fused")}
    out = t_f.as_dict()
    out["steady_ms"] = fused_ms
    return {**out, "extra": extra}


@scenario("fig6", "pipelined_vs_overlap")
def pipelined_vs_overlap(ctx):
    """A/B: task-graph ``FramePipeline`` vs two-stage ``FrameStream``.

    Both arms reconstruct the same short movie with the same
    ``Reconstructor``; the difference is purely the execution schedule —
    per-frame host fence + upload overlap (baseline) vs ``inflight``
    whole frame graphs dispatched-but-unfenced (ISSUE-9 executor).
    ``steady_ms`` is the pipelined arm's best steady per-frame time
    (what the regression gate tracks); ``extra`` carries the baseline's
    back-to-back measurement, the resulting same-machine speedup, and
    the output parity between the two movies.
    """
    p = PARAMS[ctx.size]
    F = 8
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11, frames=F)
    rec = Reconstructor(ctx.comm, newton=p["newton"], cg_iters=p["cg"],
                        channel_sum="crop")
    pipe = FramePipeline(rec, inflight=2)
    seq = FrameStream(rec)
    args = (d["y"], d["masks"], d["fov"])

    def steady(rep):
        return float(np.mean(rep.frame_ms[1:]))

    t_all = time.perf_counter()
    # first run of each arm pays trace/compile/plan builds (the staged
    # solve/image plans for the pipeline, the monolithic frame plan for
    # the baseline) and provides the movies for the parity check
    t0 = time.perf_counter()
    mov_p, _ = pipe.run(*args)
    mov_s, _ = seq.run(*args)
    compile_ms = (time.perf_counter() - t0) * 1e3
    # interleave the A/B rounds (as in cg_fused) so slow host episodes
    # hit both arms; per arm the best steady-state mean is kept
    reps_p, reps_s = [], []
    for _ in range(2):
        reps_p.append(pipe.run(*args)[1])
        reps_s.append(seq.run(*args)[1])
    pipe_ms = min(steady(r) for r in reps_p)
    overlap_ms = min(steady(r) for r in reps_s)
    best = min(reps_p, key=steady)
    err = float(jnp.max(jnp.abs(mov_p - mov_s))
                / jnp.max(jnp.abs(mov_s)))
    stats = latency_stats(best.frame_ms[1:])
    extra = {"grid": d["grid"], "ncoils": d["ncoils"], "frames": F,
             "inflight": pipe.inflight,
             "overlap_steady_ms": round(overlap_ms, 3),
             "pipelined_speedup": round(overlap_ms / max(pipe_ms, 1e-9),
                                        3),
             "max_rel_err": err,
             "steady_builds": int(sum(best.frame_plan_builds))}
    return {"wall_ms": round((time.perf_counter() - t_all) * 1e3, 3),
            "compile_ms": round(compile_ms, 3),
            "steady_ms": round(pipe_ms, 3),
            "p50_ms": stats["p50_ms"], "p95_ms": stats["p95_ms"],
            "jitter_ms": stats["jitter_ms"], "extra": extra}


@scenario("fig6", "paper_claims", devices=(1,))
def paper_claims(ctx):
    """Model-only validation of the paper's speedups + Fig. 7 energy."""
    # the paper's own problem size (grid 768 = 2x384, J=8 compressed;
    # claims ~1.7x @ 2 GPUs, ~2.1x @ 4, degradation past the IOH at 8)
    sp = models.speedup_model(768, 8)
    extra = {"model_paper_s2": round(sp[2], 2), "claim_s2": 1.7,
             "model_paper_s4": round(sp[4], 2), "claim_s4": 2.1,
             "model_paper_s8": round(sp[8], 2)}
    # fig7: energy/frame model — chips-busy vs speedup tradeoff
    for G in (1, 2, 4):
        extra[f"model_rel_J_per_frame_G{G}"] = round(G / sp[G], 2)
    return {"wall_ms": 0.0, "compile_ms": 0.0, "steady_ms": 0.0,
            "extra": extra}
