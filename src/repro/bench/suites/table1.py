"""Paper Table 1: operator breakdown of F, DF, DF^H, CG.

Asserts the structural op counts of our implementation match the paper's
table (FFT batches per operator) before timing anything — a scenario
that drifts structurally must fail loudly, not get slowly slower — then
times each operator plus the ``repro.lib.blas`` fused-epilogue rows the
library port added (one pass over w vs the two-plan form).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...lib import blas as lblas
from ...nlinv import phantom
from ...nlinv.operators import make_ops, sobolev_weight, uaxpy, udot, uinit
from ..registry import scenario

PARAMS = {"tiny": dict(n=48, J=4), "paper": dict(n=128, J=8)}

# paper Table 1 (ours: FFT batches per operator; DG/DGH include the coil
# transform W; the all-reduce column is the distributed channel sum)
EXPECTED = {
    "F": dict(fft=2, channel_sum=0, allreduce=0),
    "DF": dict(fft=3, channel_sum=0, allreduce=0),
    "DFH": dict(fft=3, channel_sum=1, allreduce=1),
    "CG": dict(scalar_products=2),
}


def _count_ffts(fn, *args):
    def rec(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "fft":
                n += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    n += rec(v.jaxpr)
                elif hasattr(v, "eqns"):
                    n += rec(v)
        return n
    return rec(jax.make_jaxpr(fn)(*args).jaxpr)


def _setup(ctx):
    p = PARAMS[ctx.size]
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11, frames=1)
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(d["grid"]))
    u0 = uinit(d["ncoils"], d["grid"])
    du = jax.tree.map(lambda x: x + 0.1, u0)
    r = jnp.asarray(d["y"][0])
    return d, ops, u0, du, r


@scenario("table1", "F")
def F(ctx):
    """Forward model F (2 FFT batches), op counts asserted first."""
    d, ops, u0, du, r = _setup(ctx)
    assert _count_ffts(ops.G, u0) == EXPECTED["F"]["fft"]
    assert _count_ffts(lambda a, b: ops.DG(a, b), u0, du) == \
        EXPECTED["DF"]["fft"]
    assert _count_ffts(lambda a, b: ops.DGH(a, b), u0, r) == \
        EXPECTED["DFH"]["fft"]
    t = ctx.measure(jax.jit(lambda u: ops.G(u)), u0)
    return {**t.as_dict(), "extra": {"grid": d["grid"], "fft": 2,
                                     "pointwise": 4}}


@scenario("table1", "DF")
def DF(ctx):
    """Derivative DF (3 FFT batches, no channel sum)."""
    d, ops, u0, du, _ = _setup(ctx)
    t = ctx.measure(jax.jit(lambda u, v: ops.DG(u, v)), u0, du)
    return {**t.as_dict(), "extra": {"grid": d["grid"], "fft": 3,
                                     "pointwise": 5}}


@scenario("table1", "DFH")
def DFH(ctx):
    """Adjoint DF^H (3 FFT batches + the distributed channel sum)."""
    d, ops, u0, _, r = _setup(ctx)
    t = ctx.measure(jax.jit(lambda u, v: ops.DGH(u, v)), u0, r)
    return {**t.as_dict(), "extra": {"grid": d["grid"], "fft": 3,
                                     "pointwise": 4, "channel_sum": 1,
                                     "allreduce": 1}}


@scenario("table1", "cg_iter")
def cg_iter(ctx):
    """One CG iteration: normal op + 2 scalar products + 3 axpys."""
    d, ops, u0, du, _ = _setup(ctx)

    def it(u, v):
        Ap = ops.normal(u, v, 0.5)
        a = jnp.real(udot(v, Ap))
        return uaxpy(1.0 / (a + 1.0), Ap, v)

    t = ctx.measure(jax.jit(it), u0, du)
    return {**t.as_dict(), "extra": {"grid": d["grid"], "ab": 6,
                                     "scalar_products": 2}}


@scenario("table1", "axpy_norm2_fused")
def axpy_norm2_fused(ctx):
    """libblas fused epilogue (one pass over w) vs the two-plan form."""
    p = PARAMS[ctx.size]
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11, frames=1)
    sx = ctx.comm.container(jnp.asarray(d["y"][0]))
    sy = ctx.comm.container(jnp.asarray(d["y"][0]) * 0.5)
    t = ctx.measure(lambda: lblas.axpy_norm2(-0.25, sx, sy)[1])
    t_split = ctx.measure(lambda: lblas.norm2(lblas.axpy(-0.25, sx, sy)))
    # the attributable plan-cache evidence is t.plan_cache (per-region
    # deltas); the process-global plan_stats() would depend on whatever
    # scenarios happened to run earlier in this child.
    return {**t.as_dict(),
            "extra": {"grid": d["grid"],
                      "split_steady_ms": t_split.steady_ms}}
