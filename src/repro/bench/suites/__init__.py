"""Scenario definitions, one module per paper figure/table plus the
layers this repo added (gridding plans, the streaming engine, LM steps).
Importing this package registers everything with
``repro.bench.registry`` (which is why it is not named ``scenarios``:
the subpackage attribute would shadow ``repro.bench.scenarios()``)."""

from . import (fig4, fig5, fig6, fig89, gridding, lm, serve,  # noqa: F401
               stream, table1)
