"""Paper Fig. 8/9: DF and DF^H runtime vs channel count; FFT batch
scaling vs the all-reduce cost that erodes DF^H beyond 2 devices.

Measured: DF / DF^H and the plan-cached batched FFT at the scenario's
channel count.  Derived: modeled multi-device times showing the paper's
crossover (the all-reduce share grows with G — execution time of DF^H
can *increase* at G=4, paper Fig. 8 right).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...core.runtime import HW
from ...lib import fft as lfft
from ...nlinv import phantom
from ...nlinv.operators import make_ops, sobolev_weight, uinit
from .. import models
from ..registry import scenario

PARAMS = {"tiny": dict(n=48, J=4, fft_n=64, fft_batch=4),
          "paper": dict(n=96, J=12, fft_n=256, fft_batch=8)}


def _ops_setup(ctx):
    p = PARAMS[ctx.size]
    d = phantom.make_dataset(n=p["n"], ncoils=p["J"], nspokes=11, frames=1)
    g = d["grid"]
    ops = make_ops(d["masks"][0], d["fov"], sobolev_weight(g))
    u0 = uinit(d["ncoils"], g)
    du = jax.tree.map(lambda x: x + 0.1, u0)
    r = jnp.asarray(d["y"][0])
    return p, g, d["ncoils"], ops, u0, du, r


@scenario("fig89", "df")
def df(ctx):
    """DF (derivative of the NLINV forward model): scales 1/G."""
    p, g, J, ops, u0, du, _ = _ops_setup(ctx)
    t = ctx.measure(jax.jit(lambda a, b: ops.DG(a, b)), u0, du)
    return {**t.as_dict(),
            "extra": {"grid": g, "ncoils": J, "model_scaling": "1/G"}}


@scenario("fig89", "dfh")
def dfh(ctx):
    """DF^H: 1/G compute + the channel-sum all-reduce that grows with G."""
    p, g, J, ops, u0, _, r = _ops_setup(ctx)
    t = ctx.measure(jax.jit(lambda a, b: ops.DGH(a, b)), u0, r)
    flop_fft = 5 * g * g * np.log2(g * g)
    t_fft1 = 3 * J * flop_fft / HW["peak_flops_bf16"]
    img_b = g * g * 8
    extra = {"grid": g, "ncoils": J}
    for G in (1, 2, 4):
        t_dfh = t_fft1 / G + models.allreduce_time(img_b // 4, G)
        extra[f"model_t{G}_us"] = round(t_dfh * 1e6, 1)
    return {**t.as_dict(), "extra": extra}


@scenario("fig89", "fft_batch")
def fft_batch(ctx):
    """Plan-cached batched FFT vs the all-reduce that would join it."""
    p = PARAMS[ctx.size]
    n, batch = p["fft_n"], p["fft_batch"]
    rng = np.random.default_rng(3)
    x = (rng.standard_normal((batch, n, n))
         + 1j * rng.standard_normal((batch, n, n))).astype(np.complex64)
    sx = ctx.comm.container(x)
    plan = lfft.plan_fft2_batched(sx)       # built once per geometry
    t = ctx.measure(lambda a: plan(a).data, sx)
    extra = {"n": n, "batch": batch}
    for G in (2, 4):
        extra[f"model_allreduce{G}_us"] = round(
            models.allreduce_time(n * n * 8, G) * 1e6, 1)
    return {**t.as_dict(), "extra": extra}
