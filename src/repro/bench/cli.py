"""The thin CLI the ``benchmarks/*.py`` scripts delegate to.

Each paper-figure script is now two lines over the registry:

    from repro.bench.cli import figure_main
    main = figure_main("fig6,stream,gridding")

``figure_main`` returns a ``main(argv)`` that forwards to
``repro.bench.run`` restricted to those figures, printing the table
without writing the repo-root artifact unless ``--out`` is given.
"""

from __future__ import annotations

import sys


def figure_main(figures: str):
    """Build a CLI entry point for a fixed set of figure names."""
    def main(argv=None) -> int:
        from .run import main as run_main
        argv = list(sys.argv[1:] if argv is None else argv)
        if not any(a == "--only" or a.startswith("--only=") for a in argv):
            argv += ["--only", figures]    # an explicit --only wins
        if not any(a == "--out" or a.startswith("--out=") for a in argv):
            argv += ["--out", "-"]
        return run_main(argv)
    return main
