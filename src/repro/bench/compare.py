"""Diff two benchmark artifacts and gate steady-state regressions.

  PYTHONPATH=src python -m repro.bench.compare BASE.json NEW.json \\
      [--threshold 25] [--min-ms 0.01] [--fail-on-missing] \\
      [--summary $GITHUB_STEP_SUMMARY]

Exit status is non-zero iff a regression is found: a scenario present in
both artifacts whose steady-state per-call cost grew by more than
``--threshold`` percent over ``max(base, --min-ms)``.  When both
artifacts carry the ``calibration_ms`` machine-speed reference
(``harness.calibrate``, stamped by the sweep runner), the new steady
states are first scaled by ``base_cal / new_cal`` so a uniformly
slower/faster host (cgroup neighbors, different runner) cancels out and
only code-induced slowdowns remain.  Clamping the base
up to the floor means sub-floor rows (scheduler jitter territory;
model-only rows report 0.0) cannot flake the gate on noise — but they
still fail once the new cost clears threshold above the floor itself,
so a sub-floor baseline never exempts a real regression.  New scenarios
pass (the trajectory is supposed to grow); scenarios that disappeared
are reported and fail only under ``--fail-on-missing``.

Serve scenarios additionally gate the worst per-client p95
(``extra.client_p95_ms``) with the same threshold/floor/scale rules: a
scheduler change that keeps the mean tick fast while starving one
client is a regression too.
"""

from __future__ import annotations

import argparse
import dataclasses

from .artifact import load_artifact

DEFAULT_THRESHOLD_PCT = 25.0
DEFAULT_MIN_MS = 0.01


@dataclasses.dataclass
class Comparison:
    """Outcome of diffing two artifacts (lists of per-scenario entries)."""

    regressions: list
    improvements: list
    unchanged: list
    below_floor: list    # skipped: steady state under the noise floor
    new: list            # keys only in the new artifact
    missing: list        # keys only in the base artifact
    threshold_pct: float
    min_ms: float
    scale: float = 1.0   # machine-speed normalization applied to `new`
    # (scenario, size) groups in the NEW artifact whose speedup_vs_1dev
    # drops anywhere as the device count grows (advisory: reported, not
    # gated — the fig. 5 scaling-shape check)
    non_monotone: list = dataclasses.field(default_factory=list)
    # serving SLO gate: scenarios whose per-client p95
    # (``extra.client_p95_ms``, worst client) regressed past the same
    # threshold — a scheduler change that keeps the mean tick fast but
    # starves one client fails here, not silently
    p95_regressions: list = dataclasses.field(default_factory=list)
    # scenarios flagged ``extra.advisory`` in either artifact: evidence
    # columns only (e.g. the chaos drill's recovery latency), excluded
    # from both the steady-state and the p95 gates by construction
    advisory: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not (self.regressions or self.p95_regressions)


def compare_artifacts(base: dict, new: dict, *,
                      threshold_pct: float = DEFAULT_THRESHOLD_PCT,
                      min_ms: float = DEFAULT_MIN_MS) -> Comparison:
    """Diff two (already validated) artifacts; see module docstring."""
    b, n = base["scenarios"], new["scenarios"]
    # normalize by relative machine speed when both artifacts carry the
    # calibration reference: neighbor contention on a shared host slows
    # the reference and every scenario together (the ratio cancels it),
    # while a genuine code regression moves only the scenario.
    bc, nc = base.get("calibration_ms"), new.get("calibration_ms")
    scale = bc / nc if bc and nc else 1.0
    cmp = Comparison([], [], [], [], [], [],
                     threshold_pct=threshold_pct, min_ms=min_ms,
                     scale=round(scale, 4))
    for key in sorted(set(b) | set(n)):
        if key not in n:
            cmp.missing.append(key)
            continue
        if key not in b:
            cmp.new.append(key)
            continue
        if (b[key].get("extra") or {}).get("advisory") \
                or (n[key].get("extra") or {}).get("advisory"):
            # a fault-injection drill's timings measure the injected
            # faults, not the code: report, never gate
            cmp.advisory.append(key)
            continue
        bs = b[key]["steady_ms"]
        ns = round(n[key]["steady_ms"] * scale, 6)
        entry = {"key": key, "base_ms": bs, "new_ms": ns,
                 "raw_new_ms": n[key]["steady_ms"],
                 "ratio": round(ns / bs, 3) if bs > 0 else None}
        # a sub-floor BASE must not exempt an unbounded regression: the
        # base is clamped up to the floor, so a noise-floor row fails
        # only once its new cost clears threshold above the floor itself
        if bs < min_ms and ns < min_ms:
            cmp.below_floor.append(entry)
        elif ns > max(bs, min_ms) * (1.0 + threshold_pct / 100.0):
            cmp.regressions.append(entry)
        elif bs >= min_ms and ns < bs * (1.0 - threshold_pct / 100.0):
            cmp.improvements.append(entry)
        else:
            cmp.unchanged.append(entry)
        # per-client SLO column (serve scenarios): same threshold/floor
        # discipline, on the worst client's p95 instead of the mean tick
        bp = (b[key].get("extra") or {}).get("client_p95_ms")
        np_ = (n[key].get("extra") or {}).get("client_p95_ms")
        if bp is not None and np_ is not None:
            np_ = round(np_ * scale, 6)
            if not (bp < min_ms and np_ < min_ms) and \
                    np_ > max(bp, min_ms) * (1.0 + threshold_pct / 100.0):
                cmp.p95_regressions.append(
                    {"key": key, "base_ms": bp, "new_ms": np_,
                     "ratio": round(np_ / bp, 3) if bp > 0 else None})
    cmp.non_monotone = _non_monotone_speedups(new)
    return cmp


def _non_monotone_speedups(art: dict) -> list:
    """(scenario, size) groups whose ``speedup_vs_1dev`` DROPS anywhere
    as the device count grows (1 device counts as speedup 1.0).  The
    paper's fig. 5 point is that transfers should scale; a schedule that
    gets slower with more devices shows up here even when it clears the
    regression threshold."""
    groups: dict = {}
    for run in art["scenarios"].values():
        sp = 1.0 if run["devices"] == 1 else run.get("speedup_vs_1dev")
        if sp is None:
            continue
        groups.setdefault((run["scenario"], run["size"]), {})[
            run["devices"]] = sp
    out = []
    for (scenario, size), by_dev in sorted(groups.items()):
        devs = sorted(by_dev)
        if len(devs) < 2:
            continue
        speeds = [by_dev[d] for d in devs]
        if any(b < a for a, b in zip(speeds, speeds[1:])):
            out.append({"key": f"{scenario}@{size}",
                        "speedups": {f"d{d}": by_dev[d] for d in devs}})
    return out


def format_report(cmp: Comparison) -> str:
    lines = [f"repro.bench.compare: threshold +{cmp.threshold_pct:g}% "
             f"steady-state, noise floor {cmp.min_ms:g} ms, "
             f"machine-speed scale {cmp.scale:g}x"]
    for entry in cmp.regressions:
        lines.append(f"  REGRESSION {entry['key']}: "
                     f"{entry['base_ms']:g} -> {entry['new_ms']:g} ms "
                     f"({entry['ratio']}x)")
    for entry in cmp.p95_regressions:
        lines.append(f"  P95 REGRESSION {entry['key']}: worst-client p95 "
                     f"{entry['base_ms']:g} -> {entry['new_ms']:g} ms "
                     f"({entry['ratio']}x)")
    for entry in cmp.improvements:
        lines.append(f"  improved   {entry['key']}: "
                     f"{entry['base_ms']:g} -> {entry['new_ms']:g} ms "
                     f"({entry['ratio']}x)")
    for key in cmp.new:
        lines.append(f"  new        {key}")
    for key in cmp.missing:
        lines.append(f"  MISSING    {key} (in base, not in new)")
    for key in cmp.advisory:
        lines.append(f"  advisory   {key} (not gated)")
    for entry in cmp.non_monotone:
        curve = " -> ".join(f"{v:g} ({d})"
                            for d, v in entry["speedups"].items())
        lines.append(f"  NON-MONOTONE scaling {entry['key']}: {curve}")
    lines.append(
        f"  {len(cmp.unchanged)} unchanged, "
        f"{len(cmp.below_floor)} under the noise floor, "
        f"{len(cmp.improvements)} improved, {len(cmp.new)} new, "
        f"{len(cmp.missing)} missing, {len(cmp.advisory)} advisory, "
        f"{len(cmp.non_monotone)} non-monotone scaling, "
        f"{len(cmp.regressions)} regressions, "
        f"{len(cmp.p95_regressions)} per-client p95 regressions")
    return "\n".join(lines)


def format_markdown(cmp: Comparison) -> str:
    """GitHub-flavored markdown table of every per-scenario steady-state
    delta — what ``--summary`` emits into the Actions job summary so the
    trajectory is visible on every PR without downloading artifacts."""
    lines = [
        "### repro.bench steady-state vs baseline",
        "",
        f"threshold +{cmp.threshold_pct:g}% · noise floor "
        f"{cmp.min_ms:g} ms · machine-speed scale {cmp.scale:g}x",
        "",
        "| scenario | base ms | new ms | ratio | status |",
        "|---|---:|---:|---:|---|",
    ]
    rows = ([(e, "🔴 regression") for e in cmp.regressions] +
            [(e, "🟢 improved") for e in cmp.improvements] +
            [(e, "unchanged") for e in cmp.unchanged] +
            [(e, "below floor") for e in cmp.below_floor])
    for entry, status in sorted(rows, key=lambda r: r[0]["key"]):
        ratio = entry["ratio"]
        lines.append(
            f"| `{entry['key']}` | {entry['base_ms']:g} | "
            f"{entry['new_ms']:g} | "
            f"{ratio if ratio is not None else '—'} | {status} |")
    for key in cmp.new:
        lines.append(f"| `{key}` | — | — | — | 🆕 new |")
    for key in cmp.missing:
        lines.append(f"| `{key}` | — | — | — | ⚠️ missing |")
    for key in cmp.advisory:
        lines.append(f"| `{key}` | — | — | — | advisory (not gated) |")
    if cmp.p95_regressions:
        lines += ["", "**Per-client p95 regressions** (serve scenarios, "
                      "worst client):", ""]
        for entry in cmp.p95_regressions:
            lines.append(f"- `{entry['key']}`: {entry['base_ms']:g} → "
                         f"{entry['new_ms']:g} ms ({entry['ratio']}x)")
    if cmp.non_monotone:
        lines += ["", "**Non-monotone `speedup_vs_1dev`** (scaling drops "
                      "somewhere as devices grow):", ""]
        for entry in cmp.non_monotone:
            curve = " → ".join(f"{v:g} ({d})"
                               for d, v in entry["speedups"].items())
            lines.append(f"- `{entry['key']}`: {curve}")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.bench.compare",
        description="diff two BENCH artifacts; non-zero exit on regression")
    ap.add_argument("base", help="baseline artifact (e.g. committed "
                                 "BENCH_paper.json)")
    ap.add_argument("new", help="freshly generated artifact")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD_PCT,
                    metavar="PCT",
                    help="steady-state growth tolerated before failing "
                         "(percent, default %(default)s)")
    ap.add_argument("--min-ms", type=float, default=DEFAULT_MIN_MS,
                    help="noise floor: the base steady state is clamped up "
                         "to this before the threshold test (ms, default "
                         "%(default)s)")
    ap.add_argument("--fail-on-missing", action="store_true",
                    help="also fail when a baseline scenario disappeared")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append a markdown table of per-scenario deltas "
                         "to PATH (CI passes $GITHUB_STEP_SUMMARY); '-' "
                         "prints it to stdout")
    args = ap.parse_args(argv)

    cmp = compare_artifacts(load_artifact(args.base), load_artifact(args.new),
                            threshold_pct=args.threshold, min_ms=args.min_ms)
    print(format_report(cmp))
    if args.summary:
        md = format_markdown(cmp)
        if args.summary == "-":
            print(md)
        else:
            with open(args.summary, "a") as f:
                f.write(md + "\n")
    if not cmp.ok:
        return 1
    if args.fail_on_missing and cmp.missing:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
