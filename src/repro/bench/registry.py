"""Scenario registry — every paper figure/table as a named, parameterized
benchmark scenario.

A *scenario* is one measured quantity from the paper (or from a layer
this repo added on top of it): a callable taking a
:class:`repro.bench.harness.BenchContext` and returning a result dict
with at least ``wall_ms`` / ``compile_ms`` / ``steady_ms`` (usually just
``ctx.measure(...).as_dict()`` plus an ``extra`` dict of model-derived
columns).  Scenarios declare which problem sizes (``tiny`` for CI,
``paper`` for the paper's own settings) and device counts they support;
the runner (``repro.bench.run``) sweeps the cross product and writes the
schema-versioned artifact.

Registration happens at import of :mod:`repro.bench.suites` (named so
the package attribute cannot shadow this module's ``scenarios()``
accessor); the registry itself stays import-light so artifact/compare
tooling can load without pulling JAX-heavy scenario modules.
"""

from __future__ import annotations

import dataclasses
from importlib import import_module
from typing import Callable, Dict

# the sweep axes of the ISSUE: problem size {tiny-CI, paper} x device
# count {1, 2, 4 simulated}
SIZES = ("tiny", "paper")
DEVICE_COUNTS = (1, 2, 4)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One registered benchmark scenario."""

    figure: str                      # paper anchor: fig4/fig5/.../stream
    name: str                        # scenario within the figure
    fn: Callable                     # BenchContext -> result dict
    sizes: tuple = SIZES             # problem sizes it supports
    devices: tuple = DEVICE_COUNTS   # device counts it supports
    doc: str = ""

    @property
    def key(self) -> str:
        return f"{self.figure}.{self.name}"


_REGISTRY: Dict[str, Scenario] = {}


def scenario(figure: str, name: str, *, sizes=SIZES,
             devices=DEVICE_COUNTS) -> Callable:
    """Decorator: register ``fn`` as scenario ``figure.name``."""
    def deco(fn):
        doc = next(iter((fn.__doc__ or "").strip().splitlines()), "")
        sc = Scenario(figure, name, fn, tuple(sizes), tuple(devices),
                      doc=doc)
        if sc.key in _REGISTRY:
            raise ValueError(f"duplicate scenario key: {sc.key}")
        _REGISTRY[sc.key] = sc
        return fn
    return deco


def load() -> None:
    """Import the scenario modules (registration side effect)."""
    import_module("repro.bench.suites")


def scenarios(figures=None) -> Dict[str, Scenario]:
    """The full registry, deterministically ordered (sorted by key).

    ``figures`` optionally restricts to a collection of figure names.
    """
    load()
    out = {k: _REGISTRY[k] for k in sorted(_REGISTRY)}
    if figures is not None:
        figures = set(figures)
        out = {k: s for k, s in out.items() if s.figure in figures}
    return out


def figure_names() -> tuple:
    """All registered figure names, sorted."""
    return tuple(sorted({s.figure for s in scenarios().values()}))
