"""Schema-versioned benchmark artifacts (``BENCH_paper.json``).

One artifact is one sweep: a set of scenario runs, each at one
(device count, problem size) point, carrying the harness timing fields
plus per-scenario extras.  The writer stamps the schema version and git
SHA so two artifacts from different commits are comparable
(``repro.bench.compare``) and the repo root's ``BENCH_paper.json``
becomes a machine-readable performance trajectory across PRs.

This module is deliberately JAX-free: validation/diff tooling must load
on any host.
"""

from __future__ import annotations

import json
import pathlib
import subprocess

SCHEMA = "repro.bench"
SCHEMA_VERSION = 1

_REPO = pathlib.Path(__file__).resolve().parents[3]

# field -> allowed types, for every scenario run
REQUIRED_FIELDS = {
    "scenario": str,          # registry key, e.g. "fig6.nlinv_frame"
    "figure": str,            # registry figure, e.g. "fig6"
    "devices": int,           # device count of the run
    "size": str,              # problem size: "tiny" | "paper"
    "wall_ms": (int, float),  # total measurement wall clock
    "compile_ms": (int, float),   # first-call (setup/compile/plan) cost
    "steady_ms": (int, float),    # steady-state best (minimum) sample
}
OPTIONAL_FIELDS = {
    "p50_ms": (int, float),
    "p95_ms": (int, float),
    "jitter_ms": (int, float),
    "iters": int,
    "warmup": int,
    "plan_cache": dict,            # PlanCache.delta regions
    "speedup_vs_1dev": (int, float),
    "extra": dict,                 # scenario-specific derived columns
}


class ArtifactError(ValueError):
    """A benchmark artifact violates the repro.bench schema."""


def run_key(run: dict) -> str:
    """Stable identity of one run inside an artifact."""
    return f"{run['scenario']}@d{run['devices']}@{run['size']}"


def git_sha(repo: pathlib.Path | None = None) -> str:
    try:
        r = subprocess.run(["git", "rev-parse", "HEAD"],
                           cwd=str(repo or _REPO), capture_output=True,
                           text=True, timeout=10)
        sha = r.stdout.strip()
        return sha if r.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def make_artifact(runs, *, sha: str | None = None, host: dict | None = None,
                  calibration_ms: float | None = None) -> dict:
    """Assemble + validate an artifact from scenario run dicts.

    Computes ``speedup_vs_1dev`` for every multi-device run whose
    (scenario, size) also ran at 1 device with a nonzero steady state.
    ``calibration_ms`` is the machine-speed reference
    (``harness.calibrate``) the compare tool normalizes by.
    """
    runs = [dict(r) for r in runs]
    base = {(r["scenario"], r["size"]): r for r in runs if r["devices"] == 1}
    for r in runs:
        b = base.get((r["scenario"], r["size"]))
        if (r["devices"] > 1 and b is not None
                and b["steady_ms"] > 0 and r["steady_ms"] > 0):
            r["speedup_vs_1dev"] = round(b["steady_ms"] / r["steady_ms"], 3)
    scen = {}
    for r in runs:
        key = run_key(r)
        if key in scen:
            raise ArtifactError(f"duplicate run for {key} (same scenario, "
                                f"device count and size measured twice)")
        scen[key] = r
    art = {
        "schema": SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "git_sha": git_sha() if sha is None else sha,
        "host": dict(host or {}),
        "scenarios": scen,
    }
    if calibration_ms is not None:
        art["calibration_ms"] = calibration_ms
    validate_artifact(art)
    return art


def validate_artifact(art) -> dict:
    """Raise :class:`ArtifactError` unless ``art`` is schema-valid."""
    if not isinstance(art, dict):
        raise ArtifactError(f"artifact must be a dict, got {type(art)}")
    if art.get("schema") != SCHEMA:
        raise ArtifactError(f"schema must be {SCHEMA!r}: {art.get('schema')!r}")
    if art.get("schema_version") != SCHEMA_VERSION:
        raise ArtifactError(
            f"schema_version must be {SCHEMA_VERSION}: "
            f"{art.get('schema_version')!r}")
    if not isinstance(art.get("git_sha"), str) or not art["git_sha"]:
        raise ArtifactError("git_sha must be a non-empty string")
    if not isinstance(art.get("host"), dict):
        raise ArtifactError("host must be a dict")
    cal = art.get("calibration_ms")
    if cal is not None and (not isinstance(cal, (int, float))
                            or isinstance(cal, bool) or cal <= 0):
        raise ArtifactError("calibration_ms must be a positive number")
    scen = art.get("scenarios")
    if not isinstance(scen, dict):
        raise ArtifactError("scenarios must be a dict")
    for key, run in scen.items():
        if not isinstance(run, dict):
            raise ArtifactError(f"{key}: run must be a dict")
        for field, types in REQUIRED_FIELDS.items():
            if field not in run:
                raise ArtifactError(f"{key}: missing field {field!r}")
            if not isinstance(run[field], types) or isinstance(run[field], bool):
                raise ArtifactError(
                    f"{key}: field {field!r} has type "
                    f"{type(run[field]).__name__}, want {types}")
        for field, types in OPTIONAL_FIELDS.items():
            if field in run and not isinstance(run[field], types):
                raise ArtifactError(
                    f"{key}: field {field!r} has type "
                    f"{type(run[field]).__name__}, want {types}")
        if run["devices"] < 1:
            raise ArtifactError(f"{key}: devices must be >= 1")
        if run["steady_ms"] < 0 or run["compile_ms"] < 0 or run["wall_ms"] < 0:
            raise ArtifactError(f"{key}: timing fields must be >= 0")
        if key != run_key(run):
            raise ArtifactError(
                f"artifact key {key!r} != run identity {run_key(run)!r}")
    return art


def write_artifact(path, art: dict) -> pathlib.Path:
    """Validate + write (deterministic field order, trailing newline)."""
    validate_artifact(art)
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(art, indent=2, sort_keys=True) + "\n")
    return path


def load_artifact(path) -> dict:
    """Load + validate an artifact from disk."""
    path = pathlib.Path(path)
    try:
        art = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise ArtifactError(f"{path}: not valid JSON: {e}") from e
    return validate_artifact(art)
