"""repro.bench — the unified benchmark subsystem.

The paper's headline result is quantitative (1.7x @ 2 GPUs, 2.1x @ 4
for real-time NLINV); this package makes the repo's own performance
trajectory machine-readable the way the 2017 follow-up paper demands:

  ``harness``    warmup-disciplined, ``block_until_ready``-fenced timing
                 with compile/plan-build cost separated from the steady
                 state (and the plan-cache counter deltas to prove it)
  ``registry``   every paper figure/table as a registered scenario,
                 parameterized over problem size {tiny, paper} and
                 device count {1, 2, 4 simulated}
  ``models``     the calibrated alpha-beta/roofline models behind every
                 derived column
  ``artifact``   schema-versioned ``BENCH_paper.json`` writer/validator
  ``compare``    artifact diff + CI regression gate (non-zero exit)
  ``run``        the sweep driver (one subprocess per device count)

CLI:  ``python -m repro.bench.run`` / ``python -m repro.bench.compare``;
the ``benchmarks/*.py`` scripts are thin entry points over the same
registry.  See docs/benchmarks.md for the methodology.
"""

from importlib import import_module

from . import registry
from .registry import Scenario, scenario, scenarios

__all__ = [
    "artifact", "compare", "harness", "models", "registry",
    "SCHEMA_VERSION", "ArtifactError", "load_artifact", "make_artifact",
    "run_key", "validate_artifact", "write_artifact",
    "Comparison", "compare_artifacts",
    "BenchContext", "Timing", "measure",
    "Scenario", "scenario", "scenarios",
]

# Everything except the registry resolves lazily (PEP 562):
#   * harness/models pull jax (and the nlinv latency machinery) — the
#     artifact/compare tooling must stay importable on any host;
#   * artifact/compare must not be imported at package level so
#     `python -m repro.bench.compare` (the CI gate) runs without the
#     runpy found-in-sys.modules RuntimeWarning.
_LAZY_MODULES = ("artifact", "compare", "harness", "models")
_LAZY_NAMES = {
    "SCHEMA_VERSION": "artifact", "ArtifactError": "artifact",
    "load_artifact": "artifact", "make_artifact": "artifact",
    "run_key": "artifact", "validate_artifact": "artifact",
    "write_artifact": "artifact",
    "Comparison": "compare", "compare_artifacts": "compare",
    "BenchContext": "harness", "Timing": "harness", "measure": "harness",
}


def __getattr__(name):
    if name in _LAZY_MODULES:
        mod = import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    if name in _LAZY_NAMES:
        obj = getattr(import_module(f".{_LAZY_NAMES[name]}", __name__), name)
        globals()[name] = obj
        return obj
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
